#!/usr/bin/env bash
# Profile-guided-optimization build recipe for the host backend.
#
# PGO lets rustc/LLVM lay out the blocked GEMM's hot loops (micro-kernel
# dispatch, pack routines, epilogue stores) from a real profile instead of
# static heuristics. The profile workload is `perf_micro` — it exercises
# every hot path the sweeps do, in minutes not hours. Typical gain on the
# host backend is a few percent on the GEMM-bound sections; measure with
# scripts/perf_compare before adopting a PGO binary anywhere.
#
# Requires llvm-profdata matching the rustc LLVM version (shipped in the
# `llvm-tools` rustup component: `rustup component add llvm-tools` — the
# script locates it in the toolchain dir, or set $LLVM_PROFDATA).
#
# Usage: scripts/pgo.sh [cargo-args...]
#   e.g. scripts/pgo.sh --bench perf_micro
set -euo pipefail

cd "$(dirname "$0")/../rust"
PROF_DIR="$(pwd)/target/pgo-profiles"
rm -rf "$PROF_DIR"
mkdir -p "$PROF_DIR"

# locate llvm-profdata: explicit override, PATH, or the rustup llvm-tools
# component of the active toolchain
if [[ -z "${LLVM_PROFDATA:-}" ]]; then
    if command -v llvm-profdata >/dev/null 2>&1; then
        LLVM_PROFDATA=llvm-profdata
    else
        sysroot="$(rustc --print sysroot)"
        LLVM_PROFDATA="$(find "$sysroot" -name llvm-profdata -type f 2>/dev/null | head -n1 || true)"
    fi
fi
if [[ -z "${LLVM_PROFDATA:-}" ]]; then
    echo "pgo.sh: llvm-profdata not found (rustup component add llvm-tools," >&2
    echo "        or set \$LLVM_PROFDATA)" >&2
    exit 1
fi

echo "== 1/3: instrumented build + profile run (perf_micro) =="
RUSTFLAGS="-Cprofile-generate=$PROF_DIR" \
    ECQX_BENCH_JSON="$PROF_DIR/bench-instrumented.json" \
    cargo bench --bench perf_micro >/dev/null

echo "== 2/3: merging profiles =="
"$LLVM_PROFDATA" merge -o "$PROF_DIR/merged.profdata" "$PROF_DIR"

echo "== 3/3: optimized build =="
RUSTFLAGS="-Cprofile-use=$PROF_DIR/merged.profdata" \
    cargo build --release "$@"

echo "pgo.sh: done — compare against a plain release build with:"
echo "  ECQX_BENCH_JSON=BENCH_pgo.json cargo bench --bench perf_micro"
echo "  scripts/perf_compare BENCH_host.json BENCH_pgo.json"
