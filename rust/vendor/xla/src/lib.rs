//! Offline stand-in for the `xla` crate (PJRT C-API bindings).
//!
//! The build container ships neither the XLA C library nor crates.io
//! access, so this vendored crate mirrors exactly the API surface
//! `ecqx::runtime` uses: client construction, HLO-text loading,
//! compilation, literals, and execution. Everything host-side (literal
//! packing, reshape, manifest-driven shape checks, the engine's
//! executable cache) works for real; only device *execution* is
//! unavailable and fails loudly with [`Error::Unavailable`].
//!
//! All types here are plain owned data — `Send + Sync` by construction —
//! which is what lets `ecqx::runtime::Engine` be shared across sweep
//! workers. [`IS_STUB`] lets tests and CLIs skip execution paths cleanly.
//! Swapping the real PJRT bindings back in is a Cargo.toml change plus a
//! one-line `pub const IS_STUB: bool = false;` shim in those bindings
//! (`ecqx::runtime::backend_is_stub` is the only consumer).

use std::fmt;

/// True for this offline stand-in; the real bindings would execute.
pub const IS_STUB: bool = true;

/// Errors surfaced by the stub (a subset of the real crate's error kinds).
#[derive(Clone, Debug)]
pub enum Error {
    /// Device execution was requested but this is the offline stub.
    Unavailable(String),
    /// Reading an HLO-text artifact failed.
    Io(String),
    /// Literal shape/dtype mismatch.
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(m) => write!(f, "xla unavailable: {m}"),
            Error::Io(m) => write!(f, "xla io error: {m}"),
            Error::Shape(m) => write!(f, "xla shape error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

/// Stub-local result type, mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

/// A host literal: typed buffer + dimensions (or a tuple of literals).
#[derive(Clone, Debug)]
pub enum Literal {
    /// f32 buffer with dimensions.
    F32 {
        /// row-major data
        data: Vec<f32>,
        /// dimensions (empty = scalar)
        dims: Vec<i64>,
    },
    /// i32 buffer with dimensions.
    I32 {
        /// row-major data
        data: Vec<i32>,
        /// dimensions (empty = scalar)
        dims: Vec<i64>,
    },
    /// Tuple of literals (artifacts are lowered with `return_tuple=True`).
    Tuple(Vec<Literal>),
}

/// Element types that can move through [`Literal`] buffers.
pub trait NativeType: Copy {
    /// Pack a rank-1 literal from a slice.
    fn vec1_literal(data: &[Self]) -> Literal;
    /// Extract the buffer, erroring on a dtype mismatch.
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn vec1_literal(data: &[f32]) -> Literal {
        Literal::F32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error::Shape(format!("expected f32 literal, got {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn vec1_literal(data: &[i32]) -> Literal {
        Literal::I32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error::Shape(format!("expected i32 literal, got {other:?}"))),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1_literal(data)
    }

    /// Reinterpret the buffer under new dimensions (element count must match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        match self {
            Literal::F32 { data, .. } => {
                if data.len() as i64 != numel {
                    return Err(Error::Shape(format!(
                        "reshape {:?}: have {} elements",
                        dims,
                        data.len()
                    )));
                }
                Ok(Literal::F32 { data, dims: dims.to_vec() })
            }
            Literal::I32 { data, .. } => {
                if data.len() as i64 != numel {
                    return Err(Error::Shape(format!(
                        "reshape {:?}: have {} elements",
                        dims,
                        data.len()
                    )));
                }
                Ok(Literal::I32 { data, dims: dims.to_vec() })
            }
            Literal::Tuple(_) => {
                Err(Error::Shape("cannot reshape a tuple literal".to_string()))
            }
        }
    }

    /// Copy the buffer out as a `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Err(Error::Shape(format!("not a tuple literal: {other:?}"))),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// An HLO module parsed from its text form (name + size only, in the stub).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    name: String,
    byte_len: usize,
}

impl HloModuleProto {
    /// Read an HLO-text artifact; the module name is taken from the
    /// `HloModule <name>` header when present.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule"))
            .and_then(|rest| {
                rest.trim()
                    .split(|c: char| c == ',' || c.is_whitespace())
                    .next()
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
            })
            .unwrap_or_else(|| "module".to_string());
        Ok(HloModuleProto { name, byte_len: text.len() })
    }

    /// Module name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Size of the text form in bytes.
    pub fn byte_len(&self) -> usize {
        self.byte_len
    }
}

/// A computation ready for compilation.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { name: proto.name.clone() }
    }

    /// Computation name.
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The PJRT client (CPU only in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Construct the CPU client; always succeeds in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform name (contains "cpu", as the real CPU client's does).
    pub fn platform_name(&self) -> String {
        "cpu (offline xla stub)".to_string()
    }

    /// Number of devices (one host CPU).
    pub fn device_count(&self) -> usize {
        1
    }

    /// "Compile" a computation: in the stub this only validates that the
    /// artifact was loadable and produces an executable handle.
    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { name: computation.name().to_string() })
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    /// Name of the compiled computation.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Device execution — unavailable offline; fails loudly instead of
    /// returning garbage so callers can degrade or skip.
    pub fn execute<T: AsRef<Literal>>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable(format!(
            "offline xla stub: cannot execute '{}' ({} input(s)); build against \
             the real PJRT bindings to run HLO artifacts",
            self.name,
            args.len()
        )))
    }
}

/// A device buffer (never actually produced by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Device-to-host copy — unreachable in the stub, present for API parity.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("offline xla stub: no device buffers".to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_and_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        assert_eq!(c.device_count(), 1);
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.clone().reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn hlo_text_parses_module_name() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("xla-stub-test-{}.hlo.txt", std::process::id()));
        std::fs::write(&path, "HloModule my_mod, entry_computation_layout={()->f32[]}\n")
            .unwrap();
        let p = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        assert_eq!(p.name(), "my_mod");
        assert!(p.byte_len() > 0);
        let comp = XlaComputation::from_proto(&p);
        assert_eq!(comp.name(), "my_mod");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn execute_fails_loudly() {
        let c = PjRtClient::cpu().unwrap();
        let exe = c
            .compile(&XlaComputation::from_proto(&HloModuleProto {
                name: "m".into(),
                byte_len: 0,
            }))
            .unwrap();
        let args = [Literal::vec1(&[0.0f32])];
        match exe.execute::<Literal>(&args) {
            Err(Error::Unavailable(m)) => assert!(m.contains("'m'")),
            other => panic!("expected Unavailable, got {:?}", other.is_ok()),
        }
    }
}
