//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build container has no crates.io access, so this vendored crate
//! provides the exact API subset `ecqx` uses — [`Error`], [`Result`],
//! the [`Context`] extension trait, and the [`anyhow!`]/[`bail!`] macros —
//! with the same semantics (context chains wrap an underlying error;
//! `Display` shows the outermost message, `Debug` shows the whole chain).
//! Swapping the real crate back in is a Cargo.toml-only change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically typed error with a chain of context messages.
///
/// Like the real `anyhow::Error`, this intentionally does **not**
/// implement [`std::error::Error`]: the blanket `From<E: Error>`
/// conversion (which powers `?`) would otherwise conflict with the
/// reflexive `From<T> for T` impl.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
    /// context frames, innermost first (push order)
    context: Vec<String>,
}

/// Adapter wrapping a plain message as the innermost error.
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.0)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

impl Error {
    /// Create an error from a printable message (what [`anyhow!`] expands to).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error { inner: Box::new(MessageError(message)), context: Vec::new() }
    }

    /// Wrap an existing error value.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { inner: Box::new(error), context: Vec::new() }
    }

    /// Attach an outer context message (innermost first, like anyhow).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The outermost message (context if any, else the wrapped error).
    fn outermost(&self) -> String {
        match self.context.last() {
            Some(c) => c.clone(),
            None => self.inner.to_string(),
        }
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        // remaining frames, outermost-to-innermost, then the source chain
        let mut causes: Vec<String> =
            self.context.iter().rev().skip(1).cloned().collect();
        if !self.context.is_empty() {
            causes.push(self.inner.to_string());
        }
        let mut src = self.inner.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (or a single printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`anyhow!`]-constructed error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn context_chains_display_outermost() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        let e = Err::<(), Error>(e).context("loading artifacts").unwrap_err();
        assert_eq!(e.to_string(), "loading artifacts");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("loading artifacts"));
        assert!(dbg.contains("reading manifest"));
        assert!(dbg.contains("missing file"));
    }

    #[test]
    fn option_context_and_with_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        let v: Option<u32> = Some(3);
        assert_eq!(v.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        fn f(x: usize) -> Result<usize> {
            if x == 0 {
                bail!("zero input x={x}");
            }
            ensure!(x < 10, "too big: {}", x);
            Ok(x * 2)
        }
        assert_eq!(f(3).unwrap(), 6);
        assert!(f(0).unwrap_err().to_string().contains("zero input x=0"));
        assert!(f(11).unwrap_err().to_string().contains("too big: 11"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert<T: Send + Sync>() {}
        assert::<Error>();
    }
}
