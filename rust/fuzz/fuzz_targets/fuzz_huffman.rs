//! Totality of the canonical Huffman decoder: any byte sequence must
//! yield Ok or CodecError — no panics, no unbounded allocation.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let _ = ecqx::codec::huffman::decode(data);
});
