//! Totality of the raw range-coder primitives under the DeepCABAC bit
//! patterns: adaptive contexts, bypass bits, and the bounded exp-golomb
//! bypass (the one fallible primitive — it must Err, not spin, on
//! zero-extended tails).

#![no_main]

use ecqx::codec::cabac::{BinDecoder, BinProb};
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let mut dec = BinDecoder::new(data);
    let mut ctx = BinProb::default();
    for _ in 0..512 {
        let _ = dec.decode(&mut ctx);
        let _ = dec.decode_bypass();
    }
    let _ = dec.decode_exp_golomb_bypass(32);
});
