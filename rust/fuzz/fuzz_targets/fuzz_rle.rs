//! Totality of the RLE decoder: the bit width comes from the input head
//! (spanning valid and invalid widths), the rest is the stream.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if data.is_empty() {
        return;
    }
    let bits = (data[0] % 20) as u32;
    let _ = ecqx::codec::sparse::rle_decode(&data[1..], bits);
});
