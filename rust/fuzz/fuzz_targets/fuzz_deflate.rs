//! Totality of the zlib/DEFLATE inflater: header checks, stored and
//! fixed-Huffman blocks, match copies, Adler-32 — all must reject
//! corruption with CodecError, never panic or over-allocate.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let _ = ecqx::codec::deflate::decompress(data);
});
