//! Totality of the chunked tensor container decoder: bit width and
//! element count are taken from the input head so corrupt metadata
//! (absurd shapes, off-grid bit widths) and corrupt chunk framing are
//! explored together.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if data.len() < 3 {
        return;
    }
    let bits = (data[0] % 20) as u32;
    let n = u16::from_le_bytes([data[1], data[2]]) as usize;
    let enc = ecqx::codec::EncodedTensor {
        shape: vec![n],
        step: 0.02,
        bits,
        payload: data[3..].to_vec(),
    };
    let _ = ecqx::codec::decode_tensor(&enc);
});
