//! Totality of the DeepCABAC level decoder: the element count is read
//! from the input head so corrupt counts (including absurd ones) are
//! explored alongside corrupt payloads.

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let n = if data.len() >= 2 {
        u16::from_le_bytes([data[0], data[1]]) as usize
    } else {
        64
    };
    let _ = ecqx::codec::deepcabac::decode_levels(data, n);
    // the count ceiling must reject without allocating
    let _ = ecqx::codec::deepcabac::decode_levels(data, usize::MAX);
});
