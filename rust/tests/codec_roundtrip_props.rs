//! Codec roundtrip property tests: encode→decode identity for the
//! Huffman, raw CABAC, and DeepCABAC coders over seeded random sparse
//! weight tensors (realistic assignments from the pure-rust ECQ^x
//! reference), driven by the offline property harness (`util::prop`).

use ecqx::codec::cabac::{BinDecoder, BinEncoder, BinProb};
use ecqx::codec::{self, deepcabac, deflate, huffman, sparse};
use ecqx::quant::{assign_ref, Codebook};
use ecqx::tensor::TensorI32;
use ecqx::util::prop;
use ecqx::util::Rng;

/// Slot indices of a realistic sparse assignment: fitted codebook +
/// entropy constraint over a seeded gaussian weight tensor.
fn sparse_assignment(rng: &mut Rng, n: usize, bits: u32, lam: f32) -> (TensorI32, Codebook) {
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.08)).collect();
    let cb = Codebook::fit(&w, bits);
    let r = vec![1.0f32; n];
    let m = vec![1.0f32; n];
    let a = assign_ref(&w, &r, &m, &cb, lam);
    (TensorI32::new(vec![n], a.idx), cb)
}

#[test]
fn property_huffman_roundtrip_on_assignments() {
    prop::check("huffman roundtrip on sparse assignments", 12, |rng| {
        let n = 512 + rng.below(4096);
        let bits = 2 + (rng.below(4) as u32);
        let lam = rng.range(0.0, 2e-3);
        let (idx, _) = sparse_assignment(rng, n, bits, lam);
        let levels = codec::slots_to_levels(&idx);
        let bytes = huffman::encode(&levels).map_err(|e| format!("encode: {e}"))?;
        let decoded = huffman::decode(&bytes).map_err(|e| format!("decode: {e}"))?;
        if decoded != levels {
            return Err("huffman roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn property_deepcabac_roundtrip_on_assignments() {
    prop::check("deepcabac roundtrip on sparse assignments", 12, |rng| {
        let n = 512 + rng.below(8192);
        let bits = 2 + (rng.below(4) as u32);
        let lam = rng.range(0.0, 4e-3);
        let (idx, _) = sparse_assignment(rng, n, bits, lam);
        let levels = codec::slots_to_levels(&idx);
        let bytes = deepcabac::encode_levels(&levels);
        let decoded =
            deepcabac::decode_levels(&bytes, levels.len()).map_err(|e| format!("{e}"))?;
        if decoded != levels {
            return Err("deepcabac roundtrip mismatch".into());
        }
        // the paper's compressibility claim: sparse sources stay far
        // below the packed bit width
        let sparsity =
            levels.iter().filter(|&&l| l == 0).count() as f64 / levels.len() as f64;
        if sparsity > 0.8 {
            let bpw = bytes.len() as f64 * 8.0 / levels.len() as f64;
            if bpw >= bits as f64 {
                return Err(format!("{sparsity:.2}-sparse coded at {bpw:.2} b/w"));
            }
        }
        Ok(())
    });
}

#[test]
fn property_raw_cabac_roundtrip_mixed_contexts() {
    // the raw range coder under the DeepCABAC binarization patterns:
    // adaptive contexts interleaved with bypass bits
    prop::check("raw cabac roundtrip (contexts + bypass)", 15, |rng| {
        let n = 200 + rng.below(3000);
        let p_one = rng.range(0.05, 0.95) as f64;
        let bits: Vec<bool> = (0..n).map(|_| rng.chance(p_one)).collect();
        let bypass: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0x0F) as u8).collect();
        let mut enc = BinEncoder::new();
        let mut ctxs = [BinProb::default(); 3];
        for (i, &b) in bits.iter().enumerate() {
            enc.encode(&mut ctxs[i % 3], b);
            if i % 7 == 0 {
                enc.encode_bypass_bits(bypass[i] as u64, 4);
            }
        }
        let bytes = enc.finish();
        let mut dec = BinDecoder::new(&bytes);
        let mut ctxs = [BinProb::default(); 3];
        for (i, &b) in bits.iter().enumerate() {
            if dec.decode(&mut ctxs[i % 3]) != b {
                return Err(format!("context bit {i} mismatched"));
            }
            if i % 7 == 0 && dec.decode_bypass_bits(4) != bypass[i] as u64 {
                return Err(format!("bypass nibble at {i} mismatched"));
            }
        }
        Ok(())
    });
}

#[test]
fn property_tensor_container_roundtrip() {
    // encode_tensor/decode_tensor: the exact path the .ecqx container and
    // compressed_size() use
    prop::check("encode_tensor roundtrip", 10, |rng| {
        let rows = 8 + rng.below(64);
        let cols = 8 + rng.below(64);
        let bits = 2 + (rng.below(4) as u32);
        let (mut idx, cb) = sparse_assignment(rng, rows * cols, bits, 1e-4);
        idx.shape = vec![rows, cols];
        let enc = codec::encode_tensor(&idx, &cb);
        let dec = codec::decode_tensor(&enc).map_err(|e| format!("decode: {e}"))?;
        if dec.data != idx.data || dec.shape != idx.shape {
            return Err("tensor container roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn property_single_bit_flips_never_panic() {
    // Adversarial mutation sweep: every encoder's output, re-decoded after
    // flipping each bit position in turn. Each flip must yield Err or a
    // differing-but-valid payload — a panic anywhere fails the test. Small
    // streams keep the full sweep (every encoder x every bit) cheap.
    prop::check("single-bit flips decode totally", 6, |rng| {
        let n = 32 + rng.below(64);
        let bits = 2 + (rng.below(4) as u32);
        let (idx, cb) = sparse_assignment(rng, n, bits, 1e-3);
        let levels = codec::slots_to_levels(&idx);

        let huff = huffman::encode(&levels).map_err(|e| format!("{e}"))?;
        for i in 0..huff.len() * 8 {
            let mut m = huff.clone();
            m[i / 8] ^= 1 << (i % 8);
            let _ = huffman::decode(&m); // Ok or Err, never panic
        }

        let cab = deepcabac::encode_levels(&levels);
        for i in 0..cab.len() * 8 {
            let mut m = cab.clone();
            m[i / 8] ^= 1 << (i % 8);
            let _ = deepcabac::decode_levels(&m, levels.len());
        }

        let rle = sparse::rle_encode(&levels, bits);
        for i in 0..rle.len() * 8 {
            let mut m = rle.clone();
            m[i / 8] ^= 1 << (i % 8);
            let _ = sparse::rle_decode(&m, bits);
        }

        let bytes_i8: Vec<u8> = levels.iter().map(|&l| l as i8 as u8).collect();
        let defl = deflate::compress(&bytes_i8);
        for i in 0..defl.len() * 8 {
            let mut m = defl.clone();
            m[i / 8] ^= 1 << (i % 8);
            let _ = deflate::decompress(&m);
        }

        let enc = codec::encode_tensor(&idx, &cb);
        for i in 0..enc.payload.len() * 8 {
            let mut m = enc.clone();
            m.payload[i / 8] ^= 1 << (i % 8);
            if let Ok(dec) = codec::decode_tensor(&m) {
                // a surviving flip must still be a valid payload of the
                // declared shape, with every slot on the codebook grid
                if dec.data.len() != n {
                    return Err(format!("flip {i}: decoded wrong length"));
                }
                if dec.data.iter().any(|&s| s as usize >= cb.values.len()) {
                    return Err(format!("flip {i}: off-grid slot survived"));
                }
            }
        }
        Ok(())
    });
}
