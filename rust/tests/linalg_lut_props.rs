//! Property tests for the sparse low-bit LUT matmul (DESIGN.md §2.7).
//!
//! Contract under test, from the outside:
//!   * deterministic tier — `lut_gather_nn_with(deterministic)` is
//!     *bitwise* the gather-GEMM, which is itself bitwise the naive
//!     reference over the clamp-dequantized dense weight matrix;
//!   * fast tier — the LUT kernel reassociates the k-sum into
//!     per-centroid partials, so it is held to the §2.6 conformance
//!     envelope (`2·(k+4)·ε_f32·Σ|a||b|`) against the f64 oracle instead;
//!   * the epilogue is fused with the exact `gemm::finish` arithmetic,
//!     so epilogues add no extra tolerance;
//!   * hardening edges (empty codebook, all-zero-centroid columns,
//!     p = 0 / p = 1 sparsity, out-of-range indices) degrade exactly
//!     like the pack-time gather path.

use ecqx::linalg::conformance::{assert_matmul_within_envelope, envelope, matmul_f64};
use ecqx::linalg::{
    gemm_gather_nn_with, lut_gather_nn_with, lut_matmul, lut_ops, reference, Epilogue, GemmOpts,
    Kernel, Workspace, MAX_LUT_CENTROIDS,
};
use ecqx::util::Rng;

const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };

/// A fast-tier option set that is still available on every host: the
/// scalar micro-kernel with an intra-op split. What matters for these
/// tests is only that it is *not* `GemmOpts::deterministic()`, so the
/// dispatcher takes the LUT branch.
const FAST: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 2 };

/// Dequantize `idx` through `codebook` with the pack-layer's clamp
/// semantics into the dense `[k, n]` weight matrix — the B operand every
/// oracle in this file compares against.
fn dequant(idx: &[i32], codebook: &[f32], k: usize, n: usize) -> Vec<f32> {
    if codebook.is_empty() {
        return vec![0.0; k * n];
    }
    let top = (codebook.len() - 1) as i32;
    idx.iter().map(|&v| codebook[v.clamp(0, top) as usize]).collect()
}

/// Random codebook-index matrix at sparsity `p` (probability of the zero
/// centroid) over a `bits`-wide symmetric codebook with `cb[0] == 0`.
fn quantized(rng: &mut Rng, bits: u32, p: f64, k: usize, n: usize) -> (Vec<i32>, Vec<f32>) {
    let side = (1usize << (bits - 1)) - 1;
    let mut cb = vec![0.0f32];
    for s in 1..=side {
        cb.push(s as f32 * 0.25);
        cb.push(-(s as f32) * 0.25);
    }
    let idx: Vec<i32> = (0..k * n)
        .map(|_| if rng.chance(p) { 0 } else { 1 + rng.below(cb.len() - 1) as i32 })
        .collect();
    (idx, cb)
}

fn randn(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

#[test]
fn deterministic_tier_is_bitwise_the_reference_chain() {
    let mut rng = Rng::new(41);
    // ragged shapes on purpose: nothing divides the block/strip sizes
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 7, 5), (13, 33, 17), (5, 64, 31)] {
        for &(bits, p) in &[(2u32, 0.5f64), (4, 0.0), (4, 0.9), (5, 0.5)] {
            let a = randn(&mut rng, m * k);
            let (idx, cb) = quantized(&mut rng, bits, p, k, n);
            let mut ws = Workspace::new();
            let mut out = vec![f32::NAN; m * n];
            lut_gather_nn_with(DET, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut out);
            let b = dequant(&idx, &cb, k, n);
            let want = reference::matmul(&a, &b, m, k, n);
            assert_eq!(out, want, "det tier must be bitwise-naive (m={m} k={k} n={n} bits={bits} p={p})");
        }
    }
}

#[test]
fn fast_tier_is_within_the_conformance_envelope() {
    let mut rng = Rng::new(42);
    for &(m, k, n) in &[(2usize, 5usize, 3usize), (7, 48, 9), (16, 127, 33)] {
        for &(bits, p) in &[(2u32, 0.5f64), (4, 0.5), (4, 0.9), (5, 0.2)] {
            let a = randn(&mut rng, m * k);
            let (idx, cb) = quantized(&mut rng, bits, p, k, n);
            let mut ws = Workspace::new();
            let mut out = vec![f32::NAN; m * n];
            lut_gather_nn_with(FAST, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut out);
            let b = dequant(&idx, &cb, k, n);
            assert_matmul_within_envelope(
                &out,
                &a,
                &b,
                m,
                k,
                n,
                &format!("lut fast m={m} k={k} n={n} bits={bits} p={p}"),
            );
        }
    }
}

#[test]
fn lut_and_gather_disagree_by_at_most_twice_the_envelope() {
    // Both tiers sit inside the same oracle-centered ball, so their
    // mutual distance is at most two envelopes — a direct cross-check
    // that needs no f64 oracle at all.
    let mut rng = Rng::new(43);
    let (m, k, n) = (6, 57, 11);
    let a = randn(&mut rng, m * k);
    let (idx, cb) = quantized(&mut rng, 4, 0.6, k, n);
    let mut ws = Workspace::new();
    let (mut lut, mut gather) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut lut);
    gemm_gather_nn_with(DET, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut gather);
    let b = dequant(&idx, &cb, k, n);
    let (_, mag) = matmul_f64(&a, &b, m, k, n);
    for (i, (&l, (&g, &mg))) in lut.iter().zip(gather.iter().zip(mag.iter())).enumerate() {
        let bound = 2.0 * envelope(k, mg);
        let err = (l as f64 - g as f64).abs();
        assert!(err <= bound, "element {i}: |lut - gather| {err:.3e} > {bound:.3e}");
    }
}

#[test]
fn epilogues_fuse_with_exact_finish_arithmetic() {
    // Fusing the epilogue must not change the tolerance story: applying
    // bias/relu/scale/mask to the *unfused* LUT accumulators reproduces
    // the fused results bit for bit.
    let mut rng = Rng::new(44);
    let (m, k, n) = (4, 19, 6);
    let a = randn(&mut rng, m * k);
    let (idx, cb) = quantized(&mut rng, 4, 0.5, k, n);
    let bias = randn(&mut rng, n);
    let scale = randn(&mut rng, m * n);
    let mut ws = Workspace::new();
    let mut plain = vec![0.0f32; m * n];
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut plain);

    let mut got = vec![0.0f32; m * n];
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::Bias(&bias), &mut got);
    for i in 0..m {
        for j in 0..n {
            assert_eq!(got[i * n + j], plain[i * n + j] + bias[j]);
        }
    }
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::BiasRelu(&bias), &mut got);
    for i in 0..m {
        for j in 0..n {
            assert_eq!(got[i * n + j], (plain[i * n + j] + bias[j]).max(0.0));
        }
    }
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::Scale(&scale), &mut got);
    for e in 0..m * n {
        assert_eq!(got[e], plain[e] * scale[e]);
    }
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::ReluMask(&scale), &mut got);
    for e in 0..m * n {
        assert_eq!(got[e], if scale[e] > 0.0 { plain[e] } else { 0.0 });
    }
}

#[test]
fn sparsity_edges_p0_and_p1() {
    let mut rng = Rng::new(45);
    let (m, k, n) = (3, 21, 8);
    let a = randn(&mut rng, m * k);
    let mut ws = Workspace::new();

    // p = 1: every index is the zero centroid -> exactly the bias
    let (idx1, cb) = quantized(&mut rng, 4, 1.0, k, n);
    assert!(idx1.iter().all(|&v| v == 0));
    let bias = randn(&mut rng, n);
    let mut out = vec![f32::NAN; m * n];
    lut_matmul(&mut ws, &a, &idx1, &cb, m, k, n, Epilogue::Bias(&bias), &mut out);
    for i in 0..m {
        for j in 0..n {
            assert_eq!(out[i * n + j], bias[j]);
        }
    }
    assert_eq!(lut_ops(&idx1, &cb, m, k, n), 0.0, "p=1 does zero arithmetic");

    // p = 0: fully dense indices still conform to the envelope, and the
    // op count stays below the dense FMA count (centroid reuse)
    let (idx0, cb) = quantized(&mut rng, 2, 0.0, k, n);
    let mut out = vec![f32::NAN; m * n];
    lut_matmul(&mut ws, &a, &idx0, &cb, m, k, n, Epilogue::None, &mut out);
    let b = dequant(&idx0, &cb, k, n);
    assert_matmul_within_envelope(&out, &a, &b, m, k, n, "lut p=0");
    assert!(lut_ops(&idx0, &cb, m, k, n) < ecqx::linalg::gemm_flops(m, k, n));
}

#[test]
fn all_zero_centroid_columns_and_empty_codebook_harden() {
    let (m, k, n) = (4, 9, 5);
    let mut rng = Rng::new(46);
    let a = randn(&mut rng, m * k);
    let cb = [0.0f32, 0.5, -0.5];
    // columns 1 and 3 are entirely zero-centroid; the rest mixed
    let idx: Vec<i32> = (0..k * n)
        .map(|e| {
            let j = e % n;
            if j == 1 || j == 3 { 0 } else { (e % 3) as i32 }
        })
        .collect();
    let mut ws = Workspace::new();
    let mut out = vec![f32::NAN; m * n];
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut out);
    for i in 0..m {
        assert_eq!(out[i * n + 1], 0.0);
        assert_eq!(out[i * n + 3], 0.0);
    }
    let b = dequant(&idx, &cb, k, n);
    assert_matmul_within_envelope(&out, &a, &b, m, k, n, "zero columns");

    // empty codebook: epilogue of zero through every entry point,
    // matching pack_b_gather's zero-fill hardening
    let bias = randn(&mut rng, n);
    let mut out = vec![f32::NAN; m * n];
    lut_gather_nn_with(FAST, &mut ws, &a, &idx, &[], m, k, n, Epilogue::Bias(&bias), &mut out);
    for i in 0..m {
        for j in 0..n {
            assert_eq!(out[i * n + j], bias[j]);
        }
    }
    assert_eq!(lut_ops(&idx, &[], m, k, n), 0.0);
}

#[test]
fn oversized_codebooks_reroute_to_gather_in_both_tiers() {
    let (m, k, n) = (3, 8, 4);
    let mut rng = Rng::new(47);
    let a = randn(&mut rng, m * k);
    let cb: Vec<f32> = (0..MAX_LUT_CENTROIDS + 3).map(|s| s as f32 * 0.125).collect();
    let idx: Vec<i32> = (0..k * n).map(|e| (e % cb.len()) as i32).collect();
    let mut ws = Workspace::new();
    for opts in [DET, FAST] {
        let (mut got, mut want) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        lut_gather_nn_with(opts, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut got);
        gemm_gather_nn_with(opts, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut want);
        assert_eq!(got, want, "wide codebook must be gather's exact bits ({opts:?})");
    }
}

#[test]
fn workspace_reuse_is_history_independent() {
    // A workspace that just packed a big panel must produce the same bits
    // for a small one: index_panels hands back truncated slices, and the
    // CSR pack overwrites every entry it reads.
    let mut rng = Rng::new(48);
    let mut ws = Workspace::new();
    let (idx_big, cb_big) = quantized(&mut rng, 5, 0.3, 64, 48);
    let a_big = randn(&mut rng, 8 * 64);
    let mut sink = vec![0.0f32; 8 * 48];
    lut_matmul(&mut ws, &a_big, &idx_big, &cb_big, 8, 64, 48, Epilogue::None, &mut sink);

    let (m, k, n) = (2, 5, 3);
    let a = randn(&mut rng, m * k);
    let (idx, cb) = quantized(&mut rng, 2, 0.4, k, n);
    let (mut warm, mut cold) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
    lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut warm);
    lut_matmul(&mut Workspace::new(), &a, &idx, &cb, m, k, n, Epilogue::None, &mut cold);
    assert_eq!(warm, cold);
}
