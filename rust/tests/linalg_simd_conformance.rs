//! Two-tier conformance suite for the vectorized GEMM micro-kernels
//! (DESIGN.md §2.6).
//!
//! Tier 1 (deterministic): the scalar kernel with serial blocks must be
//! **bitwise-equal** to the naive reference loops on every shape — this
//! is what `--deterministic` promises, and what keeps durable-store
//! byte-equality gates meaningful across machines.
//!
//! Tier 2 (fast): every vector kernel this host can run (AVX2/NEON FMA)
//! must land inside the [`conformance`] error envelope of a float64
//! oracle — `2·(k+4)·ε_f32 · Σ|a·b|` per element, a bound that stays
//! honest under heavy cancellation because it scales with summand
//! magnitudes, not the result.
//!
//! Plus the dispatch contract: requesting a kernel the host does not
//! support must fall back to scalar (bitwise — never UB, never a panic),
//! and the intra-op row split must be bitwise-identical to the serial
//! schedule under every kernel.
//!
//! All tier selection here is pinned per call via [`GemmOpts`]; the
//! process-global mode (`set_deterministic`) is set-once and shared by
//! every test thread in this binary, so no test touches it.

use ecqx::linalg::conformance::{assert_matmul_within_envelope, envelope, matmul_f64};
use ecqx::linalg::{
    self, reference, Conv2d, Epilogue, GemmOpts, Kernel, Pad, Workspace, MC, MR, NR,
};
use ecqx::util::prop::{check, normal_vec};
use ecqx::util::Rng;

const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };

/// Ragged-heavy dimension pool: degenerate sizes, off-by-one around the
/// blocking constants, and a deep-`k` value to grow the error bound's
/// lever arm.
fn dim(rng: &mut Rng) -> usize {
    const POOL: [usize; 12] =
        [1, 2, MR - 1, MR + 1, NR - 1, NR + 1, 33, MC - 1, MC + 1, 70, 100, 257];
    POOL[rng.below(POOL.len())]
}

fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; a.len()];
    for i in 0..rows {
        for j in 0..cols {
            t[j * rows + i] = a[i * cols + j];
        }
    }
    t
}

// ---------------------------------------------------------------- tier 1

#[test]
fn deterministic_tier_is_bitwise_equal_to_naive_on_ragged_shapes() {
    let mut ws = Workspace::new();
    check("deterministic tier ≡ naive (bitwise)", 40, |rng| {
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        let a = normal_vec(rng, m * k, 1.0);
        let b = normal_vec(rng, k * n, 1.0);
        let mut out = vec![0.0f32; m * n];
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
        let want = reference::matmul(&a, &b, m, k, n);
        if out != want {
            return Err(format!("scalar tier diverged from naive on {m}x{k}x{n}"));
        }
        Ok(())
    });
    // degenerate shapes too: empty m/n/k must stay bitwise (trivially)
    for &(m, k, n) in &[(0usize, 5, 5), (5, 0, 5), (5, 5, 0), (1, 1, 1)] {
        let a = vec![1.0f32; m * k];
        let b = vec![1.0f32; k * n];
        let mut out = vec![f32::NAN; m * n];
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
        assert_eq!(out, reference::matmul(&a, &b, m, k, n), "shape {m}x{k}x{n}");
    }
}

// ---------------------------------------------------------------- tier 2

#[test]
fn every_available_kernel_is_within_the_envelope_on_ragged_shapes() {
    let mut ws = Workspace::new();
    check("fast tier inside the f64-oracle envelope", 25, |rng| {
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        let a = normal_vec(rng, m * k, 1.0);
        let b = normal_vec(rng, k * n, 1.0);
        let mut out = vec![0.0f32; m * n];
        for kern in Kernel::available() {
            let opts = GemmOpts::with_kernel(kern);
            linalg::gemm_nn_with(opts, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
            assert_matmul_within_envelope(
                &out,
                &a,
                &b,
                m,
                k,
                n,
                &format!("gemm_nn[{}] {m}x{k}x{n}", kern.name()),
            );
        }
        Ok(())
    });
}

#[test]
fn tn_and_nt_forms_are_within_the_envelope_for_every_kernel() {
    // the envelope oracle speaks row-major NN, so hand it explicitly
    // transposed operands: TN computes aᵀ@b (depth m), NT computes g@wᵀ
    // (depth n)
    let (m, k, n) = (37, MR + 1, NR + 5);
    let mut rng = Rng::new(0x51D);
    let a = normal_vec(&mut rng, m * k, 1.0);
    let b = normal_vec(&mut rng, m * n, 1.0);
    let g = normal_vec(&mut rng, m * n, 1.0);
    let w = normal_vec(&mut rng, k * n, 1.0);
    let mut ws = Workspace::new();
    for kern in Kernel::available() {
        let opts = GemmOpts::with_kernel(kern);
        let mut tn = vec![0.0f32; k * n];
        linalg::gemm_tn_with(opts, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut tn);
        let at = transpose(&a, m, k);
        assert_matmul_within_envelope(&tn, &at, &b, k, m, n, &format!("gemm_tn[{}]", kern.name()));

        let mut nt = vec![0.0f32; m * k];
        linalg::gemm_nt_with(opts, &mut ws, &g, &w, m, n, k, Epilogue::None, &mut nt);
        let wt = transpose(&w, k, n);
        assert_matmul_within_envelope(&nt, &g, &wt, m, n, k, &format!("gemm_nt[{}]", kern.name()));
    }
}

#[test]
fn cancellation_heavy_inputs_stay_within_the_envelope() {
    // every row of A is [v, -v, v, -v, ...] against an all-ones B: the
    // true result is exactly 0 while the magnitude sum is k·|v| — a
    // relative-to-result bound would be vacuous here, the magnitude-sum
    // envelope is not
    let (m, k, n) = (8, 256, NR + 1);
    let mut rng = Rng::new(0xCA7);
    let a: Vec<f32> = (0..m * k)
        .map(|i| {
            let v = rng.normal_f32(0.0, 1.0).abs() + 0.5;
            if i % 2 == 0 {
                v
            } else {
                -v
            }
        })
        .collect();
    // pair up so each row sums to exactly zero in exact arithmetic
    let a: Vec<f32> = a
        .chunks_exact(2)
        .flat_map(|p| [p[0], -p[0]])
        .collect::<Vec<_>>();
    let b = vec![1.0f32; k * n];
    let (oracle, mag) = matmul_f64(&a, &b, m, k, n);
    assert!(oracle.iter().all(|&v| v == 0.0), "construction yields exact zeros");
    assert!(mag.iter().all(|&v| v > 0.0), "…with nonzero magnitude sums");
    let mut ws = Workspace::new();
    let mut out = vec![0.0f32; m * n];
    for kern in Kernel::available() {
        let opts = GemmOpts::with_kernel(kern);
        linalg::gemm_nn_with(opts, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
        assert_matmul_within_envelope(
            &out,
            &a,
            &b,
            m,
            k,
            n,
            &format!("cancellation[{}]", kern.name()),
        );
        // and the bound is genuinely tight-ish: the absolute deviation
        // must be tiny relative to the magnitude scale
        for (&got, &mg) in out.iter().zip(&mag) {
            assert!((got as f64).abs() <= envelope(k, mg));
        }
    }
}

#[test]
fn conv_fast_tier_is_within_the_envelope() {
    // materialize the im2col patch matrix and reuse the GEMM oracle: the
    // conv forward is exactly P[rows, taps] @ W[taps, co]
    fn im2col(x: &[f32], g: &Conv2d) -> Vec<f32> {
        let (oh, ow) = g.out_hw();
        let (ph, pw) = g.pad_before();
        let mut p = vec![0.0f32; g.rows() * g.taps()];
        let mut row = 0;
        for b in 0..g.n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            for ci in 0..g.c {
                                let iy = (oy * g.stride + ky) as isize - ph as isize;
                                let ix = (ox * g.stride + kx) as isize - pw as isize;
                                if iy >= 0 && (iy as usize) < g.h && ix >= 0 && (ix as usize) < g.w
                                {
                                    p[row * g.taps() + (ky * g.kw + kx) * g.c + ci] = x
                                        [((b * g.h + iy as usize) * g.w + ix as usize) * g.c + ci];
                                }
                            }
                        }
                    }
                    row += 1;
                }
            }
        }
        p
    }
    let mut rng = Rng::new(0xC02F);
    let mut ws = Workspace::new();
    for g in [
        Conv2d { n: 2, h: 7, w: 5, c: 3, kh: 3, kw: 3, co: NR + 2, stride: 1, pad: Pad::Same },
        Conv2d { n: 1, h: 9, w: 9, c: 4, kh: 2, kw: 3, co: 5, stride: 2, pad: Pad::Valid },
    ] {
        let x = normal_vec(&mut rng, g.in_len(), 1.0);
        let w = normal_vec(&mut rng, g.filter_len(), 0.5);
        let p = im2col(&x, &g);
        let mut out = vec![0.0f32; g.out_len()];
        for kern in Kernel::available() {
            let opts = GemmOpts::with_kernel(kern);
            linalg::conv2d_with(opts, &mut ws, &x, &w, &g, Epilogue::None, &mut out);
            assert_matmul_within_envelope(
                &out,
                &p,
                &w,
                g.rows(),
                g.taps(),
                g.co,
                &format!("conv2d[{}] {g:?}", kern.name()),
            );
        }
        // and the deterministic tier stays bitwise against naive direct
        linalg::conv2d_with(DET, &mut ws, &x, &w, &g, Epilogue::None, &mut out);
        assert_eq!(out, reference::conv2d_naive(&x, &w, &g), "{g:?}");
    }
}

// ------------------------------------------------------------- dispatch

#[test]
fn unavailable_kernel_falls_back_to_scalar_bitwise() {
    // at most one vector ISA exists per host, so at least one of these is
    // always unavailable — requesting it must silently run scalar
    let unavailable: Vec<Kernel> = [Kernel::Avx2, Kernel::Neon]
        .into_iter()
        .filter(|k| !k.is_available())
        .collect();
    assert!(!unavailable.is_empty(), "no host supports both AVX2 and NEON");
    let (m, k, n) = (MC + 3, 29, NR + 7);
    let mut rng = Rng::new(0xFA11);
    let a = normal_vec(&mut rng, m * k, 1.0);
    let b = normal_vec(&mut rng, k * n, 1.0);
    let mut ws = Workspace::new();
    let mut want = vec![0.0f32; m * n];
    linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut want);
    for kern in unavailable {
        let mut out = vec![0.0f32; m * n];
        let opts = GemmOpts::with_kernel(kern);
        linalg::gemm_nn_with(opts, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
        assert_eq!(out, want, "{} must fall back to scalar", kern.name());
    }
}

#[test]
fn resolve_is_deterministic_first_then_forced_then_detect() {
    // pure mode logic (the process-global wiring is set-once, so it is
    // exercised end-to-end by CI's --deterministic sweep, not here)
    let r = GemmOpts::resolve(true, Some(Kernel::detect()), 8);
    assert_eq!(r, GemmOpts::deterministic());
    let r = GemmOpts::resolve(false, Some(Kernel::Scalar), 3);
    assert_eq!(r, GemmOpts { kernel: Kernel::Scalar, threads: 3 });
    let r = GemmOpts::resolve(false, None, 0);
    assert_eq!(r.kernel, Kernel::detect());
    assert_eq!(r.threads, 1, "threads clamp to >= 1");
}

#[test]
fn row_split_is_bitwise_identical_to_serial_for_every_kernel() {
    // dense A spanning several MC blocks, gather B, and a row-indexed
    // epilogue — the split must re-base rows and change nothing
    let (m, k, n) = (2 * MC + 9, 23, NR + 3);
    let mut rng = Rng::new(0x5917);
    let a = normal_vec(&mut rng, m * k, 1.0);
    let cb = [0.0f32, 0.5, -0.25, 1.0];
    let idx: Vec<i32> = (0..k * n).map(|i| (i % 4) as i32).collect();
    let mask = normal_vec(&mut rng, m * n, 1.0);
    let mut ws = Workspace::new();
    for kern in Kernel::available() {
        let mut serial = vec![0.0f32; m * n];
        let one = GemmOpts { kernel: kern, threads: 1 };
        linalg::gemm_gather_nn_with(
            one,
            &mut ws,
            &a,
            &idx,
            &cb,
            m,
            k,
            n,
            Epilogue::ReluMask(&mask),
            &mut serial,
        );
        let mut split = vec![0.0f32; m * n];
        let four = GemmOpts { kernel: kern, threads: 4 };
        linalg::gemm_gather_nn_with(
            four,
            &mut ws,
            &a,
            &idx,
            &cb,
            m,
            k,
            n,
            Epilogue::ReluMask(&mask),
            &mut split,
        );
        assert_eq!(split, serial, "kernel {}", kern.name());
    }
}
