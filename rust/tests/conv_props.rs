//! Property suite for the im2col-GEMM conv lowering: on every geometry —
//! strided or not, SAME or VALID, 1×1 or ragged kernels, degenerate
//! 0-sized dims — the blocked conv kernels must agree with the retained
//! naive direct kernels (`linalg::reference`) **exactly**, the backward
//! kernels must be true adjoints of the forward, and the epsilon-rule
//! and α-β-rule (`alpha_beta_*`) conv LRP must conserve relevance
//! (mirroring `python/tests/test_lrp_properties.py`).
//!
//! Forward/backward comparisons use `assert_eq!`-style exact equality
//! and pin the *deterministic tier* (`DET`: scalar micro-kernel): on that
//! tier the im2col path accumulates each output element in the same
//! ascending order as the naive loops (taps for the forward, samples for
//! dW, `(m, tap)` scatter for dX), so on finite inputs the results are
//! equal to the last bit — the conv extension of the DESIGN.md §2.6
//! deterministic-tier contract. Vector kernels are covered by the
//! envelope suite in `tests/linalg_simd_conformance.rs`. Tests that
//! compare two blocked-core paths against each other (gather vs
//! materialized dense, 1×1 conv vs GEMM, workspace reuse, adjoint and
//! conservation identities) deliberately stay on runtime dispatch: both
//! sides run the same kernel over identically packed panels, so they
//! hold under *any* variant.

use ecqx::linalg::{self, reference, Conv2d, Epilogue, GemmOpts, Kernel, Pad, Workspace};
use ecqx::util::prop::{check, normal_vec};
use ecqx::util::Rng;

/// Deterministic tier, pinned per-call (never via the process-global
/// mode: that is set-once and would leak into sibling tests).
const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };

/// Geometry pool: tiny-to-moderate spatial dims, ragged kernels (incl.
/// 1×1 and non-square), strides 1–3, both paddings.
fn rand_geom(rng: &mut Rng) -> Conv2d {
    Conv2d {
        n: 1 + rng.below(3),
        h: 1 + rng.below(8),
        w: 1 + rng.below(8),
        c: 1 + rng.below(4),
        kh: 1 + rng.below(3),
        kw: 1 + rng.below(3),
        // crosses the NR=16 strip boundary now and then
        co: 1 + rng.below(20),
        stride: 1 + rng.below(3),
        pad: if rng.chance(0.5) { Pad::Same } else { Pad::Valid },
    }
}

fn eq(label: &str, got: &[f32], want: &[f32]) -> Result<(), String> {
    if got == want {
        Ok(())
    } else {
        let i = got
            .iter()
            .zip(want)
            .position(|(a, b)| a != b)
            .unwrap_or(usize::MAX);
        Err(format!("{label}: first divergence at flat index {i}"))
    }
}

#[test]
fn im2col_conv_equals_naive_direct_exactly() {
    let mut ws = Workspace::new(); // shared across cases: reuse must be inert
    check("im2col conv ≡ naive direct", 60, |rng| {
        let g = rand_geom(rng);
        if g.out_len() == 0 {
            return Ok(()); // VALID with kernel > input: covered below
        }
        let x = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.5);
        let bias = normal_vec(rng, g.co, 0.5);

        let mut out = vec![0.0f32; g.out_len()];
        linalg::conv2d_with(DET, &mut ws, &x, &w, &g, Epilogue::None, &mut out);
        let base = reference::conv2d_naive(&x, &w, &g);
        eq(&format!("{g:?}"), &out, &base)?;

        // fused bias and bias+relu equal the unfused composition
        linalg::conv2d_with(DET, &mut ws, &x, &w, &g, Epilogue::Bias(&bias), &mut out);
        let mut want: Vec<f32> = base
            .chunks_exact(g.co)
            .flat_map(|row| row.iter().zip(&bias).map(|(&z, &b)| z + b))
            .collect();
        eq("bias", &out, &want)?;
        linalg::conv2d_with(DET, &mut ws, &x, &w, &g, Epilogue::BiasRelu(&bias), &mut out);
        for z in want.iter_mut() {
            if *z < 0.0 {
                *z = 0.0;
            }
        }
        eq("bias+relu", &out, &want)?;
        Ok(())
    });
}

#[test]
fn one_by_one_kernel_is_a_pointwise_gemm() {
    // a 1×1 stride-1 conv is per-pixel matmul: SAME ≡ VALID ≡ plain GEMM
    let mut ws = Workspace::new();
    let (n, h, w, c, co) = (2, 5, 7, 3, 6);
    let mut rng = Rng::new(0xC0);
    let x = normal_vec(&mut rng, n * h * w * c, 1.0);
    let wf = normal_vec(&mut rng, c * co, 1.0);
    let mk = |pad| Conv2d { n, h, w, c, kh: 1, kw: 1, co, stride: 1, pad };
    let mut same = vec![0.0f32; n * h * w * co];
    let mut valid = vec![0.0f32; n * h * w * co];
    linalg::conv2d(&mut ws, &x, &wf, &mk(Pad::Same), Epilogue::None, &mut same);
    linalg::conv2d(&mut ws, &x, &wf, &mk(Pad::Valid), Epilogue::None, &mut valid);
    assert_eq!(same, valid);
    let mut gemm = vec![0.0f32; n * h * w * co];
    linalg::gemm_nn(&mut ws, &x, &wf, n * h * w, c, co, Epilogue::None, &mut gemm);
    assert_eq!(same, gemm);
}

#[test]
fn degenerate_dims_are_well_formed() {
    let mut ws = Workspace::new();
    let base = Conv2d { n: 2, h: 4, w: 4, c: 2, kh: 3, kw: 3, co: 3, stride: 1, pad: Pad::Same };
    // empty batch, empty output channels, kernel larger than a VALID input
    for g in [
        Conv2d { n: 0, ..base },
        Conv2d { co: 0, ..base },
        Conv2d { h: 2, pad: Pad::Valid, ..base },
    ] {
        let x = vec![0.5f32; g.in_len()];
        let w = vec![0.25f32; g.filter_len()];
        let mut out = vec![0.0f32; g.out_len()];
        linalg::conv2d_with(DET, &mut ws, &x, &w, &g, Epilogue::None, &mut out);
        assert_eq!(out, reference::conv2d_naive(&x, &w, &g), "{g:?}");
        // backward shapes stay consistent too
        let gout = vec![0.5f32; g.out_len()];
        let mut dw = vec![0.0f32; g.filter_len()];
        linalg::conv2d_bwd_filter_with(DET, &mut ws, &x, &gout, &g, Epilogue::None, &mut dw);
        assert_eq!(dw, reference::conv2d_bwd_filter_naive(&x, &gout, &g), "{g:?}");
        let mut dx = vec![f32::NAN; g.in_len()];
        linalg::conv2d_bwd_input_with(DET, &mut ws, &gout, &w, &g, &mut dx);
        assert_eq!(dx, reference::conv2d_bwd_input_naive(&gout, &w, &g), "{g:?}");
    }
    // zero input channels: an empty contraction, so the epilogue of zero
    // applies (bias-only) — the conv analogue of a k=0 dense layer
    let g = Conv2d { c: 0, ..base };
    let bias = [1.0f32, -1.0, 2.0];
    let mut out = vec![f32::NAN; g.out_len()];
    linalg::conv2d(&mut ws, &[], &[], &g, Epilogue::Bias(&bias), &mut out);
    for row in out.chunks_exact(3) {
        assert_eq!(row, [1.0, -1.0, 2.0]);
    }
}

#[test]
fn backward_kernels_equal_naive_exactly() {
    let mut ws = Workspace::new();
    check("conv backward ≡ naive direct", 60, |rng| {
        let g = rand_geom(rng);
        if g.out_len() == 0 {
            return Ok(());
        }
        let x = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.5);
        let gout = normal_vec(rng, g.out_len(), 1.0);

        let mut dw = vec![0.0f32; g.filter_len()];
        linalg::conv2d_bwd_filter_with(DET, &mut ws, &x, &gout, &g, Epilogue::None, &mut dw);
        eq("bwd_filter", &dw, &reference::conv2d_bwd_filter_naive(&x, &gout, &g))?;

        let mut dx = vec![f32::NAN; g.in_len()];
        linalg::conv2d_bwd_input_with(DET, &mut ws, &gout, &w, &g, &mut dx);
        eq("bwd_input", &dx, &reference::conv2d_bwd_input_naive(&gout, &w, &g))?;
        Ok(())
    });
}

#[test]
fn backward_kernels_are_adjoints_of_the_forward() {
    // ⟨conv(x, w), g⟩ = ⟨x, bwd_input(g, w)⟩ = ⟨w, bwd_filter(x, g)⟩ —
    // the defining property of the backward pass (f64 accumulation)
    let mut ws = Workspace::new();
    check("conv bwd adjoint identities", 40, |rng| {
        let g = rand_geom(rng);
        if g.out_len() == 0 || g.in_len() == 0 || g.filter_len() == 0 {
            return Ok(());
        }
        let x = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.5);
        let gout = normal_vec(rng, g.out_len(), 1.0);
        let dot = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&u, &v)| u as f64 * v as f64).sum()
        };
        let mut out = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut ws, &x, &w, &g, Epilogue::None, &mut out);
        let lhs = dot(&out, &gout);
        let mut dx = vec![0.0f32; g.in_len()];
        linalg::conv2d_bwd_input(&mut ws, &gout, &w, &g, &mut dx);
        let via_x = dot(&x, &dx);
        let mut dw = vec![0.0f32; g.filter_len()];
        linalg::conv2d_bwd_filter(&mut ws, &x, &gout, &g, Epilogue::None, &mut dw);
        let via_w = dot(&w, &dw);
        let scale = lhs.abs().max(1.0);
        if (lhs - via_x).abs() > 1e-3 * scale {
            return Err(format!("⟨y,g⟩={lhs} vs ⟨x,dx⟩={via_x} ({g:?})"));
        }
        if (lhs - via_w).abs() > 1e-3 * scale {
            return Err(format!("⟨y,g⟩={lhs} vs ⟨w,dw⟩={via_w} ({g:?})"));
        }
        Ok(())
    });
}

#[test]
fn gather_conv_equals_materialized_dense_with_clamping() {
    let mut ws = Workspace::new();
    check("conv gather ≡ materialize + dense", 40, |rng| {
        let g = rand_geom(rng);
        if g.out_len() == 0 {
            return Ok(());
        }
        let x = normal_vec(rng, g.in_len(), 1.0);
        let bias = normal_vec(rng, g.co, 0.5);
        let ncb = 1 + rng.below(8);
        let mut cb = normal_vec(rng, ncb, 0.5);
        cb[0] = 0.0; // the paper's codebooks always carry the zero centroid
        // ~70% zero centroid + deliberate out-of-range indices (clamp)
        let idx: Vec<i32> = (0..g.filter_len())
            .map(|_| {
                if rng.chance(0.1) {
                    if rng.chance(0.5) {
                        -3
                    } else {
                        ncb as i32 + 5
                    }
                } else if rng.chance(0.7) {
                    0
                } else {
                    rng.below(ncb) as i32
                }
            })
            .collect();
        let top = (ncb - 1) as i32;
        let dense: Vec<f32> = idx.iter().map(|&i| cb[i.clamp(0, top) as usize]).collect();
        let mut got = vec![0.0f32; g.out_len()];
        linalg::conv2d_gather(&mut ws, &x, &idx, &cb, &g, Epilogue::Bias(&bias), &mut got);
        let mut want = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut ws, &x, &dense, &g, Epilogue::Bias(&bias), &mut want);
        eq("gather", &got, &want)?;
        Ok(())
    });
}

/// Epsilon-rule stabilizer (runtime::host::stabilize semantics).
fn stabilize(z: f32) -> f32 {
    if z >= 0.0 {
        z + 1e-6
    } else {
        z - 1e-6
    }
}

#[test]
fn lrp_conv_rw_conserves_relevance() {
    // With zero bias, the epsilon rule conserves relevance through a conv
    // layer: Σ R_w ≈ Σ R_out and Σ R_in ≈ Σ R_out (small eps absorption
    // aside) — the conv mirror of test_dense_eps_conservation in
    // python/tests/test_lrp_properties.py.
    let mut ws = Workspace::new();
    check("epsilon conv LRP conservation", 30, |rng| {
        let g = Conv2d {
            n: 1 + rng.below(2),
            h: 4 + rng.below(4),
            w: 4 + rng.below(4),
            c: 2 + rng.below(2),
            kh: 3,
            kw: 3,
            co: 3 + rng.below(3),
            stride: 1 + rng.below(2),
            pad: Pad::Same,
        };
        let a = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.4);
        let mut z = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut ws, &a, &w, &g, Epilogue::None, &mut z);
        // a pre-activation near zero makes the stabilizer dominate that
        // unit's ratio; give those units zero relevance (their share of
        // both sides is then exactly zero) instead of asserting through
        // the eps spike
        let r: Vec<f32> = z
            .iter()
            .map(|&zv| if zv.abs() < 1e-2 { 0.0 } else { rng.range(0.0, 1.0) })
            .collect();
        let s: Vec<f32> = r.iter().zip(&z).map(|(&rv, &zv)| rv / stabilize(zv)).collect();

        let mut rw = vec![0.0f32; g.filter_len()];
        linalg::lrp_conv_rw(&mut ws, &a, &s, &w, &g, &mut rw);
        let mut rin = vec![0.0f32; g.in_len()];
        linalg::conv2d_bwd_input(&mut ws, &s, &w, &g, &mut rin);
        for (rv, &av) in rin.iter_mut().zip(&a) {
            *rv *= av;
        }

        let total: f64 = r.iter().map(|&v| v as f64).sum();
        let sum_rw: f64 = rw.iter().map(|&v| v as f64).sum();
        let sum_rin: f64 = rin.iter().map(|&v| v as f64).sum();
        let tol = 1e-2 * (1.0 + total.abs());
        if (sum_rw - total).abs() > tol {
            return Err(format!("Σ R_w = {sum_rw} vs Σ R_out = {total} ({g:?})"));
        }
        if (sum_rin - total).abs() > tol {
            return Err(format!("Σ R_in = {sum_rin} vs Σ R_out = {total} ({g:?})"));
        }
        Ok(())
    });
}

#[test]
fn alpha_beta_conv_lrp_conserves_relevance() {
    // the α-β rule (α+β=1) conserves relevance through a bias-free conv
    // layer: Σ R_w ≈ Σ R_in ≈ Σ R_out. Each output redistributes
    // R_j·(α·z⁺/stab(z⁺) + β·z⁻/stab(z⁻)) ≈ R_j·(α+β), so — exactly as
    // the epsilon suite does — outputs whose z⁺ or z⁻ is stabilizer-scale
    // get zero relevance instead of asserting through the eps spike. The
    // signed parts are recomputed here with the *naive* direct kernels,
    // so the check is independent of the blocked composition under test.
    let mut ws = Workspace::new();
    check("α-β conv LRP conservation", 30, |rng| {
        let g = Conv2d {
            n: 1 + rng.below(2),
            h: 4 + rng.below(4),
            w: 4 + rng.below(4),
            c: 2 + rng.below(2),
            kh: 3,
            kw: 3,
            co: 3 + rng.below(3),
            stride: 1 + rng.below(2),
            pad: Pad::Same,
        };
        let a = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.4);
        let split = |v: &[f32]| -> (Vec<f32>, Vec<f32>) {
            (
                v.iter().map(|&x| x.max(0.0)).collect(),
                v.iter().map(|&x| x.min(0.0)).collect(),
            )
        };
        let (ap, an) = split(&a);
        let (wp, wn) = split(&w);
        let add = |x: Vec<f32>, y: Vec<f32>| -> Vec<f32> {
            x.iter().zip(&y).map(|(&u, &v)| u + v).collect()
        };
        let zp = add(
            reference::conv2d_naive(&ap, &wp, &g),
            reference::conv2d_naive(&an, &wn, &g),
        );
        let zn = add(
            reference::conv2d_naive(&ap, &wn, &g),
            reference::conv2d_naive(&an, &wp, &g),
        );
        let r: Vec<f32> = zp
            .iter()
            .zip(&zn)
            .map(|(&p, &n)| {
                if p.abs() < 1e-2 || n.abs() < 1e-2 {
                    0.0
                } else {
                    rng.range(0.0, 1.0)
                }
            })
            .collect();

        let mut rw = vec![0.0f32; g.filter_len()];
        let mut rin = vec![0.0f32; g.in_len()];
        linalg::lrp_conv_ab_with(
            DET,
            &mut ws,
            &a,
            &w,
            &r,
            &g,
            linalg::LRP_ALPHA,
            linalg::LRP_BETA,
            &mut rw,
            &mut rin,
        );

        let total: f64 = r.iter().map(|&v| v as f64).sum();
        let sum_rw: f64 = rw.iter().map(|&v| v as f64).sum();
        let sum_rin: f64 = rin.iter().map(|&v| v as f64).sum();
        // |β|·R/stab amplifies roundoff relative to the epsilon rule;
        // the tolerance scales with the α/β magnitudes
        let tol = (linalg::LRP_ALPHA.abs() + linalg::LRP_BETA.abs()) as f64
            * 1e-2
            * (1.0 + total.abs());
        if (sum_rw - total).abs() > tol {
            return Err(format!("Σ R_w = {sum_rw} vs Σ R_out = {total} ({g:?})"));
        }
        if (sum_rin - total).abs() > tol {
            return Err(format!("Σ R_in = {sum_rin} vs Σ R_out = {total} ({g:?})"));
        }
        Ok(())
    });
}

#[test]
fn alpha_beta_views_sum_identically_for_any_conserving_pair() {
    // Σ R_w = Σ R_in for *every* (α, 1−α) pair and geometry — both views
    // regroup the same product terms, with no stabilizer caveat needed
    let mut ws = Workspace::new();
    check("α-β R_w/R_in view identity", 30, |rng| {
        let g = rand_geom(rng);
        if g.out_len() == 0 || g.in_len() == 0 || g.filter_len() == 0 {
            return Ok(());
        }
        let a = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.5);
        let r = normal_vec(rng, g.out_len(), 1.0);
        let alpha = rng.range(0.5, 3.0);
        let beta = 1.0 - alpha;
        let mut rw = vec![0.0f32; g.filter_len()];
        let mut rin = vec![0.0f32; g.in_len()];
        linalg::lrp_conv_ab_with(DET, &mut ws, &a, &w, &r, &g, alpha, beta, &mut rw, &mut rin);
        if rw.iter().chain(rin.iter()).any(|v| !v.is_finite()) {
            return Err(format!("non-finite relevance ({g:?})"));
        }
        let sum_rw: f64 = rw.iter().map(|&v| v as f64).sum();
        let sum_rin: f64 = rin.iter().map(|&v| v as f64).sum();
        let tol = 1e-3 * (1.0 + sum_rw.abs().max(sum_rin.abs()));
        if (sum_rw - sum_rin).abs() > tol {
            return Err(format!("Σ R_w = {sum_rw} vs Σ R_in = {sum_rin} (α={alpha}, {g:?})"));
        }
        Ok(())
    });
}

#[test]
fn alpha_beta_with_alpha_one_degenerates_to_the_z_plus_rule() {
    // (α, β) = (1, 0): the negative branch must contribute nothing, and
    // on all-positive operands the rule coincides with the epsilon rule
    // (z⁻ = 0 ⇒ z⁺ = z), up to the shared stabilizer
    let mut ws = Workspace::new();
    check("α=1 z⁺ degeneration", 20, |rng| {
        let g = Conv2d {
            n: 1,
            h: 3 + rng.below(3),
            w: 3 + rng.below(3),
            c: 1 + rng.below(3),
            kh: 1 + rng.below(3),
            kw: 1 + rng.below(3),
            co: 1 + rng.below(4),
            stride: 1,
            pad: Pad::Same,
        };
        let a: Vec<f32> = (0..g.in_len()).map(|_| rng.range(0.1, 1.0)).collect();
        let w: Vec<f32> = (0..g.filter_len()).map(|_| rng.range(0.1, 0.5)).collect();
        let r: Vec<f32> = (0..g.out_len()).map(|_| rng.range(0.0, 1.0)).collect();
        let mut rw = vec![0.0f32; g.filter_len()];
        let mut rin = vec![0.0f32; g.in_len()];
        linalg::lrp_conv_ab_with(DET, &mut ws, &a, &w, &r, &g, 1.0, 0.0, &mut rw, &mut rin);

        // epsilon-rule reference on the same (all-positive) layer
        let z = reference::conv2d_naive(&a, &w, &g);
        let s: Vec<f32> = r.iter().zip(&z).map(|(&rv, &zv)| rv / stabilize(zv)).collect();
        let mut rin_eps = vec![0.0f32; g.in_len()];
        linalg::conv2d_bwd_input(&mut ws, &s, &w, &g, &mut rin_eps);
        for (rv, &av) in rin_eps.iter_mut().zip(&a) {
            *rv *= av;
        }
        for (i, (&got, &want)) in rin.iter().zip(&rin_eps).enumerate() {
            if (got - want).abs() > 1e-4 * (1.0 + want.abs()) {
                return Err(format!("R_in[{i}] = {got} vs epsilon {want} ({g:?})"));
            }
        }
        Ok(())
    });
}

#[test]
fn workspace_reuse_across_conv_shapes_is_inert() {
    // interleave wildly different conv shapes (and a dense GEMM) through
    // ONE workspace and check each against a fresh-workspace run
    let mut shared = Workspace::new();
    let mut rng = Rng::new(0xC0D3);
    for _ in 0..10 {
        let g = rand_geom(&mut rng);
        let x = normal_vec(&mut rng, g.in_len(), 1.0);
        let w = normal_vec(&mut rng, g.filter_len(), 0.5);
        let mut out_shared = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut shared, &x, &w, &g, Epilogue::None, &mut out_shared);
        // pollute with an unrelated dense GEMM between conv calls
        let a = normal_vec(&mut rng, 33 * 17, 1.0);
        let b = normal_vec(&mut rng, 17 * 29, 1.0);
        let mut sink = vec![0.0f32; 33 * 29];
        linalg::gemm_nn(&mut shared, &a, &b, 33, 17, 29, Epilogue::None, &mut sink);
        let mut fresh = Workspace::new();
        let mut out_fresh = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut fresh, &x, &w, &g, Epilogue::None, &mut out_fresh);
        assert_eq!(out_shared, out_fresh, "{g:?}");
        // the tiled backward shares the same workspace including the
        // dCol tile buffer
        if g.out_len() > 0 {
            let gout = normal_vec(&mut rng, g.out_len(), 1.0);
            let mut dx_shared = vec![0.0f32; g.in_len()];
            linalg::conv2d_bwd_input(&mut shared, &gout, &w, &g, &mut dx_shared);
            let mut dx_fresh = vec![0.0f32; g.in_len()];
            linalg::conv2d_bwd_input(&mut fresh, &gout, &w, &g, &mut dx_fresh);
            assert_eq!(dx_shared, dx_fresh, "{g:?}");
        }
    }
}
