//! Integration tests over the PJRT runtime + HLO artifacts.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they verify
//! that the lowered L1/L2 computations agree with the independent pure-rust
//! reference implementations — the three-way cross-check of DESIGN.md.

use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::data::{Batch, DataLoader};
use ecqx::lrp::{DenseLayer, Mlp};
use ecqx::nn::ModelState;
use ecqx::quant::{assign_ref, Codebook};
use ecqx::runtime::Engine;
use ecqx::tensor::{Tensor, Value};
use ecqx::util::Rng;

/// Engine over the real artifacts, or `None` (skip) when `artifacts/` is
/// absent or the offline `xla` stub is active — these tests exercise real
/// PJRT execution, which neither case can provide. Run `make artifacts`
/// and build against the real bindings to enable them.
fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
        return None;
    }
    if ecqx::runtime::backend_is_stub() {
        eprintln!("skipping: offline xla stub cannot execute artifacts");
        return None;
    }
    Some(Engine::new(&dir).unwrap())
}

/// assign_<bucket> artifact (Pallas kernel) vs the pure-rust reference.
#[test]
fn assign_artifact_matches_rust_reference() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(101);
    for &(n, bits, lam) in
        &[(700usize, 2u32, 0.0f32), (1024, 4, 1e-4), (5000, 4, 5e-4), (9000, 5, 1e-3)]
    {
        let bucket = eng.manifest.bucket_for(n).unwrap();
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let r: Vec<f32> = (0..n).map(|_| rng.range(0.1, 3.0)).collect();
        let cb = Codebook::fit(&w, bits);
        // padded inputs exactly as the coordinator builds them
        let mut wp = w.clone();
        wp.resize(bucket, 0.0);
        let mut rp = r.clone();
        rp.resize(bucket, 1.0);
        let mut mask = vec![1.0f32; n];
        mask.resize(bucket, 0.0);
        let outs = eng
            .call(
                &format!("assign_{bucket}"),
                &[
                    Value::F32(Tensor::new(vec![bucket], wp.clone())),
                    Value::F32(Tensor::new(vec![bucket], rp.clone())),
                    Value::F32(Tensor::new(vec![bucket], mask.clone())),
                    Value::F32(Tensor::new(vec![32], cb.values.clone())),
                    Value::F32(Tensor::new(vec![32], cb.valid.clone())),
                    Value::F32(Tensor::scalar(lam)),
                ],
            )
            .unwrap();
        let reference = assign_ref(&wp, &rp, &mask, &cb, lam);
        let idx_art = &outs[0].as_i32().data;
        let mismatches = idx_art
            .iter()
            .zip(reference.idx.iter())
            .filter(|(a, b)| a != b)
            .count();
        // ties at cost boundaries may break differently in f32; allow a
        // vanishing fraction
        assert!(
            mismatches <= n / 1000 + 1,
            "n={n} bits={bits} lam={lam}: {mismatches} mismatches"
        );
        let qw_art = &outs[1].as_f32().data;
        for i in 0..n {
            if idx_art[i] == reference.idx[i] {
                assert!((qw_art[i] - reference.qw[i]).abs() < 1e-6);
            }
        }
    }
}

/// <mlp_gsc>_lrp artifact vs the independent pure-rust epsilon-LRP.
#[test]
fn lrp_artifact_matches_rust_reference() {
    let Some(eng) = engine() else { return };
    let spec = eng.manifest.model("mlp_gsc").unwrap().clone();
    let state = ModelState::init(&spec, 7);
    // build the rust reference MLP from the same weights
    let dims = [360usize, 512, 512, 256, 256, 128, 128, 12];
    let layers: Vec<DenseLayer> = (0..7)
        .map(|i| {
            DenseLayer::new(
                dims[i],
                dims[i + 1],
                state.params[&format!("w{i}")].data.clone(),
                state.params[&format!("b{i}")].data.clone(),
            )
        })
        .collect();
    let mlp = Mlp { layers };

    let ds = ecqx::data::gsc::GscDataset::new(spec.batch, 3, false);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch = dl.epoch(0).next().unwrap();

    let art = eng.manifest.artifact("mlp_gsc_lrp").unwrap().clone();
    let scalars = Scalars { eqw: 1.0, ..Default::default() };
    let inputs = bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
    let outs = eng.call_named(&art.name, &inputs).unwrap();

    let rw_ref = mlp.lrp(&batch.x, &batch.y, spec.batch, true);
    for (i, rw) in rw_ref.iter().enumerate() {
        let art_rw = outs[&format!("r_w{i}")].as_f32();
        assert_eq!(art_rw.numel(), rw.len());
        // compare relative to the layer's relevance scale
        let scale = rw.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-6);
        let mut max_rel = 0.0f32;
        for (a, b) in art_rw.data.iter().zip(rw.iter()) {
            max_rel = max_rel.max((a - b).abs() / scale);
        }
        assert!(max_rel < 2e-2, "layer w{i}: max relative diff {max_rel}");
    }
}

/// fp_train artifact at lr=0 must return parameters unchanged;
/// ste_train must return the FP background unchanged at lr=0.
#[test]
fn train_steps_are_identity_at_zero_lr() {
    let Some(eng) = engine() else { return };
    let spec = eng.manifest.model("mlp_gsc").unwrap().clone();
    let mut state = ModelState::init(&spec, 11);
    // quantize so the q_ slots exist
    for name in state.qnames() {
        let w = state.params[&name].clone();
        let cb = Codebook::fit(&w.data, 4);
        let r = vec![1.0; w.numel()];
        let m = vec![1.0; w.numel()];
        let a = assign_ref(&w.data, &r, &m, &cb, 0.0);
        state.qlayers.insert(
            name,
            ecqx::nn::QLayer {
                qw: Tensor::new(w.shape.clone(), a.qw),
                idx: ecqx::tensor::TensorI32::new(w.shape.clone(), a.idx),
                codebook: cb,
            },
        );
    }
    let ds = ecqx::data::gsc::GscDataset::new(spec.batch, 5, true);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch: Batch = dl.epoch(0).next().unwrap();
    let scalars = Scalars { t: 1.0, lr: 0.0, gs: 1.0, ..Default::default() };
    for art_name in ["mlp_gsc_fp_train", "mlp_gsc_ste_train"] {
        let art = eng.manifest.artifact(art_name).unwrap().clone();
        let inputs =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
        let outs = eng.call_named(&art.name, &inputs).unwrap();
        for name in state.pnames() {
            let before = &state.params[&name];
            let after = outs[&format!("p_{name}")].as_f32();
            for (a, b) in before.data.iter().zip(after.data.iter()) {
                assert_eq!(a, b, "{art_name} changed {name} at lr=0");
            }
        }
        assert!(outs["loss"].as_f32().as_scalar() > 0.0);
    }
}

/// Quantized gather-eval (integer indices + codebook through the Pallas
/// gather kernel) must agree with the dequantized f32 eval.
#[test]
fn gather_eval_matches_dense_eval() {
    let Some(eng) = engine() else { return };
    let spec = eng.manifest.model("mlp_gsc").unwrap().clone();
    let mut state = ModelState::init(&spec, 13);
    for name in state.qnames() {
        let w = state.params[&name].clone();
        let cb = Codebook::fit(&w.data, 4);
        let r = vec![1.0; w.numel()];
        let m = vec![1.0; w.numel()];
        let a = assign_ref(&w.data, &r, &m, &cb, 1e-4);
        state.qlayers.insert(
            name,
            ecqx::nn::QLayer {
                qw: Tensor::new(w.shape.clone(), a.qw),
                idx: ecqx::tensor::TensorI32::new(w.shape.clone(), a.idx),
                codebook: cb,
            },
        );
    }
    let ds = ecqx::data::gsc::GscDataset::new(spec.batch, 5, false);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch = dl.epoch(0).next().unwrap();
    let scalars = Scalars::default();

    let art_f = eng.manifest.artifact("mlp_gsc_eval").unwrap().clone();
    let inp_f =
        bind_inputs(&art_f, &state, ParamSource::Quantized, Some(&batch), &scalars).unwrap();
    let out_f = eng.call_named(&art_f.name, &inp_f).unwrap();

    let art_q = eng.manifest.artifact("mlp_gsc_eval_q").unwrap().clone();
    let inp_q =
        bind_inputs(&art_q, &state, ParamSource::Quantized, Some(&batch), &scalars).unwrap();
    let out_q = eng.call_named(&art_q.name, &inp_q).unwrap();

    let lf = out_f["loss"].as_f32().as_scalar();
    let lq = out_q["loss"].as_f32().as_scalar();
    assert!((lf - lq).abs() < 1e-4, "loss {lf} vs {lq}");
    assert_eq!(
        out_f["correct"].as_f32().as_scalar(),
        out_q["correct"].as_f32().as_scalar()
    );
}

/// End-to-end mini QAT run: accuracy must stay well above chance and
/// sparsity must be non-trivial (the smoke version of the e2e example).
#[test]
fn mini_qat_run_recovers() {
    let Some(eng) = engine() else { return };
    let spec = eng.manifest.model("mlp_gsc").unwrap().clone();
    use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
    use ecqx::data::gsc::GscDataset;

    // tiny dataset + brief pretrain so the test runs in seconds
    let train = GscDataset::new(1024, 21, true);
    let val = GscDataset::new(512, 21, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 1);
    let val_dl = DataLoader::new(&val, spec.batch, false, 1);
    let mut state = ModelState::init(&spec, 21);
    let pre = ecqx::coordinator::trainer::Pretrainer {
        lr: 1e-3,
        verbose: false,
        ..Default::default()
    };
    pre.run(&eng, &mut state, &train_dl, 4).unwrap();

    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 4,
            lambda: 4.0,
            p: 0.2,
            ..Default::default()
        },
        epochs: 1,
        lr: 4e-4,
        verbose: false,
        ..Default::default()
    };
    let mut qstate = state;
    let out = QatTrainer::new(cfg).run(&eng, &mut qstate, &train_dl, &val_dl).unwrap();
    assert!(out.final_sparsity > 0.15, "sparsity {}", out.final_sparsity);
    assert!(
        out.epochs.last().unwrap().val_acc > 0.4,
        "val acc {}",
        out.epochs.last().unwrap().val_acc
    );
}
