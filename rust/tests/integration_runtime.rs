//! Integration tests over the execution runtime.
//!
//! Two tiers (DESIGN.md §5):
//!
//! * **Host tier** (always runs, zero skips): the same cross-checks
//!   executed through `Engine::host_with` on a small synthetic MLP — the
//!   full train → LRP → assign → quantize → eval pipeline runs end to end
//!   with no `artifacts/` directory and no PJRT bindings present.
//! * **PJRT tier** (`#[ignore]`-by-default): the artifact-vs-reference
//!   cross-checks against real lowered HLO. Run with
//!   `cargo test -- --ignored` after `make artifacts` on a build linked
//!   against real PJRT bindings.

use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::coordinator::trainer::{evaluate, evaluate_many, Pretrainer};
use ecqx::coordinator::{AssignConfig, Method, QatConfig, QatTrainer};
use ecqx::data::gsc::GscDataset;
use ecqx::data::{Batch, DataLoader};
use ecqx::lrp::{DenseLayer, Mlp};
use ecqx::nn::{checkpoint, ModelState, QLayer};
use ecqx::quant::{assign_ref, Codebook};
use ecqx::runtime::{Engine, Manifest, ModelSpec};
use ecqx::tensor::{Tensor, TensorI32, Value};
use ecqx::util::Rng;

/// Small dense ladder over the GSC feature space: big enough to exercise
/// multi-layer LRP/backprop, small enough for debug-mode test runs.
const TINY_DIMS: [usize; 4] = [360, 48, 24, 12];
const TINY_BATCH: usize = 32;

fn host_engine() -> Engine {
    Engine::host_with(Manifest::synthetic_mlp("mlp_tiny", &TINY_DIMS, TINY_BATCH))
}

/// Engine over the real artifacts, for the `#[ignore]` PJRT tier. Fails
/// loudly (instead of skipping) when prerequisites are missing, so an
/// explicit `--ignored` run never silently passes.
fn pjrt_engine() -> Engine {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.txt").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    assert!(
        !ecqx::runtime::backend_is_stub(),
        "offline xla stub cannot execute artifacts — build against real PJRT bindings"
    );
    Engine::new(&dir).unwrap()
}

/// Recover the dense ladder `[d0, .., classes]` from a model spec.
fn mlp_dims(spec: &ModelSpec) -> Vec<usize> {
    let mut dims = vec![spec.input_dim];
    let mut i = 0usize;
    while let Some(p) = spec.params.iter().find(|p| p.name == format!("w{i}")) {
        dims.push(p.shape[1]);
        i += 1;
    }
    dims
}

/// Quantize every layer of `state` with a plain nearest-neighbour-ish
/// assignment so the `q_`/`idx_` slots exist.
fn quantize_state(state: &mut ModelState, bits: u32, lam: f32) {
    for name in state.qnames() {
        let w = state.params[&name].clone();
        let cb = Codebook::fit(&w.data, bits);
        let r = vec![1.0; w.numel()];
        let m = vec![1.0; w.numel()];
        let a = assign_ref(&w.data, &r, &m, &cb, lam);
        state.qlayers.insert(
            name,
            QLayer {
                qw: Tensor::new(w.shape.clone(), a.qw),
                idx: TensorI32::new(w.shape.clone(), a.idx),
                codebook: cb,
            },
        );
    }
}

// ---------------------------------------------------------------------------
// shared cross-check bodies (parameterized by engine — both tiers use them)
// ---------------------------------------------------------------------------

/// assign_<bucket> execution vs the pure-rust reference.
fn check_assign_matches_reference(eng: &Engine) {
    let mut rng = Rng::new(101);
    for &(n, bits, lam) in
        &[(700usize, 2u32, 0.0f32), (1024, 4, 1e-4), (5000, 4, 5e-4), (9000, 5, 1e-3)]
    {
        let bucket = eng.manifest.bucket_for(n).unwrap();
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let r: Vec<f32> = (0..n).map(|_| rng.range(0.1, 3.0)).collect();
        let cb = Codebook::fit(&w, bits);
        // padded inputs exactly as the coordinator builds them
        let mut wp = w.clone();
        wp.resize(bucket, 0.0);
        let mut rp = r.clone();
        rp.resize(bucket, 1.0);
        let mut mask = vec![1.0f32; n];
        mask.resize(bucket, 0.0);
        let outs = eng
            .call(
                &format!("assign_{bucket}"),
                &[
                    Value::F32(Tensor::new(vec![bucket], wp.clone())),
                    Value::F32(Tensor::new(vec![bucket], rp.clone())),
                    Value::F32(Tensor::new(vec![bucket], mask.clone())),
                    Value::F32(Tensor::new(vec![32], cb.values.clone())),
                    Value::F32(Tensor::new(vec![32], cb.valid.clone())),
                    Value::F32(Tensor::scalar(lam)),
                ],
            )
            .unwrap();
        let reference = assign_ref(&wp, &rp, &mask, &cb, lam);
        let idx_art = &outs[0].as_i32().data;
        let mismatches = idx_art
            .iter()
            .zip(reference.idx.iter())
            .filter(|(a, b)| a != b)
            .count();
        // ties at cost boundaries may break differently in f32; allow a
        // vanishing fraction
        assert!(
            mismatches <= n / 1000 + 1,
            "n={n} bits={bits} lam={lam}: {mismatches} mismatches"
        );
        let qw_art = &outs[1].as_f32().data;
        for i in 0..n {
            if idx_art[i] == reference.idx[i] {
                assert!((qw_art[i] - reference.qw[i]).abs() < 1e-6);
            }
        }
        // counts cover exactly the unmasked elements
        let total: f32 = outs[2].as_f32().data.iter().sum();
        assert_eq!(total, n as f32);
    }
}

/// <model>_lrp execution vs the independent pure-rust epsilon-LRP.
fn check_lrp_matches_reference(eng: &Engine, model: &str) {
    let spec = eng.manifest.model(model).unwrap().clone();
    let state = ModelState::init(&spec, 7);
    let dims = mlp_dims(&spec);
    let layers: Vec<DenseLayer> = (0..dims.len() - 1)
        .map(|i| {
            DenseLayer::new(
                dims[i],
                dims[i + 1],
                state.params[&format!("w{i}")].data.clone(),
                state.params[&format!("b{i}")].data.clone(),
            )
        })
        .collect();
    let mlp = Mlp { layers };

    let ds = GscDataset::new(spec.batch, 3, false);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch = dl.epoch(0).next().unwrap();

    let art = eng.manifest.artifact(&format!("{model}_lrp")).unwrap().clone();
    let scalars = Scalars { eqw: 1.0, ..Default::default() };
    let inputs = bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
    let outs = eng.call_named(&art.name, &inputs).unwrap();

    let rw_ref = mlp.lrp(&batch.x, &batch.y, spec.batch, true);
    for (i, rw) in rw_ref.iter().enumerate() {
        let art_rw = outs[&format!("r_w{i}")].as_f32();
        assert_eq!(art_rw.numel(), rw.len());
        // compare relative to the layer's relevance scale
        let scale = rw.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-6);
        let mut max_rel = 0.0f32;
        for (a, b) in art_rw.data.iter().zip(rw.iter()) {
            max_rel = max_rel.max((a - b).abs() / scale);
        }
        assert!(max_rel < 2e-2, "layer w{i}: max relative diff {max_rel}");
    }
}

/// fp_train / ste_train at lr=0 must return the FP background unchanged.
fn check_train_steps_identity_at_zero_lr(eng: &Engine, model: &str) {
    let spec = eng.manifest.model(model).unwrap().clone();
    let mut state = ModelState::init(&spec, 11);
    quantize_state(&mut state, 4, 0.0);
    let ds = GscDataset::new(spec.batch, 5, true);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch: Batch = dl.epoch(0).next().unwrap();
    let scalars = Scalars { t: 1.0, lr: 0.0, gs: 1.0, ..Default::default() };
    for art_name in [format!("{model}_fp_train"), format!("{model}_ste_train")] {
        let art = eng.manifest.artifact(&art_name).unwrap().clone();
        let inputs =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
        let outs = eng.call_named(&art.name, &inputs).unwrap();
        for name in state.pnames() {
            let before = &state.params[&name];
            let after = outs[&format!("p_{name}")].as_f32();
            for (a, b) in before.data.iter().zip(after.data.iter()) {
                assert_eq!(a, b, "{art_name} changed {name} at lr=0");
            }
        }
        assert!(outs["loss"].as_f32().as_scalar() > 0.0);
    }
}

/// Quantized gather-eval (integer indices + codebook) must agree with the
/// dequantized f32 eval.
fn check_gather_eval_matches_dense_eval(eng: &Engine, model: &str) {
    let spec = eng.manifest.model(model).unwrap().clone();
    let mut state = ModelState::init(&spec, 13);
    quantize_state(&mut state, 4, 1e-4);
    let ds = GscDataset::new(spec.batch, 5, false);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch = dl.epoch(0).next().unwrap();
    let scalars = Scalars::default();

    let art_f = eng.manifest.artifact(&format!("{model}_eval")).unwrap().clone();
    let inp_f =
        bind_inputs(&art_f, &state, ParamSource::Quantized, Some(&batch), &scalars).unwrap();
    let out_f = eng.call_named(&art_f.name, &inp_f).unwrap();

    let art_q = eng.manifest.artifact(&format!("{model}_eval_q")).unwrap().clone();
    let inp_q =
        bind_inputs(&art_q, &state, ParamSource::Quantized, Some(&batch), &scalars).unwrap();
    let out_q = eng.call_named(&art_q.name, &inp_q).unwrap();

    let lf = out_f["loss"].as_f32().as_scalar();
    let lq = out_q["loss"].as_f32().as_scalar();
    assert!((lf - lq).abs() < 1e-4, "loss {lf} vs {lq}");
    assert_eq!(
        out_f["correct"].as_f32().as_scalar(),
        out_q["correct"].as_f32().as_scalar()
    );
}

// ---------------------------------------------------------------------------
// host tier — always runs, no artifacts, no PJRT, zero skips
// ---------------------------------------------------------------------------

#[test]
fn host_assign_matches_rust_reference() {
    check_assign_matches_reference(&host_engine());
}

#[test]
fn host_lrp_matches_rust_reference() {
    check_lrp_matches_reference(&host_engine(), "mlp_tiny");
}

#[test]
fn host_train_steps_are_identity_at_zero_lr() {
    check_train_steps_identity_at_zero_lr(&host_engine(), "mlp_tiny");
}

#[test]
fn host_gather_eval_matches_dense_eval() {
    check_gather_eval_matches_dense_eval(&host_engine(), "mlp_tiny");
}

#[test]
fn host_eval_actq_degrades_gracefully() {
    // the Fig. 1 probe: generous activation bit widths track the clean
    // eval, 1-bit activations do not beat it
    let eng = host_engine();
    let spec = eng.manifest.model("mlp_tiny").unwrap().clone();
    let state = ModelState::init(&spec, 3);
    let ds = GscDataset::new(spec.batch, 9, false);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let batch = dl.epoch(0).next().unwrap();
    let art = eng.manifest.artifact("mlp_tiny_eval_actq").unwrap().clone();
    let loss_at = |abits: f32| -> f32 {
        let scalars = Scalars { abits, ..Default::default() };
        let inputs =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
        eng.call_named(&art.name, &inputs).unwrap()["loss"].as_f32().as_scalar()
    };
    let clean = {
        let art_f = eng.manifest.artifact("mlp_tiny_eval").unwrap().clone();
        let inputs = bind_inputs(&art_f, &state, ParamSource::Fp, Some(&batch), &Scalars::default())
            .unwrap();
        eng.call_named(&art_f.name, &inputs).unwrap()["loss"].as_f32().as_scalar()
    };
    assert!((loss_at(16.0) - clean).abs() < 1e-3, "16-bit acts ≈ clean");
    let l1 = loss_at(1.0);
    assert!(l1.is_finite() && l1 > 0.0, "1-bit probe must stay well-formed");
    assert!(
        (l1 - clean).abs() > (loss_at(16.0) - clean).abs(),
        "the 1-bit probe must perturb the loss more than the 16-bit probe"
    );
}

/// The acceptance path: full train → LRP → assign → quantize → eval
/// pipeline end-to-end on the host backend, plus compress/reload parity —
/// with no `artifacts/` directory and no PJRT bindings present.
#[test]
fn host_full_pipeline_end_to_end() {
    let eng = host_engine();
    assert_eq!(eng.backend_name(), "host");
    let spec = eng.manifest.model("mlp_tiny").unwrap().clone();

    let train = GscDataset::new(768, 21, true);
    let val = GscDataset::new(256, 21, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 1);
    let val_dl = DataLoader::new(&val, spec.batch, false, 1);

    // phase 1: FP32 pre-training from scratch
    let mut state = ModelState::init(&spec, 21);
    let pre = Pretrainer { lr: 1e-3, verbose: false, ..Default::default() };
    let curve = pre.run(&eng, &mut state, &train_dl, 8).unwrap();
    assert!(
        curve.last().unwrap().0 < curve.first().unwrap().0,
        "pre-training must reduce the loss: {curve:?}"
    );
    let baseline = evaluate(&eng, &state, &val_dl, ParamSource::Fp).unwrap();
    assert!(
        baseline.accuracy > 2.0 / 12.0,
        "baseline acc {} not above 2x chance",
        baseline.accuracy
    );

    // phase 2: ECQ^x QAT (STE steps + periodic LRP + re-assignment)
    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 4,
            lambda: 4.0,
            p: 0.2,
            ..Default::default()
        },
        epochs: 1,
        lr: 4e-4,
        verbose: false,
        ..Default::default()
    };
    let out = QatTrainer::new(cfg).run(&eng, &mut state, &train_dl, &val_dl).unwrap();
    assert!(out.final_sparsity > 0.1, "sparsity {}", out.final_sparsity);
    assert!(out.final_sparsity < 1.0, "model must not be fully pruned");
    let quantized = evaluate(&eng, &state, &val_dl, ParamSource::Quantized).unwrap();
    assert!(
        quantized.accuracy > 1.5 / 12.0,
        "quantized acc {} collapsed",
        quantized.accuracy
    );

    // phase 3: compress → reload → verify (the deployable container)
    let path = std::env::temp_dir().join(format!(
        "ecqx-host-e2e-{}.ecqx",
        std::process::id()
    ));
    let bytes = checkpoint::save_quantized(&path, &state).unwrap();
    assert!(
        bytes < state.fp32_bytes(),
        "container {bytes} B must undercut fp32 {} B",
        state.fp32_bytes()
    );
    let qm = checkpoint::load_quantized(&path).unwrap();
    let mut reloaded = ModelState::init(&spec, 21);
    for (name, t) in qm.other {
        reloaded.params.insert(name, t);
    }
    for (name, (idx, cb)) in qm.layers {
        let qw: Vec<f32> = idx.data.iter().map(|&s| cb.values[s as usize]).collect();
        let shape = idx.shape.clone();
        reloaded.qlayers.insert(
            name,
            QLayer { qw: Tensor::new(shape, qw), idx, codebook: cb },
        );
    }
    let re = evaluate(&eng, &reloaded, &val_dl, ParamSource::Quantized).unwrap();
    assert!(
        (re.accuracy - quantized.accuracy).abs() < 1e-9,
        "reload changed accuracy: {} vs {}",
        re.accuracy,
        quantized.accuracy
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// host tier — conv workload (im2col lowering through the same engine)
// ---------------------------------------------------------------------------

/// Tiny conv ladder + dense head over an 8×8×3 input.
fn cnn_engine() -> Engine {
    let m = Manifest::synthetic_cnn("cnn_tiny", (8, 8), 3, &[(4, 2), (8, 2)], &[16, 5], 4);
    Engine::host_with(m)
}

/// Deterministic hand-rolled NHWC batch matching the tiny CNN's x slot.
fn cnn_batch(spec: &ModelSpec, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let dim = spec.input_dim;
    let x: Vec<f32> = (0..spec.batch * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..spec.batch).map(|_| rng.below(spec.classes) as i32).collect();
    Batch { x, y, batch: spec.batch }
}

#[test]
fn host_cnn_train_steps_are_identity_at_zero_lr() {
    let eng = cnn_engine();
    let spec = eng.manifest.model("cnn_tiny").unwrap().clone();
    let mut state = ModelState::init(&spec, 31);
    quantize_state(&mut state, 4, 0.0);
    let batch = cnn_batch(&spec, 7);
    let scalars = Scalars { t: 1.0, lr: 0.0, gs: 1.0, ..Default::default() };
    for art_name in ["cnn_tiny_fp_train", "cnn_tiny_ste_train"] {
        let art = eng.manifest.artifact(art_name).unwrap().clone();
        let inputs =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
        let outs = eng.call_named(&art.name, &inputs).unwrap();
        for name in state.pnames() {
            let before = &state.params[&name];
            let after = outs[&format!("p_{name}")].as_f32();
            for (a, b) in before.data.iter().zip(after.data.iter()) {
                assert_eq!(a, b, "{art_name} changed {name} at lr=0");
            }
        }
        assert!(outs["loss"].as_f32().as_scalar() > 0.0);
    }
}

#[test]
fn host_cnn_gather_eval_matches_dense_eval() {
    let eng = cnn_engine();
    let spec = eng.manifest.model("cnn_tiny").unwrap().clone();
    let mut state = ModelState::init(&spec, 33);
    quantize_state(&mut state, 4, 1e-4);
    let batch = cnn_batch(&spec, 11);
    let scalars = Scalars::default();

    let art_f = eng.manifest.artifact("cnn_tiny_eval").unwrap().clone();
    let inp_f =
        bind_inputs(&art_f, &state, ParamSource::Quantized, Some(&batch), &scalars).unwrap();
    let out_f = eng.call_named(&art_f.name, &inp_f).unwrap();

    let art_q = eng.manifest.artifact("cnn_tiny_eval_q").unwrap().clone();
    let inp_q =
        bind_inputs(&art_q, &state, ParamSource::Quantized, Some(&batch), &scalars).unwrap();
    let out_q = eng.call_named(&art_q.name, &inp_q).unwrap();

    let lf = out_f["loss"].as_f32().as_scalar();
    let lq = out_q["loss"].as_f32().as_scalar();
    assert!((lf - lq).abs() < 1e-4, "loss {lf} vs {lq}");
    assert_eq!(
        out_f["correct"].as_f32().as_scalar(),
        out_q["correct"].as_f32().as_scalar()
    );
}

#[test]
fn host_cnn_lrp_emits_finite_per_layer_relevances() {
    // the conv LRP path must emit one well-formed, nonzero relevance
    // tensor per quantized layer (shape-checked by the engine against the
    // manifest); the conservation *property* lives in tests/conv_props.rs
    let eng = cnn_engine();
    let spec = eng.manifest.model("cnn_tiny").unwrap().clone();
    let state = ModelState::init(&spec, 35);
    let batch = cnn_batch(&spec, 13);
    let art = eng.manifest.artifact("cnn_tiny_lrp").unwrap().clone();
    let scalars = Scalars { eqw: 1.0, ..Default::default() };
    let inputs = bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
    let outs = eng.call_named(&art.name, &inputs).unwrap();
    for name in ["r_c0", "r_c1", "r_w0", "r_w1"] {
        let rw = outs[name].as_f32();
        assert!(rw.data.iter().all(|v| v.is_finite()), "{name} not finite");
        assert!(rw.data.iter().any(|&v| v != 0.0), "{name} all-zero");
    }
    assert_eq!(outs["r_c0"].shape(), &[3, 3, 3, 4]);
}

/// The accept/refuse contract (`exp::ALL_MODELS`): every model name
/// `exp::model_exp` accepts must run on the host backend — one fp_train
/// step plus one eval per model against the default manifest — and
/// names outside the list must be refused. Guards against re-growing
/// "registered but hollow" models (the old `vgg_*`/`resnet_*` state).
#[test]
fn host_runs_every_model_the_experiment_registry_accepts() {
    let eng = Engine::host();
    for m in ecqx::exp::ALL_MODELS {
        assert_eq!(ecqx::exp::model_exp(m.name).unwrap().name, m.name);
        let spec = eng
            .manifest
            .model(m.name)
            .unwrap_or_else(|e| panic!("{}: accepted but not in the default manifest: {e}", m.name))
            .clone();
        // one real batch from the model's own dataset family (lazy
        // synthetic generators — constructing the full-size set is free)
        let (train, _val) = ecqx::exp::datasets(&m, 41);
        let dl = DataLoader::new(&train, spec.batch, false, 41);
        let batch = dl.epoch(0).next().unwrap();
        let state = ModelState::init(&spec, 41);

        let scalars = Scalars { t: 1.0, lr: 1e-3, gs: 1.0, ..Default::default() };
        let art = eng.manifest.artifact(&format!("{}_fp_train", m.name)).unwrap().clone();
        let inputs =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
        let outs = eng
            .call_named(&art.name, &inputs)
            .unwrap_or_else(|e| panic!("{}: fp_train refused on host: {e}", m.name));
        let loss = outs["loss"].as_f32().as_scalar();
        assert!(loss.is_finite() && loss > 0.0, "{}: fp_train loss {loss}", m.name);
        for (name, v) in &outs {
            if let Value::F32(t) = v {
                assert!(
                    t.data.iter().all(|x| x.is_finite()),
                    "{}: fp_train output {name} not finite",
                    m.name
                );
            }
        }

        let art = eng.manifest.artifact(&format!("{}_eval", m.name)).unwrap().clone();
        let inputs =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &Scalars::default())
                .unwrap();
        let outs = eng
            .call_named(&art.name, &inputs)
            .unwrap_or_else(|e| panic!("{}: eval refused on host: {e}", m.name));
        assert!(outs["loss"].as_f32().as_scalar().is_finite(), "{}: eval loss", m.name);
    }
    // the refuse half: names outside ALL_MODELS must not be accepted
    for bogus in ["mlp_tiny", "vgg", "resnet", ""] {
        assert!(ecqx::exp::model_exp(bogus).is_err(), "{bogus:?} must be refused");
    }
}

#[test]
fn host_evaluate_many_fans_out_and_matches_serial() {
    let eng = host_engine();
    let spec = eng.manifest.model("mlp_tiny").unwrap().clone();
    let mut a = ModelState::init(&spec, 1);
    let mut b = ModelState::init(&spec, 2);
    quantize_state(&mut a, 4, 1e-4);
    quantize_state(&mut b, 2, 1e-4);
    let ds = GscDataset::new(128, 7, false);
    let dl = DataLoader::new(&ds, spec.batch, false, 0);
    let serial =
        evaluate_many(&eng, &[&a, &b], &dl, ParamSource::Quantized, 1).unwrap();
    let par = evaluate_many(&eng, &[&a, &b], &dl, ParamSource::Quantized, 4).unwrap();
    assert_eq!(serial.len(), 2);
    for (s, p) in serial.iter().zip(&par) {
        assert_eq!(s.loss, p.loss, "host call_batch must be order-stable");
        assert_eq!(s.accuracy, p.accuracy);
    }
}

// ---------------------------------------------------------------------------
// PJRT tier — artifact-bound, #[ignore]-by-default (tier 2)
// ---------------------------------------------------------------------------

#[test]
#[ignore = "tier 2: needs artifacts/ and real PJRT bindings (cargo test -- --ignored)"]
fn pjrt_assign_artifact_matches_rust_reference() {
    check_assign_matches_reference(&pjrt_engine());
}

#[test]
#[ignore = "tier 2: needs artifacts/ and real PJRT bindings (cargo test -- --ignored)"]
fn pjrt_lrp_artifact_matches_rust_reference() {
    check_lrp_matches_reference(&pjrt_engine(), "mlp_gsc");
}

#[test]
#[ignore = "tier 2: needs artifacts/ and real PJRT bindings (cargo test -- --ignored)"]
fn pjrt_train_steps_are_identity_at_zero_lr() {
    check_train_steps_identity_at_zero_lr(&pjrt_engine(), "mlp_gsc");
}

#[test]
#[ignore = "tier 2: needs artifacts/ and real PJRT bindings (cargo test -- --ignored)"]
fn pjrt_gather_eval_matches_dense_eval() {
    check_gather_eval_matches_dense_eval(&pjrt_engine(), "mlp_gsc");
}

#[test]
#[ignore = "tier 2: needs artifacts/ and real PJRT bindings (cargo test -- --ignored)"]
fn pjrt_mini_qat_run_recovers() {
    let eng = pjrt_engine();
    let spec = eng.manifest.model("mlp_gsc").unwrap().clone();
    // tiny dataset + brief pretrain so the test runs in seconds
    let train = GscDataset::new(1024, 21, true);
    let val = GscDataset::new(512, 21, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 1);
    let val_dl = DataLoader::new(&val, spec.batch, false, 1);
    let mut state = ModelState::init(&spec, 21);
    let pre = Pretrainer { lr: 1e-3, verbose: false, ..Default::default() };
    pre.run(&eng, &mut state, &train_dl, 4).unwrap();

    let cfg = QatConfig {
        assign: AssignConfig {
            method: Method::Ecqx,
            bits: 4,
            lambda: 4.0,
            p: 0.2,
            ..Default::default()
        },
        epochs: 1,
        lr: 4e-4,
        verbose: false,
        ..Default::default()
    };
    let mut qstate = state;
    let out = QatTrainer::new(cfg).run(&eng, &mut qstate, &train_dl, &val_dl).unwrap();
    assert!(out.final_sparsity > 0.15, "sparsity {}", out.final_sparsity);
    assert!(
        out.epochs.last().unwrap().val_acc > 0.4,
        "val acc {}",
        out.epochs.last().unwrap().val_acc
    );
}
