//! Algorithmic invariants of the ECQ^x assignment, exercised through the
//! host-backend `assign_<bucket>` artifacts via `coordinator`-style calls
//! — mirroring `python/tests/test_assign_properties.py` so both stacks
//! pin the same semantics. Driven by the offline property harness
//! (`util::prop`), replayable by seed.

use ecqx::coordinator::{AssignConfig, Assigner, Method};
use ecqx::nn::ModelState;
use ecqx::quant::{Codebook, K_MAX};
use ecqx::runtime::{Engine, Manifest};
use ecqx::tensor::{Tensor, Value};
use ecqx::util::prop;

fn host_engine() -> Engine {
    Engine::host_with(Manifest::synthetic_mlp("m", &[16, 8, 4], 4))
}

/// One assign-artifact call exactly as the coordinator builds it: pad to
/// the bucket, execute, strip padding.
fn call_assign(
    eng: &Engine,
    w: &[f32],
    r: &[f32],
    mask: &[f32],
    cb: &Codebook,
    lam: f32,
) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    let n = w.len();
    let bucket = eng.manifest.bucket_for(n).unwrap();
    let mut wp = w.to_vec();
    wp.resize(bucket, 0.0);
    let mut rp = r.to_vec();
    rp.resize(bucket, 1.0);
    let mut mp = mask.to_vec();
    mp.resize(bucket, 0.0);
    let outs = eng
        .call(
            &format!("assign_{bucket}"),
            &[
                Value::F32(Tensor::new(vec![bucket], wp)),
                Value::F32(Tensor::new(vec![bucket], rp)),
                Value::F32(Tensor::new(vec![bucket], mp)),
                Value::F32(Tensor::new(vec![K_MAX], cb.values.clone())),
                Value::F32(Tensor::new(vec![K_MAX], cb.valid.clone())),
                Value::F32(Tensor::scalar(lam)),
            ],
        )
        .unwrap();
    (
        outs[0].as_i32().data[..n].to_vec(),
        outs[1].as_f32().data[..n].to_vec(),
        outs[2].as_f32().data.clone(),
    )
}

/// With uniform relevance and lambda = 0, every weight lands on its
/// nearest *valid* centroid.
#[test]
fn property_lambda_zero_is_nearest_neighbour() {
    let eng = host_engine();
    prop::check("assign: lam=0 is nearest neighbour", 12, |rng| {
        let bits = 2 + (rng.below(4) as u32); // 2..=5
        let n = 256 + rng.below(768);
        let w = prop::normal_vec(rng, n, 0.1);
        let cb = Codebook::fit(&w, bits);
        let ones = vec![1.0f32; n];
        let (idx, _, _) = call_assign(&eng, &w, &ones, &ones, &cb, 0.0);
        for (i, (&wi, &slot)) in w.iter().zip(idx.iter()).enumerate() {
            let mut best = 0usize;
            let mut bd = f32::INFINITY;
            for c in 0..K_MAX {
                if cb.valid[c] == 0.0 {
                    continue;
                }
                let d = (wi - cb.values[c]).powi(2);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if slot != best as i32 {
                return Err(format!(
                    "weight {i} ({wi}) -> slot {slot}, nearest is {best}"
                ));
            }
        }
        Ok(())
    });
}

/// Zero-cluster sparsity is monotone in the lambda knob (in the regime
/// where the zero cluster is the nearest-neighbour mode).
#[test]
fn property_sparsity_monotone_in_lambda() {
    let eng = host_engine();
    prop::check("assign: sparsity monotone in lambda", 8, |rng| {
        let n = 2048;
        let w = prop::normal_vec(rng, n, 0.1);
        let cb = Codebook::fit(&w, 4);
        let ones = vec![1.0f32; n];
        // skip draws where sampling noise makes another cluster the mode
        let (_, _, counts) = call_assign(&eng, &w, &ones, &ones, &cb, 0.0);
        let mode = counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if mode != 0 {
            return Ok(());
        }
        let mut last = -1.0f64;
        for lam in [0.0f32, 1e-5, 1e-4, 5e-4, 2e-3] {
            let (idx, _, _) = call_assign(&eng, &w, &ones, &ones, &cb, lam);
            let sp = idx.iter().filter(|&&i| i == 0).count() as f64 / n as f64;
            if sp + 1e-9 < last {
                return Err(format!("sparsity dropped to {sp} at lam={lam}"));
            }
            last = sp;
        }
        Ok(())
    });
}

/// Raising the uniform relevance factor can only move weights OUT of the
/// zero cluster, never into it.
#[test]
fn property_relevance_monotone() {
    let eng = host_engine();
    prop::check("assign: relevance monotone", 10, |rng| {
        let n = 512;
        let w = prop::normal_vec(rng, n, 0.1);
        let cb = Codebook::fit(&w, 4);
        let ones = vec![1.0f32; n];
        let lam = 2e-4;
        let lo_r: Vec<f32> = vec![0.3; n];
        let hi_r: Vec<f32> = vec![3.0; n];
        let (lo, _, _) = call_assign(&eng, &w, &lo_r, &ones, &cb, lam);
        let (hi, _, _) = call_assign(&eng, &w, &hi_r, &ones, &cb, lam);
        let moved_in = lo
            .iter()
            .zip(hi.iter())
            .filter(|(&l, &h)| l != 0 && h == 0)
            .count();
        if moved_in != 0 {
            return Err(format!("{moved_in} weights moved INTO zero as relevance rose"));
        }
        Ok(())
    });
}

/// Every weight maps to a valid centroid index; `qw` is exactly the
/// indexed centroid; counts reflect unmasked elements only.
#[test]
fn property_idx_valid_qw_consistent_counts_masked() {
    let eng = host_engine();
    prop::check("assign: idx valid / qw consistent / counts masked", 10, |rng| {
        let n = 1024;
        let n_valid = 700 + rng.below(300);
        let w = prop::normal_vec(rng, n, 0.1);
        let bits = 2 + (rng.below(4) as u32);
        let cb = Codebook::fit(&w, bits);
        let r: Vec<f32> = (0..n).map(|_| rng.range(0.2, 3.0)).collect();
        let mask: Vec<f32> = (0..n).map(|i| (i < n_valid) as u32 as f32).collect();
        let (idx, qw, counts) = call_assign(&eng, &w, &r, &mask, &cb, 1e-4);
        for i in 0..n {
            let slot = idx[i];
            if !(0..K_MAX as i32).contains(&slot) {
                return Err(format!("idx[{i}] = {slot} out of range"));
            }
            if cb.valid[slot as usize] == 0.0 {
                return Err(format!("idx[{i}] = {slot} is an invalid codebook slot"));
            }
            if i >= n_valid && slot != 0 {
                return Err(format!("masked element {i} left the zero cluster"));
            }
            if (qw[i] - cb.values[slot as usize] * mask[i]).abs() > 1e-7 {
                return Err(format!("qw[{i}] inconsistent with centroid {slot}"));
            }
        }
        let total: f64 = counts.iter().map(|&c| c as f64).sum();
        if (total - n_valid as f64).abs() > 1e-6 {
            return Err(format!("counts total {total} != valid {n_valid}"));
        }
        for c in 0..K_MAX {
            let expect = idx[..n_valid].iter().filter(|&&s| s == c as i32).count();
            if (counts[c] - expect as f32).abs() > 1e-6 {
                return Err(format!("counts[{c}] = {} != {expect}", counts[c]));
            }
        }
        Ok(())
    });
}

/// The coordinator's `Assigner::assign_all` over the host engine leaves
/// every quantized layer with valid centroid indices and consistent
/// dequantized weights.
#[test]
fn assigner_assign_all_yields_valid_indices() {
    let eng = host_engine();
    let spec = eng.manifest.model("m").unwrap().clone();
    for seed in [1u64, 9, 42] {
        let mut state = ModelState::init(&spec, seed);
        let asg = Assigner::new(
            AssignConfig { method: Method::Ecq, bits: 4, lambda: 2.0, ..Default::default() },
            &state,
        );
        asg.assign_all(&eng, &mut state).unwrap();
        assert_eq!(state.qlayers.len(), state.qnames().len());
        for (name, ql) in &state.qlayers {
            for (i, &slot) in ql.idx.data.iter().enumerate() {
                assert!(
                    (0..K_MAX as i32).contains(&slot)
                        && ql.codebook.valid[slot as usize] > 0.5,
                    "{name}[{i}]: invalid slot {slot}"
                );
                assert_eq!(
                    ql.qw.data[i],
                    ql.codebook.values[slot as usize],
                    "{name}[{i}]: qw inconsistent"
                );
            }
        }
    }
}
