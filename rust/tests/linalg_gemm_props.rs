//! Property suite for the blocked GEMM core: on every shape — ragged or
//! block-aligned, degenerate or not — the blocked kernels must agree with
//! the retained naive reference kernels (`linalg::reference`), and every
//! fused epilogue must equal its unfused composition.
//!
//! The comparisons use `assert_eq!` (no tolerance) and pin the
//! *deterministic tier* (`DET`: scalar micro-kernel, serial blocks): on
//! that tier the blocked core accumulates each output element over `k`
//! in the same ascending order as the naive loops with no reassociation
//! or FMA contraction, so on finite inputs the results are equal to the
//! last bit (DESIGN.md §2.6). Vector kernels are *not* bitwise-equal to
//! naive (FMA's single rounding) — they are held to the conformance
//! envelope in `tests/linalg_simd_conformance.rs` instead. The
//! gather-vs-dense and reuse tests deliberately stay on runtime dispatch:
//! both sides consume identical packed panels through the same kernel,
//! so they are bitwise-equal under *any* variant.

use ecqx::linalg::{self, reference, Epilogue, GemmOpts, Kernel, Workspace, MC, MR, NC, NR};
use ecqx::runtime::host::qdense_gather;
use ecqx::util::prop::{check, normal_vec};
use ecqx::util::Rng;

/// Deterministic tier, pinned per-call (never via the process-global
/// mode: that is set-once and would leak into sibling tests).
const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };

/// Ragged-heavy dimension pool: degenerate sizes, off-by-one around every
/// blocking constant, and a couple of comfortably large values.
fn dim(rng: &mut Rng) -> usize {
    const POOL: [usize; 16] =
        [1, 2, 3, MR - 1, MR, MR + 1, NR - 1, NR, NR + 1, 33, MC - 1, MC, MC + 1, 100, NC - 1, 70];
    POOL[rng.below(POOL.len())]
}

fn eq(label: &str, got: &[f32], want: &[f32]) -> Result<(), String> {
    if got == want {
        Ok(())
    } else {
        let i = got
            .iter()
            .zip(want)
            .position(|(a, b)| a != b)
            .unwrap_or(usize::MAX);
        Err(format!("{label}: first divergence at flat index {i}"))
    }
}

#[test]
fn blocked_nn_tn_nt_match_naive_on_random_ragged_shapes() {
    let mut ws = Workspace::new(); // shared across all cases: reuse must be inert
    check("blocked gemm ≡ naive reference", 60, |rng| {
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        let a = normal_vec(rng, m * k, 1.0);
        let b = normal_vec(rng, k * n, 1.0);
        let g = normal_vec(rng, m * n, 1.0);

        let mut nn = vec![0.0f32; m * n];
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut nn);
        eq("nn", &nn, &reference::matmul(&a, &b, m, k, n))?;

        let mut tn = vec![0.0f32; k * n];
        linalg::gemm_tn_with(DET, &mut ws, &a, &g, m, k, n, Epilogue::None, &mut tn);
        eq("tn", &tn, &reference::matmul_tn(&a, &g, m, k, n))?;

        let mut nt = vec![0.0f32; m * k];
        linalg::gemm_nt_with(DET, &mut ws, &g, &b, m, n, k, Epilogue::None, &mut nt);
        eq("nt", &nt, &reference::matmul_nt(&g, &b, m, n, k))?;
        Ok(())
    });
}

#[test]
fn degenerate_shapes_match_naive() {
    let mut ws = Workspace::new();
    // m=1 row-vector, k=1 outer-product, and empty m/n/k
    for &(m, k, n) in &[(1usize, 37, 19), (23, 1, 9), (5, 8, 1), (0, 4, 4), (4, 0, 4), (4, 4, 0)] {
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut out = vec![0.0f32; m * n];
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
        assert_eq!(out, reference::matmul(&a, &b, m, k, n), "shape {m}x{k}x{n}");
    }
}

#[test]
fn fused_epilogues_match_unfused_composition() {
    check("fused epilogue ≡ unfused passes", 40, |rng| {
        let mut ws = Workspace::new();
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        let a = normal_vec(rng, m * k, 1.0);
        let b = normal_vec(rng, k * n, 1.0);
        let bias = normal_vec(rng, n, 1.0);
        let scale = normal_vec(rng, m * n, 1.0);
        let base = reference::matmul(&a, &b, m, k, n);

        // bias
        let mut fused = vec![0.0f32; m * n];
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::Bias(&bias), &mut fused);
        let mut want = base.clone();
        for row in want.chunks_exact_mut(n) {
            for (z, &bv) in row.iter_mut().zip(&bias) {
                *z += bv;
            }
        }
        eq("bias", &fused, &want)?;

        // bias + relu
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::BiasRelu(&bias), &mut fused);
        for z in want.iter_mut() {
            if *z < 0.0 {
                *z = 0.0;
            }
        }
        eq("bias+relu", &fused, &want)?;

        // elementwise scale (the LRP w ⊙ (aᵀ@s) form, applied to NN here)
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::Scale(&scale), &mut fused);
        let want: Vec<f32> = base.iter().zip(&scale).map(|(&z, &s)| z * s).collect();
        eq("scale", &fused, &want)?;

        // relu-backward mask
        linalg::gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::ReluMask(&scale), &mut fused);
        let want: Vec<f32> =
            base.iter().zip(&scale).map(|(&z, &s)| if s > 0.0 { z } else { 0.0 }).collect();
        eq("relu-mask", &fused, &want)?;
        Ok(())
    });
}

#[test]
fn gather_gemm_matches_materialized_dense_with_clamping() {
    check("gather pack ≡ materialize + dense", 40, |rng| {
        let mut ws = Workspace::new();
        let (m, k, n) = (dim(rng), dim(rng), dim(rng));
        let a = normal_vec(rng, m * k, 1.0);
        let bias = normal_vec(rng, n, 0.5);
        let ncb = 1 + rng.below(8);
        let mut cb = normal_vec(rng, ncb, 0.5);
        cb[0] = 0.0; // the paper's codebooks always carry the zero centroid
        // ~70% zero centroid + deliberate out-of-range indices (clamp)
        let idx: Vec<i32> = (0..k * n)
            .map(|_| {
                if rng.chance(0.1) {
                    if rng.chance(0.5) { -3 } else { ncb as i32 + 5 }
                } else if rng.chance(0.7) {
                    0
                } else {
                    rng.below(ncb) as i32
                }
            })
            .collect();
        let top = (ncb - 1) as i32;
        let dense: Vec<f32> = idx.iter().map(|&i| cb[i.clamp(0, top) as usize]).collect();

        let got = qdense_gather(&a, &idx, &cb, &bias, m, k, n)
            .map_err(|e| format!("gather errored: {e}"))?;
        let mut want = vec![0.0f32; m * n];
        linalg::gemm_nn(&mut ws, &a, &dense, m, k, n, Epilogue::Bias(&bias), &mut want);
        eq("gather", &got, &want)?;
        Ok(())
    });
}

#[test]
fn workspace_reuse_across_mixed_shapes_is_inert() {
    // interleave wildly different shapes through ONE workspace and check
    // each against a fresh-workspace run: panel reuse must never leak
    let mut shared = Workspace::new();
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..10 {
        let (m, k, n) = (dim(&mut rng), dim(&mut rng), dim(&mut rng));
        let a = normal_vec(&mut rng, m * k, 1.0);
        let b = normal_vec(&mut rng, k * n, 1.0);
        let mut out_shared = vec![0.0f32; m * n];
        linalg::gemm_nn(&mut shared, &a, &b, m, k, n, Epilogue::None, &mut out_shared);
        let mut fresh = Workspace::new();
        let mut out_fresh = vec![0.0f32; m * n];
        linalg::gemm_nn(&mut fresh, &a, &b, m, k, n, Epilogue::None, &mut out_fresh);
        assert_eq!(out_shared, out_fresh, "shape {m}x{k}x{n}");
    }
}
