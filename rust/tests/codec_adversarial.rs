//! Adversarial decode tests: the totality contract of DESIGN.md §2.4
//! exercised from outside the crate. Every decoder must map hostile
//! input — absurd length claims, truncations, pure noise — to a
//! `CodecError`, never a panic, a spin, or an allocation proportional to
//! a corrupt header field. These are the CI-pinned regressions backing
//! the fuzz layer (`fuzz_fallback` explores; these assert the exact
//! cases the ISSUE names).

use ecqx::codec::bitstream::BitWriter;
use ecqx::codec::{self, deepcabac, deflate, huffman, sparse, CodecError};
use ecqx::quant::Codebook;
use ecqx::tensor::TensorI32;
use ecqx::util::prop;
use ecqx::util::Rng;

/// The ISSUE's canonical attack: a 16-byte stream claiming 2^40 symbols.
/// Every count-carrying decoder must reject it before allocating.
#[test]
fn sixteen_bytes_claiming_a_trillion_symbols() {
    // huffman: header [nsym=1, n=2^40, one table entry], 16 bytes total
    let mut w = BitWriter::new();
    w.put_exp_golomb(1); // nsym
    w.put_exp_golomb(1 << 40); // n
    w.put_exp_golomb(0); // symbol 0
    w.put_bits(1, 5); // length 1
    let mut bytes = w.finish();
    bytes.resize(16, 0);
    let err = huffman::decode(&bytes).unwrap_err();
    assert!(
        matches!(err, CodecError::LengthOverflow { field: "n", .. }),
        "huffman must bound n against the payload: {err:?}"
    );

    // rle: count field of 2^40 in a tiny stream
    let mut w = BitWriter::new();
    w.put_exp_golomb(1 << 40);
    let mut bytes = w.finish();
    bytes.resize(16, 0);
    let err = sparse::rle_decode(&bytes, 4).unwrap_err();
    assert!(matches!(err, CodecError::LengthOverflow { .. }), "{err:?}");

    // deepcabac: the count is caller-supplied; the ceiling still applies
    let err = deepcabac::decode_levels(&[0u8; 16], 1 << 40).unwrap_err();
    assert!(matches!(err, CodecError::LengthOverflow { .. }), "{err:?}");

    // container: a 16-byte payload under a 2^40-element shape
    let enc = codec::EncodedTensor {
        shape: vec![1 << 40],
        step: 0.02,
        bits: 4,
        payload: vec![0u8; 16],
    };
    let err = codec::decode_tensor(&enc).unwrap_err();
    assert!(matches!(err, CodecError::LengthOverflow { .. }), "{err:?}");
}

#[test]
fn huffman_bounds_table_size_against_payload() {
    // nsym beyond what the remaining bits could encode (>= 6 bits/entry)
    let mut w = BitWriter::new();
    w.put_exp_golomb(1 << 40);
    let mut bytes = w.finish();
    bytes.resize(16, 0);
    let err = huffman::decode(&bytes).unwrap_err();
    assert!(
        matches!(err, CodecError::LengthOverflow { field: "nsym", .. }),
        "{err:?}"
    );
}

fn valid_streams(seed: u64) -> (Vec<i32>, Vec<u8>, Vec<u8>, Vec<u8>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let levels: Vec<i32> = (0..600)
        .map(|_| {
            if rng.chance(0.8) {
                0
            } else {
                let m = 1 + rng.below(7) as i32;
                if rng.chance(0.5) { m } else { -m }
            }
        })
        .collect();
    let huff = huffman::encode(&levels).unwrap();
    let cab = deepcabac::encode_levels(&levels);
    let rle = sparse::rle_encode(&levels, 4);
    let bytes_i8: Vec<u8> = levels.iter().map(|&l| l as i8 as u8).collect();
    let defl = deflate::compress(&bytes_i8);
    (levels, huff, cab, rle, defl)
}

#[test]
fn truncation_sweep_every_decoder() {
    // every prefix of a valid stream decodes totally (Ok or Err, no
    // panic); prefixes cut inside required payload must not Ok-decode to
    // the full original
    let (levels, huff, cab, rle, defl) = valid_streams(41);
    for cut in 0..huff.len() {
        if let Ok(out) = huffman::decode(&huff[..cut]) {
            assert_ne!(out, levels, "truncated huffman stream decoded to the original");
        }
    }
    for cut in 0..cab.len() {
        // cabac zero-extends by design; totality is the contract here
        let _ = deepcabac::decode_levels(&cab[..cut], levels.len());
    }
    for cut in 0..rle.len() {
        if let Ok(out) = sparse::rle_decode(&rle[..cut], 4) {
            assert_ne!(out, levels, "truncated rle stream decoded to the original");
        }
    }
    for cut in 0..defl.len() {
        assert!(
            deflate::decompress(&defl[..cut]).is_err(),
            "deflate truncated at {cut} must fail (checksum/EOF)"
        );
    }
}

#[test]
fn random_buffers_every_decoder() {
    prop::check("random buffers decode totally", 40, |rng| {
        let n = rng.below(300);
        let buf: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = huffman::decode(&buf);
        let _ = deepcabac::decode_levels(&buf, rng.below(4096));
        let _ = sparse::rle_decode(&buf, 1 + rng.below(16) as u32);
        let _ = deflate::decompress(&buf);
        let enc = codec::EncodedTensor {
            shape: vec![rng.below(65536)],
            step: 0.02,
            bits: 1 + rng.below(16) as u32,
            payload: buf,
        };
        let _ = codec::decode_tensor(&enc);
        Ok(())
    });
}

#[test]
fn container_rejects_corrupt_chunk_framing() {
    let mut rng = Rng::new(7);
    let cb = Codebook::symmetric(4, 0.02);
    let nvalid = cb.n_valid();
    let idx = TensorI32::new(
        vec![codec::CHUNK_LEVELS + 100],
        (0..codec::CHUNK_LEVELS + 100)
            .map(|_| {
                if rng.chance(0.9) {
                    0
                } else {
                    rng.below(nvalid) as i32
                }
            })
            .collect(),
    );
    let good = codec::encode_tensor(&idx, &cb);
    assert_eq!(codec::decode_tensor(&good).unwrap().data, idx.data);

    // second chunk's length field stomped to overshoot the payload
    let first_clen = u32::from_le_bytes(good.payload[0..4].try_into().unwrap()) as usize;
    let second_hdr = 4 + first_clen;
    let mut bad = good.clone();
    bad.payload[second_hdr..second_hdr + 4].copy_from_slice(&(u32::MAX / 2).to_le_bytes());
    assert!(matches!(
        codec::decode_tensor(&bad),
        Err(CodecError::LengthOverflow { field: "chunk byte length", .. })
    ));

    // payload truncated mid-chunk
    let mut bad = good.clone();
    bad.payload.truncate(second_hdr + 2);
    assert!(codec::decode_tensor(&bad).is_err());

    // shape shrunk below the payload's chunk count -> trailing bytes
    let mut bad = good;
    bad.shape = vec![100];
    assert!(codec::decode_tensor(&bad).is_err());
}

#[test]
fn zero_extended_tails_terminate() {
    // the release-mode hang regression: CABAC streams followed by (or
    // consisting of) zeros drive decode_bypass to return `false` forever;
    // the bounded exp-golomb prefix must turn that into an error
    let _ = deepcabac::decode_levels(&[0xFF; 4], 1000); // termination is the assertion
    let mut cab = deepcabac::encode_levels(&[1000000, -1000000]);
    cab.extend_from_slice(&[0u8; 64]);
    let _ = deepcabac::decode_levels(&cab, 4096); // must return, not spin
}
