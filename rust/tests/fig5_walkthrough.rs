//! Figure 5 walkthrough: one ECQ^x iteration on the paper's toy example —
//! a 4x4 weight grid, 3 centroids (symmetric 2 bit), entropy costs and
//! rho-scaled relevances determining the assignment.
//!
//! This test replays the *mechanics* of the figure: a weight is sent to
//! the zero cluster because it is irrelevant (grid cell D2 in the paper),
//! another because of the entropy constraint (C3), while a small but
//! relevant weight is re-added (regrowth).

use ecqx::quant::{assign_ref, assignment_entropy, Codebook};

#[test]
fn fig5_toy_iteration() {
    // 16 weights roughly matching the figure's magnitudes; centroid step
    // ~1.36 like the figure's w+ = 1.36.
    let step = 1.36f32;
    let cb = Codebook::symmetric(2, step); // centroids {0, +1.36, -1.36}
    #[rustfmt::skip]
    let w = [
        1.30f32, -0.12,  0.05,  1.10,
       -1.28,    0.70, -0.68,  0.02,
        0.64,   -1.50,  0.08, -0.60,
        0.55,    0.01, -1.45,  0.66,
    ];
    let ones = [1.0f32; 16];
    let mask = [1.0f32; 16];

    // (a) Plain nearest neighbour (lambda = 0): |w| < 0.68 goes to zero.
    let nn = assign_ref(&w, &ones, &mask, &cb, 0.0);
    assert_eq!(nn.idx[0], 1); // 1.30 -> +
    assert_eq!(nn.idx[4], 2); // -1.28 -> -
    assert_eq!(nn.idx[2], 0); // 0.05 -> 0
    let nn_sparsity = nn.sparsity(16);

    // (b) Entropy constraint pulls borderline weights (|w| ~ 0.7) into the
    // popular zero cluster — the C3 mechanism.
    let lam = 0.8;
    let ecq = assign_ref(&w, &ones, &mask, &cb, lam);
    assert!(ecq.sparsity(16) > nn_sparsity, "entropy must add sparsity");
    // 0.70 was nearest to + but flips to zero under the constraint
    let i070 = 5;
    assert_eq!(nn.idx[i070], 1);
    assert_eq!(ecq.idx[i070], 0);

    // (c) Relevances: protect the relevant 0.70 (factor >> 1), prune an
    // irrelevant 1.10 (factor ~ 0) — the D2 mechanism.
    let mut rel = [1.0f32; 16];
    rel[i070] = 8.0; // highly relevant -> regrowth
    rel[3] = 0.02; // irrelevant despite |w| = 1.10
    let ecqx = assign_ref(&w, &rel, &mask, &cb, lam);
    assert_eq!(ecqx.idx[i070], 1, "relevant weight must be re-added");
    assert_eq!(ecqx.idx[3], 0, "irrelevant weight must be pruned");

    // (d) Entropy of the rendered assignment stays below log2(3): the
    // low-rate representation the Lagrange term optimizes for.
    let h = assignment_entropy(&ecqx.counts);
    assert!(h < 1.585, "entropy {h} must be below log2(3)");
    assert!(h > 0.0);

    // (e) The assignment is exactly reproducible (Fig. 5 is deterministic).
    let again = assign_ref(&w, &rel, &mask, &cb, lam);
    assert_eq!(again.idx, ecqx.idx);
}

#[test]
fn fig5_candidate_grid() {
    // Step 7: different (lambda, rho-intensity) settings render different
    // assignment candidates — the candidate grid at the top left of Fig. 5.
    let mut rng = ecqx::util::Rng::new(55);
    let w: Vec<f32> = (0..256).map(|_| rng.normal_f32(0.0, 0.6)).collect();
    let cb = Codebook::fit(&w, 2);
    let mask = vec![1.0f32; 256];
    let mut candidates = std::collections::BTreeSet::new();
    for lam in [0.0f32, 0.2, 0.8] {
        for rel_strength in [0.5f32, 1.0, 2.0] {
            let r: Vec<f32> = w
                .iter()
                .enumerate()
                .map(|(i, _)| if i % 3 == 0 { rel_strength } else { 1.0 })
                .collect();
            let a = assign_ref(&w, &r, &mask, &cb, lam);
            candidates.insert(
                a.idx.iter().map(|&i| i as u8).collect::<Vec<u8>>(),
            );
        }
    }
    assert!(
        candidates.len() >= 4,
        "expected a diverse candidate grid, got {}",
        candidates.len()
    );
}
