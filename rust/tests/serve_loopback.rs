//! Integration tests for `ecqx serve` (DESIGN.md §2.7): a real loopback
//! HTTP server over the host backend, driven concurrently from scratch
//! with `std::net` clients.
//!
//! The load-bearing assertion is **batch-order independence**: concurrent
//! requests for the same working point — whatever mix of other requests
//! shares their microbatch — must return byte-identical bodies, and those
//! bodies must embed the exact CSV row the offline sweep path
//! (`SweepRunner::run_trial_spec`) produces for that point. The server
//! additionally self-checks purity per request (batched accuracy ==
//! build-time accuracy ⇒ anything else is a 500), so a 200 here is
//! already a strong claim.

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::campaign::TrialSpec;
use ecqx::coordinator::serve::{http_get, run_bench, ServeOptions, Server};
use ecqx::coordinator::sweep::{SweepConfig, SweepRunner};
use ecqx::coordinator::trainer::{evaluate, Pretrainer};
use ecqx::coordinator::{AssignConfig, Method, QatConfig};
use ecqx::data::gsc::GscDataset;
use ecqx::data::DataLoader;
use ecqx::nn::ModelState;
use ecqx::runtime::{Engine, Manifest};

fn tiny_cfg() -> SweepConfig {
    SweepConfig {
        model: "mlp_tiny".into(),
        method: Method::Ecqx,
        bits: 4,
        lambdas: vec![0.0, 0.5],
        p: 0.3,
        qat: QatConfig {
            assign: AssignConfig::default(),
            epochs: 1,
            lr: 4e-4,
            lrp_warmup: 4,
            verbose: false,
            ..Default::default()
        },
        baseline_acc: 0.0,
        seed: 17,
    }
}

/// Routing + shutdown protocol, without ever building a model: bind on an
/// ephemeral port, check /healthz and 404, then /shutdown must both
/// answer 200 and make `run()` return.
#[test]
fn routes_health_unknown_and_shutdown() {
    let engine = Engine::host_with(Manifest::synthetic_mlp("mlp_tiny", &[360, 32, 12], 32));
    let spec = engine.manifest.model("mlp_tiny").unwrap().clone();
    let train = GscDataset::new(64, 5, true);
    let val = GscDataset::new(32, 5, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 5);
    let val_dl = DataLoader::new(&val, spec.batch, false, 5);
    let runner = SweepRunner::new(&engine, ModelState::init(&spec, 5));
    let opts = ServeOptions { port: 0, jobs: 1, max_batch: 2, verbose: false };
    let server = Server::bind(&runner, tiny_cfg(), &train_dl, &val_dl, opts).unwrap();
    let addr = server.local_addr();
    assert_eq!(addr.ip().to_string(), "127.0.0.1");
    assert_ne!(addr.port(), 0, "--port=0 must resolve to a real ephemeral port");

    std::thread::scope(|scope| {
        let srv = scope.spawn(|| server.run());
        let (code, body) = http_get(addr, "/healthz").unwrap();
        assert_eq!((code, body.as_str()), (200, "ok\n"));
        let (code, _) = http_get(addr, "/no/such/route").unwrap();
        assert_eq!(code, 404);
        // bad query parameters are a clean 500, not a hang or a panic
        let (code, body) = http_get(addr, "/eval?bits=four").unwrap();
        assert_eq!(code, 500, "{body}");
        let (code, body) = http_get(addr, "/eval?method=madeup").unwrap();
        assert_eq!(code, 500, "{body}");
        let (code, body) = http_get(addr, "/shutdown").unwrap();
        assert_eq!((code, body.as_str()), (200, "shutting down\n"));
        srv.join().expect("server thread panicked").unwrap();
    });
}

/// `run_bench` degenerate inputs: zero requests per client must be a
/// clean error (not a percentile over an empty latency vector), while a
/// small real run returns a coherent summary.
#[test]
fn bench_rejects_zero_requests_and_summarizes_real_ones() {
    let engine = Engine::host_with(Manifest::synthetic_mlp("mlp_tiny", &[360, 32, 12], 32));
    let spec = engine.manifest.model("mlp_tiny").unwrap().clone();
    let train = GscDataset::new(64, 5, true);
    let val = GscDataset::new(32, 5, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 5);
    let val_dl = DataLoader::new(&val, spec.batch, false, 5);
    let runner = SweepRunner::new(&engine, ModelState::init(&spec, 5));
    let opts = ServeOptions { port: 0, jobs: 1, max_batch: 2, verbose: false };
    let server = Server::bind(&runner, tiny_cfg(), &train_dl, &val_dl, opts).unwrap();
    let addr = server.local_addr();

    std::thread::scope(|scope| {
        let srv = scope.spawn(|| server.run());
        let err = run_bench(addr, "/healthz", 2, 0).unwrap_err();
        assert!(
            format!("{err:?}").contains("zero requests"),
            "want the empty-bench guard, got {err:?}"
        );
        let summary = run_bench(addr, "/healthz", 2, 3).unwrap();
        assert_eq!((summary.clients, summary.requests), (2, 6));
        assert!(summary.p50_s.is_finite() && summary.p99_s >= summary.p50_s);
        assert!(summary.req_s > 0.0);
        let (code, _) = http_get(addr, "/shutdown").unwrap();
        assert_eq!(code, 200);
        srv.join().expect("server thread panicked").unwrap();
    });
}

/// The end-to-end gate: concurrent /eval requests across two working
/// points, batched together by the server, must (a) all succeed, (b) be
/// byte-identical per point, and (c) carry the exact sweep CSV row for
/// their point.
#[test]
fn concurrent_eval_matches_offline_sweep_rows() {
    let engine = Engine::host_with(Manifest::synthetic_mlp("mlp_tiny", &[360, 32, 12], 32));
    let spec = engine.manifest.model("mlp_tiny").unwrap().clone();
    let train = GscDataset::new(256, 5, true);
    let val = GscDataset::new(128, 5, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 5);
    let val_dl = DataLoader::new(&val, spec.batch, false, 5);

    let mut state = ModelState::init(&spec, 5);
    let pre = Pretrainer { lr: 1e-3, verbose: false, ..Default::default() };
    pre.run(&engine, &mut state, &train_dl, 2).unwrap();
    let baseline = evaluate(&engine, &state, &val_dl, ParamSource::Fp).unwrap();

    let runner = SweepRunner::new(&engine, state);
    let mut cfg = tiny_cfg();
    cfg.baseline_acc = baseline.accuracy;

    // offline oracle rows through the exact function sweep trials run
    let oracle = |lambda: f32| {
        let trial = TrialSpec { id: 0, method: Method::Ecqx, bits: 4, lambda, p: 0.3 };
        let (wp, _) = runner.run_trial_spec(&cfg, &trial, &train_dl, &val_dl).unwrap();
        wp.to_csv()
    };
    let (row_a, row_b) = (oracle(0.5), oracle(0.0));

    let opts = ServeOptions { port: 0, jobs: 2, max_batch: 4, verbose: false };
    let server = Server::bind(&runner, cfg.clone(), &train_dl, &val_dl, opts).unwrap();
    let addr = server.local_addr();

    std::thread::scope(|scope| {
        let srv = scope.spawn(|| server.run());
        // 3 clients on point A + 2 on point B fire at once, so the
        // batcher mixes the two points (and repeat requests) freely
        let paths = [
            "/eval?lambda=0.5",
            "/eval?lambda=0.5",
            "/eval?method=ecqx&bits=4&lambda=0.5&p=0.3",
            "/eval?lambda=0",
            "/eval?lambda=0",
        ];
        let handles: Vec<_> = paths
            .iter()
            .map(|p| scope.spawn(move || http_get(addr, p).unwrap()))
            .collect();
        let bodies: Vec<(u16, String)> =
            handles.into_iter().map(|h| h.join().expect("client panicked")).collect();
        for (code, body) in &bodies {
            assert_eq!(*code, 200, "{body}");
        }
        // batch-order independence: same point -> byte-identical body,
        // however the microbatches happened to be composed
        assert_eq!(bodies[0].1, bodies[1].1);
        assert_eq!(bodies[0].1, bodies[2].1, "explicit params must hit the same cache key");
        assert_eq!(bodies[3].1, bodies[4].1);
        assert_ne!(bodies[0].1, bodies[3].1, "distinct points must differ");
        // served rows are byte-equal to the offline sweep rows
        assert!(bodies[0].1.contains(&row_a), "served {} missing row {row_a}", bodies[0].1);
        assert!(bodies[3].1.contains(&row_b), "served {} missing row {row_b}", bodies[3].1);

        // a second wave hits the warm cache and must reproduce wave one
        let (code, body) = http_get(addr, "/eval?lambda=0.5").unwrap();
        assert_eq!((code, body), (200, bodies[0].1.clone()));

        let (code, _) = http_get(addr, "/shutdown").unwrap();
        assert_eq!(code, 200);
        srv.join().expect("server thread panicked").unwrap();
    });
}
