//! Determinism of the parallel campaign runner: the same grid must yield
//! bitwise-identical `WorkingPoint` rows at any `--jobs` count, with
//! every trial reported through the event stream and bounded in-flight
//! concurrency respected. Trials here are synthetic (pure functions of
//! the per-trial seed), so the suite runs without artifacts or a PJRT
//! backend — the engine-level concurrency smoke tests live in
//! `src/runtime/mod.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use ecqx::coordinator::campaign::{self, CampaignOptions, Event, Grid, TrialSpec};
use ecqx::coordinator::Method;
use ecqx::metrics::WorkingPoint;
use ecqx::util::Rng;

/// A synthetic trial: derives every field from the deterministic per-trial
/// seed, and sleeps a trial-dependent amount so finish order scrambles
/// under parallelism.
fn synthetic_trial(t: &TrialSpec, seed: u64) -> anyhow::Result<WorkingPoint> {
    std::thread::sleep(std::time::Duration::from_millis((t.id as u64 * 7) % 5));
    let mut rng = Rng::new(seed);
    Ok(WorkingPoint {
        method: t.method.as_str().to_string(),
        bits: t.bits,
        lambda: t.lambda,
        p: t.p,
        accuracy: rng.f64(),
        acc_drop: rng.f64() - 0.5,
        sparsity: rng.f64(),
        size_bytes: (rng.next_u64() % 100_000) as usize,
        compression_ratio: 1.0 + rng.f64() * 50.0,
    })
}

fn test_grid() -> Vec<TrialSpec> {
    Grid {
        methods: vec![Method::Ecq, Method::Ecqx],
        bits: vec![2, 4],
        ps: vec![0.15, 0.3],
        lambdas: vec![0.0, 0.02, 0.08],
    }
    .trials()
}

#[test]
fn parallel_rows_match_serial_bitwise() {
    let trials = test_grid();
    assert_eq!(trials.len(), 24);
    let serial = campaign::run(
        &trials,
        &CampaignOptions { jobs: 1, ..Default::default() },
        synthetic_trial,
        |_| {},
    )
    .unwrap();
    assert_eq!(serial.len(), trials.len());
    for jobs in [2, 4, 8] {
        let par = campaign::run(
            &trials,
            &CampaignOptions { jobs, ..Default::default() },
            synthetic_trial,
            |_| {},
        )
        .unwrap();
        let a: Vec<String> = serial.iter().map(|p| p.to_csv()).collect();
        let b: Vec<String> = par.iter().map(|p| p.to_csv()).collect();
        assert_eq!(a, b, "rows must be bitwise identical at jobs={jobs}");
    }
}

#[test]
fn campaign_seed_changes_rows() {
    let trials = test_grid();
    let a = campaign::run(
        &trials,
        &CampaignOptions { jobs: 4, seed: 17, ..Default::default() },
        synthetic_trial,
        |_| {},
    )
    .unwrap();
    let b = campaign::run(
        &trials,
        &CampaignOptions { jobs: 4, seed: 18, ..Default::default() },
        synthetic_trial,
        |_| {},
    )
    .unwrap();
    assert_ne!(
        a.iter().map(|p| p.to_csv()).collect::<Vec<_>>(),
        b.iter().map(|p| p.to_csv()).collect::<Vec<_>>()
    );
}

#[test]
fn events_stream_every_trial() {
    let trials = test_grid();
    let events = Mutex::new(Vec::new());
    campaign::run(
        &trials,
        &CampaignOptions { jobs: 4, ..Default::default() },
        synthetic_trial,
        |ev| events.lock().unwrap().push(ev.clone()),
    )
    .unwrap();
    let events = events.into_inner().unwrap();
    let started: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            Event::Started { id } => Some(*id),
            _ => None,
        })
        .collect();
    let mut finished: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            Event::Finished { id, wall_s, .. } => {
                assert!(*wall_s >= 0.0);
                Some(*id)
            }
            _ => None,
        })
        .collect();
    assert_eq!(started.len(), trials.len());
    finished.sort_unstable();
    assert_eq!(finished, (0..trials.len()).collect::<Vec<_>>());
}

#[test]
fn bounded_in_flight_is_respected() {
    let trials = test_grid();
    let inflight = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    campaign::run(
        &trials,
        &CampaignOptions { jobs: 8, max_in_flight: 2, ..Default::default() },
        |t, seed| {
            let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            let r = synthetic_trial(t, seed);
            inflight.fetch_sub(1, Ordering::SeqCst);
            r
        },
        |_| {},
    )
    .unwrap();
    let peak = peak.load(Ordering::SeqCst);
    assert!(peak <= 2, "in-flight bound violated: peak={peak}");
}

#[test]
fn failure_stops_new_claims() {
    let trials = test_grid();
    let ran = AtomicUsize::new(0);
    campaign::run(
        &trials,
        &CampaignOptions { jobs: 1, ..Default::default() },
        |t, seed| {
            ran.fetch_add(1, Ordering::SeqCst);
            if t.id == 3 {
                anyhow::bail!("boom");
            }
            synthetic_trial(t, seed)
        },
        |_| {},
    )
    .unwrap_err();
    // fail-fast: trials 0..=3 ran, the remaining 20 were never claimed
    assert_eq!(ran.load(Ordering::SeqCst), 4);
}

#[test]
fn failures_surface_deterministically() {
    let trials = test_grid();
    for jobs in [1, 8] {
        let err = campaign::run(
            &trials,
            &CampaignOptions { jobs, ..Default::default() },
            |t, seed| {
                if t.id == 5 || t.id == 11 {
                    anyhow::bail!("injected failure in trial {}", t.id);
                }
                synthetic_trial(t, seed)
            },
            |_| {},
        )
        .unwrap_err();
        let msg = format!("{err:?}");
        // the lowest-position failure wins regardless of completion order
        assert!(msg.contains("campaign trial 5"), "jobs={jobs}: {msg}");
        assert!(msg.contains("injected failure in trial 5"), "jobs={jobs}: {msg}");
    }
}
