//! Determinism of the parallel campaign runner: the same grid must yield
//! bitwise-identical `WorkingPoint` rows at any `--jobs` count, with
//! every trial reported through the event stream and bounded in-flight
//! concurrency respected.
//!
//! Two trial flavours run without artifacts or a PJRT backend: synthetic
//! trials (pure functions of the per-trial seed) pin the orchestrator's
//! invariants in isolation, and real QAT trials executed on the host
//! reference backend pin the whole engine-backed path end to end. The
//! engine-level concurrency smoke tests live in `src/runtime/mod.rs`.
//!
//! The robustness layer is pinned here too: panic quarantine, seeded
//! retries, cooperative cancellation, heartbeats, and the durable-store
//! gate — interrupt+resume and shard-union campaigns must reproduce the
//! exact row bytes of one uninterrupted serial run.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::campaign::{
    self, CampaignOptions, Event, Grid, RetryPolicy, TrialResult, TrialSpec,
};
use ecqx::coordinator::store::{self, ResultStore};
use ecqx::coordinator::sweep::{StoreSweepOptions, SweepConfig, SweepRunner};
use ecqx::coordinator::trainer::{evaluate, Pretrainer};
use ecqx::coordinator::{AssignConfig, Method, QatConfig};
use ecqx::data::gsc::GscDataset;
use ecqx::data::images::CifarDataset;
use ecqx::data::DataLoader;
use ecqx::metrics::WorkingPoint;
use ecqx::nn::ModelState;
use ecqx::runtime::{Engine, Manifest};
use ecqx::util::Rng;

/// A synthetic trial: derives every field from the deterministic per-trial
/// seed, and sleeps a trial-dependent amount so finish order scrambles
/// under parallelism.
fn synthetic_trial(t: &TrialSpec, seed: u64) -> anyhow::Result<WorkingPoint> {
    std::thread::sleep(std::time::Duration::from_millis((t.id as u64 * 7) % 5));
    let mut rng = Rng::new(seed);
    Ok(WorkingPoint {
        method: t.method.as_str().to_string(),
        bits: t.bits,
        lambda: t.lambda,
        p: t.p,
        accuracy: rng.f64(),
        acc_drop: rng.f64() - 0.5,
        sparsity: rng.f64(),
        size_bytes: (rng.next_u64() % 100_000) as usize,
        compression_ratio: 1.0 + rng.f64() * 50.0,
    })
}

fn test_grid() -> Vec<TrialSpec> {
    Grid {
        methods: vec![Method::Ecq, Method::Ecqx],
        bits: vec![2, 4],
        ps: vec![0.15, 0.3],
        lambdas: vec![0.0, 0.02, 0.08],
    }
    .trials()
}

#[test]
fn parallel_rows_match_serial_bitwise() {
    let trials = test_grid();
    assert_eq!(trials.len(), 24);
    let serial = campaign::run(
        &trials,
        &CampaignOptions { jobs: 1, ..Default::default() },
        synthetic_trial,
        |_| {},
    )
    .unwrap();
    assert_eq!(serial.len(), trials.len());
    for jobs in [2, 4, 8] {
        let par = campaign::run(
            &trials,
            &CampaignOptions { jobs, ..Default::default() },
            synthetic_trial,
            |_| {},
        )
        .unwrap();
        let a: Vec<String> = serial.iter().map(|p| p.to_csv()).collect();
        let b: Vec<String> = par.iter().map(|p| p.to_csv()).collect();
        assert_eq!(a, b, "rows must be bitwise identical at jobs={jobs}");
    }
}

#[test]
fn campaign_seed_changes_rows() {
    let trials = test_grid();
    let a = campaign::run(
        &trials,
        &CampaignOptions { jobs: 4, seed: 17, ..Default::default() },
        synthetic_trial,
        |_| {},
    )
    .unwrap();
    let b = campaign::run(
        &trials,
        &CampaignOptions { jobs: 4, seed: 18, ..Default::default() },
        synthetic_trial,
        |_| {},
    )
    .unwrap();
    assert_ne!(
        a.iter().map(|p| p.to_csv()).collect::<Vec<_>>(),
        b.iter().map(|p| p.to_csv()).collect::<Vec<_>>()
    );
}

#[test]
fn events_stream_every_trial() {
    let trials = test_grid();
    let events = Mutex::new(Vec::new());
    campaign::run(
        &trials,
        &CampaignOptions { jobs: 4, ..Default::default() },
        synthetic_trial,
        |ev| events.lock().unwrap().push(ev.clone()),
    )
    .unwrap();
    let events = events.into_inner().unwrap();
    let started: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            Event::Started { id } => Some(*id),
            _ => None,
        })
        .collect();
    let mut finished: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            Event::Finished { id, wall_s, .. } => {
                assert!(*wall_s >= 0.0);
                Some(*id)
            }
            _ => None,
        })
        .collect();
    assert_eq!(started.len(), trials.len());
    finished.sort_unstable();
    assert_eq!(finished, (0..trials.len()).collect::<Vec<_>>());
}

#[test]
fn bounded_in_flight_is_respected() {
    let trials = test_grid();
    let inflight = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    campaign::run(
        &trials,
        &CampaignOptions { jobs: 8, max_in_flight: 2, ..Default::default() },
        |t, seed| {
            let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            let r = synthetic_trial(t, seed);
            inflight.fetch_sub(1, Ordering::SeqCst);
            r
        },
        |_| {},
    )
    .unwrap();
    let peak = peak.load(Ordering::SeqCst);
    assert!(peak <= 2, "in-flight bound violated: peak={peak}");
}

#[test]
fn failure_stops_new_claims() {
    let trials = test_grid();
    let ran = AtomicUsize::new(0);
    campaign::run(
        &trials,
        &CampaignOptions { jobs: 1, ..Default::default() },
        |t, seed| {
            ran.fetch_add(1, Ordering::SeqCst);
            if t.id == 3 {
                anyhow::bail!("boom");
            }
            synthetic_trial(t, seed)
        },
        |_| {},
    )
    .unwrap_err();
    // fail-fast: trials 0..=3 ran, the remaining 20 were never claimed
    assert_eq!(ran.load(Ordering::SeqCst), 4);
}

/// Serial-vs-parallel determinism with *real* (host-executed) trial
/// results: a lambda sweep of engine-backed QAT runs on the host
/// reference backend must produce bitwise-identical rows at any job
/// count — the ISSUE-3 acceptance gate for real trial payloads.
#[test]
fn host_backend_trials_match_serial_bitwise() {
    let engine = Engine::host_with(Manifest::synthetic_mlp("mlp_tiny", &[360, 32, 12], 32));
    let spec = engine.manifest.model("mlp_tiny").unwrap().clone();
    let train = GscDataset::new(256, 5, true);
    let val = GscDataset::new(128, 5, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 5);
    let val_dl = DataLoader::new(&val, spec.batch, false, 5);

    // brief pre-training so the trials quantize a non-degenerate model
    let mut state = ModelState::init(&spec, 5);
    let pre = Pretrainer { lr: 1e-3, verbose: false, ..Default::default() };
    pre.run(&engine, &mut state, &train_dl, 2).unwrap();
    let baseline = evaluate(&engine, &state, &val_dl, ParamSource::Fp).unwrap();

    let runner = SweepRunner::new(&engine, state);
    let cfg = SweepConfig {
        model: "mlp_tiny".into(),
        method: Method::Ecqx,
        bits: 4,
        lambdas: vec![0.0, 0.5, 4.0],
        p: 0.3,
        qat: QatConfig {
            assign: AssignConfig::default(),
            epochs: 1,
            lr: 4e-4,
            lrp_warmup: 4,
            verbose: false,
            ..Default::default()
        },
        baseline_acc: baseline.accuracy,
        seed: 17,
    };
    let serial = runner.run_parallel(&cfg, &train_dl, &val_dl, 1).unwrap();
    assert_eq!(serial.len(), 3);
    for wp in &serial {
        // real host-executed results, not placeholders
        assert!((0.0..=1.0).contains(&wp.accuracy), "{wp:?}");
        assert!(wp.size_bytes > 0 && wp.compression_ratio > 1.0, "{wp:?}");
    }
    assert!(serial.iter().all(|wp| (0.0..1.0).contains(&wp.sparsity)));
    for jobs in [2, 4] {
        let par = runner.run_parallel(&cfg, &train_dl, &val_dl, jobs).unwrap();
        let a: Vec<String> = serial.iter().map(|p| p.to_csv()).collect();
        let b: Vec<String> = par.iter().map(|p| p.to_csv()).collect();
        assert_eq!(a, b, "host rows must be bitwise identical at jobs={jobs}");
    }
}

/// The CNN twin of the host-trial determinism gate: a lambda sweep of
/// engine-backed QAT runs over the conv workload (im2col forward, col2im
/// backward, conv LRP, conv weight assignment) must produce
/// bitwise-identical rows at any job count. This is what licenses
/// `sweep --model cnn --jobs N` — conv results are pure functions of the
/// operand values (ascending-order accumulation, fixed col2im tiling), so
/// worker scheduling cannot leak into them.
#[test]
fn cnn_host_backend_trials_match_serial_bitwise() {
    let engine = Engine::host_with(Manifest::synthetic_cnn(
        "cnn_tiny",
        (32, 32),
        3,
        &[(4, 2), (8, 2)],
        &[32, 10],
        16,
    ));
    let spec = engine.manifest.model("cnn_tiny").unwrap().clone();
    let train = CifarDataset::new(64, 9, true);
    let val = CifarDataset::new(32, 9, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 9);
    let val_dl = DataLoader::new(&val, spec.batch, false, 9);

    // brief pre-training so the trials quantize a non-degenerate model
    let mut state = ModelState::init(&spec, 9);
    let pre = Pretrainer { lr: 1e-3, verbose: false, ..Default::default() };
    pre.run(&engine, &mut state, &train_dl, 1).unwrap();
    let baseline = evaluate(&engine, &state, &val_dl, ParamSource::Fp).unwrap();

    let runner = SweepRunner::new(&engine, state);
    let cfg = SweepConfig {
        model: "cnn_tiny".into(),
        method: Method::Ecqx,
        bits: 4,
        lambdas: vec![0.0, 4.0],
        p: 0.2,
        qat: QatConfig {
            assign: AssignConfig::default(),
            epochs: 1,
            lr: 4e-4,
            lrp_warmup: 2,
            verbose: false,
            ..Default::default()
        },
        baseline_acc: baseline.accuracy,
        seed: 23,
    };
    let serial = runner.run_parallel(&cfg, &train_dl, &val_dl, 1).unwrap();
    assert_eq!(serial.len(), 2);
    for wp in &serial {
        // real host-executed conv results, not placeholders
        assert!((0.0..=1.0).contains(&wp.accuracy), "{wp:?}");
        assert!(wp.size_bytes > 0 && wp.compression_ratio > 1.0, "{wp:?}");
        assert!((0.0..1.0).contains(&wp.sparsity), "{wp:?}");
    }
    for jobs in [2, 4] {
        let par = runner.run_parallel(&cfg, &train_dl, &val_dl, jobs).unwrap();
        let a: Vec<String> = serial.iter().map(|p| p.to_csv()).collect();
        let b: Vec<String> = par.iter().map(|p| p.to_csv()).collect();
        assert_eq!(a, b, "CNN host rows must be bitwise identical at jobs={jobs}");
    }
}

/// Serial-vs-parallel determinism of the *encoder* fan-out: a multi-layer
/// model (one layer spanning several CHUNK_LEVELS frames, one small, one
/// unquantized) written via `save_quantized_jobs` must produce a
/// byte-identical `.ecqx` container at every job count, and the in-memory
/// size model must agree with itself — the ISSUE-6 acceptance gate for
/// parallel DeepCABAC encoding.
#[test]
fn quantized_container_matches_serial_bitwise() {
    use ecqx::codec::CHUNK_LEVELS;
    use ecqx::nn::{checkpoint, QLayer};
    use ecqx::quant::Codebook;
    use ecqx::runtime::{Init, ModelSpec, ParamSpec};
    use ecqx::tensor::{Tensor, TensorI32};

    let pspec = |name: &str, shape: Vec<usize>, quantize: bool| ParamSpec {
        name: name.into(),
        shape,
        init: Init::HeIn,
        quantize,
    };
    let spec = ModelSpec {
        name: "enc_det".into(),
        batch: 2,
        classes: 12,
        input_dim: 300,
        params: vec![
            // 300*240 = 72_000 levels: spans two CHUNK_LEVELS frames
            pspec("w0", vec![300, 240], true),
            pspec("w1", vec![240, 12], true),
            pspec("b0", vec![12], false),
        ],
    };
    assert!(300 * 240 > CHUNK_LEVELS);
    let mut state = ModelState::init(&spec, 31);
    let cb = Codebook::symmetric(4, 0.02);
    let mut rng = Rng::new(31);
    for (name, shape) in [("w0", vec![300usize, 240]), ("w1", vec![240, 12])] {
        let n: usize = shape.iter().product();
        // sample valid slots only (values is padded to K_MAX; the live
        // grid for `bits` has 2^bits - 1 slots)
        let nvalid = cb.n_valid();
        let slots: Vec<i32> =
            (0..n).map(|_| if rng.chance(0.85) { 0 } else { rng.below(nvalid) as i32 }).collect();
        let idx = TensorI32::new(shape.clone(), slots);
        let qw = Tensor::new(
            shape,
            idx.data.iter().map(|&s| cb.values[s as usize]).collect(),
        );
        state.qlayers.insert(name.into(), QLayer { qw, idx, codebook: cb.clone() });
    }

    let tmp = |jobs: usize| {
        std::env::temp_dir().join(format!("ecqx-encdet-{}-{jobs}.ecqx", std::process::id()))
    };
    let p1 = tmp(1);
    checkpoint::save_quantized_jobs(&p1, &state, 1).unwrap();
    let serial = std::fs::read(&p1).unwrap();
    let size1 = ecqx::coordinator::compressed_size_jobs(&state, 1);
    for jobs in 2..=4 {
        let pj = tmp(jobs);
        checkpoint::save_quantized_jobs(&pj, &state, jobs).unwrap();
        assert_eq!(
            std::fs::read(&pj).unwrap(),
            serial,
            "container must be byte-identical at jobs={jobs}"
        );
        assert_eq!(ecqx::coordinator::compressed_size_jobs(&state, jobs), size1);
        std::fs::remove_file(&pj).ok();
    }
    // and the container still decodes losslessly
    let qm = checkpoint::load_quantized(&p1).unwrap();
    assert_eq!(qm.layers["w0"].0.data, state.qlayers["w0"].idx.data);
    assert_eq!(qm.layers["w1"].0.data, state.qlayers["w1"].idx.data);
    std::fs::remove_file(&p1).ok();
}

/// A deliberately panicking trial must become a quarantined outcome —
/// its siblings keep running to completion, nothing tears down.
#[test]
fn panicking_trial_is_quarantined_without_aborting_siblings() {
    let trials = test_grid();
    for jobs in [1, 4] {
        let events = Mutex::new(Vec::new());
        let run = campaign::run_with(
            &trials,
            &CampaignOptions { jobs, quarantine: true, ..Default::default() },
            |t, seed| {
                if t.id == 7 {
                    panic!("synthetic panic in trial {}", t.id);
                }
                synthetic_trial(t, seed)
            },
            |ev| events.lock().unwrap().push(ev.clone()),
            None,
        )
        .unwrap();
        assert!(!run.cancelled);
        assert_eq!(run.outcomes.len(), trials.len(), "jobs={jobs}: no trial lost");
        for o in &run.outcomes {
            match (&o.result, o.id) {
                (TrialResult::Failed { error, attempts }, 7) => {
                    assert!(error.contains("panicked"), "jobs={jobs}: {error}");
                    assert!(error.contains("synthetic panic in trial 7"));
                    assert_eq!(*attempts, 1);
                }
                (TrialResult::Done(_), id) => assert_ne!(id, 7),
                (r, id) => panic!("jobs={jobs}: unexpected outcome {r:?} for {id}"),
            }
        }
        let failed: Vec<usize> = events
            .lock()
            .unwrap()
            .iter()
            .filter_map(|e| match e {
                Event::TrialFailed { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(failed, vec![7], "jobs={jobs}");
    }
    // without quarantine, the same panic surfaces as a campaign error —
    // caught, never a process abort
    let err = campaign::run(
        &trials,
        &CampaignOptions::default(),
        |t, seed| {
            if t.id == 2 {
                panic!("boom");
            }
            synthetic_trial(t, seed)
        },
        |_| {},
    )
    .unwrap_err();
    assert!(format!("{err:?}").contains("campaign trial 2"));
}

/// A trial that fails its first attempt and succeeds on the re-derived
/// retry seed completes the campaign; the retry is visible in the event
/// stream and results stay deterministic across job counts.
#[test]
fn flaky_trial_recovers_via_retry_with_fresh_seed() {
    let trials = test_grid();
    let opts = CampaignOptions {
        retry: RetryPolicy { retries: 2, backoff_ms: 0 },
        ..Default::default()
    };
    // trial 5 fails whenever it sees its attempt-0 seed: attempt 1's
    // re-derived seed differs, so the retry succeeds
    let flaky = |t: &TrialSpec, seed: u64| {
        if t.id == 5 && seed == campaign::trial_seed_attempt(opts.seed, 5, 0) {
            anyhow::bail!("transient failure");
        }
        synthetic_trial(t, seed)
    };
    let mut baseline: Option<Vec<String>> = None;
    for jobs in [1, 4] {
        let retried = AtomicUsize::new(0);
        let points = campaign::run(
            &trials,
            &CampaignOptions { jobs, ..opts },
            flaky,
            |ev| {
                if let Event::TrialRetried { id, error, attempt } = ev {
                    assert_eq!((*id, *attempt), (5, 1));
                    assert!(error.contains("transient failure"));
                    retried.fetch_add(1, Ordering::SeqCst);
                }
            },
        )
        .unwrap();
        assert_eq!(retried.load(Ordering::SeqCst), 1, "jobs={jobs}");
        assert_eq!(points.len(), trials.len());
        let rows: Vec<String> = points.iter().map(|p| p.to_csv()).collect();
        match &baseline {
            None => baseline = Some(rows),
            Some(b) => assert_eq!(b, &rows, "retry results deterministic at jobs={jobs}"),
        }
    }
    // without retries the same flake is fatal
    assert!(campaign::run(&trials, &CampaignOptions::default(), flaky, |_| {}).is_err());
}

/// Heartbeats fire every N outcomes with monotonic counters.
#[test]
fn heartbeats_track_progress() {
    let trials = test_grid();
    let beats = Mutex::new(Vec::new());
    campaign::run_with(
        &trials,
        &CampaignOptions { heartbeat_every: 5, ..Default::default() },
        synthetic_trial,
        |ev| {
            if let Event::Heartbeat { done, failed, total } = ev {
                beats.lock().unwrap().push((*done, *failed, *total));
            }
        },
        None,
    )
    .unwrap();
    let beats = beats.into_inner().unwrap();
    assert_eq!(beats.len(), 24 / 5);
    for (i, (done, failed, total)) in beats.iter().enumerate() {
        assert_eq!(done + failed, (i + 1) * 5);
        assert_eq!(*failed, 0);
        assert_eq!(*total, 24);
    }
}

/// Cooperative cancellation: once the flag is set, no new trials are
/// claimed; everything already produced is reported.
#[test]
fn cancellation_stops_new_claims() {
    let trials = test_grid();
    let cancel = AtomicBool::new(false);
    let seen = AtomicUsize::new(0);
    let run = campaign::run_with(
        &trials,
        &CampaignOptions::default(),
        synthetic_trial,
        |ev| {
            if matches!(ev, Event::Finished { .. })
                && seen.fetch_add(1, Ordering::SeqCst) + 1 == 5
            {
                cancel.store(true, Ordering::SeqCst);
            }
        },
        Some(&cancel),
    )
    .unwrap();
    assert!(run.cancelled);
    assert_eq!(run.outcomes.len(), 5, "serial run stops exactly at the flag");
    let ids: Vec<usize> = run.outcomes.iter().map(|o| o.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
}

/// End-to-end crash-safety gate on real (host-executed) QAT trials: a
/// campaign interrupted mid-run and resumed from its store, and a
/// campaign split across two shards, must each reproduce the exact row
/// bytes of one uninterrupted serial campaign — including at `jobs > 1`.
#[test]
fn durable_store_resume_and_shard_union_match_serial_bitwise() {
    let engine = Engine::host_with(Manifest::synthetic_mlp("mlp_tiny", &[360, 32, 12], 32));
    let spec = engine.manifest.model("mlp_tiny").unwrap().clone();
    let train = GscDataset::new(256, 5, true);
    let val = GscDataset::new(128, 5, false);
    let train_dl = DataLoader::new(&train, spec.batch, true, 5);
    let val_dl = DataLoader::new(&val, spec.batch, false, 5);
    let mut state = ModelState::init(&spec, 5);
    let pre = Pretrainer { lr: 1e-3, verbose: false, ..Default::default() };
    pre.run(&engine, &mut state, &train_dl, 2).unwrap();
    let baseline = evaluate(&engine, &state, &val_dl, ParamSource::Fp).unwrap();
    let runner = SweepRunner::new(&engine, state);
    let cfg = SweepConfig {
        model: "mlp_tiny".into(),
        method: Method::Ecqx,
        bits: 4,
        lambdas: vec![0.0, 0.5, 4.0],
        p: 0.3,
        qat: QatConfig {
            assign: AssignConfig::default(),
            epochs: 1,
            lr: 4e-4,
            lrp_warmup: 4,
            verbose: false,
            ..Default::default()
        },
        baseline_acc: baseline.accuracy,
        seed: 17,
    };
    let grid = Grid::lambda_sweep(cfg.method, cfg.bits, &cfg.lambdas, cfg.p);
    let tmp = |name: &str| {
        std::env::temp_dir().join(format!("ecqx-durable-{}-{name}", std::process::id()))
    };

    // 1) uninterrupted serial campaign: the reference row bytes
    let p_clean = tmp("clean.jsonl");
    std::fs::remove_file(&p_clean).ok();
    let mut clean = ResultStore::open_or_create(&p_clean).unwrap();
    let out = runner
        .run_store(
            &cfg,
            &grid,
            &train_dl,
            &val_dl,
            &mut clean,
            &StoreSweepOptions { jobs: 1, ..Default::default() },
            None,
        )
        .unwrap();
    assert_eq!((out.ran, out.skipped, out.quarantined), (3, 0, 0));
    assert!(!out.cancelled);
    let reference = clean.canonical_lines();
    assert_eq!(reference.len(), 3);

    // 2) interrupted after 2 trials, then resumed by a "fresh process"
    let p_resume = tmp("resume.jsonl");
    std::fs::remove_file(&p_resume).ok();
    let mut interrupted = ResultStore::open_or_create(&p_resume).unwrap();
    let out = runner
        .run_store(
            &cfg,
            &grid,
            &train_dl,
            &val_dl,
            &mut interrupted,
            &StoreSweepOptions { jobs: 1, max_trials: 2, ..Default::default() },
            None,
        )
        .unwrap();
    assert!(out.cancelled, "max-trials must interrupt the campaign");
    assert_eq!(out.ran, 2);
    drop(interrupted);
    let mut resumed = ResultStore::open_existing(&p_resume).unwrap();
    assert_eq!(resumed.rows().len(), 2, "both finished trials survived");
    let out = runner
        .run_store(
            &cfg,
            &grid,
            &train_dl,
            &val_dl,
            &mut resumed,
            &StoreSweepOptions { jobs: 1, ..Default::default() },
            None,
        )
        .unwrap();
    assert_eq!((out.ran, out.skipped), (1, 2), "resume runs only the missing trial");
    assert!(!out.cancelled);
    assert_eq!(
        resumed.canonical_lines(),
        reference,
        "interrupt + resume must be row-for-row bitwise identical to serial"
    );

    // 3) two shards (one of them parallel), merged
    let p_s0 = tmp("shard0.jsonl");
    let p_s1 = tmp("shard1.jsonl");
    std::fs::remove_file(&p_s0).ok();
    std::fs::remove_file(&p_s1).ok();
    let mut s0 = ResultStore::open_or_create(&p_s0).unwrap();
    let mut s1 = ResultStore::open_or_create(&p_s1).unwrap();
    let out0 = runner
        .run_store(
            &cfg,
            &grid,
            &train_dl,
            &val_dl,
            &mut s0,
            &StoreSweepOptions { jobs: 2, shard: Some((0, 2)), ..Default::default() },
            None,
        )
        .unwrap();
    let out1 = runner
        .run_store(
            &cfg,
            &grid,
            &train_dl,
            &val_dl,
            &mut s1,
            &StoreSweepOptions { jobs: 1, shard: Some((1, 2)), ..Default::default() },
            None,
        )
        .unwrap();
    assert_eq!(out0.ran + out1.ran, 3, "shards partition the grid exactly");
    let (meta, rows) = store::merge(&[s0, s1]).unwrap();
    assert_eq!(meta.n_trials, 3);
    let merged: Vec<String> = rows.iter().map(|r| r.to_line()).collect();
    assert_eq!(
        merged, reference,
        "shard union must be row-for-row bitwise identical to serial"
    );

    // resuming a complete store with the same cfg is a no-op...
    let out = runner
        .run_store(
            &cfg,
            &grid,
            &train_dl,
            &val_dl,
            &mut ResultStore::open_existing(&p_resume).unwrap(),
            &StoreSweepOptions { jobs: 1, ..Default::default() },
            None,
        )
        .unwrap();
    assert_eq!((out.ran, out.skipped), (0, 3));
    // ...but a wrong-seed resume is refused up front
    let mut wrong = cfg.clone();
    wrong.seed = 18;
    let err = runner
        .run_store(
            &wrong,
            &grid,
            &train_dl,
            &val_dl,
            &mut ResultStore::open_existing(&p_resume).unwrap(),
            &StoreSweepOptions { jobs: 1, ..Default::default() },
            None,
        )
        .unwrap_err();
    assert!(format!("{err:?}").contains("different campaign"));

    for p in [p_clean, p_resume, p_s0, p_s1] {
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn failures_surface_deterministically() {
    let trials = test_grid();
    for jobs in [1, 8] {
        let err = campaign::run(
            &trials,
            &CampaignOptions { jobs, ..Default::default() },
            |t, seed| {
                if t.id == 5 || t.id == 11 {
                    anyhow::bail!("injected failure in trial {}", t.id);
                }
                synthetic_trial(t, seed)
            },
            |_| {},
        )
        .unwrap_err();
        let msg = format!("{err:?}");
        // the lowest-position failure wins regardless of completion order
        assert!(msg.contains("campaign trial 5"), "jobs={jobs}: {msg}");
        assert!(msg.contains("injected failure in trial 5"), "jobs={jobs}: {msg}");
    }
}
