//! Counting-allocator proof of the workspace contract: once a
//! [`ecqx::linalg::Workspace`] is warm, the blocked GEMM hot loop performs
//! **zero** heap allocations, and a full host-backend engine step reaches
//! an allocation steady state (no per-step growth — only the unavoidable
//! output `Value` envelopes remain).
//!
//! Everything lives in ONE `#[test]` on purpose: the counter is a global
//! and libtest runs tests on multiple threads, so separate tests would
//! pollute each other's counts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use ecqx::coordinator::binder::{bind_inputs, ParamSource, Scalars};
use ecqx::data::Batch;
use ecqx::linalg::{self, Conv2d, Epilogue, Pad, Workspace};
use ecqx::nn::ModelState;
use ecqx::runtime::{Engine, Manifest};
use ecqx::tensor::{Tensor, TensorI32, Value};

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

#[test]
fn warm_workspace_gemm_is_allocation_free_and_engine_steps_reach_steady_state() {
    // -- phase 1: the blocked GEMM core, all three forms + epilogues --
    let (m, k, n) = (65, 33, 47); // deliberately ragged
    let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.13).sin()).collect();
    let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.29).cos()).collect();
    let g: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.07).sin()).collect();
    let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
    let idx: Vec<i32> = (0..k * n).map(|i| (i % 3) as i32).collect();
    let cb = [0.0f32, 0.5, -0.25];
    let mut ws = Workspace::new();
    let mut out_nn = vec![0.0f32; m * n];
    let mut out_tn = vec![0.0f32; k * n];
    let mut out_nt = vec![0.0f32; m * k];
    // warm the workspace (first call may grow the panel buffers)
    linalg::gemm_nn(&mut ws, &a, &b, m, k, n, Epilogue::BiasRelu(&bias), &mut out_nn);
    linalg::gemm_tn(&mut ws, &a, &g, m, k, n, Epilogue::None, &mut out_tn);
    linalg::gemm_nt(&mut ws, &g, &b, m, n, k, Epilogue::None, &mut out_nt);
    linalg::gemm_gather_nn(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::Bias(&bias), &mut out_nn);

    let before = allocs();
    for _ in 0..10 {
        linalg::gemm_nn(&mut ws, &a, &b, m, k, n, Epilogue::BiasRelu(&bias), &mut out_nn);
        linalg::gemm_tn(&mut ws, &a, &g, m, k, n, Epilogue::Scale(&b), &mut out_tn);
        linalg::gemm_nt(&mut ws, &g, &b, m, n, k, Epilogue::ReluMask(&g), &mut out_nt);
        linalg::gemm_gather_nn(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::Bias(&bias), &mut out_nn);
    }
    assert_eq!(
        allocs() - before,
        0,
        "warm-workspace GEMM must not touch the heap (packing scratch is reused)"
    );

    // -- phase 1b: the im2col conv kernels through the same workspace --
    // forward, dW and the tiled col2im backward all draw their scratch
    // (panels + dCol tile) from the workspace; warm, they allocate nothing
    let geom = Conv2d { n: 2, h: 9, w: 7, c: 3, kh: 3, kw: 3, co: 5, stride: 2, pad: Pad::Same };
    let cx: Vec<f32> = (0..geom.in_len()).map(|i| (i as f32 * 0.19).sin()).collect();
    let cw: Vec<f32> = (0..geom.filter_len()).map(|i| (i as f32 * 0.23).cos()).collect();
    let cg: Vec<f32> = (0..geom.out_len()).map(|i| (i as f32 * 0.31).sin()).collect();
    let cbias: Vec<f32> = (0..geom.co).map(|i| i as f32 * 0.01).collect();
    let mut cout = vec![0.0f32; geom.out_len()];
    let mut cdw = vec![0.0f32; geom.filter_len()];
    let mut cdx = vec![0.0f32; geom.in_len()];
    linalg::conv2d(&mut ws, &cx, &cw, &geom, Epilogue::BiasRelu(&cbias), &mut cout);
    linalg::conv2d_bwd_filter(&mut ws, &cx, &cg, &geom, Epilogue::None, &mut cdw);
    linalg::conv2d_bwd_input(&mut ws, &cg, &cw, &geom, &mut cdx);
    let before = allocs();
    for _ in 0..10 {
        linalg::conv2d(&mut ws, &cx, &cw, &geom, Epilogue::BiasRelu(&cbias), &mut cout);
        linalg::lrp_conv_rw(&mut ws, &cx, &cg, &cw, &geom, &mut cdw);
        linalg::conv2d_bwd_input(&mut ws, &cg, &cw, &geom, &mut cdx);
    }
    assert_eq!(
        allocs() - before,
        0,
        "warm-workspace conv2d must not touch the heap (panels + dCol tile are reused)"
    );

    // -- phase 2: full host-backend engine steps reach steady state --
    // Output Values must be freshly allocated each call (they are moved
    // to the caller), so the step count cannot be zero — but in steady
    // state the per-step allocation-call count must be exactly constant:
    // every heap touch is either warm workspace reuse (none) or an output
    // envelope of fixed shape. Any growth or per-step drift fails.
    let eng = Engine::host_with(Manifest::synthetic_mlp("t", &[6, 5, 3], 2));
    let state = ModelState::init(eng.manifest.model("t").unwrap(), 3);
    let mut inputs: Vec<Value> = state
        .spec
        .params
        .iter()
        .map(|p| Value::F32(state.params[&p.name].clone()))
        .collect();
    inputs.push(Value::F32(Tensor::ones(&[2, 6])));
    inputs.push(Value::I32(TensorI32::new(vec![2], vec![0, 2])));

    let mut scratch = Workspace::new();
    let steady = |name: &str, ins: &[Value], scratch: &mut Workspace| {
        eng.call_with(name, ins, scratch).unwrap(); // warm
        let c0 = allocs();
        eng.call_with(name, ins, scratch).unwrap();
        let c1 = allocs();
        eng.call_with(name, ins, scratch).unwrap();
        let c2 = allocs();
        assert_eq!(
            c1 - c0,
            c2 - c1,
            "{name}: steady-state per-step allocation count drifted"
        );
    };
    steady("t_eval", &inputs, &mut scratch);

    // the actual training loop: a full fp_train step (forward + backward
    // + Adam), bound exactly as the trainer binds it
    let art = eng.manifest.artifact("t_fp_train").unwrap().clone();
    let train_batch = Batch { x: vec![0.5; 2 * 6], y: vec![0, 2], batch: 2 };
    let scalars = Scalars { t: 1.0, lr: 1e-3, ..Default::default() };
    let train_inputs =
        bind_inputs(&art, &state, ParamSource::Fp, Some(&train_batch), &scalars).unwrap();
    steady("t_fp_train", &train_inputs, &mut scratch);

    // -- phase 2b: the CNN engine paths reach the same steady state --
    // a full conv train step (im2col forward + dW + col2im dX + Adam)
    // must show a constant per-step allocation count once warm
    let ceng = Engine::host_with(Manifest::synthetic_cnn(
        "tc",
        (8, 8),
        3,
        &[(4, 2), (8, 2)],
        &[16, 3],
        2,
    ));
    let cstate = ModelState::init(ceng.manifest.model("tc").unwrap(), 5);
    let ceval = ceng.manifest.artifact("tc_eval").unwrap().clone();
    let cnn_batch = Batch { x: vec![0.5; 2 * 8 * 8 * 3], y: vec![0, 2], batch: 2 };
    let ceval_inputs =
        bind_inputs(&ceval, &cstate, ParamSource::Fp, Some(&cnn_batch), &Scalars::default())
            .unwrap();
    let mut cscratch = Workspace::new();
    let csteady = |name: &str, ins: &[Value], scratch: &mut Workspace| {
        ceng.call_with(name, ins, scratch).unwrap(); // warm
        let c0 = allocs();
        ceng.call_with(name, ins, scratch).unwrap();
        let c1 = allocs();
        ceng.call_with(name, ins, scratch).unwrap();
        let c2 = allocs();
        assert_eq!(
            c1 - c0,
            c2 - c1,
            "{name}: steady-state per-step allocation count drifted"
        );
    };
    csteady("tc_eval", &ceval_inputs, &mut cscratch);
    let ctrain = ceng.manifest.artifact("tc_fp_train").unwrap().clone();
    let ctrain_inputs =
        bind_inputs(&ctrain, &cstate, ParamSource::Fp, Some(&cnn_batch), &scalars).unwrap();
    csteady("tc_fp_train", &ctrain_inputs, &mut cscratch);
}
