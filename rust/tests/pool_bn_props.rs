//! Property suite for the pooling and BatchNorm kernels backing the
//! VGG/ResNet host workloads (DESIGN.md §2.8), mirroring the conv suite
//! in `tests/conv_props.rs`:
//!
//! * pool forward kernels agree with the retained naive oracles
//!   (`linalg::reference`) **exactly** — both are plain ascending scalar
//!   loops, so equality holds to the last bit on every geometry;
//! * the pool backward kernels are true adjoints of the (locally linear)
//!   forward maps;
//! * `bn_fold` agrees with `bn_fold_naive` exactly, and a folded conv
//!   reproduces the unfolded conv → `bn_infer` composition within f32
//!   tolerance (the Fig.8 deployment-path equivalence);
//! * `bn_train_bwd` satisfies the BN orthogonality identities
//!   (Σ dz = 0 and Σ dz·x̂ = 0 per channel) and `bn_train_fwd`
//!   normalizes each channel to (β, γ²);
//! * the avg-pool LRP redistribution conserves relevance.

use ecqx::linalg::{self, reference, Conv2d, Epilogue, Pad, Pool2d, PoolOp, Workspace, BN_EPS};
use ecqx::util::prop::{check, normal_vec};
use ecqx::util::Rng;

/// Random VALID pool geometry with a non-empty output: window never
/// exceeds the image, strides 1–3, both ops.
fn rand_pool(rng: &mut Rng, op: PoolOp) -> Pool2d {
    let h = 1 + rng.below(8);
    let w = 1 + rng.below(8);
    Pool2d {
        n: 1 + rng.below(3),
        h,
        w,
        c: 1 + rng.below(4),
        kh: 1 + rng.below(h.min(3)),
        kw: 1 + rng.below(w.min(3)),
        stride: 1 + rng.below(3),
        op,
    }
}

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&u, &v)| u as f64 * v as f64).sum()
}

#[test]
fn maxpool_equals_naive_exactly_and_argmax_is_consistent() {
    check("maxpool ≡ naive", 60, |rng| {
        let g = rand_pool(rng, PoolOp::Max);
        let x = normal_vec(rng, g.in_len(), 1.0);
        let mut out = vec![0.0f32; g.out_len()];
        let mut argmax = vec![0usize; g.out_len()];
        linalg::maxpool2d(&g, &x, &mut argmax, &mut out);
        if out != reference::maxpool2d_naive(&g, &x) {
            return Err(format!("maxpool diverged from naive ({g:?})"));
        }
        // the recorded winner must actually hold the output value — the
        // WTA backward/LRP routing depends on it
        for (j, (&i, &o)) in argmax.iter().zip(&out).enumerate() {
            if x[i] != o {
                return Err(format!("argmax[{j}]={i} holds {} ≠ out {o}", x[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn avgpool_equals_naive_exactly() {
    check("avgpool ≡ naive", 60, |rng| {
        let g = rand_pool(rng, PoolOp::Avg);
        let x = normal_vec(rng, g.in_len(), 1.0);
        let mut out = vec![0.0f32; g.out_len()];
        linalg::avgpool2d(&g, &x, &mut out);
        if out != reference::avgpool2d_naive(&g, &x) {
            return Err(format!("avgpool diverged from naive ({g:?})"));
        }
        Ok(())
    });
}

#[test]
fn pool_backwards_are_adjoints_of_the_forward() {
    // avg-pool is linear, so ⟨avg(x), dy⟩ = ⟨x, avg_bwd(dy)⟩ exactly;
    // max-pool is locally linear around the recorded argmax, so the same
    // identity holds for the WTA scatter — including overlapping windows
    // (stride < k), where the scatter accumulates
    check("pool bwd adjoint identities", 40, |rng| {
        let ga = rand_pool(rng, PoolOp::Avg);
        let x = normal_vec(rng, ga.in_len(), 1.0);
        let dy = normal_vec(rng, ga.out_len(), 1.0);
        let mut out = vec![0.0f32; ga.out_len()];
        linalg::avgpool2d(&ga, &x, &mut out);
        let mut dx = vec![f32::NAN; ga.in_len()];
        linalg::avgpool2d_bwd(&ga, &dy, &mut dx);
        let (lhs, rhs) = (dot(&out, &dy), dot(&x, &dx));
        if (lhs - rhs).abs() > 1e-3 * (1.0 + lhs.abs()) {
            return Err(format!("avg: ⟨y,dy⟩={lhs} vs ⟨x,dx⟩={rhs} ({ga:?})"));
        }

        let gm = rand_pool(rng, PoolOp::Max);
        let x = normal_vec(rng, gm.in_len(), 1.0);
        let dy = normal_vec(rng, gm.out_len(), 1.0);
        let mut out = vec![0.0f32; gm.out_len()];
        let mut argmax = vec![0usize; gm.out_len()];
        linalg::maxpool2d(&gm, &x, &mut argmax, &mut out);
        let mut dx = vec![f32::NAN; gm.in_len()];
        linalg::maxpool2d_bwd(&gm, &argmax, &dy, &mut dx);
        let (lhs, rhs) = (dot(&out, &dy), dot(&x, &dx));
        if (lhs - rhs).abs() > 1e-3 * (1.0 + lhs.abs()) {
            return Err(format!("max: ⟨y,dy⟩={lhs} vs ⟨x,dx⟩={rhs} ({gm:?})"));
        }
        Ok(())
    });
}

#[test]
fn avgpool_lrp_conserves_relevance() {
    // each window redistributes r_j·(Σx)/stab(Σx) ≈ r_j; as in the conv
    // conservation suites, windows whose sum is stabilizer-scale get
    // zero relevance instead of asserting through the eps spike
    check("avgpool LRP conservation", 40, |rng| {
        let g = rand_pool(rng, PoolOp::Avg);
        let x = normal_vec(rng, g.in_len(), 1.0);
        let mut out = vec![0.0f32; g.out_len()];
        linalg::avgpool2d(&g, &x, &mut out);
        let count = (g.kh * g.kw) as f32;
        let r: Vec<f32> = out
            .iter()
            .map(|&avg| if (avg * count).abs() < 1e-2 { 0.0 } else { rng.range(0.0, 1.0) })
            .collect();
        let mut rin = vec![f32::NAN; g.in_len()];
        linalg::avgpool2d_lrp(&g, &x, &r, &mut rin);
        let total: f64 = r.iter().map(|&v| v as f64).sum();
        let got: f64 = rin.iter().map(|&v| v as f64).sum();
        // overlapping windows revisit inputs, so compare totals only
        if (got - total).abs() > 1e-2 * (1.0 + total.abs()) {
            return Err(format!("Σ R_in = {got} vs Σ R = {total} ({g:?})"));
        }
        Ok(())
    });
}

#[test]
fn bn_fold_matches_naive_exactly() {
    check("bn_fold ≡ naive", 60, |rng| {
        let c = 1 + rng.below(8);
        let taps = 1 + rng.below(30);
        let gamma: Vec<f32> = (0..c).map(|_| rng.range(0.2, 2.0)).collect();
        let beta = normal_vec(rng, c, 0.5);
        let mean = normal_vec(rng, c, 1.0);
        let var: Vec<f32> = (0..c).map(|_| rng.range(0.01, 2.0)).collect();
        let w = normal_vec(rng, taps * c, 0.5);
        let b = normal_vec(rng, c, 0.5);
        let mut wf = vec![f32::NAN; w.len()];
        let mut bf = vec![f32::NAN; c];
        linalg::bn_fold(&gamma, &beta, &mean, &var, BN_EPS, &w, &b, &mut wf, &mut bf);
        let (wf_ref, bf_ref) = reference::bn_fold_naive(&gamma, &beta, &mean, &var, BN_EPS, &w, &b);
        if wf != wf_ref || bf != bf_ref {
            return Err(format!("bn_fold diverged from naive (c={c}, taps={taps})"));
        }
        Ok(())
    });
}

#[test]
fn folded_conv_equals_conv_then_bn_infer() {
    // the deployment-path equivalence: conv(x, fold(w)) + fold(b) must
    // reproduce bn_infer(conv(x, w) + b) — f32 tolerance, since folding
    // reassociates the per-channel scale into every filter tap
    let mut ws = Workspace::new();
    check("folded conv ≡ conv → bn_infer", 30, |rng| {
        let g = Conv2d {
            n: 1 + rng.below(2),
            h: 3 + rng.below(5),
            w: 3 + rng.below(5),
            c: 1 + rng.below(3),
            kh: 1 + rng.below(3),
            kw: 1 + rng.below(3),
            co: 1 + rng.below(6),
            stride: 1 + rng.below(2),
            pad: if rng.chance(0.5) { Pad::Same } else { Pad::Valid },
        };
        if g.out_len() == 0 {
            return Ok(());
        }
        let x = normal_vec(rng, g.in_len(), 1.0);
        let w = normal_vec(rng, g.filter_len(), 0.5);
        let b = normal_vec(rng, g.co, 0.5);
        let gamma: Vec<f32> = (0..g.co).map(|_| rng.range(0.2, 2.0)).collect();
        let beta = normal_vec(rng, g.co, 0.5);
        let mean = normal_vec(rng, g.co, 1.0);
        let var: Vec<f32> = (0..g.co).map(|_| rng.range(0.01, 2.0)).collect();

        let mut wf = vec![0.0f32; w.len()];
        let mut bf = vec![0.0f32; g.co];
        linalg::bn_fold(&gamma, &beta, &mean, &var, BN_EPS, &w, &b, &mut wf, &mut bf);
        let mut folded = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut ws, &x, &wf, &g, Epilogue::Bias(&bf), &mut folded);

        let mut unfolded = vec![0.0f32; g.out_len()];
        linalg::conv2d(&mut ws, &x, &w, &g, Epilogue::Bias(&b), &mut unfolded);
        linalg::bn_infer(&gamma, &beta, &mean, &var, BN_EPS, &mut unfolded);

        for (i, (&a, &c2)) in folded.iter().zip(&unfolded).enumerate() {
            if (a - c2).abs() > 1e-4 * (1.0 + c2.abs()) {
                return Err(format!("out[{i}] folded {a} vs unfolded {c2} ({g:?})"));
            }
        }
        Ok(())
    });
}

#[test]
fn bn_train_fwd_normalizes_and_bwd_satisfies_orthogonality() {
    // forward: per-channel batch mean of y is β and variance is γ²
    // (biased); backward: the BN gradient lies in the subspace orthogonal
    // to both the constant and x̂ directions — Σ dz = 0 and Σ dz·x̂ = 0
    // per channel, the defining identities of the batch-coupled backward
    check("bn train fwd/bwd identities", 30, |rng| {
        let c = 1 + rng.below(6);
        let rows = 8 + rng.below(40);
        let z = normal_vec(rng, rows * c, 1.5);
        let gamma: Vec<f32> = (0..c).map(|_| rng.range(0.2, 2.0)).collect();
        let beta = normal_vec(rng, c, 0.5);
        let dy = normal_vec(rng, rows * c, 1.0);

        let mut y = vec![0.0f32; z.len()];
        let mut mean = vec![0.0f32; c];
        let mut var = vec![0.0f32; c];
        linalg::bn_train_fwd(&z, c, &gamma, &beta, BN_EPS, &mut y, &mut mean, &mut var);
        for ch in 0..c {
            let col: Vec<f64> = y.iter().skip(ch).step_by(c).map(|&v| v as f64).collect();
            let m = col.iter().sum::<f64>() / rows as f64;
            let v = col.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / rows as f64;
            if (m - beta[ch] as f64).abs() > 1e-3 {
                return Err(format!("ch {ch}: mean {m} vs β {}", beta[ch]));
            }
            let want = (gamma[ch] as f64).powi(2);
            if (v - want).abs() > 1e-2 * (1.0 + want) {
                return Err(format!("ch {ch}: var {v} vs γ² {want}"));
            }
        }

        let mut dz = vec![0.0f32; z.len()];
        let mut dgamma = vec![0.0f32; c];
        let mut dbeta = vec![0.0f32; c];
        linalg::bn_train_bwd(&z, c, &gamma, &mean, &var, BN_EPS, &dy, &mut dz, &mut dgamma, &mut dbeta);
        for ch in 0..c {
            let ivar = 1.0 / ((var[ch] + BN_EPS) as f64).sqrt();
            let (mut s0, mut s1) = (0.0f64, 0.0f64);
            for row in 0..rows {
                let d = dz[row * c + ch] as f64;
                let xhat = (z[row * c + ch] as f64 - mean[ch] as f64) * ivar;
                s0 += d;
                s1 += d * xhat;
            }
            let scale = dz.iter().skip(ch).step_by(c).map(|&v| (v as f64).abs()).sum::<f64>()
                + 1.0;
            if s0.abs() > 1e-3 * scale {
                return Err(format!("ch {ch}: Σ dz = {s0} not 0"));
            }
            if s1.abs() > 1e-3 * scale {
                return Err(format!("ch {ch}: Σ dz·x̂ = {s1} not 0"));
            }
            // dβ is the plain column sum; dγ the x̂-weighted one
            let want_dbeta: f64 = dy.iter().skip(ch).step_by(c).map(|&v| v as f64).sum();
            if (dbeta[ch] as f64 - want_dbeta).abs() > 1e-3 * (1.0 + want_dbeta.abs()) {
                return Err(format!("ch {ch}: dβ {} vs {want_dbeta}", dbeta[ch]));
            }
        }
        Ok(())
    });
}

#[test]
fn ema_update_converges_to_the_batch_stat() {
    // repeated updates against a fixed batch stat converge geometrically
    let mut running = vec![0.0f32, 10.0, -4.0];
    let batch = vec![2.0f32, 2.0, 2.0];
    for _ in 0..200 {
        linalg::ema_update(&mut running, &batch, 0.1);
    }
    for &r in &running {
        assert!((r - 2.0).abs() < 1e-3, "{running:?}");
    }
}
