//! Cross-module integration: quantize -> encode -> container -> decode ->
//! identical model; codec family ordering on realistic weight tensors.

use ecqx::codec;
use ecqx::quant::{assign_ref, Codebook};
use ecqx::tensor::TensorI32;
use ecqx::util::Rng;

fn realistic_assignment(n: usize, bits: u32, lam: f32, seed: u64) -> (TensorI32, Codebook) {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.08)).collect();
    let cb = Codebook::fit(&w, bits);
    let r = vec![1.0f32; n];
    let m = vec![1.0f32; n];
    let a = assign_ref(&w, &r, &m, &cb, lam);
    (TensorI32::new(vec![n / 64, 64], a.idx), cb)
}

#[test]
fn encode_decode_identity_across_bitwidths() {
    for bits in 2..=5u32 {
        let (idx, cb) = realistic_assignment(4096, bits, 2e-4, bits as u64);
        let enc = codec::encode_tensor(&idx, &cb);
        let dec = codec::decode_tensor(&enc).unwrap();
        assert_eq!(dec.data, idx.data, "bits={bits}");
        assert_eq!(dec.shape, idx.shape);
    }
}

#[test]
fn cabac_wins_on_entropy_constrained_tensors() {
    // An entropy-constrained assignment is exactly the source CABAC is
    // built for: it must beat bit-packing and stay within the codec family
    // ordering the paper's compressibility claims rely on.
    let (idx, _cb) = realistic_assignment(65536, 4, 1e-3, 9);
    let cmp = codec::compare_codecs(&idx, 4);
    assert!(cmp.cabac < cmp.packed, "{cmp:?}");
    assert!(cmp.cabac < cmp.fp32 / 10, "{cmp:?}");
    assert!(cmp.cabac <= cmp.huffman, "{cmp:?}");
    assert!(cmp.cabac <= cmp.deflate, "{cmp:?}");
}

#[test]
fn compression_ratio_tracks_lambda() {
    // Higher lambda -> sparser assignment -> smaller bitstream (Fig. 9/10
    // mechanism). Verify the monotone chain end to end on one tensor.
    let mut last = usize::MAX;
    for &lam in &[0.0f32, 2e-4, 1e-3, 4e-3] {
        let (idx, cb) = realistic_assignment(32768, 4, lam, 4);
        let enc = codec::encode_tensor(&idx, &cb);
        assert!(
            enc.payload.len() <= last,
            "payload grew at lam={lam}: {} > {last}",
            enc.payload.len()
        );
        last = enc.payload.len();
    }
    assert!(last < 32768 * 4 / 10, "4-bit sparse should be <10% of fp32");
}

#[test]
fn rle_and_csr_agree_on_nnz_scaling() {
    let (idx_lo, _) = realistic_assignment(16384, 4, 0.0, 5);
    let (idx_hi, _) = realistic_assignment(16384, 4, 4e-3, 5);
    let lo = codec::compare_codecs(&idx_lo, 4);
    let hi = codec::compare_codecs(&idx_hi, 4);
    assert!(hi.rle < lo.rle);
    assert!(hi.csr < lo.csr);
    assert!(hi.cabac < lo.cabac);
}
