//! Golden-vector conformance tests: fixture tensors generated from the
//! Python reference kernels (`python/tests/gen_golden.py`, mirroring
//! `python/compile/kernels/ref.py`) are committed under `tests/golden/`;
//! the rust host kernels must reproduce them within 1e-5.

use std::collections::HashMap;
use std::path::PathBuf;

use ecqx::linalg::{self, Conv2d, Epilogue, Pad, Pool2d, PoolOp, Workspace, BN_EPS};
use ecqx::quant::assign_raw;
use ecqx::runtime::host::{lrp_dense_rw, qdense, qdense_gather};
use ecqx::util::prop::assert_close;

/// One parsed fixture tensor: shape + raw (still textual) values.
struct Fixture {
    tensors: HashMap<String, (Vec<usize>, Vec<String>)>,
    name: String,
}

impl Fixture {
    fn load(name: &str) -> Fixture {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("tests/golden")
            .join(format!("{name}.txt"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let mut tensors = HashMap::new();
        let mut lines = text.lines().filter(|l| !l.trim_start().starts_with('#'));
        while let Some(header) = lines.next() {
            let header = header.trim();
            if header.is_empty() {
                continue;
            }
            let toks: Vec<&str> = header.split_whitespace().collect();
            assert_eq!(toks[0], "tensor", "{name}: bad fixture line {header}");
            let shape: Vec<usize> = if toks[3] == "scalar" {
                vec![]
            } else {
                toks[3].split('x').map(|d| d.parse().unwrap()).collect()
            };
            let values: Vec<String> = lines
                .next()
                .unwrap_or_else(|| panic!("{name}: {} has no data line", toks[1]))
                .split_whitespace()
                .map(str::to_string)
                .collect();
            let numel: usize = shape.iter().product();
            assert_eq!(values.len(), numel.max(1), "{name}: {} wrong numel", toks[1]);
            tensors.insert(toks[1].to_string(), (shape, values));
        }
        Fixture { tensors, name: name.to_string() }
    }

    fn shape(&self, t: &str) -> &[usize] {
        &self.tensors.get(t).unwrap_or_else(|| panic!("{}: no tensor {t}", self.name)).0
    }

    fn f32s(&self, t: &str) -> Vec<f32> {
        self.tensors[t].1.iter().map(|v| v.parse().unwrap()).collect()
    }

    fn i32s(&self, t: &str) -> Vec<i32> {
        self.tensors[t].1.iter().map(|v| v.parse().unwrap()).collect()
    }

    fn scalar(&self, t: &str) -> f32 {
        let v = self.f32s(t);
        assert_eq!(v.len(), 1);
        v[0]
    }
}

#[test]
fn golden_qdense_matches_python_reference() {
    let fx = Fixture::load("qdense");
    let (m, k) = (fx.shape("a")[0], fx.shape("a")[1]);
    let n = fx.shape("w")[1];
    let y = qdense(&fx.f32s("a"), &fx.f32s("w"), &fx.f32s("b"), m, k, n);
    assert_close(&y, &fx.f32s("y"), 1e-5).unwrap();
}

#[test]
fn golden_qdense_gather_matches_python_reference() {
    let fx = Fixture::load("qdense_gather");
    let (m, k) = (fx.shape("a")[0], fx.shape("a")[1]);
    let n = fx.shape("idx")[1];
    let y = qdense_gather(
        &fx.f32s("a"),
        &fx.i32s("idx"),
        &fx.f32s("codebook"),
        &fx.f32s("b"),
        m,
        k,
        n,
    )
    .expect("golden fixture carries a non-empty codebook");
    assert_close(&y, &fx.f32s("y"), 1e-5).unwrap();
}

#[test]
fn golden_lrp_dense_rw_matches_python_reference() {
    let fx = Fixture::load("lrp_dense_rw");
    let (batch, din) = (fx.shape("a")[0], fx.shape("a")[1]);
    let dout = fx.shape("s")[1];
    let rw = lrp_dense_rw(&fx.f32s("a"), &fx.f32s("s"), &fx.f32s("w"), batch, din, dout);
    assert_close(&rw, &fx.f32s("rw"), 1e-5).unwrap();
}

/// Conv geometry from the fixture's NHWC input + HWIO filter shapes.
fn conv_geom(fx: &Fixture, x: &str, w: &str, stride: usize, pad: Pad) -> Conv2d {
    let xs = fx.shape(x);
    let ws = fx.shape(w);
    assert_eq!(xs.len(), 4, "{x} must be NHWC");
    assert_eq!(ws.len(), 4, "{w} must be HWIO");
    Conv2d {
        n: xs[0],
        h: xs[1],
        w: xs[2],
        c: xs[3],
        kh: ws[0],
        kw: ws[1],
        co: ws[3],
        stride,
        pad,
    }
}

#[test]
fn golden_conv2d_matches_python_reference() {
    let fx = Fixture::load("conv2d");
    let mut ws = Workspace::new();
    for (out_name, stride, pad) in
        [("y_s1_same", 1, Pad::Same), ("y_s2_valid", 2, Pad::Valid)]
    {
        let g = conv_geom(&fx, "x", "w", stride, pad);
        let want = fx.f32s(out_name);
        assert_eq!(g.out_len(), want.len(), "{out_name}: fixture shape drifted");
        let mut y = vec![0.0f32; g.out_len()];
        let b = fx.f32s("b");
        linalg::conv2d(&mut ws, &fx.f32s("x"), &fx.f32s("w"), &g, Epilogue::Bias(&b), &mut y);
        assert_close(&y, &want, 1e-5).unwrap_or_else(|e| panic!("{out_name}: {e}"));
    }
}

#[test]
fn golden_conv2d_backward_matches_python_reference() {
    let fx = Fixture::load("conv2d_bwd");
    let g = conv_geom(&fx, "x", "w", 2, Pad::Same);
    let mut ws = Workspace::new();
    let mut dw = vec![0.0f32; g.filter_len()];
    linalg::conv2d_bwd_filter(&mut ws, &fx.f32s("x"), &fx.f32s("g"), &g, Epilogue::None, &mut dw);
    assert_close(&dw, &fx.f32s("dw"), 1e-5).unwrap();
    let mut dx = vec![0.0f32; g.in_len()];
    linalg::conv2d_bwd_input(&mut ws, &fx.f32s("g"), &fx.f32s("w"), &g, &mut dx);
    assert_close(&dx, &fx.f32s("dx"), 1e-5).unwrap();
}

#[test]
fn golden_lrp_conv_rw_matches_python_reference() {
    let fx = Fixture::load("lrp_conv_rw");
    let g = conv_geom(&fx, "a", "w", 1, Pad::Same);
    let mut ws = Workspace::new();
    let w = fx.f32s("w");
    let mut rw = vec![0.0f32; g.filter_len()];
    linalg::lrp_conv_rw(&mut ws, &fx.f32s("a"), &fx.f32s("s"), &w, &g, &mut rw);
    assert_close(&rw, &fx.f32s("rw"), 1e-5).unwrap();
}

#[test]
fn golden_conv2d_gather_matches_python_reference() {
    let fx = Fixture::load("conv2d_gather");
    let xs = fx.shape("x").to_vec();
    let is = fx.shape("idx").to_vec();
    let g = Conv2d {
        n: xs[0],
        h: xs[1],
        w: xs[2],
        c: xs[3],
        kh: is[0],
        kw: is[1],
        co: is[3],
        stride: 1,
        pad: Pad::Same,
    };
    let mut ws = Workspace::new();
    let b = fx.f32s("b");
    let mut y = vec![0.0f32; g.out_len()];
    linalg::conv2d_gather(
        &mut ws,
        &fx.f32s("x"),
        &fx.i32s("idx"),
        &fx.f32s("codebook"),
        &g,
        Epilogue::Bias(&b),
        &mut y,
    );
    assert_close(&y, &fx.f32s("y"), 1e-5).unwrap();
}

/// Pool geometry from the fixture's NHWC input shape (2×2 stride 2 —
/// the window the generators use).
fn pool_geom(fx: &Fixture, op: PoolOp) -> Pool2d {
    let xs = fx.shape("x");
    assert_eq!(xs.len(), 4, "x must be NHWC");
    Pool2d { n: xs[0], h: xs[1], w: xs[2], c: xs[3], kh: 2, kw: 2, stride: 2, op }
}

#[test]
fn golden_maxpool2d_matches_python_reference() {
    let fx = Fixture::load("maxpool2d");
    let g = pool_geom(&fx, PoolOp::Max);
    let x = fx.f32s("x");
    let mut y = vec![0.0f32; g.out_len()];
    let mut argmax = vec![0usize; g.out_len()];
    linalg::maxpool2d(&g, &x, &mut argmax, &mut y);
    // forward and WTA backward copy/scatter values untouched, and the
    // %.9g fixture format round-trips f32 exactly — so bitwise equality
    assert_eq!(y, fx.f32s("y"), "maxpool forward");
    let mut dx = vec![f32::NAN; g.in_len()];
    linalg::maxpool2d_bwd(&g, &argmax, &fx.f32s("dy"), &mut dx);
    assert_eq!(dx, fx.f32s("dx"), "maxpool WTA backward");
}

#[test]
fn golden_avgpool2d_matches_python_reference() {
    let fx = Fixture::load("avgpool2d");
    let g = pool_geom(&fx, PoolOp::Avg);
    let x = fx.f32s("x");
    let mut y = vec![0.0f32; g.out_len()];
    linalg::avgpool2d(&g, &x, &mut y);
    assert_close(&y, &fx.f32s("y"), 1e-5).unwrap();
    let mut dx = vec![f32::NAN; g.in_len()];
    linalg::avgpool2d_bwd(&g, &fx.f32s("dy"), &mut dx);
    assert_close(&dx, &fx.f32s("dx"), 1e-5).unwrap();
    let mut rin = vec![f32::NAN; g.in_len()];
    linalg::avgpool2d_lrp(&g, &x, &fx.f32s("r"), &mut rin);
    assert_close(&rin, &fx.f32s("rin"), 1e-5).unwrap();
}

#[test]
fn golden_bn_fold_matches_python_reference() {
    let fx = Fixture::load("bn_fold");
    let c = fx.shape("gamma")[0];
    let w = fx.f32s("w");
    let mut wf = vec![f32::NAN; w.len()];
    let mut bf = vec![f32::NAN; c];
    linalg::bn_fold(
        &fx.f32s("gamma"),
        &fx.f32s("beta"),
        &fx.f32s("mean"),
        &fx.f32s("var"),
        BN_EPS,
        &w,
        &fx.f32s("b"),
        &mut wf,
        &mut bf,
    );
    assert_close(&wf, &fx.f32s("wf"), 1e-5).unwrap();
    assert_close(&bf, &fx.f32s("bf"), 1e-5).unwrap();
}

#[test]
fn golden_bn_train_matches_python_reference() {
    let fx = Fixture::load("bn_train");
    let (rows, c) = (fx.shape("z")[0], fx.shape("z")[1]);
    let z = fx.f32s("z");
    let gamma = fx.f32s("gamma");
    let mut y = vec![0.0f32; rows * c];
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    linalg::bn_train_fwd(&z, c, &gamma, &fx.f32s("beta"), BN_EPS, &mut y, &mut mean, &mut var);
    assert_close(&y, &fx.f32s("y"), 1e-5).unwrap();
    assert_close(&mean, &fx.f32s("mean"), 1e-5).unwrap();
    assert_close(&var, &fx.f32s("var"), 1e-5).unwrap();
    let mut dz = vec![0.0f32; rows * c];
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    linalg::bn_train_bwd(
        &z,
        c,
        &gamma,
        &mean,
        &var,
        BN_EPS,
        &fx.f32s("dy"),
        &mut dz,
        &mut dgamma,
        &mut dbeta,
    );
    assert_close(&dz, &fx.f32s("dz"), 1e-5).unwrap();
    assert_close(&dgamma, &fx.f32s("dgamma"), 1e-5).unwrap();
    assert_close(&dbeta, &fx.f32s("dbeta"), 1e-5).unwrap();
}

#[test]
fn golden_lrp_conv_ab_matches_python_reference() {
    let fx = Fixture::load("lrp_conv_ab");
    let g = conv_geom(&fx, "a", "w", 1, Pad::Same);
    let mut ws = Workspace::new();
    let mut rw = vec![0.0f32; g.filter_len()];
    let mut rin = vec![0.0f32; g.in_len()];
    linalg::lrp_conv_ab(
        &mut ws,
        &fx.f32s("a"),
        &fx.f32s("w"),
        &fx.f32s("r"),
        &g,
        linalg::LRP_ALPHA,
        linalg::LRP_BETA,
        &mut rw,
        &mut rin,
    );
    // the stabilized divisions amplify gemm accumulation-order noise a
    // touch beyond the plain-conv fixtures; the generator keeps |z±|
    // > 0.05 away from the stabilizer, 5e-5 absorbs the rest
    assert_close(&rw, &fx.f32s("rw"), 5e-5).unwrap();
    assert_close(&rin, &fx.f32s("rin"), 5e-5).unwrap();
}

#[test]
fn golden_ecqx_assign_matches_python_reference() {
    let fx = Fixture::load("ecqx_assign");
    let a = assign_raw(
        &fx.f32s("w"),
        &fx.f32s("r"),
        &fx.f32s("mask"),
        &fx.f32s("centroids"),
        &fx.f32s("cvalid"),
        fx.scalar("lam"),
    );
    assert_eq!(a.idx, fx.i32s("idx"), "assignment indices diverge");
    assert_close(&a.qw, &fx.f32s("qw"), 1e-5).unwrap();
    assert_close(&a.counts, &fx.f32s("counts"), 1e-5).unwrap();
}
