//! Sweep campaigns: lambda x p x bit-width grids producing the working
//! points of Figs. 6-10 and Table 1, plus candidate selection (Fig. 5
//! step 7).
//!
//! The grid fan-out itself lives in [`super::campaign`]; this module wires
//! it to the engine-backed QAT trial: every trial clones the shared
//! pre-trained snapshot, runs QAT at its grid point, and reports one
//! [`WorkingPoint`]. Rows are identical for any `jobs` count (see the
//! campaign module's determinism invariants).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::Result;

use super::assign::AssignConfig;
use super::binder::ParamSource;
use super::campaign::{self, CampaignOptions, Event, Grid, RetryPolicy, TrialSpec};
use super::store::{self, ResultStore, Row, StoreMeta};
use super::trainer::{evaluate, QatConfig, QatTrainer};
use super::{compressed_size, compression_ratio, Method};
use crate::data::{DataLoader, Dataset};
use crate::metrics::WorkingPoint;
use crate::nn::ModelState;
use crate::runtime::Engine;

/// One sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// model name (manifest key)
    pub model: String,
    /// default method for the lambda grid
    pub method: Method,
    /// default bit width for the lambda grid
    pub bits: u32,
    /// lambda grid
    pub lambdas: Vec<f32>,
    /// default target sparsity
    pub p: f64,
    /// QAT configuration template (per-trial assign fields are overridden)
    pub qat: QatConfig,
    /// accuracy of the unquantized baseline (for the drop column)
    pub baseline_acc: f64,
    /// campaign seed; per-trial seeds derive from it deterministically
    pub seed: u64,
}

/// Runs sweeps from a shared pre-trained snapshot.
pub struct SweepRunner<'e> {
    /// shared execution engine (Sync; workers call it concurrently)
    pub engine: &'e Engine,
    /// pre-trained FP parameter snapshot (cloned into every trial)
    pub pretrained: ModelState,
}

impl<'e> SweepRunner<'e> {
    /// New runner over `engine` from the `pretrained` snapshot.
    pub fn new(engine: &'e Engine, pretrained: ModelState) -> Self {
        SweepRunner { engine, pretrained }
    }

    fn fresh_state(&self) -> ModelState {
        ModelState {
            spec: self.pretrained.spec.clone(),
            params: self.pretrained.params.clone(),
            m: self.pretrained.m.clone(),
            v: self.pretrained.v.clone(),
            t: 0,
            qlayers: Default::default(),
        }
    }

    /// Run one grid trial: QAT at `trial`'s (method, bits, lambda, p),
    /// then a quantized validation pass; returns its working point and
    /// final state. Pure in `(cfg, trial)` given the shared snapshot and
    /// loaders, which is what makes parallel campaigns deterministic.
    pub fn run_trial_spec<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        trial: &TrialSpec,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
    ) -> Result<(WorkingPoint, ModelState)> {
        let mut state = self.fresh_state();
        let mut qat = cfg.qat.clone();
        qat.assign = AssignConfig {
            method: trial.method,
            bits: trial.bits,
            lambda: trial.lambda,
            p: trial.p,
            ..qat.assign
        };
        let trainer = QatTrainer::new(qat);
        let outcome = trainer.run(self.engine, &mut state, train, val)?;
        let ev = evaluate(self.engine, &state, val, ParamSource::Quantized)?;
        let wp = WorkingPoint {
            method: trial.method.as_str().to_string(),
            bits: trial.bits,
            lambda: trial.lambda,
            p: trial.p,
            accuracy: ev.accuracy,
            acc_drop: ev.accuracy - cfg.baseline_acc,
            sparsity: outcome.final_sparsity,
            size_bytes: compressed_size(&state),
            compression_ratio: compression_ratio(&state),
        };
        Ok((wp, state))
    }

    /// Run one (method, bits, lambda, p) trial with the config's default
    /// method/bits/p; returns its working point.
    pub fn run_trial<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        lambda: f32,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
    ) -> Result<(WorkingPoint, ModelState)> {
        let trial =
            TrialSpec { id: 0, method: cfg.method, bits: cfg.bits, lambda, p: cfg.p };
        self.run_trial_spec(cfg, &trial, train, val)
    }

    /// Sweep the whole lambda grid serially; one working point per lambda.
    pub fn run<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
    ) -> Result<Vec<WorkingPoint>> {
        self.run_parallel(cfg, train, val, 1)
    }

    /// Fan the lambda grid over `jobs` campaign workers sharing this
    /// engine. Rows come back in grid order and are bitwise identical to
    /// the serial run; per-trial summaries stream as trials finish when
    /// `cfg.qat.verbose` is set (per-epoch QAT logging is suppressed for
    /// `jobs > 1` since it would interleave across workers).
    pub fn run_parallel<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
        jobs: usize,
    ) -> Result<Vec<WorkingPoint>> {
        let grid = Grid::lambda_sweep(cfg.method, cfg.bits, &cfg.lambdas, cfg.p);
        let trials = grid.trials();
        let mut trial_cfg = cfg.clone();
        trial_cfg.qat.verbose = cfg.qat.verbose && jobs <= 1;
        let verbose = cfg.qat.verbose;
        let opts = CampaignOptions { jobs, seed: cfg.seed, ..Default::default() };
        campaign::run(
            &trials,
            &opts,
            // engine-backed trials are already pure in (snapshot, cfg,
            // trial): all their randomness derives from the loader seeds,
            // so the per-trial stream stays unused here — it serves trial
            // functions that need private randomness
            |t, _seed| {
                self.run_trial_spec(&trial_cfg, t, train, val).map(|(wp, _)| wp)
            },
            |ev| {
                if verbose {
                    if let Event::Finished { point: wp, wall_s, .. } = ev {
                        println!(
                            "  [sweep {} bw={} λ={:.4} p={:.2}] acc={:.4} \
                             (drop {:+.4}) sparsity={:.4} size={:.1}kB CR={:.1}x \
                             ({wall_s:.1}s)",
                            wp.method,
                            wp.bits,
                            wp.lambda,
                            wp.p,
                            wp.accuracy,
                            wp.acc_drop,
                            wp.sparsity,
                            wp.size_bytes as f64 / 1000.0,
                            wp.compression_ratio
                        );
                    }
                }
            },
        )
    }
}

/// Options for a durable (store-backed) sweep campaign.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreSweepOptions {
    /// worker threads (1 = serial; rows are identical regardless)
    pub jobs: usize,
    /// run only shard `(i, n)` of the grid (`id % n == i`); `None` = all
    pub shard: Option<(usize, usize)>,
    /// retry policy for failed trial attempts
    pub retry: RetryPolicy,
    /// emit a progress heartbeat every this many trial outcomes (0 = off)
    pub heartbeat_every: usize,
    /// cancel after this many trial outcomes this run (0 = unlimited).
    /// With `jobs == 1` exactly this many trials run — the deterministic
    /// interruption hook behind the resume tests and CI smoke job
    pub max_trials: usize,
    /// run trials on the deterministic linalg tier (`--deterministic`):
    /// scalar GEMM kernel, serial blocks — rows become bit-stable across
    /// machines. Recorded in the store meta line, so a store written in
    /// one mode refuses to resume in the other
    pub deterministic: bool,
}

/// What a durable sweep run did (this invocation).
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreSweepOutcome {
    /// trials attempted this run
    pub ran: usize,
    /// trials skipped because the store already had their results
    pub skipped: usize,
    /// trials whose latest outcome (across the whole store) is a failure
    pub quarantined: usize,
    /// true when cancellation (external flag or `max_trials`) stopped the
    /// run before the grid was exhausted
    pub cancelled: bool,
}

impl<'e> SweepRunner<'e> {
    /// Run a grid campaign against a durable [`ResultStore`]: every trial
    /// outcome is persisted (atomically) the moment it lands, completed
    /// points already in the store are skipped (resume), an optional
    /// shard spec restricts this process to its deterministic slice of
    /// the grid, failed trials are quarantined as store rows instead of
    /// aborting siblings, and `cancel` stops new claims while in-flight
    /// trials drain to disk.
    ///
    /// Determinism contract: the union of rows across any combination of
    /// shards, resumes, and job counts is bitwise identical to one
    /// uninterrupted serial campaign — rows contain no wall-clock fields
    /// and every trial's inputs derive only from `(cfg.seed, trial id)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_store<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        grid: &Grid,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
        result_store: &mut ResultStore,
        opts: &StoreSweepOptions,
        cancel: Option<&AtomicBool>,
    ) -> Result<StoreSweepOutcome> {
        // select the mode BEFORE querying it: the query latches the env
        // default into the set-once global, and the meta line must record
        // the mode the trials actually run under (either the flag or a
        // pre-set `$ECQX_DETERMINISTIC`)
        if opts.deterministic {
            crate::linalg::set_deterministic(true);
        }
        let full = grid.trials();
        let meta = StoreMeta {
            model: cfg.model.clone(),
            backend: self.engine.backend_name().to_string(),
            seed: cfg.seed,
            grid_hash: store::grid_hash(&full),
            n_trials: full.len(),
            det: crate::linalg::deterministic_mode(),
        };
        result_store.ensure_meta(&meta)?;
        let owned = match opts.shard {
            Some((i, n)) => store::shard_trials(&full, i, n),
            None => full.clone(),
        };
        let done = result_store.done_keys();
        let pending: Vec<TrialSpec> = owned
            .iter()
            .filter(|t| !done.contains(&store::trial_key(&meta, t)))
            .cloned()
            .collect();
        let skipped = owned.len() - pending.len();
        let key_of: HashMap<usize, u64> =
            pending.iter().map(|t| (t.id, store::trial_key(&meta, t))).collect();
        let n_pending = pending.len();
        let prior_done = done.len();
        // one flag merges external cancellation (signal, trial cap) with
        // internal must-stop conditions (a store write failure): workers
        // poll it before claiming, in-flight trials still drain to disk
        let local_cancel = AtomicBool::new(false);
        let cancel_flag = cancel.unwrap_or(&local_cancel);
        let mut store_err: Option<anyhow::Error> = None;
        let mut outcomes_seen = 0usize;
        let mut trial_cfg = cfg.clone();
        trial_cfg.qat.verbose = cfg.qat.verbose && opts.jobs <= 1;
        let copts = CampaignOptions {
            jobs: opts.jobs.max(1),
            seed: cfg.seed,
            retry: opts.retry,
            quarantine: true,
            heartbeat_every: opts.heartbeat_every,
            deterministic: opts.deterministic,
            ..Default::default()
        };
        let run = campaign::run_with(
            &pending,
            &copts,
            |t, _seed| {
                self.run_trial_spec(&trial_cfg, t, train, val).map(|(wp, _)| wp)
            },
            |ev| {
                let persist: Option<Row> = match ev {
                    Event::Finished { id, point, .. } => Some(Row {
                        key: key_of[id],
                        id: *id,
                        result: campaign::TrialResult::Done(point.clone()),
                    }),
                    Event::TrialFailed { id, error, attempts } => {
                        eprintln!(
                            "[sweep] trial {id} quarantined after {attempts} \
                             attempt(s): {}",
                            error.lines().next().unwrap_or("")
                        );
                        Some(Row {
                            key: key_of[id],
                            id: *id,
                            result: campaign::TrialResult::Failed {
                                error: error.clone(),
                                attempts: *attempts,
                            },
                        })
                    }
                    Event::TrialRetried { id, error, attempt } => {
                        eprintln!(
                            "[sweep] trial {id} attempt {attempt} failed, retrying \
                             with a fresh seed: {}",
                            error.lines().next().unwrap_or("")
                        );
                        None
                    }
                    Event::Heartbeat { done, failed, total } => {
                        println!(
                            "[sweep] {}/{} done ({skipped} resumed), {failed} \
                             quarantined this run ({}/{total} this shard)",
                            prior_done + done,
                            meta.n_trials,
                            done + failed
                        );
                        None
                    }
                    Event::Started { .. } => None,
                };
                if let Some(row) = persist {
                    outcomes_seen += 1;
                    if store_err.is_none() {
                        if let Err(e) = result_store.append(row) {
                            // stop claiming: results we cannot persist
                            // would be silently lost on the next crash
                            store_err = Some(e);
                            cancel_flag.store(true, Ordering::Relaxed);
                        }
                    }
                    if opts.max_trials > 0 && outcomes_seen >= opts.max_trials {
                        cancel_flag.store(true, Ordering::Relaxed);
                    }
                }
            },
            Some(cancel_flag),
        )?;
        if let Some(e) = store_err {
            return Err(e);
        }
        // cancelled = this run left owned trials unattempted (quarantine
        // mode means every *claimed* trial produces an outcome, so any
        // shortfall is unclaimed work that a resume will pick up)
        Ok(StoreSweepOutcome {
            ran: run.outcomes.len(),
            skipped,
            quarantined: result_store.quarantined().len(),
            cancelled: run.outcomes.len() < n_pending,
        })
    }
}

/// Candidate selection (Fig. 5 step 7 / Table 1 row kinds).
pub mod select {
    use crate::metrics::WorkingPoint;

    /// Highest-accuracy candidate.
    pub fn best_accuracy(points: &[WorkingPoint]) -> Option<&WorkingPoint> {
        points
            .iter()
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
    }

    /// Highest compression without model degradation (drop >= 0).
    pub fn best_cr_no_degradation(points: &[WorkingPoint]) -> Option<&WorkingPoint> {
        points
            .iter()
            .filter(|p| p.acc_drop >= 0.0)
            .max_by(|a, b| a.compression_ratio.partial_cmp(&b.compression_ratio).unwrap())
    }

    /// Highest compression with negligible degradation (drop >= -tol).
    pub fn best_cr_negligible(points: &[WorkingPoint], tol: f64) -> Option<&WorkingPoint> {
        points
            .iter()
            .filter(|p| p.acc_drop >= -tol)
            .max_by(|a, b| a.compression_ratio.partial_cmp(&b.compression_ratio).unwrap())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn wp(acc: f64, drop: f64, cr: f64) -> WorkingPoint {
            WorkingPoint {
                method: "ECQx".into(),
                bits: 4,
                lambda: 0.0,
                p: 0.3,
                accuracy: acc,
                acc_drop: drop,
                sparsity: 0.5,
                size_bytes: 1000,
                compression_ratio: cr,
            }
        }

        #[test]
        fn selection_criteria() {
            let pts = vec![
                wp(0.92, 0.02, 10.0),
                wp(0.91, 0.01, 30.0),
                wp(0.89, -0.01, 60.0),
                wp(0.80, -0.10, 100.0),
            ];
            assert_eq!(best_accuracy(&pts).unwrap().accuracy, 0.92);
            assert_eq!(best_cr_no_degradation(&pts).unwrap().compression_ratio, 30.0);
            assert_eq!(
                best_cr_negligible(&pts, 0.02).unwrap().compression_ratio,
                60.0
            );
            assert!(best_cr_negligible(&pts[3..], 0.02).is_none());
        }

        #[test]
        fn empty_points() {
            assert!(best_accuracy(&[]).is_none());
            assert!(best_cr_no_degradation(&[]).is_none());
        }
    }
}
