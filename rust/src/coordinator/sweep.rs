//! Sweep campaigns: lambda x p x bit-width grids producing the working
//! points of Figs. 6-10 and Table 1, plus candidate selection (Fig. 5
//! step 7).

use anyhow::Result;

use super::assign::{AssignConfig, Method};
use super::binder::ParamSource;
use super::trainer::{evaluate, QatConfig, QatTrainer};
use super::{compressed_size, compression_ratio};
use crate::data::{DataLoader, Dataset};
use crate::metrics::WorkingPoint;
use crate::nn::ModelState;
use crate::runtime::Engine;

/// One sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub model: String,
    pub method: Method,
    pub bits: u32,
    pub lambdas: Vec<f32>,
    pub p: f64,
    pub qat: QatConfig,
    /// accuracy of the unquantized baseline (for the drop column)
    pub baseline_acc: f64,
}

/// Runs sweeps from a shared pre-trained snapshot.
pub struct SweepRunner<'e> {
    pub engine: &'e Engine,
    /// pre-trained FP parameter snapshot (cloned into every trial)
    pub pretrained: ModelState,
}

impl<'e> SweepRunner<'e> {
    pub fn new(engine: &'e Engine, pretrained: ModelState) -> Self {
        SweepRunner { engine, pretrained }
    }

    fn fresh_state(&self) -> ModelState {
        ModelState {
            spec: self.pretrained.spec.clone(),
            params: self.pretrained.params.clone(),
            m: self.pretrained.m.clone(),
            v: self.pretrained.v.clone(),
            t: 0,
            qlayers: Default::default(),
        }
    }

    /// Run one (method, bits, lambda, p) trial; returns its working point.
    pub fn run_trial<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        lambda: f32,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
    ) -> Result<(WorkingPoint, ModelState)> {
        let mut state = self.fresh_state();
        let mut qat = cfg.qat.clone();
        qat.assign = AssignConfig {
            method: cfg.method,
            bits: cfg.bits,
            lambda,
            p: cfg.p,
            ..qat.assign
        };
        let trainer = QatTrainer::new(qat);
        let outcome = trainer.run(self.engine, &mut state, train, val)?;
        let ev = evaluate(self.engine, &state, val, ParamSource::Quantized)?;
        let wp = WorkingPoint {
            method: cfg.method.as_str().to_string(),
            bits: cfg.bits,
            lambda,
            p: cfg.p,
            accuracy: ev.accuracy,
            acc_drop: ev.accuracy - cfg.baseline_acc,
            sparsity: outcome.final_sparsity,
            size_bytes: compressed_size(&state),
            compression_ratio: compression_ratio(&state),
        };
        Ok((wp, state))
    }

    /// Sweep the whole lambda grid; returns one working point per lambda.
    pub fn run<D: Dataset>(
        &self,
        cfg: &SweepConfig,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
    ) -> Result<Vec<WorkingPoint>> {
        let mut points = Vec::with_capacity(cfg.lambdas.len());
        for &lam in &cfg.lambdas {
            let (wp, _) = self.run_trial(cfg, lam, train, val)?;
            if cfg.qat.verbose {
                println!(
                    "  [sweep {} bw={} λ={:.4} p={:.2}] acc={:.4} (drop {:+.4}) \
                     sparsity={:.4} size={:.1}kB CR={:.1}x",
                    cfg.method.as_str(),
                    cfg.bits,
                    lam,
                    cfg.p,
                    wp.accuracy,
                    wp.acc_drop,
                    wp.sparsity,
                    wp.size_bytes as f64 / 1000.0,
                    wp.compression_ratio
                );
            }
            points.push(wp);
        }
        Ok(points)
    }
}

/// Candidate selection (Fig. 5 step 7 / Table 1 row kinds).
pub mod select {
    use crate::metrics::WorkingPoint;

    /// Highest-accuracy candidate.
    pub fn best_accuracy(points: &[WorkingPoint]) -> Option<&WorkingPoint> {
        points
            .iter()
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
    }

    /// Highest compression without model degradation (drop >= 0).
    pub fn best_cr_no_degradation(points: &[WorkingPoint]) -> Option<&WorkingPoint> {
        points
            .iter()
            .filter(|p| p.acc_drop >= 0.0)
            .max_by(|a, b| a.compression_ratio.partial_cmp(&b.compression_ratio).unwrap())
    }

    /// Highest compression with negligible degradation (drop >= -tol).
    pub fn best_cr_negligible(points: &[WorkingPoint], tol: f64) -> Option<&WorkingPoint> {
        points
            .iter()
            .filter(|p| p.acc_drop >= -tol)
            .max_by(|a, b| a.compression_ratio.partial_cmp(&b.compression_ratio).unwrap())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn wp(acc: f64, drop: f64, cr: f64) -> WorkingPoint {
            WorkingPoint {
                method: "ECQx".into(),
                bits: 4,
                lambda: 0.0,
                p: 0.3,
                accuracy: acc,
                acc_drop: drop,
                sparsity: 0.5,
                size_bytes: 1000,
                compression_ratio: cr,
            }
        }

        #[test]
        fn selection_criteria() {
            let pts = vec![
                wp(0.92, 0.02, 10.0),
                wp(0.91, 0.01, 30.0),
                wp(0.89, -0.01, 60.0),
                wp(0.80, -0.10, 100.0),
            ];
            assert_eq!(best_accuracy(&pts).unwrap().accuracy, 0.92);
            assert_eq!(best_cr_no_degradation(&pts).unwrap().compression_ratio, 30.0);
            assert_eq!(
                best_cr_negligible(&pts, 0.02).unwrap().compression_ratio,
                60.0
            );
            assert!(best_cr_negligible(&pts[3..], 0.02).is_none());
        }

        #[test]
        fn empty_points() {
            assert!(best_accuracy(&[]).is_none());
            assert!(best_cr_no_degradation(&[]).is_none());
        }
    }
}
