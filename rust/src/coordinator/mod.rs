//! The L3 coordinator: quantization-aware training (the ECQ^x loop of
//! Fig. 5), parallel hyperparameter sweep campaigns, candidate selection
//! and reporting, plus the `ecqx serve` inference front end — the system
//! that actually runs (and serves) the paper's experiments.

pub mod assign;
pub mod binder;
pub mod campaign;
pub mod serve;
pub mod store;
pub mod sweep;
pub mod trainer;

pub use assign::{AssignConfig, Assigner, Method};
pub use campaign::{CampaignOptions, Grid, RetryPolicy, TrialSpec};
pub use serve::{ServeOptions, Server};
pub use store::ResultStore;
pub use sweep::{SweepConfig, SweepRunner, StoreSweepOptions, StoreSweepOutcome};
pub use trainer::{EvalResult, Pretrainer, QatConfig, QatTrainer};

use crate::codec;
use crate::nn::ModelState;

/// In-memory compressed size (bytes) of a quantized model: CABAC payloads
/// for quantized layers + raw fp32 for the rest + per-layer header,
/// matching the `.ecqx` container layout.
pub fn compressed_size(state: &ModelState) -> usize {
    compressed_size_jobs(state, 1)
}

/// [`compressed_size`] with the per-layer entropy coding fanned out over
/// `jobs` workers. Chunk boundaries are data-independent, so the result
/// is identical at any job count (serial == parallel, bitwise).
pub fn compressed_size_jobs(state: &ModelState, jobs: usize) -> usize {
    let mut total = 8; // magic
    let qnames = state.qnames();
    let inputs: Vec<_> = qnames
        .iter()
        .map(|name| {
            let ql = &state.qlayers[name];
            (&ql.idx, &ql.codebook)
        })
        .collect();
    for (name, enc) in qnames.iter().zip(codec::encode_tensors_jobs(&inputs, jobs)) {
        total += enc.payload.len() + 16 + name.len();
    }
    for (name, t) in &state.params {
        if state.qlayers.contains_key(name) {
            continue;
        }
        total += t.numel() * 4 + 8 + name.len();
    }
    total
}

/// Compression ratio vs the FP32 model (the paper's CR column).
pub fn compression_ratio(state: &ModelState) -> f64 {
    state.fp32_bytes() as f64 / compressed_size(state).max(1) as f64
}
