//! Parallel sweep campaigns: fan a lambda × p × bit-width (× method) grid
//! over a scoped-thread worker pool with deterministic per-trial seeding,
//! bounded in-flight trials, and a progress channel that streams
//! [`WorkingPoint`]s as they finish.
//!
//! The runner is generic over the trial function, so the same machinery
//! drives both the engine-backed QAT trials of [`super::sweep`] and the
//! synthetic trials of the determinism tests. Two invariants make results
//! independent of the job count:
//!
//! 1. every trial's inputs are a pure function of `(campaign seed,
//!    trial id)` — see [`trial_seed`] — never of execution order, and
//! 2. results are collected into grid order (by trial position), so the
//!    returned rows are bitwise identical for any `jobs`.
//!
//! On top of the determinism contract sits the robustness layer used by
//! the durable-store sweeps ([`run_with`]): every trial attempt runs
//! under `catch_unwind`, a failed attempt can be retried with a fresh
//! re-derived seed ([`RetryPolicy`], [`trial_seed_attempt`]), a trial
//! that exhausts its attempts can be *quarantined* (recorded as
//! [`TrialResult::Failed`] while its siblings keep running) instead of
//! tearing the campaign down, and an external cancellation flag stops new
//! claims while in-flight trials drain — the graceful-shutdown path
//! behind `ecqx sweep --resume`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::{anyhow, Context as _, Result};

use super::assign::Method;
use crate::metrics::WorkingPoint;
use crate::util::Rng;

/// One trial of a campaign grid: a full QAT run at one
/// (method, bits, lambda, p) working point.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    /// position in the grid; must be unique within one campaign
    pub id: usize,
    /// ECQ vs ECQx
    pub method: Method,
    /// quantization bit width
    pub bits: u32,
    /// entropy-constraint intensity
    pub lambda: f32,
    /// target-sparsity hyperparameter
    pub p: f64,
}

/// The lambda × p × bit-width (× method) grid of a campaign.
#[derive(Clone, Debug)]
pub struct Grid {
    /// methods to sweep (outermost loop)
    pub methods: Vec<Method>,
    /// bit widths to sweep
    pub bits: Vec<u32>,
    /// target sparsities to sweep
    pub ps: Vec<f64>,
    /// lambda grid (innermost loop, matching the classic lambda sweep)
    pub lambdas: Vec<f32>,
}

impl Grid {
    /// Single-method lambda sweep (the classic Figs. 6–10 campaign shape).
    pub fn lambda_sweep(method: Method, bits: u32, lambdas: &[f32], p: f64) -> Grid {
        Grid {
            methods: vec![method],
            bits: vec![bits],
            ps: vec![p],
            lambdas: lambdas.to_vec(),
        }
    }

    /// Materialize the trials in deterministic (method, bits, p, lambda)
    /// order; ids are grid positions.
    pub fn trials(&self) -> Vec<TrialSpec> {
        let mut out = Vec::with_capacity(self.len());
        for &method in &self.methods {
            for &bits in &self.bits {
                for &p in &self.ps {
                    for &lambda in &self.lambdas {
                        out.push(TrialSpec { id: out.len(), method, bits, lambda, p });
                    }
                }
            }
        }
        out
    }

    /// Number of trials in the grid.
    pub fn len(&self) -> usize {
        self.methods.len() * self.bits.len() * self.ps.len() * self.lambdas.len()
    }

    /// True when any axis is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Bounded retry of failed trial attempts. Attempt `k` (0-based) runs
/// with the re-derived seed [`trial_seed_attempt`]`(seed, id, k)`, so a
/// transiently-poisoned random stream cannot fail the same way twice;
/// attempt 0 uses the classic [`trial_seed`], keeping deterministic trial
/// functions bitwise-stable across retry policies.
#[derive(Clone, Copy, Debug, Default)]
pub struct RetryPolicy {
    /// extra attempts after the first (0 = fail on first error)
    pub retries: u32,
    /// base backoff before attempt k+1, doubling per retry (0 = none)
    pub backoff_ms: u64,
}

/// Options controlling the campaign worker pool.
#[derive(Clone, Copy, Debug)]
pub struct CampaignOptions {
    /// worker threads; 1 = serial. Results are identical regardless.
    pub jobs: usize,
    /// cap on concurrently running trials (bounds peak memory — every
    /// trial holds a model-state clone). Each worker runs one trial at a
    /// time, so this simply clamps the effective worker count; 0 = no
    /// extra bound beyond `jobs`
    pub max_in_flight: usize,
    /// campaign-level seed; per-trial seeds derive from it and the trial id
    pub seed: u64,
    /// retry failed attempts before declaring the trial failed
    pub retry: RetryPolicy,
    /// quarantine exhausted trials (record + continue) instead of
    /// failing the campaign fast
    pub quarantine: bool,
    /// emit [`Event::Heartbeat`] every this many trial outcomes (0 = off)
    pub heartbeat_every: usize,
    /// run trials on the deterministic linalg tier (scalar GEMM kernel,
    /// serial blocks — `--deterministic`): rows become bit-stable across
    /// machines, not just across `--jobs` counts. Selects the
    /// process-wide mode via [`crate::linalg::set_deterministic`]
    /// (set-once, so one process cannot mix tiers inside a store)
    pub deterministic: bool,
}

impl Default for CampaignOptions {
    fn default() -> Self {
        CampaignOptions {
            jobs: 1,
            max_in_flight: 0,
            seed: 17,
            retry: RetryPolicy::default(),
            quarantine: false,
            heartbeat_every: 0,
            deterministic: false,
        }
    }
}

/// Done/failed accounting plus the heartbeat cadence, shared by the two
/// run paths of [`run_with`] (serial and collector) so they cannot drift
/// on when a [`Event::Heartbeat`] fires or what counts it carries.
struct HeartbeatCounter {
    done: usize,
    failed: usize,
    total: usize,
    every: usize,
}

impl HeartbeatCounter {
    fn new(total: usize, every: usize) -> HeartbeatCounter {
        HeartbeatCounter { done: 0, failed: 0, total, every }
    }

    /// Record one trial outcome; emits the heartbeat event when the
    /// cadence lands on this outcome (`every == 0` disables).
    fn record(&mut self, failed: bool, mut emit: impl FnMut(Event)) {
        if failed {
            self.failed += 1;
        } else {
            self.done += 1;
        }
        if self.every > 0 && (self.done + self.failed) % self.every == 0 {
            emit(Event::Heartbeat { done: self.done, failed: self.failed, total: self.total });
        }
    }
}

/// Progress events streamed (on the caller's thread) while a campaign runs.
#[derive(Clone, Debug)]
pub enum Event {
    /// a worker picked up a trial
    Started {
        /// trial id
        id: usize,
    },
    /// a trial finished; its row is available immediately
    Finished {
        /// trial id
        id: usize,
        /// the finished working point
        point: WorkingPoint,
        /// trial wall-clock seconds
        wall_s: f64,
    },
    /// an attempt failed and a retry with a re-derived seed follows
    TrialRetried {
        /// trial id
        id: usize,
        /// rendered error chain of the failed attempt
        error: String,
        /// 1-based attempt number that just failed
        attempt: u32,
    },
    /// a trial exhausted its attempts. Quarantined (siblings continue)
    /// when [`CampaignOptions::quarantine`] is set, fatal otherwise
    TrialFailed {
        /// trial id
        id: usize,
        /// rendered error chain of the last attempt
        error: String,
        /// attempts consumed (1 + retries actually taken)
        attempts: u32,
    },
    /// periodic progress: emitted after every
    /// [`CampaignOptions::heartbeat_every`] trial outcomes
    Heartbeat {
        /// trials finished successfully so far (this run)
        done: usize,
        /// trials quarantined so far (this run)
        failed: usize,
        /// trials this run will attempt
        total: usize,
    },
}

/// Terminal outcome of one trial.
#[derive(Clone, Debug)]
pub enum TrialResult {
    /// the trial produced a working point
    Done(WorkingPoint),
    /// the trial failed every attempt and was quarantined
    Failed {
        /// rendered error chain of the last attempt
        error: String,
        /// attempts consumed
        attempts: u32,
    },
}

/// One trial's terminal outcome, tagged with its grid id.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    /// trial id (grid position)
    pub id: usize,
    /// what happened
    pub result: TrialResult,
}

/// What a [`run_with`] campaign produced: grid-ordered outcomes for every
/// trial that ran to completion this invocation. Trials never claimed
/// (cancelled, or drained after a fatal failure) are simply absent.
#[derive(Clone, Debug, Default)]
pub struct CampaignRun {
    /// terminal outcomes in grid order
    pub outcomes: Vec<TrialOutcome>,
    /// true when the cancellation flag stopped the campaign early
    pub cancelled: bool,
}

fn trial_context(t: &TrialSpec) -> String {
    format!(
        "campaign trial {} ({} {}bit λ={} p={})",
        t.id,
        t.method.as_str(),
        t.bits,
        t.lambda,
        t.p
    )
}

/// Deterministic per-trial RNG seed: a stateless SplitMix-style mix of the
/// campaign seed and the trial id, so trial `k` sees the same stream no
/// matter which worker runs it or in what order.
pub fn trial_seed(campaign_seed: u64, trial_id: u64) -> u64 {
    let mut r = Rng::new(campaign_seed ^ trial_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    r.next_u64()
}

/// Per-attempt trial seed: attempt 0 is exactly [`trial_seed`] (so retry
/// policies do not perturb deterministic campaigns), later attempts mix
/// the attempt index into the campaign seed so a retry sees a fresh,
/// reproducible stream.
pub fn trial_seed_attempt(campaign_seed: u64, trial_id: u64, attempt: u32) -> u64 {
    if attempt == 0 {
        return trial_seed(campaign_seed, trial_id);
    }
    let mixed = campaign_seed ^ (attempt as u64).wrapping_mul(0xA076_1D64_78BD_642F);
    trial_seed(mixed, trial_id)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run all attempts of one trial: `catch_unwind` around every attempt (a
/// panicking trial is an error, not a pool teardown), bounded retries
/// with doubling backoff, `emit` called for each retry event.
///
/// `AssertUnwindSafe` is sound here because a failed attempt's partial
/// effects are confined to the attempt: trial functions receive shared
/// state immutably (`F: Fn + Sync`) and build their outputs privately, so
/// nothing observable is left half-mutated when an unwind is caught.
fn attempt_trial<F>(
    t: &TrialSpec,
    campaign_seed: u64,
    retry: RetryPolicy,
    run_trial: &F,
    mut emit: impl FnMut(Event),
) -> TrialResult
where
    F: Fn(&TrialSpec, u64) -> Result<WorkingPoint> + Sync,
{
    let attempts_max = retry.retries.saturating_add(1);
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        if attempt > 1 && retry.backoff_ms > 0 {
            let shift = (attempt - 2).min(6);
            std::thread::sleep(std::time::Duration::from_millis(
                retry.backoff_ms << shift,
            ));
        }
        let seed = trial_seed_attempt(campaign_seed, t.id as u64, attempt - 1);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_trial(t, seed)
        }))
        .unwrap_or_else(|p| Err(anyhow!("trial panicked: {}", panic_message(&*p))));
        match res {
            Ok(point) => return TrialResult::Done(point),
            Err(e) if attempt < attempts_max => {
                emit(Event::TrialRetried {
                    id: t.id,
                    error: format!("{e:?}"),
                    attempt,
                });
            }
            Err(e) => {
                return TrialResult::Failed { error: format!("{e:?}"), attempts: attempt }
            }
        }
    }
}

/// Run every trial through `run_trial`, fanning out over `opts.jobs`
/// scoped worker threads, with panic isolation, bounded retries,
/// optional quarantine, and cooperative cancellation.
///
/// `run_trial` receives the trial spec and its per-attempt seed
/// ([`trial_seed_attempt`]); it must be a pure function of those (plus
/// shared immutable state) for the determinism guarantee to hold.
/// `on_event` is invoked on the calling thread, in completion order —
/// the durable-store sweep uses it to persist each row as it lands.
/// When `cancel` is set (by a signal handler, a trial cap, or a store
/// error), workers stop claiming new trials, in-flight trials drain to
/// their events, and the run returns with `cancelled = true` — resuming
/// later from a persisted store re-runs exactly the absent trials.
///
/// Failure semantics: a trial that exhausts its attempts becomes a
/// [`TrialResult::Failed`] outcome. With `opts.quarantine` the campaign
/// keeps going (the paper grid loses one dot, not hours of compute);
/// without it, workers stop claiming and the caller decides — [`run`]
/// turns the lowest-grid-position failure into an error, preserving the
/// classic fail-fast contract.
pub fn run_with<F>(
    trials: &[TrialSpec],
    opts: &CampaignOptions,
    run_trial: F,
    mut on_event: impl FnMut(&Event),
    cancel: Option<&AtomicBool>,
) -> Result<CampaignRun>
where
    F: Fn(&TrialSpec, u64) -> Result<WorkingPoint> + Sync,
{
    let n = trials.len();
    if n == 0 {
        return Ok(CampaignRun::default());
    }
    let pos_of: HashMap<usize, usize> =
        trials.iter().enumerate().map(|(pos, t)| (t.id, pos)).collect();
    if pos_of.len() != n {
        anyhow::bail!("campaign trial ids must be unique");
    }
    let mut jobs = opts.jobs.max(1).min(n);
    if opts.max_in_flight != 0 {
        jobs = jobs.min(opts.max_in_flight.max(1));
    }
    if opts.deterministic {
        crate::linalg::set_deterministic(true);
    }
    let seed = opts.seed;
    let retry = opts.retry;
    let is_cancelled = || cancel.map_or(false, |c| c.load(Ordering::Relaxed));
    if jobs == 1 {
        // strictly serial: run on the caller's thread (no worker, so
        // trial output and streamed events stay in order)
        let mut outcomes = Vec::with_capacity(n);
        let mut hb = HeartbeatCounter::new(n, opts.heartbeat_every);
        let mut cancelled = false;
        for t in trials {
            if is_cancelled() {
                cancelled = true;
                break;
            }
            on_event(&Event::Started { id: t.id });
            let t0 = std::time::Instant::now();
            let result = attempt_trial(t, seed, retry, &run_trial, |ev| on_event(&ev));
            let is_failed = match &result {
                TrialResult::Done(point) => {
                    on_event(&Event::Finished {
                        id: t.id,
                        point: point.clone(),
                        wall_s: t0.elapsed().as_secs_f64(),
                    });
                    false
                }
                TrialResult::Failed { error, attempts } => {
                    on_event(&Event::TrialFailed {
                        id: t.id,
                        error: error.clone(),
                        attempts: *attempts,
                    });
                    true
                }
            };
            outcomes.push(TrialOutcome { id: t.id, result });
            hb.record(is_failed, |ev| on_event(&ev));
            if is_failed && !opts.quarantine {
                break; // fail fast: stop claiming further trials
            }
        }
        return Ok(CampaignRun { outcomes, cancelled });
    }
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<Event>();
    let mut slots: Vec<Option<TrialResult>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let stop = &stop;
            let run_trial = &run_trial;
            let quarantine = opts.quarantine;
            s.spawn(move || loop {
                // check stop/cancel BEFORE claiming: a claimed index must
                // always run to an event, or the result set would have
                // silent holes that look like completed-and-lost trials
                if stop.load(Ordering::Relaxed)
                    || cancel.map_or(false, |c| c.load(Ordering::Relaxed))
                {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let t = &trials[i];
                if tx.send(Event::Started { id: t.id }).is_err() {
                    break;
                }
                let t0 = std::time::Instant::now();
                let result = attempt_trial(t, seed, retry, run_trial, |ev| {
                    let _ = tx.send(ev);
                });
                let ev = match result {
                    TrialResult::Done(point) => Event::Finished {
                        id: t.id,
                        point,
                        wall_s: t0.elapsed().as_secs_f64(),
                    },
                    TrialResult::Failed { error, attempts } => {
                        if !quarantine {
                            // fail fast: no new claims; running trials drain
                            stop.store(true, Ordering::Relaxed);
                        }
                        Event::TrialFailed { id: t.id, error, attempts }
                    }
                };
                if tx.send(ev).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        // collector: stream events to the caller, file results by position
        let mut hb = HeartbeatCounter::new(n, opts.heartbeat_every);
        for ev in rx {
            let outcome = match &ev {
                Event::Finished { id, point, .. } => {
                    slots[pos_of[id]] = Some(TrialResult::Done(point.clone()));
                    Some(false)
                }
                Event::TrialFailed { id, error, attempts } => {
                    slots[pos_of[id]] = Some(TrialResult::Failed {
                        error: error.clone(),
                        attempts: *attempts,
                    });
                    Some(true)
                }
                _ => None,
            };
            on_event(&ev);
            if let Some(is_failed) = outcome {
                hb.record(is_failed, |ev| on_event(&ev));
            }
        }
    });
    let outcomes = slots
        .into_iter()
        .enumerate()
        .filter_map(|(pos, slot)| {
            slot.map(|result| TrialOutcome { id: trials[pos].id, result })
        })
        .collect();
    Ok(CampaignRun { outcomes, cancelled: is_cancelled() })
}

/// Classic strict campaign: every trial must succeed; the rows come back
/// in grid order, bitwise identical for any job count.
///
/// Thin wrapper over [`run_with`] (no cancellation) that converts the
/// lowest-grid-position failure into an error — claims are handed out in
/// grid order, so every position before a failure has a result and the
/// error choice is deterministic.
pub fn run<F>(
    trials: &[TrialSpec],
    opts: &CampaignOptions,
    run_trial: F,
    on_event: impl FnMut(&Event),
) -> Result<Vec<WorkingPoint>>
where
    F: Fn(&TrialSpec, u64) -> Result<WorkingPoint> + Sync,
{
    let by_id: HashMap<usize, &TrialSpec> = trials.iter().map(|t| (t.id, t)).collect();
    let run = run_with(trials, opts, run_trial, on_event, None)?;
    // outcomes are grid-ordered, so the first failure is the lowest position
    let mut got: HashMap<usize, WorkingPoint> = HashMap::with_capacity(trials.len());
    for o in run.outcomes {
        match o.result {
            TrialResult::Done(p) => {
                got.insert(o.id, p);
            }
            TrialResult::Failed { error, .. } => {
                return Err(anyhow!("{error}")).with_context(|| trial_context(by_id[&o.id]));
            }
        }
    }
    let mut points = Vec::with_capacity(trials.len());
    for t in trials {
        match got.remove(&t.id) {
            Some(p) => points.push(p),
            None => anyhow::bail!("campaign trial {} never produced a result", t.id),
        }
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_order_is_deterministic() {
        let g = Grid {
            methods: vec![Method::Ecq, Method::Ecqx],
            bits: vec![2, 4],
            ps: vec![0.15],
            lambdas: vec![0.0, 0.1],
        };
        let trials = g.trials();
        assert_eq!(trials.len(), g.len());
        assert_eq!(trials.len(), 8);
        assert!(!g.is_empty());
        // ids are positions; lambda is the innermost axis
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.id, i);
        }
        assert_eq!(trials[0].method, Method::Ecq);
        assert_eq!((trials[0].lambda, trials[1].lambda), (0.0, 0.1));
        assert_eq!((trials[0].bits, trials[2].bits), (2, 4));
        assert_eq!(trials[4].method, Method::Ecqx);
    }

    #[test]
    fn trial_seeds_are_stable_and_distinct() {
        let seeds: Vec<u64> = (0..64).map(|i| trial_seed(17, i)).collect();
        let again: Vec<u64> = (0..64).map(|i| trial_seed(17, i)).collect();
        assert_eq!(seeds, again);
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "per-trial seeds must differ");
        assert_ne!(trial_seed(17, 0), trial_seed(18, 0), "campaign seed matters");
    }

    #[test]
    fn attempt_seeds_rederive_per_attempt() {
        // attempt 0 is the classic trial seed: retry policies must not
        // perturb deterministic campaigns
        assert_eq!(trial_seed_attempt(17, 3, 0), trial_seed(17, 3));
        // later attempts see fresh, reproducible streams
        let a1 = trial_seed_attempt(17, 3, 1);
        let a2 = trial_seed_attempt(17, 3, 2);
        assert_ne!(a1, trial_seed(17, 3));
        assert_ne!(a1, a2);
        assert_eq!(a1, trial_seed_attempt(17, 3, 1));
    }

    #[test]
    fn empty_grid_runs_to_empty() {
        let points = run(
            &[],
            &CampaignOptions::default(),
            |_, _| unreachable!(),
            |_| {},
        )
        .unwrap();
        assert!(points.is_empty());
    }

    #[test]
    fn heartbeat_counter_cadence_and_accounting() {
        // the single source of truth both run paths share: fires every
        // `every` outcomes, carrying cumulative done/failed
        let mut hb = HeartbeatCounter::new(5, 2);
        let mut beats: Vec<(usize, usize, usize)> = Vec::new();
        for failed in [false, true, false, false, true] {
            hb.record(failed, |ev| {
                if let Event::Heartbeat { done, failed, total } = ev {
                    beats.push((done, failed, total));
                }
            });
        }
        assert_eq!(beats, vec![(1, 1, 5), (3, 1, 5)]);
        // every == 0 disables emission but still counts
        let mut off = HeartbeatCounter::new(3, 0);
        off.record(false, |_| panic!("heartbeat_every=0 must not emit"));
        off.record(true, |_| panic!("heartbeat_every=0 must not emit"));
        assert_eq!((off.done, off.failed), (1, 1));
    }

    #[test]
    fn duplicate_ids_rejected() {
        let t = TrialSpec { id: 0, method: Method::Ecq, bits: 4, lambda: 0.0, p: 0.3 };
        let r = run(
            &[t.clone(), t],
            &CampaignOptions::default(),
            |_, _| unreachable!(),
            |_| {},
        );
        assert!(format!("{:?}", r.unwrap_err()).contains("unique"));
    }
}
