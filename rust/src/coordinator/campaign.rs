//! Parallel sweep campaigns: fan a lambda × p × bit-width (× method) grid
//! over a scoped-thread worker pool with deterministic per-trial seeding,
//! bounded in-flight trials, and a progress channel that streams
//! [`WorkingPoint`]s as they finish.
//!
//! The runner is generic over the trial function, so the same machinery
//! drives both the engine-backed QAT trials of [`super::sweep`] and the
//! synthetic trials of the determinism tests. Two invariants make results
//! independent of the job count:
//!
//! 1. every trial's inputs are a pure function of `(campaign seed,
//!    trial id)` — see [`trial_seed`] — never of execution order, and
//! 2. results are collected into grid order (by trial position), so the
//!    returned rows are bitwise identical for any `jobs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::{anyhow, Context as _, Result};

use super::assign::Method;
use crate::metrics::WorkingPoint;
use crate::util::Rng;

/// One trial of a campaign grid: a full QAT run at one
/// (method, bits, lambda, p) working point.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    /// position in the grid; must be unique within one campaign
    pub id: usize,
    /// ECQ vs ECQx
    pub method: Method,
    /// quantization bit width
    pub bits: u32,
    /// entropy-constraint intensity
    pub lambda: f32,
    /// target-sparsity hyperparameter
    pub p: f64,
}

/// The lambda × p × bit-width (× method) grid of a campaign.
#[derive(Clone, Debug)]
pub struct Grid {
    /// methods to sweep (outermost loop)
    pub methods: Vec<Method>,
    /// bit widths to sweep
    pub bits: Vec<u32>,
    /// target sparsities to sweep
    pub ps: Vec<f64>,
    /// lambda grid (innermost loop, matching the classic lambda sweep)
    pub lambdas: Vec<f32>,
}

impl Grid {
    /// Single-method lambda sweep (the classic Figs. 6–10 campaign shape).
    pub fn lambda_sweep(method: Method, bits: u32, lambdas: &[f32], p: f64) -> Grid {
        Grid {
            methods: vec![method],
            bits: vec![bits],
            ps: vec![p],
            lambdas: lambdas.to_vec(),
        }
    }

    /// Materialize the trials in deterministic (method, bits, p, lambda)
    /// order; ids are grid positions.
    pub fn trials(&self) -> Vec<TrialSpec> {
        let mut out = Vec::with_capacity(self.len());
        for &method in &self.methods {
            for &bits in &self.bits {
                for &p in &self.ps {
                    for &lambda in &self.lambdas {
                        out.push(TrialSpec { id: out.len(), method, bits, lambda, p });
                    }
                }
            }
        }
        out
    }

    /// Number of trials in the grid.
    pub fn len(&self) -> usize {
        self.methods.len() * self.bits.len() * self.ps.len() * self.lambdas.len()
    }

    /// True when any axis is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Options controlling the campaign worker pool.
#[derive(Clone, Copy, Debug)]
pub struct CampaignOptions {
    /// worker threads; 1 = serial. Results are identical regardless.
    pub jobs: usize,
    /// cap on concurrently running trials (bounds peak memory — every
    /// trial holds a model-state clone). Each worker runs one trial at a
    /// time, so this simply clamps the effective worker count; 0 = no
    /// extra bound beyond `jobs`
    pub max_in_flight: usize,
    /// campaign-level seed; per-trial seeds derive from it and the trial id
    pub seed: u64,
}

impl Default for CampaignOptions {
    fn default() -> Self {
        CampaignOptions { jobs: 1, max_in_flight: 0, seed: 17 }
    }
}

/// Progress events streamed (on the caller's thread) while a campaign runs.
#[derive(Clone, Debug)]
pub enum Event {
    /// a worker picked up a trial
    Started {
        /// trial id
        id: usize,
    },
    /// a trial finished; its row is available immediately
    Finished {
        /// trial id
        id: usize,
        /// the finished working point
        point: WorkingPoint,
        /// trial wall-clock seconds
        wall_s: f64,
    },
    /// a trial failed (the campaign still drains, then errors)
    Failed {
        /// trial id
        id: usize,
        /// rendered error chain
        error: String,
    },
}

fn trial_context(t: &TrialSpec) -> String {
    format!(
        "campaign trial {} ({} {}bit λ={} p={})",
        t.id,
        t.method.as_str(),
        t.bits,
        t.lambda,
        t.p
    )
}

/// Deterministic per-trial RNG seed: a stateless SplitMix-style mix of the
/// campaign seed and the trial id, so trial `k` sees the same stream no
/// matter which worker runs it or in what order.
pub fn trial_seed(campaign_seed: u64, trial_id: u64) -> u64 {
    let mut r = Rng::new(campaign_seed ^ trial_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    r.next_u64()
}

/// Run every trial through `run_trial`, fanning out over `opts.jobs`
/// scoped worker threads.
///
/// `run_trial` receives the trial spec and its [`trial_seed`]-derived seed;
/// it must be a pure function of those (plus shared immutable state such as
/// the engine and pre-trained snapshot) for the determinism guarantee to
/// hold. `on_event` is invoked on the calling thread, in completion order,
/// as trials start and finish — use it to stream progress. The returned
/// rows are in grid order (trial position), identical for any job count.
///
/// On trial failure the campaign fails fast: workers stop claiming new
/// trials, already-running trials drain, and the failed trial's error is
/// returned (lowest grid position first — claims are handed out in grid
/// order, so every position before a failure has a result and the error
/// choice is deterministic).
pub fn run<F>(
    trials: &[TrialSpec],
    opts: &CampaignOptions,
    run_trial: F,
    mut on_event: impl FnMut(&Event),
) -> Result<Vec<WorkingPoint>>
where
    F: Fn(&TrialSpec, u64) -> Result<WorkingPoint> + Sync,
{
    let n = trials.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let pos_of: HashMap<usize, usize> =
        trials.iter().enumerate().map(|(pos, t)| (t.id, pos)).collect();
    if pos_of.len() != n {
        anyhow::bail!("campaign trial ids must be unique");
    }
    let mut jobs = opts.jobs.max(1).min(n);
    if opts.max_in_flight != 0 {
        jobs = jobs.min(opts.max_in_flight.max(1));
    }
    let seed = opts.seed;
    if jobs == 1 {
        // strictly serial: run on the caller's thread (no worker, so
        // trial output and streamed events stay in order) and fail fast
        let mut points = Vec::with_capacity(n);
        for t in trials {
            on_event(&Event::Started { id: t.id });
            let t0 = std::time::Instant::now();
            match run_trial(t, trial_seed(seed, t.id as u64)) {
                Ok(point) => {
                    on_event(&Event::Finished {
                        id: t.id,
                        point: point.clone(),
                        wall_s: t0.elapsed().as_secs_f64(),
                    });
                    points.push(point);
                }
                Err(e) => {
                    on_event(&Event::Failed { id: t.id, error: format!("{e:?}") });
                    return Err(e).with_context(|| trial_context(t));
                }
            }
        }
        return Ok(points);
    }
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<Event>();
    let mut slots: Vec<Option<Result<WorkingPoint>>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let stop = &stop;
            let run_trial = &run_trial;
            s.spawn(move || loop {
                // check stop BEFORE claiming: a claimed index must always
                // run to an event, or the result prefix would have holes
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let t = &trials[i];
                if tx.send(Event::Started { id: t.id }).is_err() {
                    break;
                }
                let t0 = std::time::Instant::now();
                let ev = match run_trial(t, trial_seed(seed, t.id as u64)) {
                    Ok(point) => Event::Finished {
                        id: t.id,
                        point,
                        wall_s: t0.elapsed().as_secs_f64(),
                    },
                    Err(e) => {
                        // fail fast: no new claims; running trials drain
                        stop.store(true, Ordering::Relaxed);
                        Event::Failed { id: t.id, error: format!("{e:?}") }
                    }
                };
                if tx.send(ev).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        // collector: stream events to the caller, file results by position
        for ev in rx {
            match &ev {
                Event::Finished { id, point, .. } => {
                    slots[pos_of[id]] = Some(Ok(point.clone()));
                }
                Event::Failed { id, error } => {
                    slots[pos_of[id]] = Some(Err(anyhow!("{error}")));
                }
                Event::Started { .. } => {}
            }
            on_event(&ev);
        }
    });
    // lowest-position error wins; a None slot is only legitimate when the
    // campaign stopped early after a failure elsewhere, so errors are
    // preferred over missing-result complaints
    let mut points = Vec::with_capacity(n);
    let mut first_err: Option<(usize, anyhow::Error)> = None;
    let mut first_missing: Option<usize> = None;
    for (pos, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(p)) => points.push(p),
            Some(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some((pos, e));
                }
            }
            None => {
                if first_missing.is_none() {
                    first_missing = Some(pos);
                }
            }
        }
    }
    if let Some((pos, e)) = first_err {
        return Err(e).with_context(|| trial_context(&trials[pos]));
    }
    if let Some(pos) = first_missing {
        anyhow::bail!("campaign trial {} never produced a result", trials[pos].id);
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_order_is_deterministic() {
        let g = Grid {
            methods: vec![Method::Ecq, Method::Ecqx],
            bits: vec![2, 4],
            ps: vec![0.15],
            lambdas: vec![0.0, 0.1],
        };
        let trials = g.trials();
        assert_eq!(trials.len(), g.len());
        assert_eq!(trials.len(), 8);
        assert!(!g.is_empty());
        // ids are positions; lambda is the innermost axis
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.id, i);
        }
        assert_eq!(trials[0].method, Method::Ecq);
        assert_eq!((trials[0].lambda, trials[1].lambda), (0.0, 0.1));
        assert_eq!((trials[0].bits, trials[2].bits), (2, 4));
        assert_eq!(trials[4].method, Method::Ecqx);
    }

    #[test]
    fn trial_seeds_are_stable_and_distinct() {
        let seeds: Vec<u64> = (0..64).map(|i| trial_seed(17, i)).collect();
        let again: Vec<u64> = (0..64).map(|i| trial_seed(17, i)).collect();
        assert_eq!(seeds, again);
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "per-trial seeds must differ");
        assert_ne!(trial_seed(17, 0), trial_seed(18, 0), "campaign seed matters");
    }

    #[test]
    fn empty_grid_runs_to_empty() {
        let points = run(
            &[],
            &CampaignOptions::default(),
            |_, _| unreachable!(),
            |_| {},
        )
        .unwrap();
        assert!(points.is_empty());
    }

    #[test]
    fn duplicate_ids_rejected() {
        let t = TrialSpec { id: 0, method: Method::Ecq, bits: 4, lambda: 0.0, p: 0.3 };
        let r = run(
            &[t.clone(), t],
            &CampaignOptions::default(),
            |_, _| unreachable!(),
            |_| {},
        );
        assert!(format!("{:?}", r.unwrap_err()).contains("unique"));
    }
}
