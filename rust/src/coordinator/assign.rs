//! Per-layer cluster (re-)assignment through the `assign_<bucket>` HLO
//! artifact (the L1 Pallas kernel), including the ECQ^x relevance factors
//! and the target-sparsity-p beta controller (Sec. 4.2).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::nn::{ModelState, QLayer};
use crate::quant::relevance::{control_beta, cost_factors, RelevanceState};
use crate::quant::{lambda_scale, Codebook};
use crate::runtime::Engine;
use crate::tensor::{Tensor, TensorI32, Value};

/// ECQ (entropy only) vs ECQ^x (entropy + LRP relevances).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Ecq,
    Ecqx,
}

impl Method {
    /// Paper-style display name ("ECQ" / "ECQx").
    pub fn as_str(&self) -> &'static str {
        match self {
            Method::Ecq => "ECQ",
            Method::Ecqx => "ECQx",
        }
    }
}

/// Configuration of the (re-)assignment step.
#[derive(Clone, Debug)]
pub struct AssignConfig {
    /// ECQ vs ECQx
    pub method: Method,
    pub bits: u32,
    /// global entropy-constraint intensity (per-layer scaled)
    pub lambda: f32,
    /// target sparsity p: upper bound on LRP-induced extra sparsity
    pub p: f64,
    /// initial gamma exponent for the relevance transform
    pub beta0: f32,
    /// relevance EMA momentum
    pub momentum: f32,
    pub max_beta_halvings: u32,
}

impl Default for AssignConfig {
    fn default() -> Self {
        AssignConfig {
            method: Method::Ecqx,
            bits: 4,
            lambda: 0.02,
            p: 0.3,
            beta0: 1.0,
            momentum: 0.9,
            max_beta_halvings: 6,
        }
    }
}

/// Stateful assigner: holds relevance EMAs + tuned betas per layer.
pub struct Assigner {
    pub cfg: AssignConfig,
    pub rel: BTreeMap<String, RelevanceState>,
    /// per-layer tuned beta (refreshed when relevances refresh)
    pub beta: BTreeMap<String, f32>,
    /// per-layer cached cost factors
    factors: BTreeMap<String, Vec<f32>>,
    /// largest quantized layer numel (for lambda scaling)
    max_numel: usize,
}

impl Assigner {
    /// Fresh assigner over the quantized layers of `state`.
    pub fn new(cfg: AssignConfig, state: &ModelState) -> Self {
        let mut rel = BTreeMap::new();
        let mut beta = BTreeMap::new();
        let mut max_numel = 0;
        for p in state.spec.quantized_params() {
            rel.insert(p.name.clone(), RelevanceState::new(p.numel(), cfg.momentum));
            beta.insert(p.name.clone(), cfg.beta0);
            max_numel = max_numel.max(p.numel());
        }
        Assigner { cfg, rel, beta, factors: BTreeMap::new(), max_numel }
    }

    /// Fold a new batch of raw LRP relevances (from the `<m>_lrp` artifact)
    /// into the per-layer EMAs. With `retune == true`, also re-tune beta
    /// via the target-sparsity-p controller (costs extra assign calls);
    /// otherwise only the cost factors are refreshed at the cached beta.
    /// Returns per-layer (beta, extra_sparsity) diagnostics when retuning.
    pub fn update_relevances(
        &mut self,
        engine: &Engine,
        state: &ModelState,
        raw: &BTreeMap<String, Tensor>,
        retune: bool,
    ) -> Result<BTreeMap<String, (f32, f64)>> {
        let mut diag = BTreeMap::new();
        for (name, t) in raw {
            self.rel.get_mut(name).unwrap().update(&t.data);
        }
        if !retune {
            for name in state.qnames() {
                let norm = self.rel[&name].normalized();
                let f = cost_factors(&norm, self.beta[&name]);
                self.factors.insert(name, f);
            }
            return Ok(diag);
        }
        // re-tune beta per layer against the current FP weights
        for name in state.qnames() {
            let w = &state.params[&name];
            let cb = Codebook::fit(&w.data, self.cfg.bits);
            let lam = self.layer_lambda(w.numel(), &cb);
            let norm = self.rel[&name].normalized();
            // base (ECQ) sparsity of this layer
            let ones = vec![1.0f32; w.numel()];
            let base = self.call_assign(engine, &w.data, &ones, &cb, lam)?;
            let base_sp = base.sparsity;
            let p = self.cfg.p;
            let ctl = control_beta(
                &norm,
                self.beta[&name],
                p,
                base_sp,
                |factors| {
                    self.call_assign(engine, &w.data, factors, &cb, lam)
                        .map(|a| a.sparsity)
                        .unwrap_or(1.0)
                },
                self.cfg.max_beta_halvings,
            );
            diag.insert(name.clone(), (ctl.beta, ctl.extra_sparsity));
            self.beta.insert(name.clone(), ctl.beta);
            self.factors.insert(name.clone(), ctl.factors);
        }
        Ok(diag)
    }

    /// Effective per-layer lambda: the user-facing lambda is dimensionless;
    /// it is scaled by the layer-size factor (Sec. 3.1) and by step^2 so the
    /// entropy term is commensurate with the squared-distance term
    /// regardless of the layer's weight scale.
    fn layer_lambda(&self, numel: usize, cb: &Codebook) -> f32 {
        self.cfg.lambda * lambda_scale(numel, self.max_numel) * cb.step * cb.step
    }

    /// Relevance factors for one layer under the current method/state.
    fn layer_factors(&self, name: &str, numel: usize) -> Vec<f32> {
        match self.cfg.method {
            Method::Ecq => vec![1.0; numel],
            Method::Ecqx => self
                .factors
                .get(name)
                .cloned()
                .unwrap_or_else(|| {
                    // no relevances observed yet: neutral factors
                    cost_factors(&vec![1.0; numel], 0.0)
                }),
        }
    }

    /// Re-assign every quantized layer from the current FP background
    /// weights (Fig. 5 step 6); updates `state.qlayers`.
    pub fn assign_all(&self, engine: &Engine, state: &mut ModelState) -> Result<()> {
        let qnames = state.qnames();
        for name in qnames {
            let w = state.params[&name].clone();
            let cb = Codebook::fit(&w.data, self.cfg.bits);
            let lam = self.layer_lambda(w.numel(), &cb);
            let factors = self.layer_factors(&name, w.numel());
            let out = self.call_assign(engine, &w.data, &factors, &cb, lam)?;
            let shape = w.shape.clone();
            state.qlayers.insert(
                name,
                QLayer {
                    qw: Tensor::new(shape.clone(), out.qw),
                    idx: TensorI32::new(shape, out.idx),
                    codebook: cb,
                },
            );
        }
        Ok(())
    }

    /// One assign-artifact call: pad to the bucket, execute, strip padding.
    fn call_assign(
        &self,
        engine: &Engine,
        w: &[f32],
        factors: &[f32],
        cb: &Codebook,
        lam: f32,
    ) -> Result<AssignOut> {
        let n = w.len();
        let bucket = engine.manifest.bucket_for(n)?;
        let mut wp = w.to_vec();
        wp.resize(bucket, 0.0);
        let mut rp = factors.to_vec();
        rp.resize(bucket, 1.0);
        let mut mask = vec![1.0f32; n];
        mask.resize(bucket, 0.0);
        let inputs = [
            Value::F32(Tensor::new(vec![bucket], wp)),
            Value::F32(Tensor::new(vec![bucket], rp)),
            Value::F32(Tensor::new(vec![bucket], mask)),
            Value::F32(Tensor::new(vec![cb.values.len()], cb.values.clone())),
            Value::F32(Tensor::new(vec![cb.valid.len()], cb.valid.clone())),
            Value::F32(Tensor::scalar(lam)),
        ];
        let outs = engine.call(&format!("assign_{bucket}"), &inputs)?;
        let idx = outs[0].as_i32().data[..n].to_vec();
        let qw = outs[1].as_f32().data[..n].to_vec();
        let zeros = idx.iter().filter(|&&i| i == 0).count();
        Ok(AssignOut { sparsity: zeros as f64 / n as f64, idx, qw })
    }
}

struct AssignOut {
    idx: Vec<i32>,
    qw: Vec<f32>,
    sparsity: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names() {
        assert_eq!(Method::Ecq.as_str(), "ECQ");
        assert_eq!(Method::Ecqx.as_str(), "ECQx");
    }

    #[test]
    fn default_config_sane() {
        let c = AssignConfig::default();
        assert_eq!(c.bits, 4);
        assert!(c.p > 0.0 && c.p < 1.0);
        assert!(c.beta0 <= 1.0);
    }
}
