//! Pre-training (FP32 baseline) and the ECQ^x quantization-aware training
//! loop (Fig. 5): STE step -> periodic LRP -> relevance pipeline ->
//! per-layer re-assignment -> eval.
//!
//! Both trainers are signature-driven and model-family agnostic: the same
//! loop runs the dense MLP and the conv-ladder CNN workloads, because all
//! model structure lives behind the artifact surface (`binder` matches
//! slots by name, the LRP outputs `r_<param>` map onto quantized
//! parameter names — `r_w<i>` for dense layers, `r_c<i>` for conv
//! filters — and the assigner treats every quantized tensor as a flat
//! weight vector).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::assign::{AssignConfig, Assigner, Method};
use super::binder::{apply_train_outputs, bind_inputs, ParamSource, Scalars};
use crate::data::{DataLoader, Dataset};
use crate::metrics::Meter;
use crate::nn::ModelState;
use crate::runtime::{Engine, Workspace};
use crate::tensor::{Tensor, Value};
use crate::util::timer::PhaseProfile;
use crate::util::Timer;

/// Evaluation outcome.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
}

/// Pull the `r_<layer>` outputs of the LRP artifact into a map.
fn collect_relevances(
    outs: std::collections::HashMap<String, crate::tensor::Value>,
) -> BTreeMap<String, Tensor> {
    outs.into_iter()
        .filter_map(|(k, v)| k.strip_prefix("r_").map(|n| (n.to_string(), v.into_f32())))
        .collect()
}

/// Run the `<model>_eval` artifact over a validation loader.
pub fn evaluate<D: Dataset>(
    engine: &Engine,
    state: &ModelState,
    loader: &DataLoader<D>,
    source: ParamSource,
) -> Result<EvalResult> {
    let art = engine.manifest.artifact(&format!("{}_eval", state.spec.name))?.clone();
    let mut meter = Meter::new();
    // one packing workspace for the whole validation pass: after the
    // first batch the GEMM hot loop allocates nothing
    let mut scratch = Workspace::new();
    for batch in loader.epoch(0) {
        let inputs = bind_inputs(&art, state, source, Some(&batch), &Scalars::default())?;
        let outs = engine.call_named_with(&art.name, &inputs, &mut scratch)?;
        meter.update(
            outs["loss"].as_f32().as_scalar(),
            outs["correct"].as_f32().as_scalar(),
            batch.batch,
        );
    }
    Ok(EvalResult { loss: meter.loss(), accuracy: meter.accuracy() })
}

/// Batched evaluation: score several states of the *same* model in one
/// pass over `loader`. Each batch is materialized once and fanned across
/// the states through [`Engine::call_batch`] (`jobs` worker threads), so
/// host-side batch generation and the executable-cache lookup are
/// amortized over all states instead of being paid once per validation
/// pass. Results come back in `states` order.
pub fn evaluate_many<D: Dataset>(
    engine: &Engine,
    states: &[&ModelState],
    loader: &DataLoader<D>,
    source: ParamSource,
    jobs: usize,
) -> Result<Vec<EvalResult>> {
    if states.is_empty() {
        return Ok(Vec::new());
    }
    let model = states[0].spec.name.clone();
    for st in states {
        if st.spec.name != model {
            bail!("evaluate_many: mixed models ({} vs {model})", st.spec.name);
        }
    }
    let art = engine.manifest.artifact(&format!("{model}_eval"))?.clone();
    let loss_i = art
        .outputs
        .iter()
        .position(|s| s.name == "loss")
        .with_context(|| format!("artifact {} has no loss output", art.name))?;
    let corr_i = art
        .outputs
        .iter()
        .position(|s| s.name == "correct")
        .with_context(|| format!("artifact {} has no correct output", art.name))?;
    let mut meters = vec![Meter::new(); states.len()];
    for batch in loader.epoch(0) {
        let inputs: Vec<Vec<Value>> = states
            .iter()
            .map(|&st| bind_inputs(&art, st, source, Some(&batch), &Scalars::default()))
            .collect::<Result<_>>()?;
        let outs = engine.call_batch(&art.name, &inputs, jobs)?;
        for (m, out) in meters.iter_mut().zip(outs) {
            m.update(
                out[loss_i].as_f32().as_scalar(),
                out[corr_i].as_f32().as_scalar(),
                batch.batch,
            );
        }
    }
    Ok(meters
        .iter()
        .map(|m| EvalResult { loss: m.loss(), accuracy: m.accuracy() })
        .collect())
}

/// FP32 pre-trainer (the unquantized baseline of every table).
pub struct Pretrainer {
    pub lr: f32,
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for Pretrainer {
    fn default() -> Self {
        Pretrainer { lr: 1e-3, log_every: 50, verbose: true }
    }
}

impl Pretrainer {
    /// Train for `epochs`; returns per-epoch (train_loss, train_acc).
    pub fn run<D: Dataset>(
        &self,
        engine: &Engine,
        state: &mut ModelState,
        train: &DataLoader<D>,
        epochs: usize,
    ) -> Result<Vec<(f64, f64)>> {
        let art = engine
            .manifest
            .artifact(&format!("{}_fp_train", state.spec.name))?
            .clone();
        let mut curve = Vec::with_capacity(epochs);
        // per-run packing workspace: steady-state train steps reuse it
        let mut scratch = Workspace::new();
        for epoch in 0..epochs {
            let mut meter = Meter::new();
            for batch in train.epoch(epoch as u64) {
                state.t += 1;
                let scalars = Scalars { t: state.t as f32, lr: self.lr, ..Default::default() };
                let inputs =
                    bind_inputs(&art, state, ParamSource::Fp, Some(&batch), &scalars)?;
                let outs = engine.call_named_with(&art.name, &inputs, &mut scratch)?;
                let (loss, correct) = apply_train_outputs(state, outs)?;
                meter.update(loss, correct, batch.batch);
            }
            if self.verbose {
                println!(
                    "[pretrain {}] epoch {epoch}: loss={:.4} acc={:.4}",
                    state.spec.name,
                    meter.loss(),
                    meter.accuracy()
                );
            }
            curve.push((meter.loss(), meter.accuracy()));
        }
        Ok(curve)
    }
}

/// Configuration of one QAT run.
#[derive(Clone, Debug)]
pub struct QatConfig {
    pub assign: AssignConfig,
    pub epochs: usize,
    pub lr: f32,
    /// recompute LRP relevances every N train steps (ECQx only)
    pub lrp_every: usize,
    /// re-tune beta (target-sparsity controller) every N relevance
    /// refreshes (the controller needs extra assign calls, so it runs at a
    /// coarser cadence than the EMA updates)
    pub retune_every: usize,
    /// batches of LRP on the pre-trained model before the initial
    /// assignment, so ECQx starts from well-averaged relevances
    pub lrp_warmup: usize,
    /// re-assign clusters every N train steps
    pub assign_every: usize,
    /// STE gradient scaling by |centroid| (Fig. 5 step 3)
    pub grad_scale: bool,
    /// sample weighting mode for LRP (0 = score-weighted, 1 = equal)
    pub lrp_equal_weight: bool,
    pub verbose: bool,
}

impl Default for QatConfig {
    fn default() -> Self {
        QatConfig {
            assign: AssignConfig::default(),
            epochs: 4,
            lr: 1e-4,
            lrp_every: 2,
            retune_every: 8,
            lrp_warmup: 12,
            assign_every: 2,
            grad_scale: true,
            lrp_equal_weight: false,
            verbose: true,
        }
    }
}

/// Per-epoch QAT record.
#[derive(Clone, Debug)]
pub struct QatEpoch {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    pub val_loss: f64,
    pub val_acc: f64,
    pub sparsity: f64,
}

/// Outcome of a full QAT run.
pub struct QatOutcome {
    pub epochs: Vec<QatEpoch>,
    pub profile: PhaseProfile,
    /// best validation accuracy over epochs
    pub best_val_acc: f64,
    /// final sparsity over quantized layers
    pub final_sparsity: f64,
}

/// The ECQ^x quantization-aware trainer.
pub struct QatTrainer {
    pub cfg: QatConfig,
}

impl QatTrainer {
    /// Trainer over one QAT configuration.
    pub fn new(cfg: QatConfig) -> Self {
        QatTrainer { cfg }
    }

    /// Run QAT on a pre-trained `state`.
    pub fn run<D: Dataset>(
        &self,
        engine: &Engine,
        state: &mut ModelState,
        train: &DataLoader<D>,
        val: &DataLoader<D>,
    ) -> Result<QatOutcome> {
        let cfg = &self.cfg;
        let model = state.spec.name.clone();
        let ste_art = engine.manifest.artifact(&format!("{model}_ste_train"))?.clone();
        let lrp_art = engine.manifest.artifact(&format!("{model}_lrp"))?.clone();

        let mut assigner = Assigner::new(cfg.assign.clone(), state);
        let mut profile = PhaseProfile::new();
        // one packing workspace for the whole QAT run: every STE/LRP step
        // reuses the same GEMM panels (zero steady-state allocation in
        // the blocked core)
        let mut scratch = Workspace::new();

        // ECQx: warm the relevance EMAs on the *pre-trained* model over
        // several batches before anything is quantized, so the initial
        // assignment already sees a well-averaged relevance map.
        if cfg.assign.method == Method::Ecqx && cfg.lrp_warmup > 0 {
            let t0 = Timer::start();
            for (i, batch) in train.epoch(u64::MAX).enumerate() {
                if i >= cfg.lrp_warmup {
                    break;
                }
                let scal = Scalars {
                    eqw: if cfg.lrp_equal_weight { 1.0 } else { 0.0 },
                    ..Default::default()
                };
                let inputs =
                    bind_inputs(&lrp_art, state, ParamSource::Fp, Some(&batch), &scal)?;
                let outs = engine.call_named_with(&lrp_art.name, &inputs, &mut scratch)?;
                let raw = collect_relevances(outs);
                let retune = i + 1 == cfg.lrp_warmup;
                assigner.update_relevances(engine, state, &raw, retune)?;
            }
            profile.record("lrp_warmup", t0.elapsed_s());
        }

        // Fig. 5 step 5-6: initial assignment from the pre-trained FP
        // weights (with warmed relevance factors for ECQx).
        profile.time("assign", || assigner.assign_all(engine, state))?;

        // reset Adam state for the QAT phase
        for (_, t) in state.m.iter_mut() {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        for (_, t) in state.v.iter_mut() {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        state.t = 0;

        let mut epochs = Vec::new();
        let mut best_val = 0.0f64;
        let mut step = 0usize;
        for epoch in 0..cfg.epochs {
            let mut meter = Meter::new();
            for batch in train.epoch(epoch as u64) {
                // 1) STE forward/backward through the quantized model,
                //    Adam-update of the FP background model.
                state.t += 1;
                let scalars = Scalars {
                    t: state.t as f32,
                    lr: cfg.lr,
                    gs: if cfg.grad_scale { 1.0 } else { 0.0 },
                    ..Default::default()
                };
                let t0 = Timer::start();
                // p_ slots carry the FP background model; the quantized
                // copies travel separately in the q_ slots.
                let inputs =
                    bind_inputs(&ste_art, state, ParamSource::Fp, Some(&batch), &scalars)?;
                let outs = engine.call_named_with(&ste_art.name, &inputs, &mut scratch)?;
                let (loss, correct) = apply_train_outputs(state, outs)?;
                profile.record("ste_step", t0.elapsed_s());
                meter.update(loss, correct, batch.batch);

                // 2) periodic LRP relevance refresh (ECQx only).
                if cfg.assign.method == Method::Ecqx && step % cfg.lrp_every == 0 {
                    let t1 = Timer::start();
                    let scal = Scalars {
                        eqw: if cfg.lrp_equal_weight { 1.0 } else { 0.0 },
                        ..Default::default()
                    };
                    let inputs = bind_inputs(
                        &lrp_art,
                        state,
                        ParamSource::Quantized,
                        Some(&batch),
                        &scal,
                    )?;
                    let outs = engine.call_named_with(&lrp_art.name, &inputs, &mut scratch)?;
                    let raw = collect_relevances(outs);
                    profile.record("lrp", t1.elapsed_s());
                    let t2 = Timer::start();
                    let refresh_idx = step / cfg.lrp_every;
                    let retune = refresh_idx % cfg.retune_every.max(1) == 0;
                    assigner.update_relevances(engine, state, &raw, retune)?;
                    profile.record("beta_control", t2.elapsed_s());
                }

                // 3) cluster re-assignment from the updated background model.
                if step % cfg.assign_every == 0 {
                    let t3 = Timer::start();
                    assigner.assign_all(engine, state)?;
                    profile.record("assign", t3.elapsed_s());
                }
                step += 1;
            }
            // final assignment of the epoch so eval sees fresh clusters
            profile.time("assign", || assigner.assign_all(engine, state))?;
            let t4 = Timer::start();
            let ev = evaluate(engine, state, val, ParamSource::Quantized)?;
            profile.record("eval", t4.elapsed_s());
            best_val = best_val.max(ev.accuracy);
            let sp = state.quantized_sparsity();
            if cfg.verbose {
                println!(
                    "[{} {model}] epoch {epoch}: train_acc={:.4} val_acc={:.4} sparsity={:.4}",
                    cfg.assign.method.as_str(),
                    meter.accuracy(),
                    ev.accuracy,
                    sp
                );
                for name in state.qnames() {
                    let ql = &state.qlayers[&name];
                    println!(
                        "    {name:<10} sparsity={:.3} step={:.4} max|w|={:.3}",
                        ql.qw.sparsity(),
                        ql.codebook.step,
                        state.params[&name].abs_max()
                    );
                }
            }
            epochs.push(QatEpoch {
                epoch,
                train_loss: meter.loss(),
                train_acc: meter.accuracy(),
                val_loss: ev.loss,
                val_acc: ev.accuracy,
                sparsity: sp,
            });
        }
        let final_sparsity = state.quantized_sparsity();
        Ok(QatOutcome { epochs, profile, best_val_acc: best_val, final_sparsity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gsc::GscDataset;
    use crate::runtime::{Init, ModelSpec, ParamSpec};

    fn stub_engine(tag: &str) -> Engine {
        let dir = std::env::temp_dir().join(format!(
            "ecqx-trainer-test-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "hash test\nkmax 32\nbuckets 1024\n",
        )
        .unwrap();
        Engine::new(&dir).unwrap()
    }

    fn toy_state(model: &str) -> ModelState {
        let spec = ModelSpec {
            name: model.into(),
            batch: 4,
            classes: 2,
            input_dim: 8,
            params: vec![ParamSpec {
                name: "w0".into(),
                shape: vec![8, 2],
                init: Init::HeIn,
                quantize: true,
            }],
        };
        ModelState::init(&spec, 1)
    }

    #[test]
    fn collect_relevances_maps_conv_and_dense_outputs_to_param_names() {
        // the QAT loop feeds LRP artifact outputs straight into the
        // assigner's per-parameter EMAs: `r_<param>` must strip to the
        // quantized parameter name for conv filters exactly like dense
        let mut outs = std::collections::HashMap::new();
        outs.insert(
            "r_w0".to_string(),
            crate::tensor::Value::F32(Tensor::zeros(&[4, 2])),
        );
        outs.insert(
            "r_c0".to_string(),
            crate::tensor::Value::F32(Tensor::zeros(&[3, 3, 3, 4])),
        );
        let rel = collect_relevances(outs);
        assert_eq!(
            rel.keys().cloned().collect::<Vec<_>>(),
            vec!["c0".to_string(), "w0".to_string()]
        );
        assert_eq!(rel["c0"].shape, vec![3, 3, 3, 4]);
    }

    #[test]
    fn evaluate_many_validates_inputs() {
        let eng = stub_engine("evalmany");
        let ds = GscDataset::new(8, 1, false);
        let dl = DataLoader::new(&ds, 4, false, 0);
        // empty state list: trivially done, touches nothing
        let r = evaluate_many(&eng, &[], &dl, ParamSource::Fp, 1).unwrap();
        assert!(r.is_empty());
        // mixed models are rejected before any engine work
        let (a, b) = (toy_state("m1"), toy_state("m2"));
        let err = evaluate_many(&eng, &[&a, &b], &dl, ParamSource::Fp, 1).unwrap_err();
        assert!(format!("{err:?}").contains("mixed models"));
        // same model but no eval artifact in the manifest: named error
        let err = evaluate_many(&eng, &[&a, &a], &dl, ParamSource::Fp, 2).unwrap_err();
        assert!(format!("{err:?}").contains("m1_eval"));
    }
}
