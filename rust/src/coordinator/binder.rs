//! Binds model state + batches to artifact signatures by name convention.
//!
//! Input-name conventions (set by python/compile/aot.py):
//! * `p_<param>` — parameter tensor (FP or quantized, caller's choice)
//! * `q_<param>` — quantized copy of a quantize=1 parameter
//! * `m_/v_<p>` — Adam moments
//! * `idx_/cb_<p>` — centroid indices / codebook (gather-eval)
//! * `x`, `y` — batch features / labels
//! * `t`, `lr`, `gs`, `eqw`, `abits`, `lam` — scalars

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::nn::ModelState;
use crate::runtime::{ArtifactSpec, DType};
use crate::tensor::{Tensor, TensorI32, Value};

/// Where `p_<name>` slots read from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamSource {
    /// full-precision background model (fp_train, baseline eval)
    Fp,
    /// quantized copies for quantize=1 params, FP for the rest
    /// (ste_train forward, lrp, quantized eval)
    Quantized,
}

/// Scalar inputs a call may need.
#[derive(Clone, Copy, Debug, Default)]
pub struct Scalars {
    pub t: f32,
    pub lr: f32,
    pub gs: f32,
    pub eqw: f32,
    pub abits: f32,
    pub lam: f32,
}

/// Build the input value list for `spec` from the model state + batch.
pub fn bind_inputs(
    spec: &ArtifactSpec,
    state: &ModelState,
    source: ParamSource,
    batch: Option<&Batch>,
    scalars: &Scalars,
) -> Result<Vec<Value>> {
    let mut vals = Vec::with_capacity(spec.inputs.len());
    for inp in &spec.inputs {
        let name = inp.name.as_str();
        let v: Value = if let Some(p) = name.strip_prefix("p_") {
            let t = match source {
                ParamSource::Fp => &state.params[p],
                ParamSource::Quantized => state.quantized_param(p),
            };
            Value::F32(t.clone())
        } else if let Some(p) = name.strip_prefix("q_") {
            let ql = state
                .qlayers
                .get(p)
                .ok_or_else(|| anyhow::anyhow!("layer {p} not quantized yet"))?;
            Value::F32(ql.qw.clone())
        } else if let Some(p) = name.strip_prefix("m_") {
            Value::F32(state.m[p].clone())
        } else if let Some(p) = name.strip_prefix("v_") {
            Value::F32(state.v[p].clone())
        } else if let Some(p) = name.strip_prefix("idx_") {
            let ql = &state.qlayers[p];
            Value::I32(ql.idx.clone())
        } else if let Some(p) = name.strip_prefix("cb_") {
            let ql = &state.qlayers[p];
            Value::F32(Tensor::new(vec![ql.codebook.values.len()], ql.codebook.values.clone()))
        } else {
            match name {
                "x" => {
                    let b = batch.ok_or_else(|| anyhow::anyhow!("artifact needs a batch"))?;
                    Value::F32(Tensor::new(inp.shape.clone(), b.x.clone()))
                }
                "y" => {
                    let b = batch.ok_or_else(|| anyhow::anyhow!("artifact needs a batch"))?;
                    Value::I32(TensorI32::new(inp.shape.clone(), b.y.clone()))
                }
                "t" => Value::F32(Tensor::scalar(scalars.t)),
                "lr" => Value::F32(Tensor::scalar(scalars.lr)),
                "gs" => Value::F32(Tensor::scalar(scalars.gs)),
                "eqw" => Value::F32(Tensor::scalar(scalars.eqw)),
                "abits" => Value::F32(Tensor::scalar(scalars.abits)),
                "lam" => Value::F32(Tensor::scalar(scalars.lam)),
                other => bail!("unknown artifact input name: {other}"),
            }
        };
        // dtype sanity (shapes are checked by the engine)
        let ok = matches!(
            (&v, inp.dtype),
            (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32)
        );
        if !ok {
            bail!("input {name}: bound wrong dtype");
        }
        vals.push(v);
    }
    Ok(vals)
}

/// Write train-step outputs (p_*/m_*/v_*) back into the state.
pub fn apply_train_outputs(
    state: &mut ModelState,
    outputs: HashMap<String, Value>,
) -> Result<(f32, f32)> {
    let mut loss = 0.0;
    let mut correct = 0.0;
    for (name, v) in outputs {
        if let Some(p) = name.strip_prefix("p_") {
            state.params.insert(p.to_string(), v.into_f32());
        } else if let Some(p) = name.strip_prefix("m_") {
            state.m.insert(p.to_string(), v.into_f32());
        } else if let Some(p) = name.strip_prefix("v_") {
            state.v.insert(p.to_string(), v.into_f32());
        } else if name == "loss" {
            loss = v.as_f32().as_scalar();
        } else if name == "correct" {
            correct = v.as_f32().as_scalar();
        } else {
            bail!("unexpected train output {name}");
        }
    }
    Ok((loss, correct))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Init, ModelSpec, ParamSpec, TensorSpec};

    fn toy() -> (ArtifactSpec, ModelState) {
        let spec = ModelSpec {
            name: "toy".into(),
            batch: 2,
            classes: 2,
            input_dim: 3,
            params: vec![
                ParamSpec { name: "w0".into(), shape: vec![3, 2], init: Init::HeIn, quantize: true },
                ParamSpec { name: "b0".into(), shape: vec![2], init: Init::Zeros, quantize: false },
            ],
        };
        let art = ArtifactSpec {
            name: "toy_eval".into(),
            file: "/dev/null".into(),
            attrs: Default::default(),
            inputs: vec![
                TensorSpec { name: "p_w0".into(), dtype: DType::F32, shape: vec![3, 2] },
                TensorSpec { name: "p_b0".into(), dtype: DType::F32, shape: vec![2] },
                TensorSpec { name: "x".into(), dtype: DType::F32, shape: vec![2, 3] },
                TensorSpec { name: "y".into(), dtype: DType::I32, shape: vec![2] },
                TensorSpec { name: "lr".into(), dtype: DType::F32, shape: vec![] },
            ],
            outputs: vec![],
        };
        (art, ModelState::init(&spec, 1))
    }

    #[test]
    fn binds_in_order() {
        let (art, state) = toy();
        let batch = Batch { x: vec![0.0; 6], y: vec![0, 1], batch: 2 };
        let scalars = Scalars { lr: 0.1, ..Default::default() };
        let vals =
            bind_inputs(&art, &state, ParamSource::Fp, Some(&batch), &scalars).unwrap();
        assert_eq!(vals.len(), 5);
        assert_eq!(vals[0].shape(), &[3, 2]);
        assert_eq!(vals[3].as_i32().data, vec![0, 1]);
        assert_eq!(vals[4].as_f32().as_scalar(), 0.1);
    }

    #[test]
    fn missing_batch_errors() {
        let (art, state) = toy();
        let r = bind_inputs(&art, &state, ParamSource::Fp, None, &Scalars::default());
        assert!(r.is_err());
    }

    #[test]
    fn apply_outputs_updates_state() {
        let (_, mut state) = toy();
        let mut outs = HashMap::new();
        outs.insert("p_w0".to_string(), Value::F32(Tensor::ones(&[3, 2])));
        outs.insert("m_w0".to_string(), Value::F32(Tensor::full(&[3, 2], 0.5)));
        outs.insert("loss".to_string(), Value::F32(Tensor::scalar(1.25)));
        outs.insert("correct".to_string(), Value::F32(Tensor::scalar(2.0)));
        let (loss, corr) = apply_train_outputs(&mut state, outs).unwrap();
        assert_eq!(loss, 1.25);
        assert_eq!(corr, 2.0);
        assert!(state.params["w0"].data.iter().all(|&x| x == 1.0));
        assert!(state.m["w0"].data.iter().all(|&x| x == 0.5));
    }
}
