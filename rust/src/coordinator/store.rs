//! Durable campaign results: an append-only JSONL store that survives
//! anything short of disk loss, and the resume / shard / merge logic
//! built on top of it.
//!
//! ## On-disk format (DESIGN.md §2.5)
//!
//! One line per record. The first line is a `meta` record binding the
//! store to a campaign identity — model, backend, campaign seed, and a
//! fingerprint of the trial grid — so a store can never silently be
//! resumed against a different campaign. Every following line is a `row`
//! record: the terminal outcome of one trial, keyed by the working-point
//! hash of `(campaign seed, method, bits, lambda, p, model, backend)`.
//!
//! Every line is *sealed*: its body is suffixed with
//! `,"crc":"<fnv1a64 of body, 16 hex>"}`. The file itself is only ever
//! replaced whole via tmp-file + atomic rename ([`crate::util::fsx`]),
//! so a `kill -9` mid-flush leaves either the previous complete store or
//! the new complete store. The per-row checksum is the second line of
//! defence — against torn appends from foreign writers, filesystem-level
//! corruption, or hand edits: a corrupt **last** line is detected and
//! dropped (at most one trial re-runs on resume), a corrupt line
//! anywhere else is an error, never silently skipped.
//!
//! Rows carry no timestamps or wall-clock fields: a row's bytes are a
//! pure function of the trial's inputs, which is what lets the
//! resume/shard bitwise-identity gate ([`ResultStore::canonical_lines`])
//! compare whole stores by string equality.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::campaign::{TrialResult, TrialSpec};
use crate::metrics::WorkingPoint;
use crate::util::jsonx::{self, Val};
use crate::util::{fnv1a64, fsx};

/// Campaign identity a store is bound to. Two stores are mergeable and a
/// store is resumable exactly when these match.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreMeta {
    /// model name the campaign quantizes
    pub model: String,
    /// backend name ("host", "xla", ...)
    pub backend: String,
    /// campaign-level seed (per-trial seeds derive from it)
    pub seed: u64,
    /// fingerprint of the full trial grid — see [`grid_hash`]
    pub grid_hash: u64,
    /// number of trials in the full (unsharded) grid
    pub n_trials: usize,
    /// whether the campaign ran on the deterministic linalg tier
    /// (`--deterministic`). Part of the identity: fast-tier rows are only
    /// bit-stable within one machine/kernel, so resuming a deterministic
    /// store in fast mode (or vice versa) would silently mix tiers —
    /// [`ResultStore::ensure_meta`] refuses instead. Stores written
    /// before this field existed parse as `det: false` (the fast tier,
    /// which was the only tier then)
    pub det: bool,
}

/// One persisted trial outcome.
#[derive(Clone, Debug)]
pub struct Row {
    /// working-point key — see [`working_point_key`]
    pub key: u64,
    /// trial id (grid position)
    pub id: usize,
    /// what happened
    pub result: TrialResult,
}

/// Seal a JSON body (everything up to but excluding the closing brace)
/// with its FNV-1a checksum: `{…` → `{…,"crc":"<16 hex>"}`.
fn seal(body: &str) -> String {
    format!("{body},\"crc\":\"{:016x}\"}}", fnv1a64(body.as_bytes()))
}

/// Split a sealed line back into its body and verify the checksum.
fn unseal(line: &str) -> Result<&str> {
    const MARK: &str = ",\"crc\":\"";
    let at = line.rfind(MARK).ok_or_else(|| anyhow!("line has no crc seal"))?;
    let body = &line[..at];
    let rest = &line[at + MARK.len()..];
    let hex = rest
        .strip_suffix("\"}")
        .ok_or_else(|| anyhow!("malformed crc seal framing"))?;
    let stored = u64::from_str_radix(hex, 16)
        .map_err(|_| anyhow!("crc is not 16 hex digits"))?;
    if hex.len() != 16 {
        bail!("crc is not 16 hex digits");
    }
    let actual = fnv1a64(body.as_bytes());
    if stored != actual {
        bail!("crc mismatch: stored {stored:016x}, computed {actual:016x}");
    }
    Ok(body)
}

impl StoreMeta {
    fn to_line(&self) -> String {
        // the u64 seed is stored as a string: it can exceed 2^53, and the
        // store must not depend on any reader's float-free integer range
        let body = format!(
            "{{\"kind\":\"meta\",\"v\":1,\"model\":{},\"backend\":{},\"seed\":\"{}\",\
             \"grid\":\"{:016x}\",\"trials\":{},\"det\":{}",
            jsonx::quote(&self.model),
            jsonx::quote(&self.backend),
            self.seed,
            self.grid_hash,
            self.n_trials,
            self.det
        );
        seal(&body)
    }

    fn from_json(obj: &BTreeMap<String, Val>) -> Result<StoreMeta> {
        let v: u32 = field_num(obj, "v")?;
        if v != 1 {
            bail!("unsupported store version {v}");
        }
        Ok(StoreMeta {
            model: field_str(obj, "model")?.to_string(),
            backend: field_str(obj, "backend")?.to_string(),
            seed: field_num(obj, "seed")?,
            grid_hash: field_hex(obj, "grid")?,
            n_trials: field_num(obj, "trials")?,
            // absent in stores written before the two-tier contract:
            // those campaigns ran what is now the fast tier
            det: match obj.get("det") {
                Some(v) => v
                    .num::<bool>()
                    .ok_or_else(|| anyhow!("field \"det\" must be a boolean"))?,
                None => false,
            },
        })
    }
}

fn field<'a>(obj: &'a BTreeMap<String, Val>, k: &str) -> Result<&'a Val> {
    obj.get(k).ok_or_else(|| anyhow!("missing field {k:?}"))
}

fn field_str<'a>(obj: &'a BTreeMap<String, Val>, k: &str) -> Result<&'a str> {
    field(obj, k)?
        .as_str()
        .ok_or_else(|| anyhow!("field {k:?} must be a string"))
}

fn field_num<T: std::str::FromStr>(obj: &BTreeMap<String, Val>, k: &str) -> Result<T> {
    field(obj, k)?
        .num()
        .ok_or_else(|| anyhow!("field {k:?} is not a valid number"))
}

fn field_hex(obj: &BTreeMap<String, Val>, k: &str) -> Result<u64> {
    let s = field_str(obj, k)?;
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("field {k:?} is not hex"))
}

impl Row {
    /// Serialize to one sealed JSONL line. Byte-deterministic: two rows
    /// for the same trial outcome are identical strings.
    pub fn to_line(&self) -> String {
        let head = format!(
            "{{\"kind\":\"row\",\"k\":\"{:016x}\",\"id\":{}",
            self.key, self.id
        );
        let body = match &self.result {
            TrialResult::Done(p) => {
                format!("{head},\"status\":\"done\",{}", p.json_fields())
            }
            TrialResult::Failed { error, attempts } => format!(
                "{head},\"status\":\"failed\",\"attempts\":{attempts},\"error\":{}",
                jsonx::quote(error)
            ),
        };
        seal(&body)
    }

    fn from_json(obj: &BTreeMap<String, Val>) -> Result<Row> {
        let key = field_hex(obj, "k")?;
        let id = field_num(obj, "id")?;
        let result = match field_str(obj, "status")? {
            "done" => TrialResult::Done(WorkingPoint::from_json(obj)?),
            "failed" => TrialResult::Failed {
                error: field_str(obj, "error")?.to_string(),
                attempts: field_num(obj, "attempts")?,
            },
            other => bail!("unknown row status {other:?}"),
        };
        Ok(Row { key, id, result })
    }
}

enum Record {
    Meta(StoreMeta),
    Row(Row),
}

fn parse_record(line: &str) -> Result<Record> {
    let body = unseal(line)?;
    let obj = jsonx::parse_object(&format!("{body}}}")).map_err(|e| anyhow!(e))?;
    match field_str(&obj, "kind")? {
        "meta" => Ok(Record::Meta(StoreMeta::from_json(&obj)?)),
        "row" => Ok(Record::Row(Row::from_json(&obj)?)),
        other => bail!("unknown record kind {other:?}"),
    }
}

/// The durable results store: campaign meta + rows, mirrored to a JSONL
/// file on every append via atomic whole-file replace.
#[derive(Debug)]
pub struct ResultStore {
    path: PathBuf,
    meta: Option<StoreMeta>,
    rows: Vec<Row>,
    dropped_tail: bool,
}

impl ResultStore {
    /// Open `path` if it exists (validating every line), otherwise start
    /// an empty store that will be created on the first flush.
    pub fn open_or_create(path: &Path) -> Result<ResultStore> {
        if path.exists() {
            Self::open_existing(path)
        } else {
            Ok(ResultStore {
                path: path.to_path_buf(),
                meta: None,
                rows: Vec::new(),
                dropped_tail: false,
            })
        }
    }

    /// Open an existing store file; errors if it is missing or invalid.
    pub fn open_existing(path: &Path) -> Result<ResultStore> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read results store {}", path.display()))?;
        let mut meta: Option<StoreMeta> = None;
        let mut rows: Vec<Row> = Vec::new();
        let mut dropped_tail = false;
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        for (i, line) in lines.iter().enumerate() {
            match parse_record(line) {
                Ok(Record::Meta(m)) => {
                    if meta.is_some() || !rows.is_empty() {
                        bail!(
                            "{}: line {}: meta record must be the first line",
                            path.display(),
                            i + 1
                        );
                    }
                    meta = Some(m);
                }
                Ok(Record::Row(r)) => {
                    if meta.is_none() {
                        bail!(
                            "{}: line {}: row before meta record",
                            path.display(),
                            i + 1
                        );
                    }
                    rows.push(r);
                }
                Err(e) if i + 1 == lines.len() => {
                    // torn tail: a foreign append died mid-line. Drop it —
                    // at worst one trial re-runs on resume.
                    eprintln!(
                        "[store] {}: dropping truncated tail line ({e:#})",
                        path.display()
                    );
                    dropped_tail = true;
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "{}: line {} is corrupt (not the tail — refusing to \
                             silently drop completed results)",
                            path.display(),
                            i + 1
                        )
                    })
                }
            }
        }
        Ok(ResultStore { path: path.to_path_buf(), meta, rows, dropped_tail })
    }

    /// Bind the store to a campaign identity. A fresh store adopts `meta`
    /// and flushes; an existing store must match exactly, else this is a
    /// wrong-campaign resume and we refuse.
    pub fn ensure_meta(&mut self, meta: &StoreMeta) -> Result<()> {
        match &self.meta {
            Some(have) if have == meta => Ok(()),
            Some(have) => bail!(
                "store {} belongs to a different campaign: \
                 store has model={} backend={} seed={} grid={:016x} trials={} det={}, \
                 this run has model={} backend={} seed={} grid={:016x} trials={} det={}",
                self.path.display(),
                have.model,
                have.backend,
                have.seed,
                have.grid_hash,
                have.n_trials,
                have.det,
                meta.model,
                meta.backend,
                meta.seed,
                meta.grid_hash,
                meta.n_trials,
                meta.det
            ),
            None => {
                self.meta = Some(meta.clone());
                self.flush()
            }
        }
    }

    /// Record one trial outcome and mirror the store to disk immediately
    /// — after this returns, the row survives `kill -9`.
    pub fn append(&mut self, row: Row) -> Result<()> {
        self.rows.push(row);
        self.flush()
    }

    /// Rewrite the backing file atomically (tmp + rename). The store is
    /// small (one line per trial), so whole-file replace keeps the
    /// crash-safety argument trivial: the destination path always holds a
    /// complete, checksummed store.
    pub fn flush(&self) -> Result<()> {
        fsx::atomic_write_with(&self.path, |w| {
            use std::io::Write;
            if let Some(m) = &self.meta {
                writeln!(w, "{}", m.to_line())?;
            }
            for r in &self.rows {
                writeln!(w, "{}", r.to_line())?;
            }
            Ok(())
        })
        .with_context(|| format!("flush results store {}", self.path.display()))
    }

    /// The campaign identity, if the store has one yet.
    pub fn meta(&self) -> Option<&StoreMeta> {
        self.meta.as_ref()
    }

    /// All rows in append order (including superseded ones).
    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// True when loading dropped a truncated tail line.
    pub fn dropped_tail(&self) -> bool {
        self.dropped_tail
    }

    /// Latest outcome per trial id. `Done` supersedes `Failed` (a
    /// quarantined trial that later succeeds on resume is healed); among
    /// rows of equal status the last append wins.
    pub fn latest_by_id(&self) -> BTreeMap<usize, &Row> {
        let mut out: BTreeMap<usize, &Row> = BTreeMap::new();
        for r in &self.rows {
            match out.get(&r.id) {
                Some(prev)
                    if matches!(prev.result, TrialResult::Done(_))
                        && matches!(r.result, TrialResult::Failed { .. }) => {}
                _ => {
                    out.insert(r.id, r);
                }
            }
        }
        out
    }

    /// Working-point keys of successfully completed trials — resume
    /// skips exactly these. Failed (quarantined) trials are *not* here:
    /// a resume retries them.
    pub fn done_keys(&self) -> BTreeSet<u64> {
        self.latest_by_id()
            .values()
            .filter(|r| matches!(r.result, TrialResult::Done(_)))
            .map(|r| r.key)
            .collect()
    }

    /// Completed working points in grid (trial id) order.
    pub fn done_points(&self) -> Vec<(usize, WorkingPoint)> {
        self.latest_by_id()
            .into_iter()
            .filter_map(|(id, r)| match &r.result {
                TrialResult::Done(p) => Some((id, p.clone())),
                TrialResult::Failed { .. } => None,
            })
            .collect()
    }

    /// Quarantined trials (latest outcome is a failure), grid order.
    pub fn quarantined(&self) -> Vec<(usize, String, u32)> {
        self.latest_by_id()
            .into_iter()
            .filter_map(|(id, r)| match &r.result {
                TrialResult::Failed { error, attempts } => {
                    Some((id, error.clone(), *attempts))
                }
                TrialResult::Done(_) => None,
            })
            .collect()
    }

    /// Canonical serialized form: latest row per trial, sorted by id, one
    /// sealed line each. Two stores describe the same campaign results
    /// iff these line vectors are equal — the bitwise-identity gate for
    /// resume and shard-union runs.
    pub fn canonical_lines(&self) -> Vec<String> {
        self.latest_by_id().values().map(|r| r.to_line()).collect()
    }
}

/// Merge shard stores into one row set. All metas must match; `Done`
/// supersedes `Failed` per trial id; two *different* `Done` rows for the
/// same id mean the shards disagree about a completed trial — that is
/// corruption or a seed mismatch, and the merge refuses.
pub fn merge(stores: &[ResultStore]) -> Result<(StoreMeta, Vec<Row>)> {
    let first = stores
        .first()
        .ok_or_else(|| anyhow!("merge needs at least one store"))?;
    let meta = first
        .meta()
        .ok_or_else(|| anyhow!("store {} has no meta record", first.path().display()))?
        .clone();
    let mut by_id: BTreeMap<usize, Row> = BTreeMap::new();
    for s in stores {
        let m = s
            .meta()
            .ok_or_else(|| anyhow!("store {} has no meta record", s.path().display()))?;
        if *m != meta {
            bail!(
                "store {} belongs to a different campaign than {}",
                s.path().display(),
                first.path().display()
            );
        }
        for (id, r) in s.latest_by_id() {
            match by_id.get(&id) {
                None => {
                    by_id.insert(id, r.clone());
                }
                Some(prev) => match (&prev.result, &r.result) {
                    (TrialResult::Done(_), TrialResult::Done(_)) => {
                        if prev.to_line() != r.to_line() {
                            bail!(
                                "conflicting completed rows for trial {id} across \
                                 stores (results differ — wrong seed or corrupt shard?)"
                            );
                        }
                    }
                    (TrialResult::Done(_), TrialResult::Failed { .. }) => {}
                    _ => {
                        by_id.insert(id, r.clone());
                    }
                },
            }
        }
    }
    Ok((meta, by_id.into_values().collect()))
}

/// Working-point key: a stable 64-bit fingerprint of everything that
/// determines a trial's result. Floats enter by bit pattern, not by
/// formatting, so `0.1f32` and a re-parsed `0.1` always agree.
pub fn working_point_key(
    model: &str,
    backend: &str,
    seed: u64,
    method: &str,
    bits: u32,
    lambda: f32,
    p: f64,
) -> u64 {
    let canon = format!(
        "{model}|{backend}|{seed}|{method}|{bits}|{:08x}|{:016x}",
        lambda.to_bits(),
        p.to_bits()
    );
    fnv1a64(canon.as_bytes())
}

/// [`working_point_key`] for a grid trial.
pub fn trial_key(meta: &StoreMeta, t: &TrialSpec) -> u64 {
    working_point_key(
        &meta.model,
        &meta.backend,
        meta.seed,
        t.method.as_str(),
        t.bits,
        t.lambda,
        t.p,
    )
}

/// Fingerprint of a trial grid: order-sensitive digest of every trial's
/// id and hyperparameters. Resuming with a different grid (different
/// lambda list, bit set, ...) changes this and is refused.
pub fn grid_hash(trials: &[TrialSpec]) -> u64 {
    let mut canon = String::new();
    for t in trials {
        canon.push_str(&format!(
            "{}:{}:{}:{:08x}:{:016x};",
            t.id,
            t.method.as_str(),
            t.bits,
            t.lambda.to_bits(),
            t.p.to_bits()
        ));
    }
    fnv1a64(canon.as_bytes())
}

/// Parse a `--shard i/n` spec: zero-based index `i` of `n` partitions.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let (i, n) = s
        .split_once('/')
        .ok_or_else(|| anyhow!("shard spec must be i/n, e.g. 0/4 (got {s:?})"))?;
    let i: usize = i
        .trim()
        .parse()
        .map_err(|_| anyhow!("shard index {i:?} is not an integer"))?;
    let n: usize = n
        .trim()
        .parse()
        .map_err(|_| anyhow!("shard count {n:?} is not an integer"))?;
    if n == 0 {
        bail!("shard count must be >= 1");
    }
    if i >= n {
        bail!("shard index {i} out of range for {n} shards (use 0..{})", n - 1);
    }
    Ok((i, n))
}

/// The subset of `trials` shard `i` of `n` owns: deterministic partition
/// by trial id (`id % n == i`), independent of job count or timing.
pub fn shard_trials(trials: &[TrialSpec], i: usize, n: usize) -> Vec<TrialSpec> {
    trials.iter().filter(|t| t.id % n == i).cloned().collect()
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    use super::*;
    use crate::coordinator::assign::Method;

    fn wp(lambda: f32) -> WorkingPoint {
        WorkingPoint {
            method: "ECQx".into(),
            bits: 4,
            lambda,
            p: 0.3,
            accuracy: 0.9125,
            acc_drop: -0.0125,
            sparsity: 0.8,
            size_bytes: 10_000,
            compression_ratio: 12.5,
        }
    }

    fn meta() -> StoreMeta {
        StoreMeta {
            model: "mlp_gsc".into(),
            backend: "host".into(),
            seed: u64::MAX - 3, // above 2^53: exercises string-seed storage
            grid_hash: 0xdead_beef_cafe_f00d,
            n_trials: 4,
            det: false,
        }
    }

    fn row(id: usize, result: TrialResult) -> Row {
        Row { key: 0x1000 + id as u64, id, result }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ecqx-store-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrips_meta_and_rows() {
        let p = tmp("roundtrip.jsonl");
        std::fs::remove_file(&p).ok();
        let mut s = ResultStore::open_or_create(&p).unwrap();
        s.ensure_meta(&meta()).unwrap();
        s.append(row(0, TrialResult::Done(wp(0.02)))).unwrap();
        s.append(row(
            1,
            TrialResult::Failed { error: "trial panicked: \"boom\"\nline2".into(), attempts: 3 },
        ))
        .unwrap();
        let back = ResultStore::open_existing(&p).unwrap();
        assert_eq!(back.meta(), Some(&meta()));
        assert!(!back.dropped_tail());
        assert_eq!(back.rows().len(), 2);
        assert_eq!(back.canonical_lines(), s.canonical_lines());
        let done = back.done_points();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, 0);
        assert_eq!(done[0].1.lambda.to_bits(), 0.02f32.to_bits());
        let q = back.quarantined();
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].0, 1);
        assert!(q[0].1.contains("boom"));
        assert_eq!(q[0].2, 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn det_mode_roundtrips_and_gates_resume() {
        let p = tmp("det.jsonl");
        std::fs::remove_file(&p).ok();
        let det_meta = StoreMeta { det: true, ..meta() };
        let mut s = ResultStore::open_or_create(&p).unwrap();
        s.ensure_meta(&det_meta).unwrap();
        drop(s);
        let mut back = ResultStore::open_existing(&p).unwrap();
        assert_eq!(back.meta(), Some(&det_meta), "det: true survives the roundtrip");
        // resuming in the other tier would silently mix bitwise-stable
        // and fast-tier rows in one store — it must be refused
        let err = back.ensure_meta(&meta()).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("det=true"), "{msg}");
        assert!(msg.contains("det=false"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn pre_det_meta_lines_parse_as_fast_tier() {
        // a meta line written before the `det` field existed (those
        // campaigns ran what is now the fast tier) must still open, as
        // det: false — and so resume cleanly from a fast-tier run
        let p = tmp("predet.jsonl");
        std::fs::remove_file(&p).ok();
        let body = format!(
            "{{\"kind\":\"meta\",\"v\":1,\"model\":\"mlp_gsc\",\"backend\":\"host\",\
             \"seed\":\"{}\",\"grid\":\"{:016x}\",\"trials\":4",
            u64::MAX - 3,
            0xdead_beef_cafe_f00du64,
        );
        std::fs::write(&p, format!("{}\n", seal(&body))).unwrap();
        let mut back = ResultStore::open_existing(&p).unwrap();
        assert_eq!(back.meta(), Some(&meta()), "absent det parses as false");
        back.ensure_meta(&meta()).unwrap();
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_tail_is_dropped_not_fatal() {
        let p = tmp("tail.jsonl");
        std::fs::remove_file(&p).ok();
        let mut s = ResultStore::open_or_create(&p).unwrap();
        s.ensure_meta(&meta()).unwrap();
        s.append(row(0, TrialResult::Done(wp(0.0)))).unwrap();
        s.append(row(1, TrialResult::Done(wp(0.1)))).unwrap();
        // simulate a foreign writer dying mid-append
        let text = std::fs::read_to_string(&p).unwrap();
        let cut = text.len() - 10;
        std::fs::write(&p, &text[..cut]).unwrap();
        let back = ResultStore::open_existing(&p).unwrap();
        assert!(back.dropped_tail());
        assert_eq!(back.rows().len(), 1, "only the torn row is lost");
        assert_eq!(back.rows()[0].id, 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mid_file_corruption_is_fatal() {
        let p = tmp("midfile.jsonl");
        std::fs::remove_file(&p).ok();
        let mut s = ResultStore::open_or_create(&p).unwrap();
        s.ensure_meta(&meta()).unwrap();
        s.append(row(0, TrialResult::Done(wp(0.0)))).unwrap();
        s.append(row(1, TrialResult::Done(wp(0.1)))).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        // flip one byte inside the first row's payload (not the tail line)
        let bytes = unsafe { lines[1].as_bytes_mut() };
        bytes[20] ^= 1;
        std::fs::write(&p, lines.join("\n")).unwrap();
        let err = ResultStore::open_existing(&p).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("refusing"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn crc_catches_any_single_bit_flip_in_tail() {
        let r = row(7, TrialResult::Done(wp(0.25)));
        let line = r.to_line();
        assert!(unseal(&line).is_ok());
        // flip each byte of the body once; the seal must always catch it
        for i in 0..line.rfind(",\"crc\":\"").unwrap() {
            let mut b = line.clone().into_bytes();
            b[i] ^= 0x01;
            if let Ok(bad) = String::from_utf8(b) {
                assert!(
                    parse_record(&bad).is_err(),
                    "bit flip at byte {i} went undetected"
                );
            }
        }
    }

    #[test]
    fn resume_against_wrong_campaign_is_refused() {
        let p = tmp("wrongmeta.jsonl");
        std::fs::remove_file(&p).ok();
        let mut s = ResultStore::open_or_create(&p).unwrap();
        s.ensure_meta(&meta()).unwrap();
        let mut other = meta();
        other.seed ^= 1;
        let err = s.ensure_meta(&other).unwrap_err();
        assert!(format!("{err:?}").contains("different campaign"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn done_supersedes_failed_and_resume_retries_failures() {
        let p = tmp("supersede.jsonl");
        std::fs::remove_file(&p).ok();
        let mut s = ResultStore::open_or_create(&p).unwrap();
        s.ensure_meta(&meta()).unwrap();
        s.append(row(0, TrialResult::Failed { error: "flake".into(), attempts: 1 }))
            .unwrap();
        s.append(row(1, TrialResult::Done(wp(0.1)))).unwrap();
        // failed trials are not "done": resume will retry them
        assert!(!s.done_keys().contains(&0x1000));
        assert!(s.done_keys().contains(&0x1001));
        // the trial later succeeds on resume; Done wins
        s.append(row(0, TrialResult::Done(wp(0.0)))).unwrap();
        assert!(s.done_keys().contains(&0x1000));
        assert!(s.quarantined().is_empty());
        // and a stale Failed appended after a Done cannot demote it
        s.append(row(1, TrialResult::Failed { error: "stale".into(), attempts: 1 }))
            .unwrap();
        assert!(s.done_keys().contains(&0x1001));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn merge_unions_shards_and_rejects_conflicts() {
        let pa = tmp("merge-a.jsonl");
        let pb = tmp("merge-b.jsonl");
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
        let mut a = ResultStore::open_or_create(&pa).unwrap();
        let mut b = ResultStore::open_or_create(&pb).unwrap();
        a.ensure_meta(&meta()).unwrap();
        b.ensure_meta(&meta()).unwrap();
        a.append(row(0, TrialResult::Done(wp(0.0)))).unwrap();
        a.append(row(2, TrialResult::Done(wp(0.2)))).unwrap();
        b.append(row(1, TrialResult::Done(wp(0.1)))).unwrap();
        b.append(row(3, TrialResult::Failed { error: "q".into(), attempts: 2 }))
            .unwrap();
        let (m, rows) = merge(&[a, b]).unwrap();
        assert_eq!(m, meta());
        assert_eq!(rows.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // conflicting Done rows for the same trial are refused
        let mut c = ResultStore::open_or_create(&pa).unwrap();
        let mut d = ResultStore::open_or_create(&pb).unwrap();
        c.ensure_meta(&meta()).unwrap();
        d.ensure_meta(&meta()).unwrap();
        c.append(row(0, TrialResult::Done(wp(0.0)))).unwrap();
        d.append(row(0, TrialResult::Done(wp(0.5)))).unwrap();
        let err = merge(&[c, d]).unwrap_err();
        assert!(format!("{err:?}").contains("conflicting"));
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
    }

    #[test]
    fn working_point_keys_are_distinct_per_axis() {
        let base = working_point_key("m", "host", 17, "ECQx", 4, 0.02, 0.3);
        assert_eq!(base, working_point_key("m", "host", 17, "ECQx", 4, 0.02, 0.3));
        let variants = [
            working_point_key("m2", "host", 17, "ECQx", 4, 0.02, 0.3),
            working_point_key("m", "xla", 17, "ECQx", 4, 0.02, 0.3),
            working_point_key("m", "host", 18, "ECQx", 4, 0.02, 0.3),
            working_point_key("m", "host", 17, "ECQ", 4, 0.02, 0.3),
            working_point_key("m", "host", 17, "ECQx", 2, 0.02, 0.3),
            working_point_key("m", "host", 17, "ECQx", 4, 0.021, 0.3),
            working_point_key("m", "host", 17, "ECQx", 4, 0.02, 0.31),
        ];
        let mut all: HashSet<u64> = variants.iter().copied().collect();
        all.insert(base);
        assert_eq!(all.len(), variants.len() + 1, "every axis must perturb the key");
    }

    #[test]
    fn shard_partition_is_exact_and_disjoint() {
        let trials: Vec<TrialSpec> = (0..10)
            .map(|id| TrialSpec { id, method: Method::Ecqx, bits: 4, lambda: 0.0, p: 0.3 })
            .collect();
        assert!(parse_shard("0/1").is_ok());
        assert_eq!(parse_shard("2/3").unwrap(), (2, 3));
        assert!(parse_shard("3/3").is_err());
        assert!(parse_shard("1").is_err());
        assert!(parse_shard("a/2").is_err());
        assert!(parse_shard("0/0").is_err());
        let s0 = shard_trials(&trials, 0, 3);
        let s1 = shard_trials(&trials, 1, 3);
        let s2 = shard_trials(&trials, 2, 3);
        let mut union: Vec<usize> =
            s0.iter().chain(&s1).chain(&s2).map(|t| t.id).collect();
        union.sort_unstable();
        assert_eq!(union, (0..10).collect::<Vec<_>>());
        assert_eq!(s0.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 3, 6, 9]);
    }

    #[test]
    fn grid_hash_is_order_and_value_sensitive() {
        let t = |id, lambda| TrialSpec {
            id,
            method: Method::Ecq,
            bits: 4,
            lambda,
            p: 0.3,
        };
        let a = grid_hash(&[t(0, 0.0), t(1, 0.1)]);
        assert_eq!(a, grid_hash(&[t(0, 0.0), t(1, 0.1)]));
        assert_ne!(a, grid_hash(&[t(1, 0.1), t(0, 0.0)]));
        assert_ne!(a, grid_hash(&[t(0, 0.0), t(1, 0.2)]));
        assert_ne!(a, grid_hash(&[t(0, 0.0)]));
    }
}
