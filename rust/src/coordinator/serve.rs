//! `ecqx serve` — a dependency-free HTTP loopback server that turns the
//! worker-pool / `call_batch` machinery into measured requests-per-second:
//! the deployment half of the paper's claim that 2–5-bit sparse networks
//! are cheap to run (Sec. 5.2.3), sitting directly on the sparse LUT
//! inference path ([`crate::linalg::lut`]).
//!
//! Architecture (DESIGN.md §2.7):
//!
//! * **Protocol** — plain HTTP/1.1 over `std::net`, GET only,
//!   `Connection: close` per request (no keep-alive state machine, no
//!   external deps). Endpoints: `/healthz`, `/shutdown`, and
//!   `/eval?method=&bits=&lambda=&p=` — query parameters default to the
//!   server's [`SweepConfig`], so `/eval?lambda=0.08` addresses the same
//!   working point as the corresponding `ecqx sweep` row.
//! * **Model cache** — working points are built on demand through
//!   [`SweepRunner::run_trial_spec`] (the *same* pure function sweep
//!   trials run, so a served row is byte-identical to the sweep CSV row;
//!   the JSON response carries that CSV line verbatim for CI to diff) and
//!   cached keyed by `(method, bits, lambda, p)`. A per-key build lock
//!   means concurrent first requests for one point build it once, while
//!   distinct points build concurrently.
//! * **Microbatching** — handlers never touch the engine directly; they
//!   enqueue an eval job and block on its reply channel. A single batcher
//!   thread drains up to `max_batch` jobs at a time and fans each
//!   validation batch across the drained states via
//!   [`Engine::call_batch`] — cross-request batching with per-worker
//!   workspaces for free. Because kernels are pure functions of their
//!   operands (workspace- and thread-count-independent, §2.6), the reply
//!   is identical whatever mix of concurrent requests shared the batch;
//!   the server *asserts* this per request by comparing the batched
//!   accuracy against the working point's build-time accuracy (a
//!   divergence is a 500, never silent).
//! * **Shutdown** — `/shutdown` flips a flag held *inside* the queue
//!   mutex and wakes everyone: new submissions are refused (503) under
//!   the same lock, the batcher drains already-accepted jobs before
//!   exiting (no handler left waiting on a dead channel), and a loopback
//!   self-connect unblocks the accept loop. `run` returns only after
//!   every handler thread joined.
//!
//! `--deterministic` needs no plumbing here: `main` pins the process-wide
//! linalg tier before dispatch, exactly as for `sweep`, and everything
//! below the serve layer reads that global.

use std::collections::HashMap;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use super::binder::{bind_inputs, ParamSource, Scalars};
use super::campaign::TrialSpec;
use super::sweep::{SweepConfig, SweepRunner};
use super::Method;
use crate::data::{DataLoader, Dataset};
use crate::metrics::{Meter, WorkingPoint};
use crate::nn::ModelState;
use crate::runtime::ArtifactSpec;
use crate::tensor::Value;
use crate::util::{jsonx, Timer};

/// Server knobs (CLI flags of `ecqx serve`).
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// TCP port on 127.0.0.1; `0` binds an ephemeral port (tests, bench)
    pub port: u16,
    /// worker threads for the batched eval fan-out (`Engine::call_batch`)
    pub jobs: usize,
    /// max eval jobs drained into one microbatch
    pub max_batch: usize,
    /// per-request log lines on stdout
    pub verbose: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { port: 8737, jobs: 1, max_batch: 8, verbose: false }
    }
}

/// A built working point: the sweep row and the quantized state it came
/// from, shared between the cache, in-flight eval jobs, and handlers.
struct Built {
    wp: WorkingPoint,
    state: ModelState,
}

/// One queued eval request: score `built.state` over the validation set,
/// reply with `(loss, accuracy)` or a formatted error.
struct EvalJob {
    built: Arc<Built>,
    reply: mpsc::Sender<std::result::Result<(f64, f64), String>>,
}

/// Queue state guarded by one mutex: the shutdown flag lives *with* the
/// jobs so "refuse new work" and "drain accepted work, then exit" are
/// decided under the same lock — a submission can never slip in after the
/// batcher decided the queue is dry and gone.
#[derive(Default)]
struct QueueState {
    jobs: std::collections::VecDeque<EvalJob>,
    shutdown: bool,
}

struct EvalQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl EvalQueue {
    fn new() -> Self {
        EvalQueue { state: Mutex::new(QueueState::default()), cv: Condvar::new() }
    }

    /// Enqueue unless shutting down (refusal becomes a 503 upstream).
    fn push(&self, job: EvalJob) -> std::result::Result<(), ()> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(());
        }
        st.jobs.push_back(job);
        self.cv.notify_all();
        Ok(())
    }

    /// Drain up to `max` jobs; `None` means shutdown + queue fully dry.
    fn pop_batch(&self, max: usize) -> Option<Vec<EvalJob>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.jobs.is_empty() {
                let take = st.jobs.len().min(max.max(1));
                return Some(st.jobs.drain(..take).collect());
            }
            if st.shutdown {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn begin_shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }
}

/// Cache key of a working point. Float grid values are keyed by their
/// bits — `0.02` must hit the same entry every time, and no float lands
/// in a `HashMap` key directly.
type WpKey = (&'static str, u32, u32, u64);

fn wp_key(method: Method, bits: u32, lambda: f32, p: f64) -> WpKey {
    (method.as_str(), bits, lambda.to_bits(), p.to_bits())
}

type Cache = Mutex<HashMap<WpKey, Arc<Mutex<Option<Arc<Built>>>>>>;

/// The loopback inference server. Construct with [`Server::bind`], drive
/// with [`Server::run`] (blocks until `/shutdown`).
pub struct Server<'e, D: Dataset> {
    listener: TcpListener,
    addr: SocketAddr,
    runner: &'e SweepRunner<'e>,
    cfg: SweepConfig,
    train: &'e DataLoader<'e, D>,
    val: &'e DataLoader<'e, D>,
    opts: ServeOptions,
    art: ArtifactSpec,
    loss_i: usize,
    corr_i: usize,
    queue: EvalQueue,
    cache: Cache,
}

impl<'e, D: Dataset> Server<'e, D> {
    /// Bind 127.0.0.1:`opts.port` (`0` = ephemeral) and resolve the eval
    /// artifact. No threads start until [`Server::run`].
    pub fn bind(
        runner: &'e SweepRunner<'e>,
        cfg: SweepConfig,
        train: &'e DataLoader<'e, D>,
        val: &'e DataLoader<'e, D>,
        opts: ServeOptions,
    ) -> Result<Server<'e, D>> {
        let art = runner
            .engine
            .manifest
            .artifact(&format!("{}_eval", cfg.model))?
            .clone();
        let loss_i = art
            .outputs
            .iter()
            .position(|s| s.name == "loss")
            .with_context(|| format!("artifact {} has no loss output", art.name))?;
        let corr_i = art
            .outputs
            .iter()
            .position(|s| s.name == "correct")
            .with_context(|| format!("artifact {} has no correct output", art.name))?;
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            addr,
            runner,
            cfg,
            train,
            val,
            opts,
            art,
            loss_i,
            corr_i,
            queue: EvalQueue::new(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The bound address (the real port when `--port=0` was requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept-and-serve until `/shutdown`. One handler thread per
    /// connection (loopback scale by design), one batcher thread; all
    /// joined before returning.
    pub fn run(&self) -> Result<()> {
        println!("serving {} on {}", self.cfg.model, self.addr);
        std::thread::scope(|scope| -> Result<()> {
            let batcher = scope.spawn(|| self.batcher_loop());
            loop {
                let (stream, _) = self.listener.accept().context("accept")?;
                if self.queue.state.lock().unwrap().shutdown {
                    // the /shutdown handler's self-connect (or any
                    // straggler) lands here; nothing more is served
                    drop(stream);
                    break;
                }
                scope.spawn(move || {
                    if let Err(e) = self.handle(stream) {
                        eprintln!("[serve] connection error: {e:#}");
                    }
                });
            }
            batcher.join().expect("batcher panicked");
            Ok(())
        })
    }

    /// Batcher: drain ≤ `max_batch` jobs, run one shared validation pass
    /// with [`Engine::call_batch`], reply per job. Exits only when the
    /// queue reports shutdown *and* dry, so every accepted job is
    /// answered.
    fn batcher_loop(&self) {
        while let Some(jobs) = self.queue.pop_batch(self.opts.max_batch) {
            let replies = self.eval_batch(&jobs);
            match replies {
                Ok(per_job) => {
                    for (job, r) in jobs.iter().zip(per_job) {
                        let _ = job.reply.send(Ok(r));
                    }
                }
                Err(e) => {
                    let msg = format!("batched eval failed: {e:#}");
                    for job in &jobs {
                        let _ = job.reply.send(Err(msg.clone()));
                    }
                }
            }
        }
    }

    /// One microbatch: the `evaluate_many` loop over the drained states.
    fn eval_batch(&self, jobs: &[EvalJob]) -> Result<Vec<(f64, f64)>> {
        let mut meters = vec![Meter::new(); jobs.len()];
        for batch in self.val.epoch(0) {
            let inputs: Vec<Vec<Value>> = jobs
                .iter()
                .map(|j| {
                    bind_inputs(
                        &self.art,
                        &j.built.state,
                        ParamSource::Quantized,
                        Some(&batch),
                        &Scalars::default(),
                    )
                })
                .collect::<Result<_>>()?;
            let outs = self.runner.engine.call_batch(&self.art.name, &inputs, self.opts.jobs)?;
            for (m, out) in meters.iter_mut().zip(outs) {
                m.update(
                    out[self.loss_i].as_f32().as_scalar(),
                    out[self.corr_i].as_f32().as_scalar(),
                    batch.batch,
                );
            }
        }
        Ok(meters.iter().map(|m| (m.loss(), m.accuracy())).collect())
    }

    /// Get-or-build the model at a working point. Distinct points build
    /// concurrently; concurrent requests for one point build it once
    /// (per-key mutex). Failed builds are not cached — the next request
    /// retries.
    fn model_at(&self, method: Method, bits: u32, lambda: f32, p: f64) -> Result<Arc<Built>> {
        let slot = {
            let mut cache = self.cache.lock().unwrap();
            cache
                .entry(wp_key(method, bits, lambda, p))
                .or_insert_with(|| Arc::new(Mutex::new(None)))
                .clone()
        };
        let mut slot = slot.lock().unwrap();
        if let Some(built) = slot.as_ref() {
            return Ok(built.clone());
        }
        let t = Timer::start();
        let trial = TrialSpec { id: 0, method, bits, lambda, p };
        let (wp, state) = self
            .runner
            .run_trial_spec(&self.cfg, &trial, self.train, self.val)?;
        if self.opts.verbose {
            println!(
                "[serve] built {} bw={bits} λ={lambda:.4} p={p:.2}: acc={:.4} ({:.1}s)",
                method.as_str(),
                wp.accuracy,
                t.elapsed_s()
            );
        }
        let built = Arc::new(Built { wp, state });
        *slot = Some(built.clone());
        Ok(built)
    }

    /// `/eval` body: resolve the working point, score it through the
    /// microbatch queue, self-check purity, render JSON.
    fn eval_response(&self, query: &str) -> Result<String> {
        let params = parse_query(query)?;
        let mut method = self.cfg.method;
        let mut bits = self.cfg.bits;
        let mut lambda = self.cfg.lambdas.first().copied().unwrap_or(0.0);
        let mut p = self.cfg.p;
        for (k, v) in &params {
            match k.as_str() {
                "method" => {
                    method = match v.as_str() {
                        "ecq" => Method::Ecq,
                        "ecqx" => Method::Ecqx,
                        other => bail!("unknown method {other} (use ecq|ecqx)"),
                    }
                }
                "bits" => bits = v.parse().with_context(|| format!("bits={v:?}"))?,
                "lambda" => lambda = v.parse().with_context(|| format!("lambda={v:?}"))?,
                "p" => p = v.parse().with_context(|| format!("p={v:?}"))?,
                other => bail!("unknown query parameter {other:?} (use method|bits|lambda|p)"),
            }
        }
        let built = self.model_at(method, bits, lambda, p)?;
        let (rx_loss, rx_acc) = {
            let (tx, rx) = mpsc::channel();
            if self.queue.push(EvalJob { built: built.clone(), reply: tx }).is_err() {
                bail!("server is shutting down");
            }
            rx.recv().context("batcher dropped the reply channel")?
                .map_err(anyhow::Error::msg)?
        };
        // Purity self-check: the microbatched score must equal the score
        // computed at build time (run_trial_spec's serial evaluate),
        // whatever mix of concurrent requests shared the batch. This is
        // the §2.6 batch-order-independence argument, asserted per
        // request.
        if rx_acc != built.wp.accuracy {
            bail!(
                "batched eval diverged from build-time eval: {} != {} \
                 (batch-order independence violated)",
                rx_acc,
                built.wp.accuracy
            );
        }
        let wp = &built.wp;
        Ok(format!(
            "{{\"method\": {}, \"bits\": {}, \"lambda\": {}, \"p\": {}, \
             \"accuracy\": {}, \"acc_drop\": {}, \"sparsity\": {}, \
             \"size_bytes\": {}, \"cr\": {}, \"loss\": {}, \"csv\": {}}}\n",
            jsonx::quote(&wp.method),
            wp.bits,
            jsonx::num_f64(wp.lambda as f64),
            jsonx::num_f64(wp.p),
            jsonx::num_f64(wp.accuracy),
            jsonx::num_f64(wp.acc_drop),
            jsonx::num_f64(wp.sparsity),
            wp.size_bytes,
            jsonx::num_f64(wp.compression_ratio),
            jsonx::num_f64(rx_loss),
            jsonx::quote(&wp.to_csv()),
        ))
    }

    /// One connection: parse the request line, route, write one response.
    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        let (target, ok) = read_request(&mut stream)?;
        if !ok {
            return respond(&mut stream, 405, "text/plain", "only GET is served\n");
        }
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p, q),
            None => (target.as_str(), ""),
        };
        match path {
            "/healthz" => respond(&mut stream, 200, "text/plain", "ok\n"),
            "/shutdown" => {
                respond(&mut stream, 200, "text/plain", "shutting down\n")?;
                self.queue.begin_shutdown();
                // unblock the accept loop; the flag is already set, so
                // this connection is dropped unserved
                let _ = TcpStream::connect(self.addr);
                Ok(())
            }
            "/eval" => match self.eval_response(query) {
                Ok(body) => respond(&mut stream, 200, "application/json", &body),
                Err(e) => {
                    let msg = format!("{e:#}");
                    let code = if msg.contains("shutting down") { 503 } else { 500 };
                    respond(&mut stream, code, "text/plain", &format!("{msg}\n"))
                }
            },
            other => respond(&mut stream, 404, "text/plain", &format!("no route {other}\n")),
        }
    }
}

/// `k=v&k=v` → pairs. No percent-decoding: every legal value is a number
/// or a method name, so an escape is just an invalid value.
fn parse_query(q: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for part in q.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = part
            .split_once('=')
            .with_context(|| format!("query parameter {part:?} has no value"))?;
        out.push((k.to_string(), v.to_string()));
    }
    Ok(out)
}

/// Read one request head; returns `(target, is_get)`.
fn read_request(stream: &mut TcpStream) -> Result<(String, bool)> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk).context("reading request")?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let line = head.lines().next().unwrap_or_default();
    let mut it = line.split_whitespace();
    let meth = it.next().unwrap_or_default();
    let target = it.next().unwrap_or("/").to_string();
    Ok((target, meth == "GET"))
}

/// Write one `Connection: close` response.
fn respond(stream: &mut TcpStream, code: u16, ctype: &str, body: &str) -> Result<()> {
    let reason = match code {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Minimal blocking HTTP GET against a loopback server; returns
/// `(status, body)`. Shared by the CLI bench mode, the serve integration
/// test, and CI's serve-smoke job (via `ecqx serve --bench`).
pub fn http_get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).context("reading response")?;
    let code: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed response: {raw:.60?}"))?;
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((code, body))
}

/// Saturating-throughput bench summary (`ecqx serve --bench`).
#[derive(Clone, Copy, Debug)]
pub struct BenchSummary {
    /// concurrent client threads
    pub clients: usize,
    /// total requests completed (all of them 200s, or the bench errors)
    pub requests: usize,
    /// whole-bench wall clock
    pub wall_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// requests per second at saturation (`requests / wall_s`)
    pub req_s: f64,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[pos.min(sorted.len() - 1)]
}

/// Drive `clients` threads of back-to-back `GET path` requests,
/// `per_client` each, against an already-warm server. Every response must
/// be a 200 and byte-identical to the warmup response — the throughput
/// number is only meaningful if the answers stay right under load.
pub fn run_bench(
    addr: SocketAddr,
    path: &str,
    clients: usize,
    per_client: usize,
) -> Result<BenchSummary> {
    let (code, reference) = http_get(addr, path)?;
    if code != 200 {
        bail!("bench warmup GET {path} returned {code}: {reference}");
    }
    let wall = Timer::start();
    let lat: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients.max(1))
            .map(|_| {
                let reference = reference.as_str();
                scope.spawn(move || -> Result<Vec<f64>> {
                    let mut times = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let t = Timer::start();
                        let (code, body) = http_get(addr, path)?;
                        times.push(t.elapsed_s());
                        if code != 200 || body != reference {
                            bail!("response diverged under load (status {code})");
                        }
                    }
                    Ok(times)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bench client panicked"))
            .collect::<Result<_>>()
    })?;
    let wall_s = wall.elapsed_s();
    let mut all: Vec<f64> = lat.into_iter().flatten().collect();
    if all.is_empty() {
        // per_client = 0: percentiles and req/s would be meaningless
        // (and a later unwrap-happy consumer could divide by zero)
        bail!("bench completed zero requests ({} clients x {per_client} each)", clients.max(1));
    }
    // total_cmp, not partial_cmp().unwrap(): a NaN latency (however a
    // timer misbehaves) must not panic mid-bench
    all.sort_by(f64::total_cmp);
    let requests = all.len();
    Ok(BenchSummary {
        clients: clients.max(1),
        requests,
        wall_s,
        p50_s: percentile(&all, 0.50),
        p99_s: percentile(&all, 0.99),
        req_s: requests as f64 / wall_s.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_parsing_and_rejection() {
        let ps = parse_query("method=ecq&bits=2&lambda=0.08&p=0.5").unwrap();
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0], ("method".into(), "ecq".into()));
        assert!(parse_query("").unwrap().is_empty());
        assert!(parse_query("bits").is_err(), "valueless parameter is an error");
    }

    #[test]
    fn percentiles_of_sorted_latencies() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 51.0); // round(0.5*99)=50 -> v[50]
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn queue_refuses_after_shutdown_and_drains_before() {
        let q = EvalQueue::new();
        let (tx, _rx) = mpsc::channel();
        let built = Arc::new(Built {
            wp: WorkingPoint {
                method: "ECQx".into(),
                bits: 4,
                lambda: 0.0,
                p: 0.3,
                accuracy: 0.5,
                acc_drop: 0.0,
                sparsity: 0.5,
                size_bytes: 1,
                compression_ratio: 2.0,
            },
            state: ModelState::init(
                crate::runtime::Manifest::synthetic_mlp("t", &[8, 4, 2], 4)
                    .model("t")
                    .unwrap(),
                1,
            ),
        });
        q.push(EvalJob { built: built.clone(), reply: tx.clone() }).unwrap();
        q.begin_shutdown();
        // accepted-before-shutdown job still drains...
        let batch = q.pop_batch(8).expect("pre-shutdown job must drain");
        assert_eq!(batch.len(), 1);
        // ...then the queue reports dry, and new pushes are refused
        assert!(q.pop_batch(8).is_none());
        assert!(q.push(EvalJob { built, reply: tx }).is_err());
    }

    #[test]
    fn wp_key_is_bit_exact() {
        assert_eq!(
            wp_key(Method::Ecqx, 4, 0.02, 0.3),
            wp_key(Method::Ecqx, 4, 0.02, 0.3)
        );
        assert_ne!(
            wp_key(Method::Ecqx, 4, 0.02, 0.3),
            wp_key(Method::Ecq, 4, 0.02, 0.3)
        );
        assert_ne!(
            wp_key(Method::Ecqx, 4, 0.02, 0.3),
            wp_key(Method::Ecqx, 4, 0.02000001, 0.3)
        );
    }
}
