//! ECQ^x: Explainability-Driven Quantization for Low-Bit and Sparse DNNs.
//!
//! Rust coordinator (L3) of the three-layer rust + JAX + Pallas stack:
//! the JAX/Pallas side (`python/compile/`) is AOT-lowered once to HLO-text
//! artifacts; this crate owns everything that runs at experiment time —
//! synthetic datasets, the quantization-aware training loop, the ECQ/ECQx
//! assignment logic, LRP relevance post-processing, the DeepCABAC-style
//! entropy codec, the sweep campaigns reproducing every figure/table of
//! the paper, and the CLI.
//!
//! Layer map (see DESIGN.md):
//! * [`runtime`] — backend-generic engine: the concurrent PJRT backend
//!   (sharded executable cache over `artifacts/*.hlo.txt`) and the
//!   pure-rust host reference backend ([`runtime::host`], no artifacts
//!   or PJRT needed)
//! * [`coordinator`] — QAT loop, parallel sweep campaigns
//!   ([`coordinator::campaign`]), candidate selection, reports
//! * [`linalg`] — blocked SIMD-friendly GEMM core with fused epilogues,
//!   per-worker workspaces, and the im2col conv2d lowering over the same
//!   core (the host backend's hot path)
//! * [`quant`] — centroids, entropy, pure-rust assignment reference
//! * [`lrp`] — relevance pipeline + rust LRP reference implementation
//! * [`codec`] — CABAC-style coder + baselines (compression ratios)
//! * [`data`] / [`nn`] / [`tensor`] / [`util`] / [`metrics`] — substrates
//!   (including the scoped-thread worker pool in [`util::pool`])

pub mod bench;
pub mod codec;
pub mod exp;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod lrp;
pub mod metrics;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
