//! Minimal zlib/DEFLATE encoder — the offline stand-in for `flate2`
//! (general-purpose baseline in the codec comparison; see the DESIGN.md
//! substitution table).
//!
//! Emits RFC 1950/1951-conformant output: a zlib header, one final
//! fixed-Huffman DEFLATE block, and the Adler-32 trailer. Matching is
//! deliberately simple — distance-1 run matches only (the dominant
//! structure of sparse quantized weight tensors is zero runs) — so this
//! is a *size baseline*, not a competitive compressor; CABAC/Huffman must
//! beat it on the paper's sources and the comparison stays honest.

/// LSB-first bit writer (DEFLATE bit order: codes MSB-first, everything
/// else LSB-first, bytes filled from the low bit).
struct BitWriter {
    out: Vec<u8>,
    cur: u32,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { out: Vec::new(), cur: 0, nbits: 0 }
    }

    /// Push `n` bits of `v`, LSB-first (extra bits, block header).
    fn put(&mut self, v: u32, n: u32) {
        self.cur |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.cur & 0xFF) as u8);
            self.cur >>= 8;
            self.nbits -= 8;
        }
    }

    /// Push a Huffman code of `n` bits, MSB of the code first.
    fn put_code(&mut self, code: u32, n: u32) {
        let mut rev = 0u32;
        for i in 0..n {
            rev |= ((code >> i) & 1) << (n - 1 - i);
        }
        self.put(rev, n);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.cur & 0xFF) as u8);
        }
        self.out
    }
}

/// Fixed-Huffman literal/length code (RFC 1951 §3.2.6).
fn put_litlen(w: &mut BitWriter, sym: u32) {
    match sym {
        0..=143 => w.put_code(0x30 + sym, 8),
        144..=255 => w.put_code(0x190 + (sym - 144), 9),
        256..=279 => w.put_code(sym - 256, 7),
        _ => w.put_code(0xC0 + (sym - 280), 8),
    }
}

/// Length code table: (code, extra_bits, base_length) per RFC 1951.
const LEN_CODES: [(u32, u32, u32); 29] = [
    (257, 0, 3),
    (258, 0, 4),
    (259, 0, 5),
    (260, 0, 6),
    (261, 0, 7),
    (262, 0, 8),
    (263, 0, 9),
    (264, 0, 10),
    (265, 1, 11),
    (266, 1, 13),
    (267, 1, 15),
    (268, 1, 17),
    (269, 2, 19),
    (270, 2, 23),
    (271, 2, 27),
    (272, 2, 31),
    (273, 3, 35),
    (274, 3, 43),
    (275, 3, 51),
    (276, 3, 59),
    (277, 4, 67),
    (278, 4, 83),
    (279, 4, 99),
    (280, 4, 115),
    (281, 5, 131),
    (282, 5, 163),
    (283, 5, 195),
    (284, 5, 227),
    (285, 0, 258),
];

/// Emit a (length, distance=1) match.
fn put_match(w: &mut BitWriter, len: u32) {
    debug_assert!((3..=258).contains(&len));
    let (code, extra, base) = *LEN_CODES
        .iter()
        .rev()
        .find(|&&(_, _, base)| base <= len)
        .unwrap();
    put_litlen(w, code);
    if extra > 0 {
        w.put(len - base, extra);
    }
    // distance code 0 (distance 1): fixed 5-bit code, no extra bits
    w.put_code(0, 5);
}

fn adler32(bytes: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in bytes.chunks(5552) {
        for &x in chunk {
            a += x as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Compress `bytes` into a zlib stream (header + one fixed-Huffman block
/// + Adler-32).
pub fn compress(bytes: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    // zlib header: CM=8/CINFO=7, check bits making the pair ≡ 0 (mod 31)
    w.out.extend_from_slice(&[0x78, 0x9C]);
    // BFINAL=1, BTYPE=01 (fixed Huffman)
    w.put(1, 1);
    w.put(1, 2);
    let n = bytes.len();
    let mut i = 0usize;
    while i < n {
        let mut run = 0usize;
        if i > 0 {
            let prev = bytes[i - 1];
            while run < 258 && i + run < n && bytes[i + run] == prev {
                run += 1;
            }
        }
        if run >= 3 {
            put_match(&mut w, run as u32);
            i += run;
        } else {
            put_litlen(&mut w, bytes[i] as u32);
            i += 1;
        }
    }
    put_litlen(&mut w, 256); // end of block
    let mut out = w.finish();
    out.extend_from_slice(&adler32(bytes).to_be_bytes());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zero_runs_collapse() {
        let sz = compress(&[0u8; 1024]).len();
        assert!(sz < 64, "1 kB of zeros must code tiny, got {sz}");
        let sz4 = compress(&[0u8; 4096]).len();
        assert!(sz4 < 96, "zero-run cost must grow sublinearly, got {sz4}");
    }

    #[test]
    fn deterministic_and_nonempty() {
        assert_eq!(compress(b"hello"), compress(b"hello"));
        assert!(!compress(b"hello").is_empty());
        // empty input still carries header + EOB + adler
        let e = compress(&[]);
        assert!(e.len() >= 7 && e.len() < 16);
        assert_eq!(&e[..2], &[0x78, 0x9C]);
    }

    #[test]
    fn incompressible_data_costs_about_one_byte_per_byte() {
        let mut rng = Rng::new(11);
        let data: Vec<u8> = (0..4096).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let sz = compress(&data).len();
        // 8/9-bit literals: bounded blow-up, no pathological growth
        assert!(sz >= 4096 && sz < 4096 * 9 / 8 + 64, "size {sz}");
    }

    #[test]
    fn sparser_sources_code_smaller() {
        let mut rng = Rng::new(5);
        let mk = |p_zero: f64, rng: &mut Rng| -> Vec<u8> {
            (0..16384)
                .map(|_| if rng.chance(p_zero) { 0u8 } else { (1 + rng.below(15)) as u8 })
                .collect()
        };
        let sparse = compress(&mk(0.95, &mut rng)).len();
        let dense = compress(&mk(0.30, &mut rng)).len();
        assert!(sparse < dense, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn adler_reference_values() {
        // RFC 1950 example: "Wikipedia" -> 0x11E60398
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
        assert_eq!(adler32(&[]), 1);
    }
}
