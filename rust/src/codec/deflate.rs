//! Minimal zlib/DEFLATE codec — the offline stand-in for `flate2`
//! (general-purpose baseline in the codec comparison; see the DESIGN.md
//! substitution table).
//!
//! [`compress`] emits RFC 1950/1951-conformant output: a zlib header, one
//! final fixed-Huffman DEFLATE block, and the Adler-32 trailer. Matching
//! is deliberately simple — distance-1 run matches only (the dominant
//! structure of sparse quantized weight tensors is zero runs) — so this
//! is a *size baseline*, not a competitive compressor; CABAC/Huffman must
//! beat it on the paper's sources and the comparison stays honest.
//!
//! [`decompress`] is the fallible inverse: it inflates stored and
//! fixed-Huffman blocks (any match distance, not just 1), verifies the
//! Adler-32 trailer, and rejects malformed input with [`CodecError`]
//! instead of panicking. Output allocation is structurally bounded: every
//! emitted byte consumes stream bits (a literal >= 7 bits, a match of
//! <= 258 bytes >= 12 bits), so a `len`-byte input can never inflate past
//! ~172x `len` and no header field is trusted for a pre-allocation.

use super::error::{CodecError, CodecResult};

/// LSB-first bit writer (DEFLATE bit order: codes MSB-first, everything
/// else LSB-first, bytes filled from the low bit).
struct BitWriter {
    out: Vec<u8>,
    cur: u32,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { out: Vec::new(), cur: 0, nbits: 0 }
    }

    /// Push `n` bits of `v`, LSB-first (extra bits, block header).
    fn put(&mut self, v: u32, n: u32) {
        self.cur |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.cur & 0xFF) as u8);
            self.cur >>= 8;
            self.nbits -= 8;
        }
    }

    /// Push a Huffman code of `n` bits, MSB of the code first.
    fn put_code(&mut self, code: u32, n: u32) {
        let mut rev = 0u32;
        for i in 0..n {
            rev |= ((code >> i) & 1) << (n - 1 - i);
        }
        self.put(rev, n);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.cur & 0xFF) as u8);
        }
        self.out
    }
}

/// Fixed-Huffman literal/length code (RFC 1951 §3.2.6).
fn put_litlen(w: &mut BitWriter, sym: u32) {
    match sym {
        0..=143 => w.put_code(0x30 + sym, 8),
        144..=255 => w.put_code(0x190 + (sym - 144), 9),
        256..=279 => w.put_code(sym - 256, 7),
        _ => w.put_code(0xC0 + (sym - 280), 8),
    }
}

/// Length code table: (code, extra_bits, base_length) per RFC 1951.
const LEN_CODES: [(u32, u32, u32); 29] = [
    (257, 0, 3),
    (258, 0, 4),
    (259, 0, 5),
    (260, 0, 6),
    (261, 0, 7),
    (262, 0, 8),
    (263, 0, 9),
    (264, 0, 10),
    (265, 1, 11),
    (266, 1, 13),
    (267, 1, 15),
    (268, 1, 17),
    (269, 2, 19),
    (270, 2, 23),
    (271, 2, 27),
    (272, 2, 31),
    (273, 3, 35),
    (274, 3, 43),
    (275, 3, 51),
    (276, 3, 59),
    (277, 4, 67),
    (278, 4, 83),
    (279, 4, 99),
    (280, 4, 115),
    (281, 5, 131),
    (282, 5, 163),
    (283, 5, 195),
    (284, 5, 227),
    (285, 0, 258),
];

/// Emit a (length, distance=1) match.
fn put_match(w: &mut BitWriter, len: u32) {
    debug_assert!((3..=258).contains(&len));
    let (code, extra, base) = *LEN_CODES
        .iter()
        .rev()
        .find(|&&(_, _, base)| base <= len)
        .unwrap();
    put_litlen(w, code);
    if extra > 0 {
        w.put(len - base, extra);
    }
    // distance code 0 (distance 1): fixed 5-bit code, no extra bits
    w.put_code(0, 5);
}

fn adler32(bytes: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in bytes.chunks(5552) {
        for &x in chunk {
            a += x as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Compress `bytes` into a zlib stream (header + one fixed-Huffman block
/// + Adler-32).
pub fn compress(bytes: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    // zlib header: CM=8/CINFO=7, check bits making the pair ≡ 0 (mod 31)
    w.out.extend_from_slice(&[0x78, 0x9C]);
    // BFINAL=1, BTYPE=01 (fixed Huffman)
    w.put(1, 1);
    w.put(1, 2);
    let n = bytes.len();
    let mut i = 0usize;
    while i < n {
        let mut run = 0usize;
        if i > 0 {
            let prev = bytes[i - 1];
            while run < 258 && i + run < n && bytes[i + run] == prev {
                run += 1;
            }
        }
        if run >= 3 {
            put_match(&mut w, run as u32);
            i += run;
        } else {
            put_litlen(&mut w, bytes[i] as u32);
            i += 1;
        }
    }
    put_litlen(&mut w, 256); // end of block
    let mut out = w.finish();
    out.extend_from_slice(&adler32(bytes).to_be_bytes());
    out
}

/// Fixed distance code table: (extra_bits, base_distance) per RFC 1951.
const DIST_CODES: [(u32, u32); 30] = [
    (0, 1),
    (0, 2),
    (0, 3),
    (0, 4),
    (1, 5),
    (1, 7),
    (2, 9),
    (2, 13),
    (3, 17),
    (3, 25),
    (4, 33),
    (4, 49),
    (5, 65),
    (5, 97),
    (6, 129),
    (6, 193),
    (7, 257),
    (7, 385),
    (8, 513),
    (8, 769),
    (9, 1025),
    (9, 1537),
    (10, 2049),
    (10, 3073),
    (11, 4097),
    (11, 6145),
    (12, 8193),
    (12, 12289),
    (13, 16385),
    (13, 24577),
];

/// LSB-first fallible bit reader (DEFLATE bit order).
struct LsbReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> LsbReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        LsbReader { buf, pos: 0 }
    }

    fn get_bit(&mut self) -> CodecResult<u32> {
        let byte = self.pos / 8;
        if byte >= self.buf.len() {
            return Err(CodecError::UnexpectedEof { at_bit: self.pos });
        }
        let bit = (self.buf[byte] >> (self.pos % 8)) & 1;
        self.pos += 1;
        Ok(bit as u32)
    }

    /// Read `n <= 32` bits, LSB-first (extra bits, headers).
    fn get(&mut self, n: u32) -> CodecResult<u32> {
        let mut v = 0u32;
        for i in 0..n {
            v |= self.get_bit()? << i;
        }
        Ok(v)
    }

    /// Discard padding up to the next byte boundary (stored blocks,
    /// trailer).
    fn align_byte(&mut self) {
        self.pos = self.pos.div_ceil(8) * 8;
    }

    fn byte_pos(&self) -> usize {
        self.pos / 8
    }
}

/// Decode one fixed-Huffman literal/length symbol (inverse of
/// [`put_litlen`]): codes are read MSB-first and resolved at lengths
/// 7, 8 and 9 per RFC 1951 §3.2.6.
fn get_litlen(r: &mut LsbReader) -> CodecResult<u32> {
    let mut code = 0u32;
    for _ in 0..7 {
        code = (code << 1) | r.get_bit()?;
    }
    if code <= 0x17 {
        return Ok(256 + code);
    }
    code = (code << 1) | r.get_bit()?;
    if (0x30..=0xBF).contains(&code) {
        return Ok(code - 0x30);
    }
    if (0xC0..=0xC7).contains(&code) {
        return Ok(280 + (code - 0xC0));
    }
    code = (code << 1) | r.get_bit()?;
    if (0x190..=0x1FF).contains(&code) {
        return Ok(144 + (code - 0x190));
    }
    Err(CodecError::CorruptPrefix { at_bit: r.pos })
}

/// Inflate a zlib stream produced by [`compress`] (or any stored /
/// fixed-Huffman zlib stream) and verify its Adler-32 trailer.
pub fn decompress(buf: &[u8]) -> CodecResult<Vec<u8>> {
    if buf.len() < 2 {
        return Err(CodecError::Malformed { detail: "zlib header truncated" });
    }
    let (cmf, flg) = (buf[0] as u32, buf[1] as u32);
    if cmf & 0x0F != 8 {
        return Err(CodecError::Unsupported { detail: "zlib CM != 8 (not deflate)" });
    }
    if (cmf * 256 + flg) % 31 != 0 {
        return Err(CodecError::Malformed { detail: "zlib header check bits" });
    }
    if flg & 0x20 != 0 {
        return Err(CodecError::Unsupported { detail: "zlib preset dictionary" });
    }
    let body = &buf[2..];
    let mut r = LsbReader::new(body);
    let mut out = Vec::new();
    loop {
        let bfinal = r.get(1)?;
        match r.get(2)? {
            0 => {
                // stored block: LEN/NLEN are a 1's-complement pair and LEN
                // is checked against the remaining bytes before any copy
                r.align_byte();
                let len = r.get(16)? as usize;
                let nlen = r.get(16)? as usize;
                if len != !nlen & 0xFFFF {
                    return Err(CodecError::Malformed { detail: "stored LEN != !NLEN" });
                }
                let start = r.byte_pos();
                if start + len > body.len() {
                    return Err(CodecError::UnexpectedEof { at_bit: r.pos });
                }
                out.extend_from_slice(&body[start..start + len]);
                r.pos = (start + len) * 8;
            }
            1 => loop {
                let sym = get_litlen(&mut r)?;
                if sym < 256 {
                    out.push(sym as u8);
                    continue;
                }
                if sym == 256 {
                    break; // end of block
                }
                if sym > 285 {
                    return Err(CodecError::Malformed { detail: "invalid length code" });
                }
                let (_, extra, base) = LEN_CODES[(sym - 257) as usize];
                let len = (base + r.get(extra)?) as usize;
                let mut dcode = 0u32;
                for _ in 0..5 {
                    dcode = (dcode << 1) | r.get_bit()?;
                }
                if dcode >= 30 {
                    return Err(CodecError::Malformed { detail: "invalid distance code" });
                }
                let (dextra, dbase) = DIST_CODES[dcode as usize];
                let dist = (dbase + r.get(dextra)?) as usize;
                if dist > out.len() {
                    return Err(CodecError::Malformed {
                        detail: "match distance beyond produced output",
                    });
                }
                // byte-by-byte copy: overlapping matches (dist < len)
                // replicate the run, exactly as LZ77 defines
                for _ in 0..len {
                    let b = out[out.len() - dist];
                    out.push(b);
                }
            },
            2 => {
                return Err(CodecError::Unsupported {
                    detail: "dynamic Huffman block (encoder never emits one)",
                })
            }
            _ => return Err(CodecError::Malformed { detail: "reserved block type 11" }),
        }
        if bfinal == 1 {
            break;
        }
    }
    r.align_byte();
    let start = r.byte_pos();
    if start + 4 > body.len() {
        return Err(CodecError::Malformed { detail: "Adler-32 trailer truncated" });
    }
    let stored = u32::from_be_bytes([
        body[start],
        body[start + 1],
        body[start + 2],
        body[start + 3],
    ]);
    let computed = adler32(&out);
    if stored != computed {
        return Err(CodecError::ChecksumMismatch { stored, computed });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zero_runs_collapse() {
        let sz = compress(&[0u8; 1024]).len();
        assert!(sz < 64, "1 kB of zeros must code tiny, got {sz}");
        let sz4 = compress(&[0u8; 4096]).len();
        assert!(sz4 < 96, "zero-run cost must grow sublinearly, got {sz4}");
    }

    #[test]
    fn deterministic_and_nonempty() {
        assert_eq!(compress(b"hello"), compress(b"hello"));
        assert!(!compress(b"hello").is_empty());
        // empty input still carries header + EOB + adler
        let e = compress(&[]);
        assert!(e.len() >= 7 && e.len() < 16);
        assert_eq!(&e[..2], &[0x78, 0x9C]);
    }

    #[test]
    fn incompressible_data_costs_about_one_byte_per_byte() {
        let mut rng = Rng::new(11);
        let data: Vec<u8> = (0..4096).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let sz = compress(&data).len();
        // 8/9-bit literals: bounded blow-up, no pathological growth
        assert!(sz >= 4096 && sz < 4096 * 9 / 8 + 64, "size {sz}");
    }

    #[test]
    fn sparser_sources_code_smaller() {
        let mut rng = Rng::new(5);
        let mk = |p_zero: f64, rng: &mut Rng| -> Vec<u8> {
            (0..16384)
                .map(|_| if rng.chance(p_zero) { 0u8 } else { (1 + rng.below(15)) as u8 })
                .collect()
        };
        let sparse = compress(&mk(0.95, &mut rng)).len();
        let dense = compress(&mk(0.30, &mut rng)).len();
        assert!(sparse < dense, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn decompress_roundtrips() {
        for data in [
            Vec::new(),
            b"hello".to_vec(),
            vec![0u8; 4096],
            b"abcabcabcabc".to_vec(),
        ] {
            assert_eq!(decompress(&compress(&data)).unwrap(), data, "{} bytes", data.len());
        }
        let mut rng = Rng::new(21);
        for n in [1usize, 63, 1024, 16384] {
            let mix: Vec<u8> = (0..n)
                .map(|_| if rng.chance(0.7) { 0u8 } else { (rng.next_u64() & 0xFF) as u8 })
                .collect();
            assert_eq!(decompress(&compress(&mix)).unwrap(), mix);
        }
    }

    #[test]
    fn decompress_rejects_corrupt_header() {
        assert!(matches!(
            decompress(&[]),
            Err(CodecError::Malformed { .. })
        ));
        // CM != 8
        assert!(matches!(
            decompress(&[0x79, 0x9C, 0, 0]),
            Err(CodecError::Unsupported { .. } | CodecError::Malformed { .. })
        ));
        // broken FCHECK
        assert!(matches!(
            decompress(&[0x78, 0x9D, 0, 0]),
            Err(CodecError::Malformed { .. })
        ));
    }

    #[test]
    fn decompress_rejects_bad_checksum() {
        let mut bytes = compress(b"checksummed payload");
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        let err = decompress(&bytes).unwrap_err();
        assert!(matches!(err, CodecError::ChecksumMismatch { .. }), "{err:?}");
    }

    #[test]
    fn decompress_rejects_truncation_everywhere() {
        let bytes = compress(b"some payload with a zero run \0\0\0\0\0\0\0\0 inside");
        for cut in 0..bytes.len() {
            let res = decompress(&bytes[..cut]);
            assert!(res.is_err(), "truncation at {cut} must fail, got {res:?}");
        }
        assert!(decompress(&bytes).is_ok());
    }

    #[test]
    fn adler_reference_values() {
        // RFC 1950 example: "Wikipedia" -> 0x11E60398
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
        assert_eq!(adler32(&[]), 1);
    }
}
