//! The `CodecError` taxonomy of the fallible decode surface (DESIGN.md
//! §2.4).
//!
//! Every decoder in [`crate::codec`] is *total*: any byte sequence — a
//! truncation, a bit flip, or pure noise — yields `Ok` or one of these
//! variants. No panics, no unwinding, and no allocation proportional to a
//! corrupt length field (the allocation-bounding rule: every in-stream
//! length/count is validated against a bound derived from the remaining
//! payload, or against [`crate::codec::MAX_DECODE_ELEMS`] when the coder
//! is sub-linear and no payload bound exists).

use std::fmt;

/// Why a bitstream or container was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The stream ended while more payload bits were required.
    UnexpectedEof {
        /// Bit offset at which the read ran past the buffer.
        at_bit: usize,
    },
    /// A decoded length/count field exceeds what the payload could
    /// possibly back — rejected *before* any allocation.
    LengthOverflow {
        /// Which header field made the claim.
        field: &'static str,
        /// The claimed count (saturated to `u64::MAX` on overflow).
        claimed: u64,
        /// The payload-derived (or policy) bound it violated.
        max: u64,
    },
    /// A prefix-code walk left the valid code space (corrupt prefix).
    CorruptPrefix {
        /// Approximate bit offset of the failed walk.
        at_bit: usize,
    },
    /// A Huffman code table violating the Kraft inequality or carrying a
    /// zero/overlong code length.
    InvalidTable {
        /// What was wrong with the table.
        detail: &'static str,
    },
    /// Encoding met a symbol outside the code table's alphabet.
    UnknownSymbol {
        /// The out-of-alphabet level.
        symbol: i32,
    },
    /// A decoded value is outside the representable/plausible range.
    ValueOverflow {
        /// Which value overflowed and its bound.
        detail: &'static str,
    },
    /// Container-level framing violation (magic, section or chunk
    /// structure).
    Malformed {
        /// What the framing check found.
        detail: &'static str,
    },
    /// A stored checksum does not match the decoded payload.
    ChecksumMismatch {
        /// Checksum carried by the stream.
        stored: u32,
        /// Checksum recomputed over the decoded payload.
        computed: u32,
    },
    /// Structurally valid but intentionally unsupported (e.g. dynamic
    /// Huffman blocks in the deflate stand-in).
    Unsupported {
        /// The unsupported feature.
        detail: &'static str,
    },
}

/// Result alias for the codec decode surface.
pub type CodecResult<T> = std::result::Result<T, CodecError>;

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::UnexpectedEof { at_bit } => {
                write!(f, "bitstream ended early (at bit {at_bit})")
            }
            CodecError::LengthOverflow { field, claimed, max } => {
                write!(f, "{field} claims {claimed} but the payload bounds it at {max}")
            }
            CodecError::CorruptPrefix { at_bit } => {
                write!(f, "prefix-code walk left the code space near bit {at_bit}")
            }
            CodecError::InvalidTable { detail } => write!(f, "invalid code table: {detail}"),
            CodecError::UnknownSymbol { symbol } => {
                write!(f, "symbol {symbol} is outside the code alphabet")
            }
            CodecError::ValueOverflow { detail } => {
                write!(f, "decoded value out of range: {detail}")
            }
            CodecError::Malformed { detail } => write!(f, "malformed stream: {detail}"),
            CodecError::ChecksumMismatch { stored, computed } => {
                write!(f, "checksum mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            CodecError::Unsupported { detail } => {
                write!(f, "unsupported stream feature: {detail}")
            }
        }
    }
}

impl std::error::Error for CodecError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = CodecError::LengthOverflow { field: "nsym", claimed: 1 << 40, max: 128 };
        let s = e.to_string();
        assert!(s.contains("nsym") && s.contains("128"), "{s}");
        let e = CodecError::ChecksumMismatch { stored: 0xDEAD_BEEF, computed: 1 };
        assert!(e.to_string().contains("0xdeadbeef"), "{e}");
    }

    #[test]
    fn is_std_error_and_converts_to_anyhow() {
        fn f() -> anyhow::Result<()> {
            Err(CodecError::InvalidTable { detail: "zero-length code" })?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("zero-length code"));
    }
}
