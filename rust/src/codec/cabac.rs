//! Context-adaptive binary arithmetic coder (LZMA-style range coder with
//! 11-bit adaptive probabilities) — the engine of the DeepCABAC-style
//! weight codec.
//!
//! Robustness contract: the per-bit decode primitives are *total* — any
//! byte sequence yields some bit sequence (a range coder cannot detect
//! corruption at the bit level), so they stay infallible and corrupt
//! streams are rejected one layer up, at the binarization
//! ([`crate::codec::deepcabac`]) and container ([`crate::codec`]) layers.
//! The one place a raw CABAC read can diverge — an unbounded zero-run in
//! an Exp-Golomb bypass prefix — is fallible here:
//! [`BinDecoder::decode_exp_golomb_bypass`] bounds the prefix walk and
//! returns [`CodecError::CorruptPrefix`] instead of spinning on an
//! exhausted buffer.

use super::error::{CodecError, CodecResult};

const PROB_BITS: u32 = 11;
const PROB_INIT: u16 = 1 << (PROB_BITS - 1); // 1024 == p(0) = 0.5
const ADAPT_SHIFT: u32 = 5;
const TOP: u32 = 1 << 24;

/// Adaptive probability state of one context (probability of bit == 0).
#[derive(Clone, Copy, Debug)]
pub struct BinProb(pub u16);

impl Default for BinProb {
    fn default() -> Self {
        BinProb(PROB_INIT)
    }
}

impl BinProb {
    fn update(&mut self, bit: bool) {
        if bit {
            self.0 -= self.0 >> ADAPT_SHIFT;
        } else {
            self.0 += ((1 << PROB_BITS) - self.0) >> ADAPT_SHIFT;
        }
    }
}

/// Binary range encoder.
pub struct BinEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl Default for BinEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl BinEncoder {
    pub fn new() -> Self {
        BinEncoder { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    fn shift_low(&mut self) {
        if (self.low as u32) < 0xFF00_0000 || (self.low >> 32) != 0 {
            let carry = (self.low >> 32) as u8;
            let mut temp = self.cache;
            loop {
                self.out.push(temp.wrapping_add(carry));
                temp = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        // NB: the 32-bit truncation must happen BEFORE the shift (the
        // dropped top byte is tracked as pending 0xFFs via cache_size).
        self.low = ((self.low as u32) << 8) as u64;
    }

    /// Encode one bit with an adaptive context.
    pub fn encode(&mut self, ctx: &mut BinProb, bit: bool) {
        let bound = (self.range >> PROB_BITS) * ctx.0 as u32;
        if !bit {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        ctx.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Encode one equiprobable (bypass) bit.
    pub fn encode_bypass(&mut self, bit: bool) {
        let bound = self.range >> 1;
        if !bit {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Bypass-encode the low `n` bits of `v`, MSB first.
    pub fn encode_bypass_bits(&mut self, v: u64, n: u32) {
        for i in (0..n).rev() {
            self.encode_bypass((v >> i) & 1 == 1);
        }
    }

    /// Bypass-coded order-0 Exp-Golomb (the DeepCABAC remainder
    /// binarization). Inverse: [`BinDecoder::decode_exp_golomb_bypass`].
    pub fn encode_exp_golomb_bypass(&mut self, v: u64) {
        let x = v + 1;
        let nbits = 64 - x.leading_zeros();
        for _ in 0..nbits - 1 {
            self.encode_bypass(false);
        }
        self.encode_bypass_bits(x, nbits);
    }

    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

/// Binary range decoder.
pub struct BinDecoder<'a> {
    code: u32,
    range: u32,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinDecoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        let mut d = BinDecoder { code: 0, range: u32::MAX, buf, pos: 1 };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    fn next_byte(&mut self) -> u8 {
        let b = if self.pos < self.buf.len() { self.buf[self.pos] } else { 0 };
        self.pos += 1;
        b
    }

    pub fn decode(&mut self, ctx: &mut BinProb) -> bool {
        let bound = (self.range >> PROB_BITS) * ctx.0 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            false
        } else {
            self.code -= bound;
            self.range -= bound;
            true
        };
        ctx.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }

    pub fn decode_bypass(&mut self) -> bool {
        let bound = self.range >> 1;
        let bit = if self.code < bound {
            self.range = bound;
            false
        } else {
            self.code -= bound;
            self.range -= bound;
            true
        };
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }

    pub fn decode_bypass_bits(&mut self, n: u32) -> u64 {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.decode_bypass() as u64;
        }
        v
    }

    /// Fallible inverse of [`BinEncoder::encode_exp_golomb_bypass`].
    ///
    /// A well-formed prefix has at most `max_prefix` zeros (the encoder
    /// emits `nbits - 1 <= 63`; callers pass the bound their value range
    /// implies, e.g. 32 for an `i32` remainder). A longer run can only
    /// come from a corrupt or exhausted stream — on a zeroed tail the raw
    /// bypass read yields `false` forever, so without this bound the loop
    /// would never terminate in release builds.
    pub fn decode_exp_golomb_bypass(&mut self, max_prefix: u32) -> CodecResult<u64> {
        debug_assert!(max_prefix < 64);
        let mut zeros = 0u32;
        while !self.decode_bypass() {
            zeros += 1;
            if zeros > max_prefix {
                return Err(CodecError::CorruptPrefix { at_bit: self.pos * 8 });
            }
        }
        let rest = self.decode_bypass_bits(zeros);
        Ok(((1u64 << zeros) | rest) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_random_bits() {
        let mut rng = Rng::new(1);
        let bits: Vec<bool> = (0..5000).map(|_| rng.chance(0.5)).collect();
        let mut enc = BinEncoder::new();
        let mut ctx = BinProb::default();
        for &b in &bits {
            enc.encode(&mut ctx, b);
        }
        let bytes = enc.finish();
        let mut dec = BinDecoder::new(&bytes);
        let mut ctx = BinProb::default();
        for &b in &bits {
            assert_eq!(dec.decode(&mut ctx), b);
        }
    }

    #[test]
    fn skewed_source_compresses() {
        // 95% zeros should code well below 1 bit/symbol
        let mut rng = Rng::new(2);
        let n = 20_000;
        let bits: Vec<bool> = (0..n).map(|_| rng.chance(0.05)).collect();
        let mut enc = BinEncoder::new();
        let mut ctx = BinProb::default();
        for &b in &bits {
            enc.encode(&mut ctx, b);
        }
        let bytes = enc.finish();
        let bits_per_symbol = bytes.len() as f64 * 8.0 / n as f64;
        // H(0.05) ~ 0.286; adaptive coder should get close
        assert!(bits_per_symbol < 0.4, "bits/symbol = {bits_per_symbol}");
        // and round-trip
        let mut dec = BinDecoder::new(&bytes);
        let mut ctx = BinProb::default();
        for &b in &bits {
            assert_eq!(dec.decode(&mut ctx), b);
        }
    }

    #[test]
    fn bypass_roundtrip() {
        let mut rng = Rng::new(3);
        let vals: Vec<u64> = (0..1000).map(|_| rng.next_u64() & 0xFFFF).collect();
        let mut enc = BinEncoder::new();
        for &v in &vals {
            enc.encode_bypass_bits(v, 16);
        }
        let bytes = enc.finish();
        // bypass is incompressible: ~16 bits/value
        assert!(bytes.len() >= 1000 * 2 - 8);
        let mut dec = BinDecoder::new(&bytes);
        for &v in &vals {
            assert_eq!(dec.decode_bypass_bits(16), v);
        }
    }

    #[test]
    fn exp_golomb_bypass_roundtrip() {
        let vals = [0u64, 1, 2, 3, 7, 100, 65_535, (1 << 31) - 1];
        let mut enc = BinEncoder::new();
        for &v in &vals {
            enc.encode_exp_golomb_bypass(v);
        }
        let bytes = enc.finish();
        let mut dec = BinDecoder::new(&bytes);
        for &v in &vals {
            assert_eq!(dec.decode_exp_golomb_bypass(32).unwrap(), v);
        }
    }

    #[test]
    fn exp_golomb_bypass_bounds_zero_runs() {
        // a stream whose bypass bits are all zeros must be rejected by the
        // prefix bound, not spin forever on the zero-extended tail
        let mut dec = BinDecoder::new(&[0u8; 16]);
        let err = dec.decode_exp_golomb_bypass(32).unwrap_err();
        assert!(matches!(err, CodecError::CorruptPrefix { .. }), "{err:?}");
    }

    #[test]
    fn mixed_ctx_and_bypass() {
        let mut enc = BinEncoder::new();
        let mut c1 = BinProb::default();
        let mut c2 = BinProb::default();
        for i in 0..1000u32 {
            enc.encode(&mut c1, i % 3 == 0);
            enc.encode_bypass(i % 2 == 0);
            enc.encode(&mut c2, i % 7 == 0);
        }
        let bytes = enc.finish();
        let mut dec = BinDecoder::new(&bytes);
        let mut c1 = BinProb::default();
        let mut c2 = BinProb::default();
        for i in 0..1000u32 {
            assert_eq!(dec.decode(&mut c1), i % 3 == 0);
            assert_eq!(dec.decode_bypass(), i % 2 == 0);
            assert_eq!(dec.decode(&mut c2), i % 7 == 0);
        }
    }
}
