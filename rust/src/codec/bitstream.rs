//! Bit-level I/O for the entropy coders.
//!
//! The reader is *fallible*: every read past the end of the buffer is an
//! [`CodecError::UnexpectedEof`], never a silent zero-pad. Encoders pad
//! only within the final byte, so a well-formed decode never consumes a
//! bit beyond `buf.len() * 8` — any overrun is proof of corruption and
//! surfaces as an error at the exact bit offset.

use super::error::{CodecError, CodecResult};

/// MSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write the lowest `n` bits of `v`, MSB first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Order-0 Exp-Golomb code of a non-negative integer.
    pub fn put_exp_golomb(&mut self, v: u64) {
        let x = v + 1;
        let nbits = 64 - x.leading_zeros();
        for _ in 0..nbits - 1 {
            self.put_bit(false);
        }
        self.put_bits(x, nbits);
    }

    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// MSB-first fallible bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Current bit offset into the buffer.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Bits left before the reader runs off the end of the buffer.
    pub fn remaining_bits(&self) -> usize {
        (self.buf.len() * 8).saturating_sub(self.pos)
    }

    pub fn get_bit(&mut self) -> CodecResult<bool> {
        let byte = self.pos / 8;
        if byte >= self.buf.len() {
            return Err(CodecError::UnexpectedEof { at_bit: self.pos });
        }
        let off = 7 - (self.pos % 8);
        self.pos += 1;
        Ok((self.buf[byte] >> off) & 1 == 1)
    }

    pub fn get_bits(&mut self, n: u32) -> CodecResult<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Ok(v)
    }

    /// Order-0 Exp-Golomb decode. A zero-run longer than 63 bits cannot
    /// come from [`BitWriter::put_exp_golomb`] and is rejected as a
    /// corrupt prefix instead of overflowing the shift below.
    pub fn get_exp_golomb(&mut self) -> CodecResult<u64> {
        let mut zeros = 0u32;
        while !self.get_bit()? {
            zeros += 1;
            if zeros > 63 {
                return Err(CodecError::CorruptPrefix { at_bit: self.pos });
            }
        }
        let rest = self.get_bits(zeros)?;
        Ok(((1u64 << zeros) | rest) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bits(0xDEAD, 16);
        w.put_bit(true);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4).unwrap(), 0b1011);
        assert_eq!(r.get_bits(16).unwrap(), 0xDEAD);
        assert!(r.get_bit().unwrap());
    }

    #[test]
    fn exp_golomb_roundtrip() {
        let vals = [0u64, 1, 2, 3, 7, 14, 100, 1_000_000];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_exp_golomb(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.get_exp_golomb().unwrap(), v);
        }
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.put_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
        assert_eq!(w.finish().len(), 2);
    }

    #[test]
    fn read_past_end_is_eof_not_zero() {
        let mut r = BitReader::new(&[0xFF]);
        assert_eq!(r.get_bits(8).unwrap(), 0xFF);
        assert_eq!(r.remaining_bits(), 0);
        assert_eq!(r.get_bit(), Err(CodecError::UnexpectedEof { at_bit: 8 }));
        // an empty buffer fails immediately
        let mut r = BitReader::new(&[]);
        assert!(r.get_bit().is_err());
        assert!(r.get_bits(3).is_err());
        assert!(r.get_exp_golomb().is_err());
    }

    #[test]
    fn all_zero_prefix_is_corrupt_not_infinite() {
        // 9 bytes of zeros: 72 zero bits, no terminating 1 — the exp-golomb
        // prefix walk must reject after 64 zeros, not loop or shift-overflow
        let err = BitReader::new(&[0u8; 9]).get_exp_golomb().unwrap_err();
        assert!(matches!(err, CodecError::CorruptPrefix { .. }), "{err:?}");
    }

    #[test]
    fn remaining_bits_tracks_position() {
        let mut r = BitReader::new(&[0xAB, 0xCD]);
        assert_eq!(r.remaining_bits(), 16);
        r.get_bits(5).unwrap();
        assert_eq!(r.remaining_bits(), 11);
        assert_eq!(r.bit_pos(), 5);
    }
}
