//! Bit-level I/O for the entropy coders.

/// MSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write the lowest `n` bits of `v`, MSB first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Order-0 Exp-Golomb code of a non-negative integer.
    pub fn put_exp_golomb(&mut self, v: u64) {
        let x = v + 1;
        let nbits = 64 - x.leading_zeros();
        for _ in 0..nbits - 1 {
            self.put_bit(false);
        }
        self.put_bits(x, nbits);
    }

    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// MSB-first bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn get_bit(&mut self) -> bool {
        let byte = self.pos / 8;
        let off = 7 - (self.pos % 8);
        self.pos += 1;
        if byte >= self.buf.len() {
            return false; // zero-padded tail
        }
        (self.buf[byte] >> off) & 1 == 1
    }

    pub fn get_bits(&mut self, n: u32) -> u64 {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit() as u64;
        }
        v
    }

    pub fn get_exp_golomb(&mut self) -> u64 {
        let mut zeros = 0u32;
        while !self.get_bit() {
            zeros += 1;
            if zeros > 63 {
                return 0;
            }
        }
        let rest = self.get_bits(zeros);
        ((1u64 << zeros) | rest) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bits(0xDEAD, 16);
        w.put_bit(true);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4), 0b1011);
        assert_eq!(r.get_bits(16), 0xDEAD);
        assert!(r.get_bit());
    }

    #[test]
    fn exp_golomb_roundtrip() {
        let vals = [0u64, 1, 2, 3, 7, 14, 100, 1_000_000];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_exp_golomb(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.get_exp_golomb(), v);
        }
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.put_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
        assert_eq!(w.finish().len(), 2);
    }
}
