//! Canonical Huffman coder over integer weight levels — the classic
//! baseline the CABAC codec is compared against (Deep Compression [16]
//! uses Huffman as its third stage).

use std::collections::BTreeMap;

use super::bitstream::{BitReader, BitWriter};

/// Code table: symbol -> (code, length).
#[derive(Debug, Clone)]
pub struct HuffTable {
    /// sorted symbols with canonical code lengths
    pub lengths: Vec<(i32, u8)>,
}

fn build_lengths(freqs: &BTreeMap<i32, u64>) -> Vec<(i32, u8)> {
    // package-merge-free plain Huffman over a heap (few symbols here).
    #[derive(Debug)]
    struct Node {
        freq: u64,
        sym: Option<i32>,
        kids: Option<(usize, usize)>,
    }
    let mut nodes: Vec<Node> = freqs
        .iter()
        .map(|(&s, &f)| Node { freq: f.max(1), sym: Some(s), kids: None })
        .collect();
    if nodes.is_empty() {
        return Vec::new();
    }
    if nodes.len() == 1 {
        return vec![(nodes[0].sym.unwrap(), 1)];
    }
    let mut live: Vec<usize> = (0..nodes.len()).collect();
    while live.len() > 1 {
        live.sort_by_key(|&i| std::cmp::Reverse(nodes[i].freq));
        let a = live.pop().unwrap();
        let b = live.pop().unwrap();
        nodes.push(Node {
            freq: nodes[a].freq + nodes[b].freq,
            sym: None,
            kids: Some((a, b)),
        });
        live.push(nodes.len() - 1);
    }
    let root = live[0];
    let mut out = Vec::new();
    let mut stack = vec![(root, 0u8)];
    while let Some((i, depth)) = stack.pop() {
        if let Some(s) = nodes[i].sym {
            out.push((s, depth.max(1)));
        } else if let Some((a, b)) = nodes[i].kids {
            stack.push((a, depth + 1));
            stack.push((b, depth + 1));
        }
    }
    out
}

fn canonical_codes(lengths: &[(i32, u8)]) -> Vec<(i32, u32, u8)> {
    let mut sorted: Vec<(i32, u8)> = lengths.to_vec();
    sorted.sort_by_key(|&(s, l)| (l, s));
    let mut codes = Vec::with_capacity(sorted.len());
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &(s, l) in &sorted {
        code <<= l - prev_len;
        codes.push((s, code, l));
        code += 1;
        prev_len = l;
    }
    codes
}

/// Encode levels; the output embeds the code table (symbol set + lengths)
/// so the measured size is a fair end-to-end file size.
pub fn encode(levels: &[i32]) -> Vec<u8> {
    let mut freqs = BTreeMap::new();
    for &l in levels {
        *freqs.entry(l).or_insert(0u64) += 1;
    }
    let lengths = build_lengths(&freqs);
    let codes = canonical_codes(&lengths);
    let by_sym: BTreeMap<i32, (u32, u8)> =
        codes.iter().map(|&(s, c, l)| (s, (c, l))).collect();

    let mut w = BitWriter::new();
    // header: symbol count, then (symbol zigzag exp-golomb, length 5 bits)
    w.put_exp_golomb(codes.len() as u64);
    w.put_exp_golomb(levels.len() as u64);
    for &(s, _, l) in &codes {
        let zz = ((s << 1) ^ (s >> 31)) as u32 as u64; // zigzag
        w.put_exp_golomb(zz);
        w.put_bits(l as u64, 5);
    }
    for &lv in levels {
        let (c, l) = by_sym[&lv];
        w.put_bits(c as u64, l as u32);
    }
    w.finish()
}

/// Decode a stream produced by [`encode`].
pub fn decode(buf: &[u8]) -> Vec<i32> {
    let mut r = BitReader::new(buf);
    let nsym = r.get_exp_golomb() as usize;
    let n = r.get_exp_golomb() as usize;
    let mut lengths = Vec::with_capacity(nsym);
    for _ in 0..nsym {
        let zz = r.get_exp_golomb() as u32;
        let s = ((zz >> 1) as i32) ^ -((zz & 1) as i32);
        let l = r.get_bits(5) as u8;
        lengths.push((s, l));
    }
    let codes = canonical_codes(&lengths);
    // decode by longest-prefix walk (tiny alphabets -> linear scan is fine)
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut code = 0u32;
        let mut len = 0u8;
        loop {
            code = (code << 1) | r.get_bit() as u32;
            len += 1;
            if let Some(&(s, _, _)) =
                codes.iter().find(|&&(_, c, l)| l == len && c == code)
            {
                out.push(s);
                break;
            }
            assert!(len < 32, "corrupt huffman stream");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_sparse() {
        let mut rng = Rng::new(8);
        let levels: Vec<i32> = (0..10_000)
            .map(|_| {
                if rng.chance(0.8) {
                    0
                } else {
                    (rng.below(15) as i32 + 1) * if rng.chance(0.5) { 1 } else { -1 }
                }
            })
            .collect();
        let bytes = encode(&levels);
        assert_eq!(decode(&bytes), levels);
        // entropy ~1.7 bits; symbol-granular huffman pays the 1-bit floor
        // on the 80%-probable zero symbol but must beat 5-bit packing
        let bits = bytes.len() as f64 * 8.0 / levels.len() as f64;
        assert!(bits < 2.5, "bits/weight {bits}");
    }

    #[test]
    fn roundtrip_single_symbol() {
        let levels = vec![3i32; 100];
        assert_eq!(decode(&encode(&levels)), levels);
    }

    #[test]
    fn roundtrip_empty() {
        assert_eq!(decode(&encode(&[])), Vec::<i32>::new());
    }

    #[test]
    fn property_roundtrip() {
        crate::util::prop::check("huffman roundtrip", 15, |rng| {
            let n = rng.below(3000);
            let levels: Vec<i32> = (0..n)
                .map(|_| rng.below(31) as i32 - 15)
                .collect();
            if decode(&encode(&levels)) != levels {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }
}
