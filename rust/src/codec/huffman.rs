//! Canonical Huffman coder over integer weight levels — the classic
//! baseline the CABAC codec is compared against (Deep Compression [16]
//! uses Huffman as its third stage).
//!
//! Both directions are fallible: [`encode_with_table`] rejects
//! out-of-alphabet symbols ([`CodecError::UnknownSymbol`]) and [`decode`]
//! rejects corrupt streams — oversized count fields are bounded against
//! the payload *before* any allocation, code tables must satisfy the
//! Kraft inequality, and a prefix walk that leaves the code space is a
//! [`CodecError::CorruptPrefix`], never a panic.

use std::collections::BTreeMap;

use super::bitstream::{BitReader, BitWriter};
use super::error::{CodecError, CodecResult};

/// Longest representable code: lengths are stored in 5 bits.
const MAX_CODE_LEN: u8 = 31;

/// Code table: sorted symbols with canonical code lengths.
#[derive(Debug, Clone)]
pub struct HuffTable {
    /// sorted symbols with canonical code lengths
    pub lengths: Vec<(i32, u8)>,
}

impl HuffTable {
    /// Build a table from the frequency profile of `levels`.
    pub fn from_levels(levels: &[i32]) -> Self {
        let mut freqs = BTreeMap::new();
        for &l in levels {
            *freqs.entry(l).or_insert(0u64) += 1;
        }
        HuffTable { lengths: build_lengths(&freqs) }
    }
}

fn build_lengths(freqs: &BTreeMap<i32, u64>) -> Vec<(i32, u8)> {
    // package-merge-free plain Huffman over a heap (few symbols here).
    #[derive(Debug)]
    struct Node {
        freq: u64,
        sym: Option<i32>,
        kids: Option<(usize, usize)>,
    }
    let mut nodes: Vec<Node> = freqs
        .iter()
        .map(|(&s, &f)| Node { freq: f.max(1), sym: Some(s), kids: None })
        .collect();
    if nodes.is_empty() {
        return Vec::new();
    }
    if nodes.len() == 1 {
        return vec![(nodes[0].sym.unwrap(), 1)];
    }
    let mut live: Vec<usize> = (0..nodes.len()).collect();
    while live.len() > 1 {
        live.sort_by_key(|&i| std::cmp::Reverse(nodes[i].freq));
        let a = live.pop().unwrap();
        let b = live.pop().unwrap();
        nodes.push(Node {
            freq: nodes[a].freq + nodes[b].freq,
            sym: None,
            kids: Some((a, b)),
        });
        live.push(nodes.len() - 1);
    }
    let root = live[0];
    let mut out = Vec::new();
    let mut stack = vec![(root, 0u8)];
    while let Some((i, depth)) = stack.pop() {
        if let Some(s) = nodes[i].sym {
            out.push((s, depth.max(1)));
        } else if let Some((a, b)) = nodes[i].kids {
            stack.push((a, depth + 1));
            stack.push((b, depth + 1));
        }
    }
    out
}

/// Assign canonical codes to validated lengths (each `1..=MAX_CODE_LEN`,
/// Kraft sum <= 1 — both checked by the callers, so the shifts below
/// cannot overflow).
fn canonical_codes(lengths: &[(i32, u8)]) -> Vec<(i32, u32, u8)> {
    let mut sorted: Vec<(i32, u8)> = lengths.to_vec();
    sorted.sort_by_key(|&(s, l)| (l, s));
    let mut codes = Vec::with_capacity(sorted.len());
    let mut code = 0u64;
    let mut prev_len = 0u8;
    for &(s, l) in &sorted {
        code <<= l - prev_len;
        codes.push((s, code as u32, l));
        code += 1;
        prev_len = l;
    }
    codes
}

/// Check lengths are in range and the Kraft inequality holds (the code
/// space is not over-subscribed), so canonical assignment is well-defined.
fn validate_lengths(lengths: &[(i32, u8)]) -> CodecResult<()> {
    let mut kraft = 0u64; // in units of 2^-MAX_CODE_LEN
    for &(_, l) in lengths {
        if l == 0 {
            return Err(CodecError::InvalidTable { detail: "zero code length" });
        }
        if l > MAX_CODE_LEN {
            return Err(CodecError::InvalidTable { detail: "code length exceeds 31" });
        }
        kraft += 1u64 << (MAX_CODE_LEN - l);
        if kraft > 1u64 << MAX_CODE_LEN {
            return Err(CodecError::InvalidTable {
                detail: "Kraft inequality violated (over-subscribed code space)",
            });
        }
    }
    Ok(())
}

/// Encode levels with an explicit table; the output embeds the table
/// (symbol set + lengths) so the measured size is a fair end-to-end file
/// size. Fails with [`CodecError::UnknownSymbol`] on any level outside
/// the table's alphabet.
pub fn encode_with_table(table: &HuffTable, levels: &[i32]) -> CodecResult<Vec<u8>> {
    validate_lengths(&table.lengths)?;
    let codes = canonical_codes(&table.lengths);
    let by_sym: BTreeMap<i32, (u32, u8)> =
        codes.iter().map(|&(s, c, l)| (s, (c, l))).collect();

    let mut w = BitWriter::new();
    // header: symbol count, then (symbol zigzag exp-golomb, length 5 bits)
    w.put_exp_golomb(codes.len() as u64);
    w.put_exp_golomb(levels.len() as u64);
    for &(s, _, l) in &codes {
        let zz = ((s << 1) ^ (s >> 31)) as u32 as u64; // zigzag
        w.put_exp_golomb(zz);
        w.put_bits(l as u64, 5);
    }
    for &lv in levels {
        let (c, l) = *by_sym
            .get(&lv)
            .ok_or(CodecError::UnknownSymbol { symbol: lv })?;
        w.put_bits(c as u64, l as u32);
    }
    Ok(w.finish())
}

/// Encode levels under a table fitted to their own frequency profile.
pub fn encode(levels: &[i32]) -> CodecResult<Vec<u8>> {
    encode_with_table(&HuffTable::from_levels(levels), levels)
}

/// Decode a stream produced by [`encode`].
pub fn decode(buf: &[u8]) -> CodecResult<Vec<i32>> {
    let mut r = BitReader::new(buf);
    let nsym = r.get_exp_golomb()?;
    // each table entry costs >= 6 bits (1-bit exp-golomb + 5-bit length)
    let max_sym = (r.remaining_bits() / 6) as u64;
    if nsym > max_sym {
        return Err(CodecError::LengthOverflow { field: "nsym", claimed: nsym, max: max_sym });
    }
    let n = r.get_exp_golomb()?;
    // each coded level costs >= 1 bit of payload
    let max_n = (buf.len() * 8) as u64;
    if n > max_n {
        return Err(CodecError::LengthOverflow { field: "n", claimed: n, max: max_n });
    }
    let (nsym, n) = (nsym as usize, n as usize);
    let mut lengths = Vec::with_capacity(nsym);
    for _ in 0..nsym {
        let zz = r.get_exp_golomb()? as u32;
        let s = ((zz >> 1) as i32) ^ -((zz & 1) as i32);
        let l = r.get_bits(5)? as u8;
        lengths.push((s, l));
    }
    if nsym == 0 && n > 0 {
        return Err(CodecError::InvalidTable { detail: "empty table with nonzero count" });
    }
    validate_lengths(&lengths)?;
    let codes = canonical_codes(&lengths);
    let max_len = lengths.iter().map(|&(_, l)| l).max().unwrap_or(0);
    // decode by longest-prefix walk (tiny alphabets -> linear scan is fine)
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut code = 0u32;
        let mut len = 0u8;
        loop {
            code = (code << 1) | r.get_bit()? as u32;
            len += 1;
            if let Some(&(s, _, _)) =
                codes.iter().find(|&&(_, c, l)| l == len && c == code)
            {
                out.push(s);
                break;
            }
            if len >= max_len {
                // an under-subscribed table leaves unassigned prefixes;
                // landing on one is proof of corruption
                return Err(CodecError::CorruptPrefix { at_bit: r.bit_pos() });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_sparse() {
        let mut rng = Rng::new(8);
        let levels: Vec<i32> = (0..10_000)
            .map(|_| {
                if rng.chance(0.8) {
                    0
                } else {
                    (rng.below(15) as i32 + 1) * if rng.chance(0.5) { 1 } else { -1 }
                }
            })
            .collect();
        let bytes = encode(&levels).unwrap();
        assert_eq!(decode(&bytes).unwrap(), levels);
        // entropy ~1.7 bits; symbol-granular huffman pays the 1-bit floor
        // on the 80%-probable zero symbol but must beat 5-bit packing
        let bits = bytes.len() as f64 * 8.0 / levels.len() as f64;
        assert!(bits < 2.5, "bits/weight {bits}");
    }

    #[test]
    fn roundtrip_single_symbol() {
        let levels = vec![3i32; 100];
        assert_eq!(decode(&encode(&levels).unwrap()).unwrap(), levels);
    }

    #[test]
    fn roundtrip_empty() {
        assert_eq!(decode(&encode(&[]).unwrap()).unwrap(), Vec::<i32>::new());
    }

    #[test]
    fn property_roundtrip() {
        crate::util::prop::check("huffman roundtrip", 15, |rng| {
            let n = rng.below(3000);
            let levels: Vec<i32> = (0..n)
                .map(|_| rng.below(31) as i32 - 15)
                .collect();
            if decode(&encode(&levels).unwrap()).unwrap() != levels {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn unknown_symbol_is_an_error_not_a_panic() {
        // regression: encoding a level outside the table's alphabet used
        // to panic on `by_sym[&lv]`
        let table = HuffTable::from_levels(&[0, 0, 1, -1]);
        let err = encode_with_table(&table, &[0, 5]).unwrap_err();
        assert_eq!(err, CodecError::UnknownSymbol { symbol: 5 });
    }

    #[test]
    fn corrupt_prefix_is_an_error_not_a_panic() {
        // regression: an under-subscribed table (Kraft sum 1/2) leaves the
        // prefix `1` unassigned; a payload presenting it used to trip
        // `assert!(len < 32, "corrupt huffman stream")`
        let mut w = BitWriter::new();
        w.put_exp_golomb(1); // nsym = 1
        w.put_exp_golomb(2); // n = 2
        w.put_exp_golomb(0); // symbol 0 (zigzag)
        w.put_bits(2, 5); // length 2 -> only code 00 is assigned
        w.put_bits(0b00, 2); // first level decodes fine
        w.put_bits(0b11, 2); // second lands on an unassigned prefix
        let err = decode(&w.finish()).unwrap_err();
        assert!(matches!(err, CodecError::CorruptPrefix { .. }), "{err:?}");
    }

    #[test]
    fn oversubscribed_table_rejected() {
        // three symbols of length 1 violate Kraft (2^-1 * 3 > 1)
        let mut w = BitWriter::new();
        w.put_exp_golomb(3); // nsym
        w.put_exp_golomb(0); // n
        for zz in [0u64, 1, 2] {
            w.put_exp_golomb(zz);
            w.put_bits(1, 5); // length 1
        }
        let err = decode(&w.finish()).unwrap_err();
        assert!(matches!(err, CodecError::InvalidTable { .. }), "{err:?}");
    }

    #[test]
    fn zero_code_length_rejected() {
        let mut w = BitWriter::new();
        w.put_exp_golomb(1);
        w.put_exp_golomb(1);
        w.put_exp_golomb(0);
        w.put_bits(0, 5); // length 0 is meaningless
        let err = decode(&w.finish()).unwrap_err();
        assert_eq!(err, CodecError::InvalidTable { detail: "zero code length" });
    }

    #[test]
    fn truncated_stream_is_eof() {
        let bytes = encode(&[1, 2, 3, 4, 5, 1, 2, 3]).unwrap();
        // cutting the stream in half lands mid-table: reads must hit EOF,
        // not read zeros off the end
        let err = decode(&bytes[..bytes.len() / 2]).unwrap_err();
        assert!(
            matches!(err, CodecError::UnexpectedEof { .. } | CodecError::CorruptPrefix { .. }),
            "{err:?}"
        );
    }
}
