//! Entropy-coding substrate: the DeepCABAC-style codec (the paper's
//! compression-ratio measurements, Table 1 / Figs. 9-10) plus baselines
//! (Huffman, RLE, CSR size model, deflate) for the codec comparison.

pub mod bitstream;
pub mod cabac;
pub mod deepcabac;
pub mod deflate;
pub mod huffman;
pub mod sparse;

use crate::quant::Codebook;
use crate::tensor::TensorI32;

/// Compressed representation of one quantized tensor.
#[derive(Clone, Debug)]
pub struct EncodedTensor {
    pub shape: Vec<usize>,
    pub step: f32,
    pub bits: u32,
    pub payload: Vec<u8>,
}

/// Convert centroid-slot indices to signed integer levels.
pub fn slots_to_levels(idx: &TensorI32) -> Vec<i32> {
    idx.data
        .iter()
        .map(|&s| Codebook::slot_to_level(s as usize))
        .collect()
}

/// Encode a quantized tensor (slot indices + codebook metadata) with the
/// DeepCABAC-style coder.
pub fn encode_tensor(idx: &TensorI32, cb: &Codebook) -> EncodedTensor {
    let levels = slots_to_levels(idx);
    EncodedTensor {
        shape: idx.shape.clone(),
        step: cb.step,
        bits: cb.bits,
        payload: deepcabac::encode_levels(&levels),
    }
}

/// Decode back to slot indices (lossless inverse of [`encode_tensor`]).
pub fn decode_tensor(enc: &EncodedTensor) -> TensorI32 {
    let n: usize = enc.shape.iter().product();
    let levels = deepcabac::decode_levels(&enc.payload, n);
    let data = levels
        .iter()
        .map(|&l| Codebook::level_to_slot(l) as i32)
        .collect();
    TensorI32::new(enc.shape.clone(), data)
}

/// Size comparison of one tensor across codecs (bytes).
#[derive(Clone, Debug)]
pub struct CodecComparison {
    pub fp32: usize,
    pub packed: usize,
    pub cabac: usize,
    pub huffman: usize,
    pub rle: usize,
    pub csr: usize,
    pub deflate: usize,
}

/// Compare codec families on one quantized tensor.
pub fn compare_codecs(idx: &TensorI32, bits: u32) -> CodecComparison {
    let levels = slots_to_levels(idx);
    let n = levels.len();
    let rows = if idx.shape.len() >= 2 { idx.shape[0] } else { 1 };
    let cols = n / rows.max(1);
    let nnz = levels.iter().filter(|&&l| l != 0).count();
    let packed = (n * bits as usize).div_ceil(8);
    let bytes_i8: Vec<u8> = levels.iter().map(|&l| l as i8 as u8).collect();
    let deflate = deflate_size(&bytes_i8);
    CodecComparison {
        fp32: n * 4,
        packed,
        cabac: deepcabac::encode_levels(&levels).len(),
        huffman: huffman::encode(&levels).len(),
        rle: sparse::rle_encode(&levels, bits).len(),
        csr: sparse::csr_size_bytes(rows, cols, nnz, bits),
        deflate,
    }
}

/// Deflate-compressed size of a byte buffer (general-purpose baseline,
/// via the offline [`deflate`] stand-in for `flate2`).
pub fn deflate_size(bytes: &[u8]) -> usize {
    deflate::compress(bytes).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_idx(n: usize, bits: u32, sparsity: f64, seed: u64) -> TensorI32 {
        let mut rng = Rng::new(seed);
        let side = (1usize << (bits - 1)) - 1;
        let data: Vec<i32> = (0..n)
            .map(|_| {
                if rng.chance(sparsity) {
                    0
                } else {
                    let lvl = 1 + rng.below(side) as i32;
                    let lvl = if rng.chance(0.5) { lvl } else { -lvl };
                    Codebook::level_to_slot(lvl) as i32
                }
            })
            .collect();
        TensorI32::new(vec![n], data)
    }

    #[test]
    fn tensor_roundtrip() {
        let idx = random_idx(4096, 4, 0.8, 1);
        let cb = Codebook::symmetric(4, 0.02);
        let enc = encode_tensor(&idx, &cb);
        let dec = decode_tensor(&enc);
        assert_eq!(dec.data, idx.data);
        assert_eq!(enc.step, cb.step);
    }

    #[test]
    fn cabac_beats_packed_on_sparse() {
        let idx = random_idx(65536, 4, 0.9, 2);
        let cmp = compare_codecs(&idx, 4);
        assert!(cmp.cabac < cmp.packed, "{cmp:?}");
        assert!(cmp.cabac < cmp.fp32 / 8, "{cmp:?}");
        // CABAC should also beat symbol-granular Huffman on skewed sources
        assert!(cmp.cabac <= cmp.huffman, "{cmp:?}");
    }

    #[test]
    fn deflate_nonzero() {
        assert!(deflate_size(&[0u8; 1024]) < 64);
        assert!(deflate_size(b"hello") > 0);
    }

    #[test]
    fn compression_grows_with_sparsity() {
        let cmp_lo = compare_codecs(&random_idx(32768, 4, 0.5, 3), 4);
        let cmp_hi = compare_codecs(&random_idx(32768, 4, 0.95, 3), 4);
        assert!(cmp_hi.cabac < cmp_lo.cabac);
        assert!(cmp_hi.rle < cmp_lo.rle);
        assert!(cmp_hi.csr < cmp_lo.csr);
    }
}
