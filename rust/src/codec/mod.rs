//! Entropy-coding substrate: the DeepCABAC-style codec (the paper's
//! compression-ratio measurements, Table 1 / Figs. 9-10) plus baselines
//! (Huffman, RLE, CSR size model, deflate) for the codec comparison.
//!
//! Robustness contract (DESIGN.md §2.4): every decoder in this tree is
//! *total* — an arbitrary byte buffer yields `Ok` or a [`CodecError`],
//! never a panic, an unbounded allocation, or a spin. Length and count
//! fields read from a stream are validated against payload-derived
//! bounds before any allocation; where a coder is sub-linear (zero-run
//! coding) and no payload bound exists, the policy ceiling
//! [`MAX_DECODE_ELEMS`] applies instead.
//!
//! Tensor payloads are chunked at fixed [`CHUNK_LEVELS`] boundaries so
//! [`encode_tensor_jobs`] can fan chunks out across the worker pool;
//! because the boundaries are data-independent and the pool map is
//! order-preserving, the serial and parallel encodings are bitwise
//! identical by construction.

pub mod bitstream;
pub mod cabac;
pub mod deepcabac;
pub mod deflate;
pub mod error;
pub mod huffman;
pub mod sparse;

pub use error::{CodecError, CodecResult};

use crate::quant::Codebook;
use crate::tensor::TensorI32;
use crate::util::pool::par_map_indexed;

/// Ceiling on any in-stream element count a decoder will honor.
///
/// Zero-run coders (CABAC sigflag runs, RLE) spend sub-linear bits per
/// element, so a tiny hostile stream can claim astronomically many
/// elements; counts are clamped here (2^27 ~ 134M levels, far above any
/// single layer in the paper's models) before `Vec::with_capacity`.
pub const MAX_DECODE_ELEMS: usize = 1 << 27;

/// Fixed chunk size (in levels) for tensor payload framing.
///
/// Boundaries depend only on element count — never on values — which is
/// what makes parallel encoding deterministic: chunk `i` always covers
/// levels `[i * CHUNK_LEVELS, (i + 1) * CHUNK_LEVELS)` regardless of how
/// many workers encode it.
pub const CHUNK_LEVELS: usize = 1 << 16;

/// Compressed representation of one quantized tensor.
///
/// `payload` is a sequence of `ceil(numel / CHUNK_LEVELS)` frames, each
/// `[u32 LE byte length || DeepCABAC stream]`; the chunk count is implied
/// by `shape`, so a corrupt count cannot be smuggled in-band.
#[derive(Clone, Debug)]
pub struct EncodedTensor {
    pub shape: Vec<usize>,
    pub step: f32,
    pub bits: u32,
    pub payload: Vec<u8>,
}

/// Convert centroid-slot indices to signed integer levels.
pub fn slots_to_levels(idx: &TensorI32) -> Vec<i32> {
    idx.data
        .iter()
        .map(|&s| Codebook::slot_to_level(s as usize))
        .collect()
}

/// Encode integer levels into the chunked container payload.
fn encode_levels_chunked(levels: &[i32], jobs: usize) -> Vec<u8> {
    let chunks: Vec<&[i32]> = levels.chunks(CHUNK_LEVELS).collect();
    let encoded = par_map_indexed(&chunks, jobs, |_, c| deepcabac::encode_levels(c));
    let mut payload = Vec::with_capacity(encoded.iter().map(|e| 4 + e.len()).sum());
    for e in &encoded {
        payload.extend_from_slice(&(e.len() as u32).to_le_bytes());
        payload.extend_from_slice(e);
    }
    payload
}

/// Encode a quantized tensor (slot indices + codebook metadata) with the
/// DeepCABAC-style coder, serially. Equivalent to
/// [`encode_tensor_jobs`] with `jobs == 1` — and bitwise identical to it
/// at any job count.
pub fn encode_tensor(idx: &TensorI32, cb: &Codebook) -> EncodedTensor {
    encode_tensor_jobs(idx, cb, 1)
}

/// Encode a quantized tensor, fanning chunks across `jobs` workers.
pub fn encode_tensor_jobs(idx: &TensorI32, cb: &Codebook, jobs: usize) -> EncodedTensor {
    let levels = slots_to_levels(idx);
    EncodedTensor {
        shape: idx.shape.clone(),
        step: cb.step,
        bits: cb.bits,
        payload: encode_levels_chunked(&levels, jobs),
    }
}

/// Encode many tensors in one pool pass, fanning the flat list of
/// (tensor, chunk) work units across `jobs` workers so small layers do
/// not serialize behind large ones. Output order matches input order and
/// each payload is bitwise identical to its [`encode_tensor`] encoding.
pub fn encode_tensors_jobs(
    inputs: &[(&TensorI32, &Codebook)],
    jobs: usize,
) -> Vec<EncodedTensor> {
    let all_levels: Vec<Vec<i32>> =
        inputs.iter().map(|(idx, _)| slots_to_levels(idx)).collect();
    let units: Vec<(usize, &[i32])> = all_levels
        .iter()
        .enumerate()
        .flat_map(|(ti, lv)| lv.chunks(CHUNK_LEVELS).map(move |c| (ti, c)))
        .collect();
    let encoded = par_map_indexed(&units, jobs, |_, &(_, c)| deepcabac::encode_levels(c));
    let mut out: Vec<EncodedTensor> = inputs
        .iter()
        .map(|(idx, cb)| EncodedTensor {
            shape: idx.shape.clone(),
            step: cb.step,
            bits: cb.bits,
            payload: Vec::new(),
        })
        .collect();
    // units iterates chunks in-order per tensor and par_map_indexed
    // preserves unit order, so this assembly is position-deterministic
    for (&(ti, _), e) in units.iter().zip(&encoded) {
        out[ti].payload.extend_from_slice(&(e.len() as u32).to_le_bytes());
        out[ti].payload.extend_from_slice(e);
    }
    out
}

/// Decode back to slot indices (lossless inverse of [`encode_tensor`]).
///
/// Total over arbitrary `EncodedTensor` contents: the shape product is
/// clamped by [`MAX_DECODE_ELEMS`] before allocation, every chunk length
/// is validated against the remaining payload, decoded levels must fit
/// the `bits`-wide codebook grid (so `Codebook::level_to_slot` cannot
/// overflow and downstream codebook lookups cannot index out of bounds),
/// and trailing bytes after the final chunk are rejected.
pub fn decode_tensor(enc: &EncodedTensor) -> CodecResult<TensorI32> {
    if enc.bits == 0 || enc.bits > 16 {
        return Err(CodecError::Malformed { detail: "codebook bit-width outside 1..=16" });
    }
    let mut numel: u128 = 1;
    for &d in &enc.shape {
        numel = numel
            .checked_mul(d as u128)
            .ok_or(CodecError::LengthOverflow {
                field: "tensor numel",
                claimed: u64::MAX,
                max: MAX_DECODE_ELEMS as u64,
            })?;
    }
    if numel > MAX_DECODE_ELEMS as u128 {
        return Err(CodecError::LengthOverflow {
            field: "tensor numel",
            claimed: numel.min(u64::MAX as u128) as u64,
            max: MAX_DECODE_ELEMS as u64,
        });
    }
    let n = numel as usize;
    let nchunks = n.div_ceil(CHUNK_LEVELS);
    // Every frame is 4 length bytes plus a CABAC stream of >= 5 bytes
    // (BinEncoder::finish always flushes five), so this floor holds for
    // any well-formed payload and bounds the work loop up front.
    if enc.payload.len() < nchunks * 9 {
        return Err(CodecError::Malformed { detail: "payload shorter than its chunk-framing floor" });
    }
    let side = (1u32 << (enc.bits - 1)) - 1;
    let mut data: Vec<i32> = Vec::with_capacity(n);
    let mut off = 0usize;
    for ci in 0..nchunks {
        let want = (n - ci * CHUNK_LEVELS).min(CHUNK_LEVELS);
        let Some(hdr) = enc.payload.get(off..off + 4) else {
            return Err(CodecError::UnexpectedEof { at_bit: enc.payload.len() * 8 });
        };
        let clen = u32::from_le_bytes(hdr.try_into().unwrap()) as usize;
        off += 4;
        if clen > enc.payload.len() - off {
            return Err(CodecError::LengthOverflow {
                field: "chunk byte length",
                claimed: clen as u64,
                max: (enc.payload.len() - off) as u64,
            });
        }
        let levels = deepcabac::decode_levels(&enc.payload[off..off + clen], want)?;
        off += clen;
        for &lv in &levels {
            if lv.unsigned_abs() > side {
                return Err(CodecError::ValueOverflow {
                    detail: "level outside the codebook grid",
                });
            }
            data.push(Codebook::level_to_slot(lv) as i32);
        }
    }
    if off != enc.payload.len() {
        return Err(CodecError::Malformed { detail: "trailing bytes after final chunk" });
    }
    Ok(TensorI32::new(enc.shape.clone(), data))
}

/// Size comparison of one tensor across codecs (bytes).
#[derive(Clone, Debug)]
pub struct CodecComparison {
    pub fp32: usize,
    pub packed: usize,
    pub cabac: usize,
    pub huffman: usize,
    pub rle: usize,
    pub csr: usize,
    pub deflate: usize,
}

/// Compare codec families on one quantized tensor.
pub fn compare_codecs(idx: &TensorI32, bits: u32) -> CodecComparison {
    let levels = slots_to_levels(idx);
    let n = levels.len();
    let rows = if idx.shape.len() >= 2 { idx.shape[0] } else { 1 };
    let cols = n / rows.max(1);
    let nnz = levels.iter().filter(|&&l| l != 0).count();
    let packed = (n * bits as usize).div_ceil(8);
    let bytes_i8: Vec<u8> = levels.iter().map(|&l| l as i8 as u8).collect();
    let deflate = deflate_size(&bytes_i8);
    CodecComparison {
        fp32: n * 4,
        packed,
        cabac: deepcabac::encode_levels(&levels).len(),
        huffman: huffman::encode(&levels)
            .expect("a freshly built table covers its own input")
            .len(),
        rle: sparse::rle_encode(&levels, bits).len(),
        csr: sparse::csr_size_bytes(rows, cols, nnz, bits),
        deflate,
    }
}

/// Deflate-compressed size of a byte buffer (general-purpose baseline,
/// via the offline [`deflate`] stand-in for `flate2`).
pub fn deflate_size(bytes: &[u8]) -> usize {
    deflate::compress(bytes).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_idx(n: usize, bits: u32, sparsity: f64, seed: u64) -> TensorI32 {
        let mut rng = Rng::new(seed);
        let side = (1usize << (bits - 1)) - 1;
        let data: Vec<i32> = (0..n)
            .map(|_| {
                if rng.chance(sparsity) {
                    0
                } else {
                    let lvl = 1 + rng.below(side) as i32;
                    let lvl = if rng.chance(0.5) { lvl } else { -lvl };
                    Codebook::level_to_slot(lvl) as i32
                }
            })
            .collect();
        TensorI32::new(vec![n], data)
    }

    #[test]
    fn tensor_roundtrip() {
        let idx = random_idx(4096, 4, 0.8, 1);
        let cb = Codebook::symmetric(4, 0.02);
        let enc = encode_tensor(&idx, &cb);
        let dec = decode_tensor(&enc).unwrap();
        assert_eq!(dec.data, idx.data);
        assert_eq!(enc.step, cb.step);
    }

    #[test]
    fn multi_chunk_roundtrip() {
        // spans three CHUNK_LEVELS frames, including a partial tail
        let n = 2 * CHUNK_LEVELS + CHUNK_LEVELS / 3;
        let idx = random_idx(n, 4, 0.85, 7);
        let cb = Codebook::symmetric(4, 0.02);
        let enc = encode_tensor(&idx, &cb);
        assert_eq!(decode_tensor(&enc).unwrap().data, idx.data);
    }

    #[test]
    fn parallel_encode_is_bitwise_identical() {
        let n = 2 * CHUNK_LEVELS + 1234;
        let idx = random_idx(n, 4, 0.9, 8);
        let cb = Codebook::symmetric(4, 0.02);
        let serial = encode_tensor_jobs(&idx, &cb, 1);
        for jobs in 2..=4 {
            let par = encode_tensor_jobs(&idx, &cb, jobs);
            assert_eq!(par.payload, serial.payload, "jobs={jobs}");
        }
    }

    #[test]
    fn multi_tensor_encode_matches_per_tensor() {
        // the flat (tensor, chunk) fan-out must reassemble each payload
        // exactly as the single-tensor path produces it, at any job count
        let a = random_idx(CHUNK_LEVELS + 77, 4, 0.9, 10);
        let b = random_idx(513, 2, 0.7, 11);
        let c = random_idx(3 * CHUNK_LEVELS, 4, 0.95, 12);
        let cba = Codebook::symmetric(4, 0.02);
        let cbb = Codebook::symmetric(2, 0.05);
        let inputs = vec![(&a, &cba), (&b, &cbb), (&c, &cba)];
        let serial: Vec<EncodedTensor> =
            inputs.iter().map(|&(idx, cb)| encode_tensor(idx, cb)).collect();
        for jobs in 1..=4 {
            let par = encode_tensors_jobs(&inputs, jobs);
            assert_eq!(par.len(), serial.len());
            for (p, s) in par.iter().zip(&serial) {
                assert_eq!(p.payload, s.payload, "jobs={jobs}");
                assert_eq!(p.shape, s.shape);
                assert_eq!(p.bits, s.bits);
            }
        }
    }

    #[test]
    fn absurd_shape_rejected_before_allocation() {
        // a 16-byte payload claiming 2^40 elements must be rejected by the
        // numel ceiling, not attempted as a terabyte allocation
        let enc = EncodedTensor {
            shape: vec![1 << 20, 1 << 20],
            step: 0.02,
            bits: 4,
            payload: vec![0u8; 16],
        };
        let err = decode_tensor(&enc).unwrap_err();
        assert!(matches!(err, CodecError::LengthOverflow { field: "tensor numel", .. }), "{err:?}");
        // and a shape product that overflows u128 is the same error
        let enc = EncodedTensor {
            shape: vec![usize::MAX, usize::MAX, usize::MAX],
            step: 0.02,
            bits: 4,
            payload: vec![0u8; 16],
        };
        assert!(matches!(decode_tensor(&enc), Err(CodecError::LengthOverflow { .. })));
    }

    #[test]
    fn corrupt_framing_rejected() {
        let idx = random_idx(1000, 4, 0.8, 9);
        let cb = Codebook::symmetric(4, 0.02);
        let good = encode_tensor(&idx, &cb);

        // payload below the 9-byte/chunk floor
        let mut enc = good.clone();
        enc.payload.truncate(6);
        assert!(matches!(decode_tensor(&enc), Err(CodecError::Malformed { .. })));

        // chunk length pointing past the payload end
        let mut enc = good.clone();
        enc.payload[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_tensor(&enc), Err(CodecError::LengthOverflow { .. })));

        // trailing garbage after the final chunk
        let mut enc = good.clone();
        enc.payload.push(0xAB);
        assert!(matches!(
            decode_tensor(&enc),
            Err(CodecError::Malformed { detail: "trailing bytes after final chunk" })
        ));

        // nonsense bit-width
        let mut enc = good;
        enc.bits = 99;
        assert!(matches!(decode_tensor(&enc), Err(CodecError::Malformed { .. })));
    }

    #[test]
    fn off_grid_level_rejected() {
        // a stream carrying |level| beyond the bits-wide grid must not
        // become an out-of-range slot index for codebook lookups
        let levels = vec![0i32, 100, -3];
        let payload = encode_levels_chunked(&levels, 1);
        let enc = EncodedTensor { shape: vec![3], step: 0.02, bits: 4, payload };
        let err = decode_tensor(&enc).unwrap_err();
        assert!(matches!(err, CodecError::ValueOverflow { .. }), "{err:?}");
    }

    #[test]
    fn empty_tensor_roundtrip() {
        let idx = TensorI32::new(vec![0], vec![]);
        let cb = Codebook::symmetric(4, 0.02);
        let enc = encode_tensor(&idx, &cb);
        assert!(enc.payload.is_empty());
        assert_eq!(decode_tensor(&enc).unwrap().data, Vec::<i32>::new());
    }

    #[test]
    fn cabac_beats_packed_on_sparse() {
        let idx = random_idx(65536, 4, 0.9, 2);
        let cmp = compare_codecs(&idx, 4);
        assert!(cmp.cabac < cmp.packed, "{cmp:?}");
        assert!(cmp.cabac < cmp.fp32 / 8, "{cmp:?}");
        // CABAC should also beat symbol-granular Huffman on skewed sources
        assert!(cmp.cabac <= cmp.huffman, "{cmp:?}");
    }

    #[test]
    fn deflate_nonzero() {
        assert!(deflate_size(&[0u8; 1024]) < 64);
        assert!(deflate_size(b"hello") > 0);
    }

    #[test]
    fn compression_grows_with_sparsity() {
        let cmp_lo = compare_codecs(&random_idx(32768, 4, 0.5, 3), 4);
        let cmp_hi = compare_codecs(&random_idx(32768, 4, 0.95, 3), 4);
        assert!(cmp_hi.cabac < cmp_lo.cabac);
        assert!(cmp_hi.rle < cmp_lo.rle);
        assert!(cmp_hi.csr < cmp_lo.csr);
    }
}
