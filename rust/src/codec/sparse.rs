//! Sparse-format baselines: zero-run-length coding and the Compressed
//! Sparse Row (CSR) size model the paper cites ([49]): formats that allow
//! inference directly in the compressed representation.

use super::bitstream::{BitReader, BitWriter};
use super::error::{CodecError, CodecResult};

/// RLE + fixed-width packing: zero runs as Exp-Golomb, non-zero levels as
/// sign + (bits-1)-bit magnitude.
pub fn rle_encode(levels: &[i32], bits: u32) -> Vec<u8> {
    let mag_bits = bits - 1;
    let mut w = BitWriter::new();
    w.put_exp_golomb(levels.len() as u64);
    let mut run = 0u64;
    for &lv in levels {
        if lv == 0 {
            run += 1;
            continue;
        }
        w.put_exp_golomb(run);
        run = 0;
        w.put_bit(lv < 0);
        let mag = lv.unsigned_abs() as u64;
        debug_assert!(mag < (1 << mag_bits), "level {lv} exceeds {bits}-bit grid");
        w.put_bits(mag, mag_bits);
    }
    // trailing zero run marker: run covering the tail
    w.put_exp_golomb(run);
    w.finish()
}

/// Decode an RLE stream (inverse of [`rle_encode`]).
///
/// Zero runs code sub-linearly, so the element count cannot be bounded by
/// the payload size; it is bounded by [`crate::codec::MAX_DECODE_ELEMS`]
/// instead, and every read past the true end of the stream is an error
/// rather than a zero-fill.
pub fn rle_decode(buf: &[u8], bits: u32) -> CodecResult<Vec<i32>> {
    if bits == 0 || bits > 16 {
        return Err(CodecError::Malformed { detail: "bit-width outside 1..=16" });
    }
    let mag_bits = bits - 1;
    let mut r = BitReader::new(buf);
    let n = r.get_exp_golomb()?;
    if n > super::MAX_DECODE_ELEMS as u64 {
        return Err(CodecError::LengthOverflow {
            field: "element count",
            claimed: n,
            max: super::MAX_DECODE_ELEMS as u64,
        });
    }
    let n = n as usize;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let run = r.get_exp_golomb()? as usize;
        for _ in 0..run.min(n - out.len()) {
            out.push(0);
        }
        if out.len() < n {
            let neg = r.get_bit()?;
            let mag = r.get_bits(mag_bits)? as i32;
            out.push(if neg { -mag } else { mag });
        }
    }
    Ok(out)
}

/// CSR size model (bytes) for a sparse matrix of `rows x cols` with `nnz`
/// non-zeros and `bits`-bit values: value array (bits each) + column
/// indices (ceil(log2 cols) each) + row pointers (32 bit each).
pub fn csr_size_bytes(rows: usize, cols: usize, nnz: usize, bits: u32) -> usize {
    let col_bits = (usize::BITS - (cols.max(2) - 1).leading_zeros()) as usize;
    let val_bits = bits as usize;
    let total_bits = nnz * (val_bits + col_bits) + (rows + 1) * 32;
    total_bits.div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rle_roundtrip() {
        let levels = vec![0, 0, 0, 5, -3, 0, 0, 1, 0, 0, 0, 0, -7, 0];
        let b = rle_encode(&levels, 4);
        assert_eq!(rle_decode(&b, 4).unwrap(), levels);
    }

    #[test]
    fn rle_all_zero_tiny() {
        let levels = vec![0i32; 100_000];
        let b = rle_encode(&levels, 4);
        assert!(b.len() < 16, "all-zero RLE should be tiny, got {}", b.len());
        assert_eq!(rle_decode(&b, 4).unwrap(), levels);
    }

    #[test]
    fn rle_no_zeros() {
        let levels = vec![1, -1, 2, -2, 3, -3];
        let b = rle_encode(&levels, 3);
        assert_eq!(rle_decode(&b, 3).unwrap(), levels);
    }

    #[test]
    fn rle_rejects_absurd_count_and_truncation() {
        // a count field beyond the decode ceiling is rejected before any
        // allocation; a truncated nonzero entry is an EOF, not a zero-fill
        let mut w = BitWriter::new();
        w.put_exp_golomb(1 << 40);
        let err = rle_decode(&w.finish(), 4).unwrap_err();
        assert!(matches!(err, CodecError::LengthOverflow { .. }), "{err:?}");

        let b = rle_encode(&[0, 0, 7, -7, 3], 4);
        let err = rle_decode(&b[..b.len() - 1], 4).unwrap_err();
        assert!(
            matches!(err, CodecError::UnexpectedEof { .. } | CodecError::CorruptPrefix { .. }),
            "{err:?}"
        );
        assert!(matches!(rle_decode(&b, 0), Err(CodecError::Malformed { .. })));
    }

    #[test]
    fn rle_property() {
        crate::util::prop::check("rle roundtrip", 20, |rng| {
            let n = rng.below(2000);
            let bits = 2 + rng.below(4) as u32;
            let top = (1i32 << (bits - 1)) - 1;
            let levels: Vec<i32> = (0..n)
                .map(|_| {
                    if rng.chance(0.7) || top == 0 {
                        0
                    } else {
                        let m = 1 + rng.below(top as usize) as i32;
                        if rng.chance(0.5) { m } else { -m }
                    }
                })
                .collect();
            if rle_decode(&rle_encode(&levels, bits), bits) != levels {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn csr_scales_with_nnz() {
        let dense = csr_size_bytes(512, 512, 512 * 512, 4);
        let sparse = csr_size_bytes(512, 512, 512 * 51, 4);
        assert!(sparse < dense / 5);
        // sanity: 10% nnz of a 512x512 4-bit matrix ~ (4+9)*26214 bits
        let expect = (512 * 51 * (4 + 9) + 513 * 32) / 8;
        assert!((sparse as i64 - expect as i64).abs() <= 1);
    }
}
