//! DeepCABAC-style codec for quantized weight tensors (integer levels).
//!
//! Binarization per weight level (following the NNR / DeepCABAC scheme,
//! [47] in the paper):
//!   * sigflag  — level != 0, context conditioned on the previous
//!     element's significance (captures zero-run structure),
//!   * sign     — one adaptive context,
//!   * abs > 1, abs > 2, abs > 3 — per-position adaptive contexts,
//!   * remainder (abs - 4)       — order-0 Exp-Golomb in bypass mode.
//!
//! Fully lossless: `decode_levels(&encode_levels(x), x.len()).unwrap() == x`.
//!
//! Decoding is fallible and total: the range-coder primitives always
//! yield bits, so corruption is detected at this binarization layer —
//! bounded remainder prefixes ([`CodecError::CorruptPrefix`]), magnitude
//! caps ([`CodecError::ValueOverflow`]) and an element-count ceiling
//! ([`crate::codec::MAX_DECODE_ELEMS`]) keep hostile streams from
//! panicking, spinning, or allocating unboundedly.

use super::cabac::{BinDecoder, BinEncoder, BinProb};
use super::error::{CodecError, CodecResult};

/// Context bank for one tensor.
#[derive(Default)]
struct Contexts {
    sig: [BinProb; 2],
    sign: BinProb,
    gt: [BinProb; 3],
}

/// Encode integer weight levels into a CABAC bitstream.
pub fn encode_levels(levels: &[i32]) -> Vec<u8> {
    let mut enc = BinEncoder::new();
    let mut ctx = Contexts::default();
    let mut prev_sig = 0usize;
    for &lv in levels {
        let sig = lv != 0;
        enc.encode(&mut ctx.sig[prev_sig], sig);
        prev_sig = sig as usize;
        if !sig {
            continue;
        }
        enc.encode(&mut ctx.sign, lv < 0);
        let abs = lv.unsigned_abs();
        let mut coded = 1u32;
        for (i, c) in ctx.gt.iter_mut().enumerate() {
            let gt = abs > (i as u32 + 1);
            enc.encode(c, gt);
            if !gt {
                break;
            }
            coded = i as u32 + 2;
        }
        if coded == 4 && abs >= 4 {
            // Exp-Golomb order-0 remainder in bypass mode.
            enc.encode_exp_golomb_bypass((abs - 4) as u64);
        }
    }
    enc.finish()
}

/// Decode `n` integer weight levels from a CABAC bitstream.
///
/// `n` is the caller's element count (the CABAC stream is headerless);
/// container layers validate it against their framing first, and it is
/// re-checked here against [`crate::codec::MAX_DECODE_ELEMS`] so no call
/// path can turn a corrupt count into an unbounded allocation.
pub fn decode_levels(buf: &[u8], n: usize) -> CodecResult<Vec<i32>> {
    if n > super::MAX_DECODE_ELEMS {
        return Err(CodecError::LengthOverflow {
            field: "level count",
            claimed: n as u64,
            max: super::MAX_DECODE_ELEMS as u64,
        });
    }
    let mut dec = BinDecoder::new(buf);
    let mut ctx = Contexts::default();
    let mut prev_sig = 0usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let sig = dec.decode(&mut ctx.sig[prev_sig]);
        prev_sig = sig as usize;
        if !sig {
            out.push(0);
            continue;
        }
        let neg = dec.decode(&mut ctx.sign);
        let mut abs = 1u64;
        for (i, c) in ctx.gt.iter_mut().enumerate() {
            if dec.decode(c) {
                abs = i as u64 + 2;
            } else {
                break;
            }
        }
        if abs == 4 {
            // matches the encoder: abs >= 4 carries a remainder whose
            // prefix is bounded (a valid i32 magnitude needs <= 32 zeros)
            abs = 4 + dec.decode_exp_golomb_bypass(32)?;
        }
        // the encoder only ever emits |level| <= i32::MAX; anything above
        // is corruption, and signed conversion below must not wrap
        if abs > i32::MAX as u64 {
            return Err(CodecError::ValueOverflow {
                detail: "level magnitude exceeds i32::MAX",
            });
        }
        out.push(if neg { -(abs as i32) } else { abs as i32 });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(levels: &[i32]) -> usize {
        let bytes = encode_levels(levels);
        let dec = decode_levels(&bytes, levels.len()).unwrap();
        assert_eq!(dec, levels);
        bytes.len()
    }

    #[test]
    fn roundtrip_all_zero() {
        let n = 10_000;
        let sz = roundtrip(&vec![0i32; n]);
        // all-zero tensor must code to almost nothing
        assert!(sz < 100, "size {sz} for all-zero");
    }

    #[test]
    fn roundtrip_sparse_quantized() {
        let mut rng = Rng::new(4);
        let levels: Vec<i32> = (0..50_000)
            .map(|_| {
                if rng.chance(0.85) {
                    0
                } else {
                    let mag = 1 + rng.below(7) as i32;
                    if rng.chance(0.5) { mag } else { -mag }
                }
            })
            .collect();
        let sz = roundtrip(&levels);
        // 85% sparse 4-bit-ish source: far below 4 bits/weight
        let bits_per_w = sz as f64 * 8.0 / levels.len() as f64;
        assert!(bits_per_w < 1.4, "bits/weight {bits_per_w}");
    }

    #[test]
    fn roundtrip_extreme_magnitudes() {
        let levels = vec![0, 1, -1, 4, -4, 15, -15, 100, -100, 1000, -1000, 0, 3];
        roundtrip(&levels);
    }

    #[test]
    fn roundtrip_empty() {
        assert_eq!(decode_levels(&encode_levels(&[]), 0).unwrap(), Vec::<i32>::new());
    }

    #[test]
    fn oversized_count_rejected_before_allocation() {
        // a corrupt container could claim astronomically many levels for a
        // tiny stream; the ceiling must reject it without allocating
        let bytes = encode_levels(&[1, -1, 0]);
        let err = decode_levels(&bytes, usize::MAX).unwrap_err();
        assert!(matches!(err, CodecError::LengthOverflow { .. }), "{err:?}");
    }

    #[test]
    fn random_buffers_decode_totally() {
        // decode over noise must terminate with Ok or Err — bounded
        // remainder prefixes keep zero-extended tails from spinning
        crate::util::prop::check("deepcabac total on noise", 25, |rng| {
            let len = rng.below(256);
            let buf: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let n = rng.below(4096);
            match decode_levels(&buf, n) {
                Ok(out) => {
                    if out.len() != n {
                        return Err(format!("decoded {} of {n} levels", out.len()));
                    }
                }
                Err(_) => {}
            }
            Ok(())
        });
    }

    #[test]
    fn denser_source_costs_more() {
        let mut rng = Rng::new(6);
        let mk = |p_zero: f64, rng: &mut Rng| -> Vec<i32> {
            (0..20_000)
                .map(|_| {
                    if rng.chance(p_zero) {
                        0
                    } else if rng.chance(0.5) {
                        1
                    } else {
                        -1
                    }
                })
                .collect()
        };
        let sparse = encode_levels(&mk(0.95, &mut rng)).len();
        let dense = encode_levels(&mk(0.30, &mut rng)).len();
        assert!(sparse < dense, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn property_roundtrip_random() {
        crate::util::prop::check("deepcabac roundtrip", 25, |rng| {
            let n = rng.below(5000);
            let levels: Vec<i32> = (0..n)
                .map(|_| {
                    if rng.chance(0.6) {
                        0
                    } else {
                        let m = 1 + rng.below(15) as i32;
                        if rng.chance(0.5) { m } else { -m }
                    }
                })
                .collect();
            let bytes = encode_levels(&levels);
            if decode_levels(&bytes, n).unwrap() != levels {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }
}
