//! DeepCABAC-style codec for quantized weight tensors (integer levels).
//!
//! Binarization per weight level (following the NNR / DeepCABAC scheme,
//! [47] in the paper):
//!   * sigflag  — level != 0, context conditioned on the previous
//!     element's significance (captures zero-run structure),
//!   * sign     — one adaptive context,
//!   * abs > 1, abs > 2, abs > 3 — per-position adaptive contexts,
//!   * remainder (abs - 4)       — order-0 Exp-Golomb in bypass mode.
//!
//! Fully lossless: `decode_levels(encode_levels(x)).unwrap() == x`.

use super::cabac::{BinDecoder, BinEncoder, BinProb};

/// Context bank for one tensor.
#[derive(Default)]
struct Contexts {
    sig: [BinProb; 2],
    sign: BinProb,
    gt: [BinProb; 3],
}

/// Encode integer weight levels into a CABAC bitstream.
pub fn encode_levels(levels: &[i32]) -> Vec<u8> {
    let mut enc = BinEncoder::new();
    let mut ctx = Contexts::default();
    let mut prev_sig = 0usize;
    for &lv in levels {
        let sig = lv != 0;
        enc.encode(&mut ctx.sig[prev_sig], sig);
        prev_sig = sig as usize;
        if !sig {
            continue;
        }
        enc.encode(&mut ctx.sign, lv < 0);
        let abs = lv.unsigned_abs();
        let mut coded = 1u32;
        for (i, c) in ctx.gt.iter_mut().enumerate() {
            let gt = abs > (i as u32 + 1);
            enc.encode(c, gt);
            if !gt {
                break;
            }
            coded = i as u32 + 2;
        }
        if coded == 4 && abs >= 4 {
            // Exp-Golomb order-0 remainder in bypass mode.
            let v = (abs - 4) as u64;
            let x = v + 1;
            let nbits = 64 - x.leading_zeros();
            for _ in 0..nbits - 1 {
                enc.encode_bypass(false);
            }
            enc.encode_bypass_bits(x, nbits);
        }
    }
    enc.finish()
}

/// Decode `n` integer weight levels from a CABAC bitstream.
pub fn decode_levels(buf: &[u8], n: usize) -> Vec<i32> {
    let mut dec = BinDecoder::new(buf);
    let mut ctx = Contexts::default();
    let mut prev_sig = 0usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let sig = dec.decode(&mut ctx.sig[prev_sig]);
        prev_sig = sig as usize;
        if !sig {
            out.push(0);
            continue;
        }
        let neg = dec.decode(&mut ctx.sign);
        let mut abs = 1u32;
        for (i, c) in ctx.gt.iter_mut().enumerate() {
            if dec.decode(c) {
                abs = i as u32 + 2;
            } else {
                break;
            }
        }
        if abs == 4 {
            // matches the encoder: abs >= 4 carries a remainder
            let mut zeros = 0u32;
            while !dec.decode_bypass() {
                zeros += 1;
                debug_assert!(zeros < 64);
            }
            let rest = dec.decode_bypass_bits(zeros);
            let v = ((1u64 << zeros) | rest) - 1;
            abs = 4 + v as u32;
        }
        out.push(if neg { -(abs as i32) } else { abs as i32 });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(levels: &[i32]) -> usize {
        let bytes = encode_levels(levels);
        let dec = decode_levels(&bytes, levels.len());
        assert_eq!(dec, levels);
        bytes.len()
    }

    #[test]
    fn roundtrip_all_zero() {
        let n = 10_000;
        let sz = roundtrip(&vec![0i32; n]);
        // all-zero tensor must code to almost nothing
        assert!(sz < 100, "size {sz} for all-zero");
    }

    #[test]
    fn roundtrip_sparse_quantized() {
        let mut rng = Rng::new(4);
        let levels: Vec<i32> = (0..50_000)
            .map(|_| {
                if rng.chance(0.85) {
                    0
                } else {
                    let mag = 1 + rng.below(7) as i32;
                    if rng.chance(0.5) { mag } else { -mag }
                }
            })
            .collect();
        let sz = roundtrip(&levels);
        // 85% sparse 4-bit-ish source: far below 4 bits/weight
        let bits_per_w = sz as f64 * 8.0 / levels.len() as f64;
        assert!(bits_per_w < 1.4, "bits/weight {bits_per_w}");
    }

    #[test]
    fn roundtrip_extreme_magnitudes() {
        let levels = vec![0, 1, -1, 4, -4, 15, -15, 100, -100, 1000, -1000, 0, 3];
        roundtrip(&levels);
    }

    #[test]
    fn roundtrip_empty() {
        assert_eq!(decode_levels(&encode_levels(&[]), 0), Vec::<i32>::new());
    }

    #[test]
    fn denser_source_costs_more() {
        let mut rng = Rng::new(6);
        let mk = |p_zero: f64, rng: &mut Rng| -> Vec<i32> {
            (0..20_000)
                .map(|_| {
                    if rng.chance(p_zero) {
                        0
                    } else if rng.chance(0.5) {
                        1
                    } else {
                        -1
                    }
                })
                .collect()
        };
        let sparse = encode_levels(&mk(0.95, &mut rng)).len();
        let dense = encode_levels(&mk(0.30, &mut rng)).len();
        assert!(sparse < dense, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn property_roundtrip_random() {
        crate::util::prop::check("deepcabac roundtrip", 25, |rng| {
            let n = rng.below(5000);
            let levels: Vec<i32> = (0..n)
                .map(|_| {
                    if rng.chance(0.6) {
                        0
                    } else {
                        let m = 1 + rng.below(15) as i32;
                        if rng.chance(0.5) { m } else { -m }
                    }
                })
                .collect();
            let bytes = encode_levels(&levels);
            if decode_levels(&bytes, n) != levels {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }
}
