//! Offline fuzzing fallback for the codec decode surface.
//!
//! The "real" fuzzers live in `rust/fuzz/` (cargo-fuzz / libFuzzer, one
//! target per decoder) but need nightly and network access. This binary
//! is the CI-friendly stand-in: a deterministic, seeded sweep that feeds
//! every decoder (huffman, raw cabac, deepcabac, rle, deflate, tensor
//! container) two hostile input families —
//!
//!   * mutations of valid encoder output (bit flips, byte stomps,
//!     truncations, extensions), and
//!   * pure-random buffers,
//!
//! asserting the totality contract of DESIGN.md §2.4: every input yields
//! `Ok` or `Err`, never a panic (a panic exits nonzero with a hex dump of
//! the offending input). Run locally with
//! `cargo run --release --bin fuzz_fallback -- --iters 10000`.

use std::panic::{self, AssertUnwindSafe};

use ecqx::codec::{self, cabac, deepcabac, deflate, huffman, sparse};
use ecqx::quant::Codebook;
use ecqx::tensor::TensorI32;
use ecqx::util::Rng;

/// One decoder under test: a name, valid seed streams to mutate, and the
/// decode entry point (which must be total).
struct Target {
    name: &'static str,
    seeds: Vec<Vec<u8>>,
    decode: fn(&[u8]),
}

fn fuzz_huffman(buf: &[u8]) {
    let _ = huffman::decode(buf);
}

fn fuzz_cabac(buf: &[u8]) {
    // drive the raw range coder through the DeepCABAC bit patterns:
    // adaptive contexts, bypass bits, and the bounded exp-golomb bypass
    let mut dec = cabac::BinDecoder::new(buf);
    let mut ctx = cabac::BinProb::default();
    for _ in 0..256 {
        let _ = dec.decode(&mut ctx);
        let _ = dec.decode_bypass();
    }
    let _ = dec.decode_exp_golomb_bypass(32);
}

fn fuzz_deepcabac(buf: &[u8]) {
    // element count taken from the stream head, spanning valid and absurd
    let n = if buf.len() >= 2 {
        u16::from_le_bytes([buf[0], buf[1]]) as usize
    } else {
        64
    };
    let _ = deepcabac::decode_levels(buf, n);
    let _ = deepcabac::decode_levels(buf, usize::MAX);
}

fn fuzz_rle(buf: &[u8]) {
    let bits = if buf.is_empty() { 4 } else { (buf[0] % 20) as u32 };
    let body = if buf.is_empty() { buf } else { &buf[1..] };
    let _ = sparse::rle_decode(body, bits);
}

fn fuzz_deflate(buf: &[u8]) {
    let _ = deflate::decompress(buf);
}

fn fuzz_container(buf: &[u8]) {
    // structured harness: [bits, numel u16 LE, payload...] so corrupt
    // metadata and corrupt payload are explored together
    if buf.len() < 3 {
        return;
    }
    let bits = (buf[0] % 20) as u32;
    let n = u16::from_le_bytes([buf[1], buf[2]]) as usize;
    let enc = codec::EncodedTensor {
        shape: vec![n],
        step: 0.02,
        bits,
        payload: buf[3..].to_vec(),
    };
    let _ = codec::decode_tensor(&enc);
}

/// Random sparse slot tensor on the `bits` grid.
fn random_idx(rng: &mut Rng, n: usize, bits: u32) -> TensorI32 {
    let side = (1usize << (bits - 1)) - 1;
    let data: Vec<i32> = (0..n)
        .map(|_| {
            if rng.chance(0.8) || side == 0 {
                0
            } else {
                let lvl = 1 + rng.below(side) as i32;
                let lvl = if rng.chance(0.5) { lvl } else { -lvl };
                Codebook::level_to_slot(lvl) as i32
            }
        })
        .collect();
    TensorI32::new(vec![n], data)
}

fn random_levels(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| {
            if rng.chance(0.8) {
                0
            } else {
                let m = 1 + rng.below(7) as i32;
                if rng.chance(0.5) { m } else { -m }
            }
        })
        .collect()
}

/// Container seed in the [`fuzz_container`] wire shape.
fn container_seed(rng: &mut Rng, n: usize, bits: u32) -> Vec<u8> {
    let idx = random_idx(rng, n, bits);
    let cb = Codebook::symmetric(bits, 0.02);
    let enc = codec::encode_tensor(&idx, &cb);
    let mut out = vec![bits as u8, (n & 0xFF) as u8, ((n >> 8) & 0xFF) as u8];
    out.extend_from_slice(&enc.payload);
    out
}

fn build_targets(rng: &mut Rng) -> Vec<Target> {
    let mut huff_seeds = Vec::new();
    let mut cabac_seeds = Vec::new();
    let mut rle_seeds = Vec::new();
    let mut defl_seeds = Vec::new();
    let mut cont_seeds = Vec::new();
    for _ in 0..8 {
        let n = 16 + rng.below(512);
        let levels = random_levels(rng, n);
        huff_seeds.push(huffman::encode(&levels).expect("fresh table covers input"));
        let mut enc = deepcabac::encode_levels(&levels);
        // prepend the count header fuzz_deepcabac reads
        let mut framed = (n as u16).to_le_bytes().to_vec();
        framed.append(&mut enc);
        cabac_seeds.push(framed);
        rle_seeds.push({
            let mut b = vec![4u8];
            b.extend_from_slice(&sparse::rle_encode(&levels, 4));
            b
        });
        let bytes_i8: Vec<u8> = levels.iter().map(|&l| l as i8 as u8).collect();
        defl_seeds.push(deflate::compress(&bytes_i8));
        cont_seeds.push(container_seed(rng, n, 2 + (rng.below(4) as u32)));
    }
    vec![
        Target {
            name: "huffman",
            seeds: huff_seeds,
            decode: fuzz_huffman,
        },
        Target {
            name: "cabac",
            seeds: cabac_seeds.clone(),
            decode: fuzz_cabac,
        },
        Target {
            name: "deepcabac",
            seeds: cabac_seeds,
            decode: fuzz_deepcabac,
        },
        Target {
            name: "rle",
            seeds: rle_seeds,
            decode: fuzz_rle,
        },
        Target {
            name: "deflate",
            seeds: defl_seeds,
            decode: fuzz_deflate,
        },
        Target {
            name: "container",
            seeds: cont_seeds,
            decode: fuzz_container,
        },
    ]
}

/// Mutate a valid stream: a handful of bit flips, byte stomps, and a
/// possible truncation or random-tail extension.
fn mutate(rng: &mut Rng, seed_stream: &[u8]) -> Vec<u8> {
    let mut buf = seed_stream.to_vec();
    let edits = 1 + rng.below(8);
    for _ in 0..edits {
        if buf.is_empty() {
            break;
        }
        match rng.below(4) {
            0 => {
                let i = rng.below(buf.len());
                buf[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.below(buf.len());
                buf[i] = (rng.next_u64() & 0xFF) as u8;
            }
            2 => {
                buf.truncate(rng.below(buf.len() + 1));
            }
            _ => {
                let extra = rng.below(16);
                for _ in 0..extra {
                    buf.push((rng.next_u64() & 0xFF) as u8);
                }
            }
        }
    }
    buf
}

fn run_target(t: &Target, iters: usize, rng: &mut Rng) -> Result<(), Vec<u8>> {
    for _ in 0..iters {
        let buf = if rng.chance(0.6) && !t.seeds.is_empty() {
            let s = rng.below(t.seeds.len());
            mutate(rng, &t.seeds[s])
        } else {
            let n = rng.below(512);
            (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
        };
        let decode = t.decode;
        if panic::catch_unwind(AssertUnwindSafe(|| decode(&buf))).is_err() {
            return Err(buf);
        }
    }
    Ok(())
}

/// The determinism half of the contract: parallel encode must be bitwise
/// identical to serial on a freshly drawn multi-chunk tensor.
fn check_parallel_identity(rng: &mut Rng) -> Result<(), String> {
    let n = codec::CHUNK_LEVELS * 2 + rng.below(codec::CHUNK_LEVELS);
    let idx = random_idx(rng, n, 4);
    let cb = Codebook::symmetric(4, 0.02);
    let serial = codec::encode_tensor_jobs(&idx, &cb, 1);
    for jobs in 2..=4 {
        let par = codec::encode_tensor_jobs(&idx, &cb, jobs);
        if par.payload != serial.payload {
            return Err(format!("parallel encode diverged from serial at jobs={jobs}"));
        }
    }
    let dec = codec::decode_tensor(&serial).map_err(|e| format!("decode failed: {e}"))?;
    if dec.data != idx.data {
        return Err("roundtrip mismatch on valid input".into());
    }
    Ok(())
}

/// Parse a u64 accepting both decimal and `0x`-prefixed hex (the seed is
/// conventionally quoted in hex in logs and CI).
fn parse_u64(v: &str) -> Option<u64> {
    match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => v.parse().ok(),
    }
}

fn main() {
    let mut iters = 10_000usize;
    let mut seed = 0xECC5_F022u64;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        match (args[i].as_str(), args.get(i + 1)) {
            ("--iters", Some(v)) => {
                iters = v.parse().expect("--iters takes an integer");
                i += 2;
            }
            ("--seed", Some(v)) => {
                seed = parse_u64(v).expect("--seed takes an integer (decimal or 0x hex)");
                i += 2;
            }
            (other, _) => {
                eprintln!("usage: fuzz_fallback [--iters N] [--seed N] (got {other:?})");
                std::process::exit(2);
            }
        }
    }

    let mut rng = Rng::new(seed);
    let targets = build_targets(&mut rng);

    // silence the per-panic stderr spew; catch_unwind reports the failure
    let saved_hook = panic::take_hook();
    panic::set_hook(Box::new(|_| {}));
    let mut failed = false;
    for t in &targets {
        match run_target(t, iters, &mut rng) {
            Ok(()) => println!("fuzz-fallback: {:<10} {iters} inputs, zero panics", t.name),
            Err(buf) => {
                failed = true;
                let hex: String = buf.iter().take(64).map(|b| format!("{b:02x}")).collect();
                eprintln!(
                    "fuzz-fallback: {} PANICKED on a {}-byte input (first 64: {hex})",
                    t.name,
                    buf.len()
                );
            }
        }
    }
    panic::set_hook(saved_hook);

    if let Err(e) = check_parallel_identity(&mut rng) {
        eprintln!("fuzz-fallback: encode determinism check FAILED: {e}");
        failed = true;
    } else {
        println!("fuzz-fallback: parallel-encode identity holds (jobs 1..=4)");
    }

    if failed {
        std::process::exit(1);
    }
    println!(
        "fuzz-fallback: OK — {} targets x {iters} inputs (seed {seed:#x}), zero panics",
        targets.len()
    );
}
