//! Quantization substrate: centroid grids, k-means, entropy, and a pure
//! rust reference of the ECQ/ECQ^x assignment function (Eq. 1 / Eq. 11).
//!
//! The hot-path assignment runs inside the `assign_<bucket>` HLO artifact
//! (Pallas kernel, L1); the implementation here is the semantically
//! identical reference used by tests (three-way cross-check vs the jnp
//! oracle and the artifact) and by host-side analyses.

/// Centroid codebooks (symmetric integer grids, step fitting).
pub mod centroids;
/// 1-D k-means reference (Fig. 2 comparison).
pub mod kmeans;
/// Lloyd refinement ablation of the integer grid.
pub mod refine;
/// Relevance EMAs, cost factors and the beta controller.
pub mod relevance;
/// Structured (group) sparsification variants.
pub mod structured;

pub use centroids::{Codebook, K_MAX};

use crate::tensor::Tensor;

/// Sentinel cost for invalid codebook slots.
pub const BIG: f32 = 1e30;
/// Probability floor inside entropy terms.
pub const P_EPS: f32 = 1e-9;

/// Result of assigning one layer.
#[derive(Clone, Debug)]
pub struct Assignment {
    /// centroid index per weight (0 == zero cluster)
    pub idx: Vec<i32>,
    /// dequantized weights
    pub qw: Vec<f32>,
    /// per-cluster assignment counts (len K_MAX)
    pub counts: Vec<f32>,
}

impl Assignment {
    /// Fraction of the first `n_valid` weights sent to the zero cluster.
    pub fn sparsity(&self, n_valid: usize) -> f64 {
        if n_valid == 0 {
            return 0.0;
        }
        let zeros = self.idx.iter().take(n_valid).filter(|&&i| i == 0).count();
        zeros as f64 / n_valid as f64
    }
}

/// Pure-rust ECQ^x assignment (reference semantics of the Pallas kernel +
/// its two-phase probability wrapper `assign_full`).
///
/// `w`, `r`, `mask` have equal (padded) length; `codebook.values[0]` must
/// be the zero centroid; `lam` is the layer-scaled Lagrange multiplier.
/// With `r == 1` everywhere this is exactly ECQ (Eq. 1).
pub fn assign_ref(
    w: &[f32],
    r: &[f32],
    mask: &[f32],
    codebook: &Codebook,
    lam: f32,
) -> Assignment {
    assign_raw(w, r, mask, &codebook.values, &codebook.valid, lam)
}

/// Slice-level ECQ^x assignment over a raw `(values, valid)` codebook —
/// the form the `assign_<bucket>` artifact signature carries and the one
/// `runtime::host` executes directly. [`assign_ref`] is the
/// [`Codebook`]-typed wrapper.
pub fn assign_raw(
    w: &[f32],
    r: &[f32],
    mask: &[f32],
    values: &[f32],
    valid: &[f32],
    lam: f32,
) -> Assignment {
    let k = values.len();
    assert_eq!(w.len(), r.len());
    assert_eq!(w.len(), mask.len());
    assert_eq!(values.len(), valid.len());
    // Phase 1: nearest-neighbour source distribution P_c.
    let mut counts = vec![0f64; k];
    let mut total = 0f64;
    for i in 0..w.len() {
        let mut best = 0usize;
        let mut bd = f32::INFINITY;
        for c in 0..k {
            if valid[c] == 0.0 {
                continue;
            }
            let d = (w[i] - values[c]).powi(2);
            if d < bd {
                bd = d;
                best = c;
            }
        }
        counts[best] += mask[i] as f64;
        total += mask[i] as f64;
    }
    let total = total.max(1.0);
    let mut entcost = vec![0f32; k];
    for c in 0..k {
        let p = ((counts[c] / total) as f32).max(P_EPS);
        entcost[c] = -lam * p.log2();
        if valid[c] == 0.0 {
            entcost[c] += BIG;
        }
    }
    // Phase 2: relevance-adjusted cost argmin (Eq. 11).
    let mut idx = vec![0i32; w.len()];
    let mut qw = vec![0f32; w.len()];
    let mut fcounts = vec![0f32; k];
    for i in 0..w.len() {
        let mut best = 0usize;
        let mut bc = f32::INFINITY;
        for c in 0..k {
            let d = (w[i] - values[c]).powi(2);
            let mut cost = d + entcost[c];
            if c == 0 {
                cost *= r[i];
            }
            if cost < bc {
                bc = cost;
                best = c;
            }
        }
        if mask[i] > 0.5 {
            idx[i] = best as i32;
            qw[i] = values[best];
            fcounts[best] += 1.0;
        }
    }
    Assignment { idx, qw, counts: fcounts }
}

/// Per-layer lambda scaling: layers with more parameters get the full
/// constraint, smaller layers a proportionally weaker one (Sec. 3.1:
/// "scaled with a factor based on the number of parameters a layer has in
/// proportion to other layers ... to mitigate the constraint for smaller
/// layers").
pub fn lambda_scale(layer_numel: usize, max_numel: usize) -> f32 {
    if max_numel == 0 {
        return 1.0;
    }
    (layer_numel as f32 / max_numel as f32).sqrt()
}

/// Uniform symmetric post-training quantization of a tensor to `bits`
/// (2^bits - 1 levels incl. 0): the Fig. 1 weight-sensitivity probe and
/// the classic baseline.
pub fn uniform_quantize(t: &Tensor, bits: u32) -> Tensor {
    let levels = (1i64 << bits) - 1; // symmetric, includes 0
    let half = (levels / 2) as f32;
    let mx = t.abs_max();
    if mx == 0.0 || half == 0.0 {
        return t.clone();
    }
    let step = mx / half;
    let data = t
        .data
        .iter()
        .map(|&x| (x / step).round().clamp(-half, half) * step)
        .collect();
    Tensor::new(t.shape.clone(), data)
}

/// First-order entropy (bits/weight) of an assignment — the rate the
/// entropy constraint optimizes (Sec. 3.1).
pub fn assignment_entropy(counts: &[f32]) -> f64 {
    let total: f64 = counts.iter().map(|&c| c as f64).sum();
    if total == 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c <= 0.0 {
            continue;
        }
        let p = c as f64 / total;
        h -= p * p.log2();
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_codebook(bits: u32, step: f32) -> Codebook {
        Codebook::symmetric(bits, step)
    }

    #[test]
    fn ecq_zero_lambda_is_nearest_neighbour() {
        let cb = toy_codebook(2, 0.5); // centroids 0, +0.5, -0.5
        let w = [0.1f32, 0.4, -0.4, -0.1, 0.26];
        let r = [1.0f32; 5];
        let m = [1.0f32; 5];
        let a = assign_ref(&w, &r, &m, &cb, 0.0);
        // nearest neighbour: 0.1->0, 0.4->+0.5, -0.4->-0.5, -0.1->0, 0.26->+0.5
        assert_eq!(&a.idx[..], &[0, 1, 2, 0, 1]);
        assert_eq!(a.qw[1], 0.5);
        assert_eq!(a.qw[2], -0.5);
    }

    #[test]
    fn entropy_constraint_pulls_to_popular_cluster() {
        let cb = toy_codebook(2, 0.5);
        // Most weights near zero -> zero cluster popular; a borderline
        // weight flips to zero when lambda is large enough.
        let mut w = vec![0.01f32; 99];
        w.push(0.26); // nearest neighbour is +0.5
        let r = vec![1.0f32; 100];
        let m = vec![1.0f32; 100];
        let a0 = assign_ref(&w, &r, &m, &cb, 0.0);
        assert_eq!(a0.idx[99], 1);
        let a1 = assign_ref(&w, &r, &m, &cb, 0.05);
        assert_eq!(a1.idx[99], 0, "large lambda must pull into zero cluster");
        assert!(a1.sparsity(100) > a0.sparsity(100));
    }

    #[test]
    fn relevance_protects_and_prunes() {
        let cb = toy_codebook(2, 0.5);
        let mut w = vec![0.01f32; 99];
        w.push(0.26);
        let m = vec![1.0f32; 100];
        let lam = 0.05;
        // relevant weight (r >> 1): zero cluster becomes expensive -> kept
        let mut r = vec![1.0f32; 100];
        r[99] = 50.0;
        let a = assign_ref(&w, &r, &m, &cb, lam);
        assert_eq!(a.idx[99], 1, "high relevance must keep the weight");
        // irrelevant weight (r ~ 0): nearest-neighbour non-zero weight
        // gets pushed into the zero cluster even with lambda = 0
        let mut r2 = vec![1.0f32; 100];
        r2[99] = 0.0;
        let a2 = assign_ref(&w, &r2, &m, &cb, 0.0);
        assert_eq!(a2.idx[99], 0, "zero relevance must prune the weight");
    }

    #[test]
    fn mask_excludes_padding() {
        let cb = toy_codebook(2, 0.5);
        let w = [0.4f32, 0.4, 0.4, 0.4];
        let r = [1.0f32; 4];
        let m = [1.0f32, 1.0, 0.0, 0.0];
        let a = assign_ref(&w, &r, &m, &cb, 0.0);
        assert_eq!(&a.idx[..], &[1, 1, 0, 0]);
        assert_eq!(a.qw[2], 0.0);
        let total: f32 = a.counts.iter().sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn uniform_quantize_roundtrip() {
        let t = Tensor::new(vec![4], vec![-1.0, -0.33, 0.33, 1.0]);
        let q = uniform_quantize(&t, 2); // levels {-1, 0, 1} * step
        assert_eq!(q.data[0], -1.0);
        assert_eq!(q.data[3], 1.0);
        assert_eq!(q.data[1], 0.0); // -0.33 rounds to 0 at step 1.0
        let q8 = uniform_quantize(&t, 8);
        for (a, b) in q8.data.iter().zip(t.data.iter()) {
            assert!((a - b).abs() < 0.01);
        }
    }

    #[test]
    fn lambda_scale_monotone() {
        assert!(lambda_scale(100, 1000) < lambda_scale(1000, 1000));
        assert_eq!(lambda_scale(1000, 1000), 1.0);
        assert_eq!(lambda_scale(10, 0), 1.0);
    }

    #[test]
    fn assignment_entropy_bounds() {
        assert_eq!(assignment_entropy(&[10.0, 0.0, 0.0]), 0.0);
        let h = assignment_entropy(&[5.0, 5.0, 5.0, 5.0]);
        assert!((h - 2.0).abs() < 1e-9);
    }

    #[test]
    fn property_ecqx_reduces_to_ecq_with_unit_relevance() {
        crate::util::prop::check("ecqx==ecq when r=1", 20, |rng| {
            let n = 64 + rng.below(200);
            let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.2)).collect();
            let r = vec![1.0f32; n];
            let m = vec![1.0f32; n];
            let cb = Codebook::symmetric(3, 0.1);
            let lam = rng.range(0.0, 0.1);
            let a = assign_ref(&w, &r, &m, &cb, lam);
            let b = assign_ref(&w, &r, &m, &cb, lam);
            if a.idx != b.idx {
                return Err("non-deterministic".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_sparsity_monotone_in_lambda() {
        crate::util::prop::check("sparsity monotone in lambda", 10, |rng| {
            let n = 512;
            let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.2)).collect();
            let r = vec![1.0f32; n];
            let m = vec![1.0f32; n];
            // fitted grid: the zero cluster is the NN mode (weights peak
            // at 0), which is the regime where monotonicity holds; skip
            // draws where sampling noise makes another cluster the mode
            let cb = Codebook::fit(&w, 4);
            let nn = assign_ref(&w, &r, &m, &cb, 0.0);
            let argmax = nn
                .counts
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax != 0 {
                return Ok(());
            }
            let mut last = -1.0f64;
            for lam in [0.0, 0.01, 0.05, 0.2, 0.5] {
                let a = assign_ref(&w, &r, &m, &cb, lam);
                let s = a.sparsity(n);
                if s + 1e-9 < last {
                    return Err(format!("sparsity dropped: {s} < {last} at lam={lam}"));
                }
                last = s;
            }
            Ok(())
        });
    }
}
