//! Structured sparsification baseline (paper §2, [19]): zero entire rows
//! (input neurons) or columns (output neurons) of a weight matrix by
//! aggregate saliency, in contrast to ECQ(x)'s unstructured zero-cluster
//! assignment. Used by the ablation bench to show the cost of structure
//! constraints at matched sparsity.

use crate::tensor::Tensor;

/// Saliency aggregate for a row/column group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupSaliency {
    /// sum of |w| (magnitude-based, the classic criterion)
    L1,
    /// sum of w^2
    L2,
    /// sum of |relevance| (LRP-based, Yeom et al. [51] style)
    Relevance,
}

/// Which dimension forms a group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupKind {
    /// rows of the [in, out] matrix == input neurons
    Row,
    /// columns == output neurons
    Column,
}

/// Result of a structured sparsification pass on one matrix.
#[derive(Clone, Debug)]
pub struct StructuredResult {
    /// pruned copy of the weights
    pub weights: Tensor,
    /// indices of the zeroed groups
    pub zeroed: Vec<usize>,
    /// resulting element sparsity
    pub sparsity: f64,
}

fn group_scores(
    w: &Tensor,
    rel: Option<&[f32]>,
    kind: GroupKind,
    saliency: GroupSaliency,
) -> Vec<f64> {
    assert_eq!(w.shape.len(), 2, "structured sparsity needs a 2-D matrix");
    let (rows, cols) = (w.shape[0], w.shape[1]);
    let groups = match kind {
        GroupKind::Row => rows,
        GroupKind::Column => cols,
    };
    let mut scores = vec![0f64; groups];
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            let g = match kind {
                GroupKind::Row => r,
                GroupKind::Column => c,
            };
            scores[g] += match saliency {
                GroupSaliency::L1 => w.data[i].abs() as f64,
                GroupSaliency::L2 => (w.data[i] as f64).powi(2),
                GroupSaliency::Relevance => {
                    rel.expect("relevance saliency needs relevances")[i].abs() as f64
                }
            };
        }
    }
    scores
}

/// Zero the lowest-saliency groups until at least `target_sparsity` of the
/// elements are zero.
pub fn sparsify_structured(
    w: &Tensor,
    rel: Option<&[f32]>,
    kind: GroupKind,
    saliency: GroupSaliency,
    target_sparsity: f64,
) -> StructuredResult {
    let (rows, cols) = (w.shape[0], w.shape[1]);
    let scores = group_scores(w, rel, kind, saliency);
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let group_elems = match kind {
        GroupKind::Row => cols,
        GroupKind::Column => rows,
    };
    let total = rows * cols;
    let need = (target_sparsity * total as f64).ceil() as usize;
    let n_groups = need.div_ceil(group_elems).min(order.len());
    let zeroed: Vec<usize> = order[..n_groups].to_vec();
    let mut out = w.data.clone();
    for &g in &zeroed {
        match kind {
            GroupKind::Row => {
                out[g * cols..(g + 1) * cols].iter_mut().for_each(|v| *v = 0.0);
            }
            GroupKind::Column => {
                for r in 0..rows {
                    out[r * cols + g] = 0.0;
                }
            }
        }
    }
    let weights = Tensor::new(w.shape.clone(), out);
    let sparsity = weights.sparsity();
    StructuredResult { weights, zeroed, sparsity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(
            vec![rows, cols],
            (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.1)).collect(),
        )
    }

    #[test]
    fn zeroes_whole_rows() {
        let w = toy(8, 4, 1);
        let r = sparsify_structured(&w, None, GroupKind::Row, GroupSaliency::L1, 0.5);
        assert_eq!(r.zeroed.len(), 4);
        for &g in &r.zeroed {
            assert!(r.weights.data[g * 4..(g + 1) * 4].iter().all(|&v| v == 0.0));
        }
        assert!(r.sparsity >= 0.5);
    }

    #[test]
    fn zeroes_whole_columns() {
        let w = toy(6, 10, 2);
        let r =
            sparsify_structured(&w, None, GroupKind::Column, GroupSaliency::L2, 0.3);
        assert_eq!(r.zeroed.len(), 3);
        for &g in &r.zeroed {
            for row in 0..6 {
                assert_eq!(r.weights.data[row * 10 + g], 0.0);
            }
        }
    }

    #[test]
    fn prunes_lowest_saliency_first() {
        // make row 0 clearly the smallest
        let mut w = toy(4, 4, 3);
        for c in 0..4 {
            w.data[c] = 1e-4;
        }
        let r = sparsify_structured(&w, None, GroupKind::Row, GroupSaliency::L1, 0.25);
        assert_eq!(r.zeroed, vec![0]);
    }

    #[test]
    fn relevance_saliency_uses_relevances() {
        let w = toy(4, 4, 4);
        // relevance says row 2 is the least relevant even if magnitudes differ
        let mut rel = vec![1.0f32; 16];
        for c in 0..4 {
            rel[2 * 4 + c] = 1e-6;
        }
        let r = sparsify_structured(
            &w,
            Some(&rel),
            GroupKind::Row,
            GroupSaliency::Relevance,
            0.25,
        );
        assert_eq!(r.zeroed, vec![2]);
    }

    #[test]
    fn structured_is_coarser_than_unstructured() {
        // structured pruning at the same element sparsity removes whole
        // groups, so the achieved sparsity overshoots the target less
        // precisely than per-element selection — it lands on a group
        // multiple.
        let w = toy(16, 16, 5);
        let r = sparsify_structured(&w, None, GroupKind::Row, GroupSaliency::L1, 0.4);
        // 0.4 * 16 rows = 6.4 -> 7 rows
        assert_eq!(r.zeroed.len(), 7);
        assert!((r.sparsity - 7.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn property_target_reached() {
        crate::util::prop::check("structured target sparsity", 15, |rng| {
            let rows = 4 + rng.below(20);
            let cols = 4 + rng.below(20);
            let w = toy(rows, cols, rng.next_u64());
            let t = rng.f64() * 0.9;
            let r = sparsify_structured(&w, None, GroupKind::Row, GroupSaliency::L1, t);
            if r.sparsity + 1e-9 < t {
                return Err(format!("sparsity {} below target {t}", r.sparsity));
            }
            Ok(())
        });
    }
}
