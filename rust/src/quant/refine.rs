//! Centroid refinement ablation: the paper's ECQ deliberately does NOT
//! train centroid values ("to facilitate integer arithmetic on general
//! hardware", Sec. 3.1), unlike EC2T/TTQ which learn them. This module
//! implements the alternative — per-cluster Lloyd refinement of the
//! non-zero centroids after assignment — so the design choice can be
//! ablated: how much distortion does the integer-grid constraint cost?

use super::centroids::Codebook;
use super::Assignment;

/// Distortion (mean squared quantization error) of an assignment.
pub fn distortion(w: &[f32], qw: &[f32]) -> f64 {
    assert_eq!(w.len(), qw.len());
    if w.is_empty() {
        return 0.0;
    }
    w.iter()
        .zip(qw.iter())
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / w.len() as f64
}

/// One Lloyd step: move every non-zero centroid to the mean of its
/// assigned weights (the zero centroid stays at 0 — sparsity is the
/// point). Returns the refined (non-integer!) codebook and the refreshed
/// dequantized weights.
pub fn refine_centroids(
    w: &[f32],
    assignment: &Assignment,
    codebook: &Codebook,
) -> (Codebook, Vec<f32>) {
    let k = codebook.values.len();
    let mut sums = vec![0f64; k];
    let mut counts = vec![0u64; k];
    for (i, &slot) in assignment.idx.iter().enumerate() {
        sums[slot as usize] += w[i] as f64;
        counts[slot as usize] += 1;
    }
    let mut refined = codebook.clone();
    for c in 1..k {
        // slot 0 == zero centroid, never moved
        if counts[c] > 0 && codebook.valid[c] > 0.5 {
            refined.values[c] = (sums[c] / counts[c] as f64) as f32;
        }
    }
    let qw = assignment
        .idx
        .iter()
        .map(|&s| refined.values[s as usize])
        .collect();
    (refined, qw)
}

/// Ablation record: distortion with the hardware-friendly integer grid vs
/// after k Lloyd refinements.
#[derive(Clone, Debug)]
pub struct RefineAblation {
    /// MSE of the hardware-friendly integer grid
    pub integer_grid_mse: f64,
    /// MSE after Lloyd refinement
    pub refined_mse: f64,
    /// relative distortion reduction given up for integer arithmetic
    pub integer_cost: f64,
}

/// Measure the distortion cost of staying on the integer grid vs
/// `lloyd_steps` of centroid refinement (Sec. 3.1 ablation).
pub fn ablate_refinement(
    w: &[f32],
    assignment: &Assignment,
    codebook: &Codebook,
    lloyd_steps: usize,
) -> RefineAblation {
    let base = distortion(w, &assignment.qw);
    let mut cb = codebook.clone();
    let mut qw = assignment.qw.clone();
    for _ in 0..lloyd_steps {
        let (ncb, nqw) = refine_centroids(w, assignment, &cb);
        cb = ncb;
        qw = nqw;
    }
    let refined = distortion(w, &qw);
    RefineAblation {
        integer_grid_mse: base,
        refined_mse: refined,
        integer_cost: if refined > 0.0 { base / refined } else { 1.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::assign_ref;
    use crate::util::Rng;

    fn setup(n: usize, seed: u64) -> (Vec<f32>, Assignment, Codebook) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let cb = Codebook::fit(&w, 4);
        let ones = vec![1.0f32; n];
        let a = assign_ref(&w, &ones, &ones, &cb, 1e-4);
        (w, a, cb)
    }

    #[test]
    fn refinement_reduces_distortion() {
        let (w, a, cb) = setup(4096, 1);
        let ab = ablate_refinement(&w, &a, &cb, 1);
        assert!(
            ab.refined_mse <= ab.integer_grid_mse + 1e-12,
            "{ab:?}"
        );
        assert!(ab.integer_cost >= 1.0);
    }

    #[test]
    fn zero_centroid_never_moves() {
        let (w, a, cb) = setup(1024, 2);
        let (refined, _) = refine_centroids(&w, &a, &cb);
        assert_eq!(refined.values[0], 0.0);
    }

    #[test]
    fn refined_qw_matches_assignment() {
        let (w, a, cb) = setup(512, 3);
        let (refined, qw) = refine_centroids(&w, &a, &cb);
        for (i, &slot) in a.idx.iter().enumerate() {
            assert_eq!(qw[i], refined.values[slot as usize]);
        }
    }

    #[test]
    fn distortion_zero_for_exact() {
        let w = [0.1f32, -0.2];
        assert_eq!(distortion(&w, &w), 0.0);
        assert_eq!(distortion(&[], &[]), 0.0);
    }

    #[test]
    fn property_lloyd_monotone() {
        crate::util::prop::check("lloyd step monotone", 10, |rng| {
            let (w, a, cb) = setup(1024, rng.next_u64());
            let one = ablate_refinement(&w, &a, &cb, 1);
            let three = ablate_refinement(&w, &a, &cb, 3);
            // with fixed assignment, repeated refinement converges in one
            // step (means don't change) — allow equality
            if three.refined_mse > one.refined_mse + 1e-12 {
                return Err(format!(
                    "more steps increased distortion: {} > {}",
                    three.refined_mse, one.refined_mse
                ));
            }
            Ok(())
        });
    }
}
