//! LRP relevance post-processing pipeline (Sec. 4.2).
//!
//! Raw per-weight relevances arrive (signed, batch-aggregated) from the
//! `<model>_lrp` artifact. Per layer we
//!   1. take absolute values ("negative contributions ... might still be
//!      relevant to the network functionality"),
//!   2. apply an EMA over data batches (the momentum folded into rho),
//!   3. normalize to [0, 1],
//!   4. gamma-transform with exponent beta and convert to the zero-cluster
//!      cost factor  rho * R^beta  ==  (R / R_mean)^beta, which satisfies
//!      the paper's neutrality condition rho * (R_mean)^beta = 1 exactly,
//!   5. auto-tune beta downward whenever the LRP-induced *extra* sparsity
//!      of a layer exceeds the target-sparsity hyperparameter p.

/// EMA state of one layer's relevances.
#[derive(Clone, Debug)]
pub struct RelevanceState {
    /// smoothed |relevance| per weight
    pub ema: Vec<f32>,
    /// momentum coefficient (0 => no history)
    pub momentum: f32,
    initialized: bool,
}

impl RelevanceState {
    /// Fresh EMA state for a layer of `n` weights.
    pub fn new(n: usize, momentum: f32) -> Self {
        RelevanceState { ema: vec![0.0; n], momentum, initialized: false }
    }

    /// Fold a new batch of signed relevances into the EMA.
    pub fn update(&mut self, raw: &[f32]) {
        assert_eq!(raw.len(), self.ema.len());
        if !self.initialized {
            for (e, &r) in self.ema.iter_mut().zip(raw.iter()) {
                *e = r.abs();
            }
            self.initialized = true;
        } else {
            let m = self.momentum;
            for (e, &r) in self.ema.iter_mut().zip(raw.iter()) {
                *e = m * *e + (1.0 - m) * r.abs();
            }
        }
    }

    /// Normalized relevances in [0, 1].
    pub fn normalized(&self) -> Vec<f32> {
        let mx = self.ema.iter().fold(0.0f32, |m, &x| m.max(x));
        if mx <= 0.0 {
            return vec![0.0; self.ema.len()];
        }
        self.ema.iter().map(|&x| x / mx).collect()
    }
}

/// Stabilizer added to relevances before the gamma transform so that
/// beta -> 0 neutralizes the factor even for exactly-zero relevances
/// (otherwise 0^beta == 0 for every beta > 0 and the target-sparsity
/// controller could never bound the LRP-induced pruning).
pub const REL_EPS: f32 = 1e-3;

/// Convert normalized relevances to zero-cluster cost factors
/// (rho * R^beta with rho = mean^-beta): factor 1 at the mean relevance,
/// > 1 above (protects relevant weights), < 1 below (prunes irrelevant
/// ones); beta in [0, 1] controls the intensity.
pub fn cost_factors(norm_rel: &[f32], beta: f32) -> Vec<f32> {
    let n = norm_rel.len();
    if n == 0 {
        return Vec::new();
    }
    let mean = (norm_rel.iter().map(|&x| x as f64).sum::<f64>() / n as f64)
        .max(1e-12) as f32;
    norm_rel
        .iter()
        .map(|&r| {
            if beta == 0.0 {
                1.0
            } else {
                ((r.max(0.0) + REL_EPS) / (mean + REL_EPS))
                    .powf(beta)
                    .clamp(FACTOR_LO, FACTOR_HI)
            }
        })
        .collect()
}

/// Bounds on the relevance cost factor: keeps single-batch relevance noise
/// from making any weight's zero-cluster cost collapse to ~0 (irreversible
/// prune) or explode (unbounded protection) within one refresh.
pub const FACTOR_LO: f32 = 0.2;
/// Upper bound of the relevance cost factor (see [`FACTOR_LO`]).
pub const FACTOR_HI: f32 = 5.0;

/// Outcome of the beta controller for one layer.
#[derive(Clone, Debug)]
pub struct BetaControl {
    pub beta: f32,
    pub factors: Vec<f32>,
    /// LRP-induced extra sparsity at the chosen beta
    pub extra_sparsity: f64,
    pub halvings: u32,
}

/// Tune beta so the LRP-induced extra sparsity stays below the target `p`.
///
/// `sparsity_at` evaluates the layer sparsity for a given factor vector
/// (by running the assignment); `base_sparsity` is the lambda-only (ECQ)
/// sparsity of the same layer. beta is halved until the constraint holds
/// (beta -> 0 recovers plain ECQ, so the loop terminates).
pub fn control_beta(
    norm_rel: &[f32],
    beta0: f32,
    p: f64,
    base_sparsity: f64,
    mut sparsity_at: impl FnMut(&[f32]) -> f64,
    max_halvings: u32,
) -> BetaControl {
    let mut beta = beta0.clamp(0.0, 1.0);
    let mut halvings = 0;
    loop {
        let factors = cost_factors(norm_rel, beta);
        let s = sparsity_at(&factors);
        let extra = s - base_sparsity;
        if extra <= p {
            return BetaControl { beta, factors, extra_sparsity: extra, halvings };
        }
        if beta <= 1e-3 || halvings >= max_halvings {
            // give up: fall back to beta = 0 (plain ECQ, extra == 0) so the
            // target-sparsity bound is respected exactly
            let factors = cost_factors(norm_rel, 0.0);
            return BetaControl { beta: 0.0, factors, extra_sparsity: 0.0, halvings };
        }
        beta *= 0.5;
        halvings += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_tracks_abs() {
        let mut st = RelevanceState::new(3, 0.5);
        st.update(&[-2.0, 0.0, 4.0]);
        assert_eq!(st.ema, vec![2.0, 0.0, 4.0]);
        st.update(&[0.0, 0.0, 0.0]);
        assert_eq!(st.ema, vec![1.0, 0.0, 2.0]);
        let n = st.normalized();
        assert_eq!(n, vec![0.5, 0.0, 1.0]);
    }

    #[test]
    fn factors_neutral_at_mean() {
        let rel = vec![0.2f32, 0.4, 0.6, 0.8];
        let f = cost_factors(&rel, 1.0);
        // mean = 0.5; factor at 0.5-relevance would be exactly 1
        assert!(f[0] < 1.0 && f[3] > 1.0);
        let prod_mean: f32 = 0.5 / 0.5;
        assert!((prod_mean - 1.0).abs() < 1e-6);
        // beta=0 -> all neutral
        let f0 = cost_factors(&rel, 0.0);
        assert!(f0.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn smaller_beta_compresses_factors() {
        let rel = vec![0.01f32, 0.5, 1.0];
        let f1 = cost_factors(&rel, 1.0);
        let f01 = cost_factors(&rel, 0.1);
        // low-relevance factor moves toward 1 as beta shrinks
        assert!(f01[0] > f1[0]);
        assert!(f01[2] < f1[2]);
    }

    #[test]
    fn controller_halves_until_target() {
        let rel = vec![0.1f32; 100];
        // fake sparsity model: extra sparsity proportional to beta
        let ctl = control_beta(&rel, 1.0, 0.1, 0.5, |f| {
            let intensity = f.iter().map(|&x| (1.0 - x).abs() as f64).sum::<f64>();
            0.5 + 0.4 * (intensity > 0.0) as u64 as f64 * 0.0 + 0.4 * ctl_beta_proxy(f)
        }, 10);
        assert!(ctl.extra_sparsity <= 0.1 + 1e-9 || ctl.beta <= 1e-3);
    }

    // proxy: mean deviation of factors from 1 stands in for LRP intensity
    fn ctl_beta_proxy(f: &[f32]) -> f64 {
        f.iter().map(|&x| (1.0 - x).abs() as f64).sum::<f64>() / f.len() as f64
    }

    #[test]
    fn controller_zero_p_drives_beta_down() {
        let rel: Vec<f32> = (0..50).map(|i| i as f32 / 50.0).collect();
        let ctl = control_beta(&rel, 1.0, 0.0, 0.3, |f| {
            // extra sparsity strictly positive unless factors all 1
            let dev: f64 =
                f.iter().map(|&x| (1.0 - x).abs() as f64).sum::<f64>() / 50.0;
            0.3 + dev
        }, 12);
        assert!(ctl.beta < 1.0, "beta should have been reduced");
    }
}
