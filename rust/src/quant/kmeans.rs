//! 1-D k-means (Lloyd) over weight values — the non-uniform quantization
//! scheme of Fig. 2 and an alternative centroid initializer.

use crate::util::Rng;

/// Outcome of one [`kmeans_1d`] run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// final centroid positions (len k)
    pub centroids: Vec<f32>,
    /// number of weights assigned to each centroid
    pub counts: Vec<usize>,
    /// sum of squared distances
    pub inertia: f64,
    /// Lloyd iterations until convergence (or the cap)
    pub iterations: usize,
}

/// Lloyd's k-means on scalars with k-means++-style seeding.
pub fn kmeans_1d(xs: &[f32], k: usize, max_iter: usize, seed: u64) -> KMeansResult {
    assert!(k >= 1 && !xs.is_empty());
    let mut rng = Rng::new(seed);
    // k-means++ seeding
    let mut centroids = Vec::with_capacity(k);
    centroids.push(xs[rng.below(xs.len())]);
    while centroids.len() < k {
        let d2: Vec<f64> = xs
            .iter()
            .map(|&x| {
                centroids
                    .iter()
                    .map(|&c| ((x - c) as f64).powi(2))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            centroids.push(xs[rng.below(xs.len())]);
            continue;
        }
        let mut target = rng.f64() * total;
        let mut pick = 0;
        for (i, &d) in d2.iter().enumerate() {
            target -= d;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.push(xs[pick]);
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut counts = vec![0usize; k];
    let mut inertia = 0.0;
    let mut iters = 0;
    for it in 0..max_iter {
        iters = it + 1;
        let mut sums = vec![0f64; k];
        counts = vec![0usize; k];
        inertia = 0.0;
        for &x in xs {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, &cen) in centroids.iter().enumerate() {
                let d = ((x - cen) as f64).powi(2);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            sums[best] += x as f64;
            counts[best] += 1;
            inertia += bd;
        }
        let mut moved = 0.0f64;
        for c in 0..k {
            if counts[c] > 0 {
                let nc = (sums[c] / counts[c] as f64) as f32;
                moved += ((nc - centroids[c]) as f64).abs();
                centroids[c] = nc;
            }
        }
        if moved < 1e-7 {
            break;
        }
    }
    KMeansResult { centroids, counts, inertia, iterations: iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn separates_two_clusters() {
        let mut rng = Rng::new(5);
        let mut xs = Vec::new();
        for _ in 0..200 {
            xs.push(rng.normal_f32(-1.0, 0.05));
            xs.push(rng.normal_f32(1.0, 0.05));
        }
        let r = kmeans_1d(&xs, 2, 50, 1);
        let mut c = r.centroids.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((c[0] + 1.0).abs() < 0.1, "{c:?}");
        assert!((c[1] - 1.0).abs() < 0.1, "{c:?}");
        assert_eq!(r.counts.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let xs = [1.0f32, 2.0, 3.0];
        let r = kmeans_1d(&xs, 3, 50, 2);
        assert!(r.inertia < 1e-9, "inertia={}", r.inertia);
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Rng::new(11);
        let xs: Vec<f32> = (0..500).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let i2 = kmeans_1d(&xs, 2, 50, 3).inertia;
        let i7 = kmeans_1d(&xs, 7, 50, 3).inertia;
        assert!(i7 < i2, "i7={i7} i2={i2}");
    }
}
