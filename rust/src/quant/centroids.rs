//! Centroid codebooks: symmetric integer grids (hardware-friendly, the
//! ECQ/ECQ^x default) and per-layer step-size fitting.
//!
//! Layout contract shared with the Pallas kernel: fixed capacity
//! `K_MAX = 32` slots, slot 0 is the zero centroid, slots 1.. alternate
//! +k*step, -k*step; `valid` masks unused slots (so one HLO artifact
//! serves every bit width 2-5).

/// Fixed codebook capacity (2^5 - 1 = 31 centroids for 5 bit, padded to 32).
pub const K_MAX: usize = 32;

/// A fixed-capacity centroid codebook (see the module layout contract).
#[derive(Clone, Debug)]
pub struct Codebook {
    /// centroid values, len K_MAX, slot 0 == 0.0
    pub values: Vec<f32>,
    /// 1.0 for valid slots, 0.0 for padding
    pub valid: Vec<f32>,
    /// bit width this codebook represents
    pub bits: u32,
    /// integer step size (scaling factor)
    pub step: f32,
}

impl Codebook {
    /// Symmetric integer grid: {0, ±step, ±2·step, …, ±(2^(bits-1)-1)·step}.
    ///
    /// `2^bits - 1` centroids — the ternary case (bits=2) is {0, ±step},
    /// matching EC2T; centroids are NOT trained (integer arithmetic on
    /// general hardware, Sec. 3.1).
    pub fn symmetric(bits: u32, step: f32) -> Self {
        assert!((2..=5).contains(&bits), "bit width must be in 2..=5");
        let kmax_side = (1usize << (bits - 1)) - 1; // e.g. 7 for 4 bit
        let mut values = vec![0.0f32; K_MAX];
        let mut valid = vec![0.0f32; K_MAX];
        valid[0] = 1.0;
        for k in 1..=kmax_side {
            values[2 * k - 1] = k as f32 * step;
            values[2 * k] = -(k as f32) * step;
            valid[2 * k - 1] = 1.0;
            valid[2 * k] = 1.0;
        }
        Codebook { values, valid, bits, step }
    }

    /// Fit the step size to the weight distribution.
    ///
    /// bits >= 3: step = max|w| / (2^(bits-1) - 1) (grid spans the range).
    /// bits == 2 (ternary): max-fitting would put the nearest-neighbour
    /// dead zone at ±max|w|/2 and zero out ~everything; instead use the
    /// TWN-style threshold delta = 0.7·E|w| (i.e. step = 1.4·E|w|), the
    /// standard ternary scaling the EC2T lineage builds on.
    pub fn fit(weights: &[f32], bits: u32) -> Self {
        let step = if bits == 2 {
            let mean_abs = if weights.is_empty() {
                0.0
            } else {
                weights.iter().map(|w| w.abs() as f64).sum::<f64>() as f32
                    / weights.len() as f32
            };
            1.4 * mean_abs
        } else {
            let mx = weights.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let half = ((1usize << (bits - 1)) - 1) as f32;
            if half > 0.0 {
                mx / half
            } else {
                0.0
            }
        };
        Self::symmetric(bits, if step > 0.0 { step } else { 1.0 })
    }

    /// Number of valid centroids.
    pub fn n_valid(&self) -> usize {
        self.valid.iter().filter(|&&v| v > 0.5).count()
    }

    /// The signed integer level of a centroid slot (for entropy coding):
    /// slot 0 -> 0, slot 2k-1 -> +k, slot 2k -> -k.
    pub fn slot_to_level(slot: usize) -> i32 {
        if slot == 0 {
            0
        } else if slot % 2 == 1 {
            ((slot + 1) / 2) as i32
        } else {
            -((slot / 2) as i32)
        }
    }

    /// Inverse of [`Self::slot_to_level`].
    pub fn level_to_slot(level: i32) -> usize {
        if level == 0 {
            0
        } else if level > 0 {
            (2 * level - 1) as usize
        } else {
            (-2 * level) as usize
        }
    }

    /// Dequantize an integer level.
    pub fn level_value(&self, level: i32) -> f32 {
        level as f32 * self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_layout() {
        let cb = Codebook::symmetric(4, 0.1);
        assert_eq!(cb.values[0], 0.0);
        assert_eq!(cb.n_valid(), 15); // 2^4 - 1
        assert!((cb.values[1] - 0.1).abs() < 1e-7);
        assert!((cb.values[2] + 0.1).abs() < 1e-7);
        assert!((cb.values[13] - 0.7).abs() < 1e-6);
        assert!((cb.values[14] + 0.7).abs() < 1e-6);
        assert_eq!(cb.valid[15], 0.0);
    }

    #[test]
    fn ternary_is_three_centroids() {
        let cb = Codebook::symmetric(2, 0.5);
        assert_eq!(cb.n_valid(), 3);
        assert_eq!(cb.values[1], 0.5);
        assert_eq!(cb.values[2], -0.5);
        assert_eq!(cb.valid[3], 0.0);
    }

    #[test]
    fn fit_spans_range() {
        let w = [-0.7f32, 0.2, 0.69];
        let cb = Codebook::fit(&w, 4);
        // max|w| = 0.7, half-levels = 7 -> step = 0.1
        assert!((cb.step - 0.1).abs() < 1e-6);
        let top = cb.values.iter().cloned().fold(0.0f32, f32::max);
        assert!((top - 0.7).abs() < 1e-6);
    }

    #[test]
    fn fit_handles_zeros() {
        let cb = Codebook::fit(&[0.0, 0.0], 3);
        assert_eq!(cb.step, 1.0);
    }

    #[test]
    fn slot_level_roundtrip() {
        for slot in 0..31 {
            let lvl = Codebook::slot_to_level(slot);
            assert_eq!(Codebook::level_to_slot(lvl), slot);
        }
        assert_eq!(Codebook::slot_to_level(1), 1);
        assert_eq!(Codebook::slot_to_level(2), -1);
        assert_eq!(Codebook::slot_to_level(13), 7);
        assert_eq!(Codebook::slot_to_level(14), -7);
    }

    #[test]
    fn level_values_match_slots() {
        let cb = Codebook::symmetric(5, 0.2);
        for slot in 0..cb.n_valid() {
            let lvl = Codebook::slot_to_level(slot);
            assert!((cb.level_value(lvl) - cb.values[slot]).abs() < 1e-6);
        }
    }
}
