//! Metrics + report formatting: accuracy meters, run records, and the
//! markdown/CSV tables that regenerate the paper's figures.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::jsonx::{self, Val};

/// Streaming accuracy/loss meter over batches.
#[derive(Default, Clone, Debug)]
pub struct Meter {
    pub loss_sum: f64,
    pub correct: f64,
    pub samples: u64,
    pub batches: u64,
}

impl Meter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, loss: f32, correct: f32, batch: usize) {
        self.loss_sum += loss as f64 * batch as f64;
        self.correct += correct as f64;
        self.samples += batch as u64;
        self.batches += 1;
    }

    pub fn loss(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.loss_sum / self.samples as f64
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.correct / self.samples as f64
        }
    }
}

/// One working point of a quantization trial (a dot in Figs. 6-10).
#[derive(Clone, Debug)]
pub struct WorkingPoint {
    pub method: String,
    pub bits: u32,
    pub lambda: f32,
    pub p: f64,
    pub accuracy: f64,
    pub acc_drop: f64,
    pub sparsity: f64,
    pub size_bytes: usize,
    pub compression_ratio: f64,
}

impl WorkingPoint {
    pub fn csv_header() -> &'static str {
        "method,bits,lambda,p,accuracy,acc_drop,sparsity,size_kb,cr"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{:.5},{:.3},{:.4},{:+.4},{:.4},{:.2},{:.2}",
            self.method,
            self.bits,
            self.lambda,
            self.p,
            self.accuracy,
            self.acc_drop,
            self.sparsity,
            self.size_bytes as f64 / 1000.0,
            self.compression_ratio
        )
    }

    /// JSON field fragment (`"method":...,"cr":...`, no braces) for the
    /// durable results store. Floats use exact round-trip formatting
    /// ([`jsonx::num_f32`]/[`jsonx::num_f64`]), so a row re-read from disk
    /// reconstructs this working point bit for bit — the property the
    /// resume/shard bitwise-identity gate rests on. The inverse is
    /// [`WorkingPoint::from_json`].
    pub fn json_fields(&self) -> String {
        format!(
            "\"method\":{},\"bits\":{},\"lambda\":{},\"p\":{},\"accuracy\":{},\
             \"acc_drop\":{},\"sparsity\":{},\"size_bytes\":{},\"cr\":{}",
            jsonx::quote(&self.method),
            self.bits,
            jsonx::num_f32(self.lambda),
            jsonx::num_f64(self.p),
            jsonx::num_f64(self.accuracy),
            jsonx::num_f64(self.acc_drop),
            jsonx::num_f64(self.sparsity),
            self.size_bytes,
            jsonx::num_f64(self.compression_ratio)
        )
    }

    /// Rebuild a working point from a parsed store row (exact inverse of
    /// [`WorkingPoint::json_fields`]); missing or non-numeric fields are
    /// an error, never a default.
    pub fn from_json(obj: &BTreeMap<String, Val>) -> Result<WorkingPoint> {
        fn req<'a>(obj: &'a BTreeMap<String, Val>, k: &str) -> Result<&'a Val> {
            obj.get(k).ok_or_else(|| anyhow!("missing field {k:?}"))
        }
        fn num<T: std::str::FromStr>(obj: &BTreeMap<String, Val>, k: &str) -> Result<T> {
            req(obj, k)?
                .num()
                .ok_or_else(|| anyhow!("field {k:?} is not a valid number"))
        }
        Ok(WorkingPoint {
            method: req(obj, "method")?
                .as_str()
                .ok_or_else(|| anyhow!("field \"method\" must be a string"))?
                .to_string(),
            bits: num(obj, "bits")?,
            lambda: num(obj, "lambda")?,
            p: num(obj, "p")?,
            accuracy: num(obj, "accuracy")?,
            acc_drop: num(obj, "acc_drop")?,
            sparsity: num(obj, "sparsity")?,
            size_bytes: num(obj, "size_bytes")?,
            compression_ratio: num(obj, "cr")?,
        })
    }
}

/// Fixed-width table writer for terminal reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[c]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_averages() {
        let mut m = Meter::new();
        m.update(2.0, 10.0, 32);
        m.update(1.0, 20.0, 32);
        assert!((m.loss() - 1.5).abs() < 1e-9);
        assert!((m.accuracy() - 30.0 / 64.0).abs() < 1e-9);
        assert_eq!(m.batches, 2);
        assert_eq!(Meter::new().accuracy(), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("| name"));
        assert!(s.contains("| longer | 2.5"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn working_point_csv() {
        let wp = WorkingPoint {
            method: "ecqx".into(),
            bits: 4,
            lambda: 0.02,
            p: 0.3,
            accuracy: 0.9,
            acc_drop: -0.01,
            sparsity: 0.8,
            size_bytes: 100_000,
            compression_ratio: 25.0,
        };
        let csv = wp.to_csv();
        assert!(csv.starts_with("ecqx,4,"));
        assert!(csv.contains("100.00"));
        assert_eq!(
            WorkingPoint::csv_header().split(',').count(),
            csv.split(',').count()
        );
    }

    #[test]
    fn working_point_json_roundtrips_bitwise() {
        let wp = WorkingPoint {
            method: "ECQx".into(),
            bits: 4,
            lambda: 0.02,
            p: 0.3,
            accuracy: 1.0 / 3.0,
            acc_drop: -1e-7,
            sparsity: 0.876543219,
            size_bytes: 123_456,
            compression_ratio: 25.000001,
        };
        let line = format!("{{{}}}", wp.json_fields());
        let obj = jsonx::parse_object(&line).unwrap();
        let back = WorkingPoint::from_json(&obj).unwrap();
        assert_eq!(back.method, wp.method);
        assert_eq!(back.bits, wp.bits);
        assert_eq!(back.lambda.to_bits(), wp.lambda.to_bits());
        assert_eq!(back.p.to_bits(), wp.p.to_bits());
        assert_eq!(back.accuracy.to_bits(), wp.accuracy.to_bits());
        assert_eq!(back.acc_drop.to_bits(), wp.acc_drop.to_bits());
        assert_eq!(back.sparsity.to_bits(), wp.sparsity.to_bits());
        assert_eq!(back.size_bytes, wp.size_bytes);
        assert_eq!(
            back.compression_ratio.to_bits(),
            wp.compression_ratio.to_bits()
        );
        // and serialization itself is deterministic
        assert_eq!(back.json_fields(), wp.json_fields());
    }

    #[test]
    fn working_point_json_rejects_missing_fields() {
        let obj = jsonx::parse_object("{\"method\":\"ECQx\",\"bits\":4}").unwrap();
        let err = WorkingPoint::from_json(&obj).unwrap_err();
        assert!(format!("{err:?}").contains("lambda"), "{err:?}");
        let obj = jsonx::parse_object("{\"method\":7}").unwrap();
        assert!(WorkingPoint::from_json(&obj).is_err());
    }
}
