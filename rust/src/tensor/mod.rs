//! Minimal row-major tensor types used by the coordinator.
//!
//! These are host-side containers for weights, batches and relevances; all
//! heavy math runs inside the PJRT artifacts. Conversions to/from
//! `xla::Literal` live in [`crate::runtime`].

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn as_scalar(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "not a scalar: {:?}", self.shape);
        self.data[0]
    }

    /// Fraction of exactly-zero elements (the paper's sparsity measure).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.data.len() as f64
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }
}

/// Row-major i32 tensor (centroid assignment indices, labels).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        TensorI32 { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// A value passing through the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(TensorI32),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    /// Element count, regardless of dtype.
    pub fn numel(&self) -> usize {
        match self {
            Value::F32(t) => t.numel(),
            Value::I32(t) => t.numel(),
        }
    }

    pub fn as_f32(&self) -> &Tensor {
        match self {
            Value::F32(t) => t,
            Value::I32(_) => panic!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> &TensorI32 {
        match self {
            Value::I32(t) => t,
            Value::F32(_) => panic!("expected i32 tensor, got f32"),
        }
    }

    pub fn into_f32(self) -> Tensor {
        match self {
            Value::F32(t) => t,
            Value::I32(_) => panic!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_i32(self) -> TensorI32 {
        match self {
            Value::I32(t) => t,
            Value::F32(_) => panic!("expected i32 tensor, got f32"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_sparsity() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0]);
        assert_eq!(t.numel(), 6);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(t.abs_max(), 3.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn scalar_roundtrip() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.as_scalar(), 3.5);
        assert_eq!(s.numel(), 1);
    }

    #[test]
    fn value_accessors() {
        let v = Value::F32(Tensor::zeros(&[2]));
        assert_eq!(v.shape(), &[2]);
        assert_eq!(v.as_f32().numel(), 2);
        assert_eq!(v.numel(), 2);
        let vi = Value::I32(TensorI32::zeros(&[3]));
        assert_eq!(vi.as_i32().numel(), 3);
        assert_eq!(vi.numel(), 3);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).reshape(vec![2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.data[3], 4.0);
    }
}
