//! Synthetic datasets standing in for Google Speech Commands, CIFAR-10 and
//! Pascal VOC (repro substitution — see DESIGN.md §3).
//!
//! Each dataset is a deterministic, lazily-generated class-conditional
//! generator: sample `i` is fully determined by `(dataset seed, i)`, so
//! train/val splits are reproducible across processes and experiments.

pub mod gsc;
pub mod images;
pub mod loader;

pub use loader::{Batch, DataLoader};

/// A labelled classification dataset producing flat f32 feature vectors.
pub trait Dataset: Sync {
    /// Number of samples.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Feature dimensionality (flattened).
    fn dim(&self) -> usize;
    /// Number of classes.
    fn classes(&self) -> usize;
    /// Write sample `i`'s features into `out` (len == dim()); return label.
    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32;
}

#[cfg(test)]
mod tests {
    use super::gsc::GscDataset;
    use super::images::{CifarDataset, VocDataset};
    use super::*;

    fn class_balance<D: Dataset>(ds: &D) -> Vec<usize> {
        let mut counts = vec![0usize; ds.classes()];
        let mut buf = vec![0.0; ds.dim()];
        for i in 0..ds.len() {
            let y = ds.sample_into(i, &mut buf);
            counts[y as usize] += 1;
        }
        counts
    }

    #[test]
    fn datasets_deterministic() {
        let a = GscDataset::new(64, 7, true);
        let b = GscDataset::new(64, 7, true);
        let mut xa = vec![0.0; a.dim()];
        let mut xb = vec![0.0; b.dim()];
        for i in 0..8 {
            let ya = a.sample_into(i, &mut xa);
            let yb = b.sample_into(i, &mut xb);
            assert_eq!(ya, yb);
            assert_eq!(xa, xb);
        }
    }

    #[test]
    fn gsc_roughly_balanced() {
        let ds = GscDataset::new(600, 1, true);
        let counts = class_balance(&ds);
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 20, "class {c} has only {n} samples");
        }
    }

    #[test]
    fn cifar_and_voc_shapes() {
        let c = CifarDataset::new(128, 2, true);
        assert_eq!(c.dim(), 32 * 32 * 3);
        assert_eq!(c.classes(), 10);
        let v = VocDataset::new(128, 3, true);
        assert_eq!(v.dim(), 32 * 32 * 3);
        assert_eq!(v.classes(), 20);
        let counts = class_balance(&v);
        assert!(counts.iter().all(|&n| n > 0));
    }

    #[test]
    fn train_val_differ() {
        let tr = GscDataset::new(32, 1, true);
        let va = GscDataset::new(32, 1, false);
        let mut xt = vec![0.0; tr.dim()];
        let mut xv = vec![0.0; va.dim()];
        tr.sample_into(0, &mut xt);
        va.sample_into(0, &mut xv);
        assert_ne!(xt, xv);
    }
}
