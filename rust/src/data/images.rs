//! Synthetic image datasets: CIFAR-10-like (10 classes) and Pascal-VOC-like
//! (20 classes), 32x32x3 NHWC, class-conditional textures with the paper's
//! augmentation structure (normalization, random horizontal flip, jitter).
//!
//! Layout contract: each sample is flattened HWC — element `(y, x, ch)`
//! lives at [`hwc_index`]`(y, x, ch)` — so a `[batch, DIM]` batch from the
//! loader is byte-identical to the `[batch, H, W, C]` NHWC tensor the CNN
//! manifests declare for their `x` slot. The host conv pipeline relies on
//! this: batches bind to 4D conv inputs without any transpose.

use super::Dataset;
use crate::util::Rng;

pub const H: usize = 32;
pub const W: usize = 32;
pub const C: usize = 3;
pub const DIM: usize = H * W * C;
/// The NHWC per-sample shape `[H, W, C]` the CNN manifests declare.
pub const SHAPE: [usize; 3] = [H, W, C];

/// Flat offset of pixel `(y, x)` channel `ch` in a sample — the single
/// definition of the HWC flattening both this module's generators and the
/// conv manifests assume.
#[inline]
pub fn hwc_index(y: usize, x: usize, ch: usize) -> usize {
    (y * W + x) * C + ch
}

/// Class texture: oriented sinusoidal gratings + a colour bias + a
/// class-dependent blob position. Distinct enough to be learnable,
/// overlapping enough (shared orientations) to be non-trivial.
fn texture(class: usize, tag: u64, px: &mut [f32]) {
    let mut crng = Rng::new(tag ^ (class as u64).wrapping_mul(0xA24B_AED4_963E_E407));
    let ncomp = 3;
    let mut comps = Vec::with_capacity(ncomp);
    for k in 0..ncomp {
        // orientation shared between neighbouring classes for overlap
        let share = if k == 0 { class / 2 } else { class };
        let mut srng = Rng::new(tag ^ (share as u64 * 31337 + k as u64 * 271));
        let th = srng.range(0.0, std::f32::consts::PI);
        let freq = 1.0 + 4.0 * srng.f32();
        let phase = srng.range(0.0, std::f32::consts::TAU);
        comps.push((th.cos() * freq, th.sin() * freq, phase, 0.4 + 0.5 * srng.f32()));
    }
    let cb = [crng.f32(), crng.f32(), crng.f32()];
    let (bx, by) = (crng.range(8.0, 24.0), crng.range(8.0, 24.0));
    for y in 0..H {
        for x in 0..W {
            let mut v = 0.0f32;
            for &(fx, fy, ph, amp) in &comps {
                v += amp
                    * ((fx * x as f32 / W as f32 + fy * y as f32 / H as f32)
                        * std::f32::consts::TAU
                        + ph)
                        .sin();
            }
            let d2 = ((x as f32 - bx).powi(2) + (y as f32 - by).powi(2)) / 40.0;
            let blob = (-d2).exp();
            for ch in 0..C {
                px[hwc_index(y, x, ch)] = v * (0.5 + cb[ch]) + blob * (cb[ch] - 0.5) * 2.0;
            }
        }
    }
}

fn hflip(px: &mut [f32]) {
    for y in 0..H {
        for x in 0..W / 2 {
            for ch in 0..C {
                px.swap(hwc_index(y, x, ch), hwc_index(y, W - 1 - x, ch));
            }
        }
    }
}

/// Translate by (dx, dy) with zero fill (the random-crop stand-in).
fn jitter(px: &mut [f32], dx: isize, dy: isize) {
    if dx == 0 && dy == 0 {
        return;
    }
    let mut tmp = vec![0.0f32; DIM];
    for y in 0..H as isize {
        for x in 0..W as isize {
            let (sx, sy) = (x - dx, y - dy);
            if sx >= 0 && sx < W as isize && sy >= 0 && sy < H as isize {
                for ch in 0..C {
                    tmp[hwc_index(y as usize, x as usize, ch)] =
                        px[hwc_index(sy as usize, sx as usize, ch)];
                }
            }
        }
    }
    px.copy_from_slice(&tmp);
}

/// Shared generator for both image datasets.
struct ImageGen {
    n: usize,
    seed: u64,
    classes: usize,
    augment: bool,
}

impl ImageGen {
    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        assert_eq!(out.len(), DIM);
        let mut rng =
            Rng::new(self.seed ^ (i as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93));
        let class = rng.below(self.classes);
        texture(class, self.seed & !1, out);
        // intra-class variability: blend in another class's texture with a
        // per-sample coefficient — samples near m = 0.5 are intrinsically
        // ambiguous, bounding achievable accuracy like real image clutter
        {
            let other = (class + 1 + rng.below(self.classes - 1)) % self.classes;
            let m = 0.5 * rng.f32();
            let mut mix = vec![0.0f32; DIM];
            texture(other, self.seed & !1, &mut mix);
            for (o, x) in out.iter_mut().zip(mix.iter()) {
                *o = (1.0 - m) * *o + m * x;
            }
        }
        if self.augment {
            if rng.chance(0.5) {
                hflip(out);
            }
            let dx = rng.below(9) as isize - 4;
            let dy = rng.below(9) as isize - 4;
            jitter(out, dx, dy);
            let noise = 0.05 + 0.15 * rng.f32();
            for v in out.iter_mut() {
                *v += rng.normal_f32(0.0, noise);
            }
        } else {
            for v in out.iter_mut() {
                *v += rng.normal_f32(0.0, 0.05);
            }
        }
        // per-sample normalization (the paper normalizes inputs)
        let mean: f32 = out.iter().sum::<f32>() / DIM as f32;
        let var: f32 =
            out.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / DIM as f32;
        let std = var.sqrt().max(1e-4);
        out.iter_mut().for_each(|v| *v = (*v - mean) / std);
        class as i32
    }
}

/// CIFAR-10-like: 10 classes, 32x32x3.
pub struct CifarDataset(ImageGen);

impl CifarDataset {
    pub fn new(n: usize, seed: u64, train: bool) -> Self {
        let seed = seed.wrapping_mul(2) + if train { 0 } else { 1 };
        CifarDataset(ImageGen { n, seed, classes: 10, augment: train })
    }
}

impl Dataset for CifarDataset {
    fn len(&self) -> usize {
        self.0.n
    }
    fn dim(&self) -> usize {
        DIM
    }
    fn classes(&self) -> usize {
        10
    }
    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        self.0.sample_into(i, out)
    }
}

/// Pascal-VOC-like: 20 classes, 32x32x3 (scaled substitution; see DESIGN.md).
pub struct VocDataset(ImageGen);

impl VocDataset {
    pub fn new(n: usize, seed: u64, train: bool) -> Self {
        let seed = seed.wrapping_mul(2) + if train { 0 } else { 1 };
        // distinct texture space from CIFAR via the high seed bit
        VocDataset(ImageGen {
            n,
            seed: seed ^ 0x8000_0000_0000_0000,
            classes: 20,
            augment: train,
        })
    }
}

impl Dataset for VocDataset {
    fn len(&self) -> usize {
        self.0.n
    }
    fn dim(&self) -> usize {
        DIM
    }
    fn classes(&self) -> usize {
        20
    }
    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        self.0.sample_into(i, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_output() {
        let ds = CifarDataset::new(16, 5, true);
        let mut buf = vec![0.0; DIM];
        ds.sample_into(3, &mut buf);
        let mean: f32 = buf.iter().sum::<f32>() / DIM as f32;
        assert!(mean.abs() < 1e-3, "mean {mean}");
    }

    #[test]
    fn hflip_involution() {
        let ds = VocDataset::new(4, 1, false);
        let mut a = vec![0.0; DIM];
        ds.sample_into(0, &mut a);
        let mut b = a.clone();
        hflip(&mut b);
        assert_ne!(a, b);
        hflip(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn jitter_translates() {
        let mut px = vec![0.0f32; DIM];
        px[(5 * W + 5) * C] = 1.0;
        jitter(&mut px, 2, 3);
        assert_eq!(px[(8 * W + 7) * C], 1.0);
    }

    #[test]
    fn flattening_is_nhwc() {
        // the flat sample layout must match the [H, W, C] row-major
        // interpretation the CNN manifests declare for the x slot
        assert_eq!(SHAPE.iter().product::<usize>(), DIM);
        assert_eq!(hwc_index(0, 0, 0), 0);
        assert_eq!(hwc_index(0, 0, C - 1), C - 1); // channels innermost
        assert_eq!(hwc_index(0, 1, 0), C); // then columns
        assert_eq!(hwc_index(1, 0, 0), W * C); // then rows
        assert_eq!(hwc_index(H - 1, W - 1, C - 1), DIM - 1);
    }

    #[test]
    fn textures_differ_between_classes() {
        let mut a = vec![0.0; DIM];
        let mut b = vec![0.0; DIM];
        texture(1, 7, &mut a);
        texture(8, 7, &mut b);
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        assert!(d > 1.0);
    }
}
