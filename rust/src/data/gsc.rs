//! Synthetic Google Speech Commands: MFCC-like keyword fingerprints.
//!
//! Mirrors the structure of the real task (Sec. 5.1.1): 12 classes = 10
//! "keywords" + "unknown" (a mixture of off-vocabulary prototypes) +
//! "silence" (pure noise). Features are 24 frames x 15 MFCC bins = 360
//! dims. Augmentation mirrors the paper's pipeline: background noise with
//! p = 0.8 and a time shift with p = 0.5.

use super::Dataset;
use crate::util::Rng;

pub const FRAMES: usize = 24;
pub const BINS: usize = 15;
pub const DIM: usize = FRAMES * BINS;
pub const CLASSES: usize = 12;
const UNKNOWN: usize = 10;
const SILENCE: usize = 11;
/// number of hidden off-vocabulary prototypes feeding "unknown"
const OFF_VOCAB: usize = 6;

/// Deterministic per-class spectral prototype: a sum of smooth
/// time-frequency components whose frequencies/phases derive from the
/// class id. Neighbouring classes share one component, which induces the
/// class overlap that makes magnitude and relevance decorrelate (Fig. 4).
fn prototype(class: usize, seed: u64, out: &mut [f32]) {
    let mut rng = Rng::new(seed ^ (0xC1A5_5000 + class as u64));
    let ncomp = 3;
    out.iter_mut().for_each(|v| *v = 0.0);
    for comp in 0..ncomp {
        // shared component between class c and c+1: derive from min id
        let share = if comp == 0 { class.min(class + 1) } else { class };
        let mut crng = Rng::new(seed ^ (share as u64 * 7919 + comp as u64 * 104729));
        let ft = 0.5 + 2.5 * crng.f32(); // temporal frequency
        let fb = 0.5 + 3.0 * crng.f32(); // spectral frequency
        let pt = crng.range(0.0, std::f32::consts::TAU);
        let pb = crng.range(0.0, std::f32::consts::TAU);
        let amp = 0.5 + 0.8 * crng.f32();
        // spectral localization: each formant-like component lives in a
        // narrow band (real keywords occupy localized time-frequency
        // regions, leaving many MFCC bins uninformative — the structure
        // the LRP relevances exploit)
        let bc = crng.range(1.0, BINS as f32 - 1.0); // band centre
        let bw = 1.2 + 2.3 * crng.f32(); // band width
        let _ = rng.f32();
        for t in 0..FRAMES {
            for b in 0..BINS {
                let vt = (ft * t as f32 / FRAMES as f32 * std::f32::consts::TAU + pt).sin();
                let vb = (fb * b as f32 / BINS as f32 * std::f32::consts::TAU + pb).cos();
                let band = (-((b as f32 - bc) / bw).powi(2)).exp();
                out[t * BINS + b] += amp * vt * vb * band;
            }
        }
    }
    // temporal envelope: keywords are short events centred in the window
    for t in 0..FRAMES {
        let x = (t as f32 - FRAMES as f32 / 2.0) / (FRAMES as f32 / 3.0);
        let env = (-x * x).exp();
        for b in 0..BINS {
            out[t * BINS + b] *= env;
        }
    }
}

pub struct GscDataset {
    n: usize,
    seed: u64,
    /// training split applies augmentation; validation is clean
    augment: bool,
}

impl GscDataset {
    pub fn new(n: usize, seed: u64, train: bool) -> Self {
        // train/val draw from disjoint seed spaces
        let seed = seed.wrapping_mul(2) + if train { 0 } else { 1 };
        GscDataset { n, seed, augment: train }
    }
}

impl Dataset for GscDataset {
    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        DIM
    }

    fn classes(&self) -> usize {
        CLASSES
    }

    fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        assert_eq!(out.len(), DIM);
        let mut rng = Rng::new(self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let class = rng.below(CLASSES);
        match class {
            SILENCE => out.iter_mut().for_each(|v| *v = 0.0),
            UNKNOWN => {
                // an off-vocabulary word: one of the hidden prototypes
                let hidden = CLASSES + rng.below(OFF_VOCAB);
                prototype(hidden, self.seed & !1, out);
            }
            c => prototype(c, self.seed & !1, out),
        }
        // pronunciation variability: blend in a confusable word's
        // prototype with a per-sample coefficient (samples near m = 0.5
        // are intrinsically ambiguous, bounding achievable accuracy like
        // real speaker variation does)
        if class != SILENCE {
            let other = (class + 1 + rng.below(CLASSES + OFF_VOCAB - 1))
                % (CLASSES + OFF_VOCAB);
            let m = 0.5 * rng.f32();
            let mut mix = vec![0.0f32; DIM];
            prototype(other, self.seed & !1, &mut mix);
            for (o, x) in out.iter_mut().zip(mix.iter()) {
                *o = (1.0 - m) * *o + m * x;
            }
        }
        // speaker gain variation (wide: quiet speakers are hard)
        let gain = 0.35 + 0.9 * rng.f32();
        out.iter_mut().for_each(|v| *v *= gain);
        if self.augment {
            // time shift +-3 frames with p = 0.5 (paper: +-100 ms, p = 0.5)
            if rng.chance(0.5) {
                let shift = rng.below(7) as isize - 3;
                time_shift(out, shift);
            }
            // background noise with p = 0.8
            if rng.chance(0.8) {
                let snr = 0.25 + 0.45 * rng.f32();
                for v in out.iter_mut() {
                    *v += rng.normal_f32(0.0, snr);
                }
            }
        } else {
            // validation: moderate noise + occasional time shift, so the
            // split is not easier than deployment conditions
            if rng.chance(0.5) {
                let shift = rng.below(7) as isize - 3;
                time_shift(out, shift);
            }
            for v in out.iter_mut() {
                *v += rng.normal_f32(0.0, 0.35);
            }
        }
        class as i32
    }
}

fn time_shift(x: &mut [f32], shift: isize) {
    if shift == 0 {
        return;
    }
    let mut tmp = vec![0.0f32; DIM];
    for t in 0..FRAMES {
        let src = t as isize - shift;
        if src >= 0 && (src as usize) < FRAMES {
            let s = src as usize;
            tmp[t * BINS..(t + 1) * BINS].copy_from_slice(&x[s * BINS..(s + 1) * BINS]);
        }
    }
    x.copy_from_slice(&tmp);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silence_has_less_energy_than_speech() {
        let ds = GscDataset::new(2000, 9, false);
        let mut buf = vec![0.0; DIM];
        let mut sil = (0.0f64, 0u32);
        let mut spk = (0.0f64, 0u32);
        for i in 0..300 {
            let y = ds.sample_into(i, &mut buf);
            let energy: f64 =
                buf.iter().map(|v| (v * v) as f64).sum::<f64>() / DIM as f64;
            if y as usize == SILENCE {
                sil = (sil.0 + energy, sil.1 + 1);
            } else {
                spk = (spk.0 + energy, spk.1 + 1);
            }
        }
        assert!(sil.1 > 0, "no silence sample in 300 draws");
        let sil_e = sil.0 / sil.1 as f64;
        let spk_e = spk.0 / spk.1 as f64;
        // silence = noise only; speech = (band-localized) prototype + noise,
        // so speech carries measurably more energy on average
        assert!(
            sil_e < spk_e * 0.95,
            "silence energy {sil_e} not below speech energy {spk_e}"
        );
    }

    #[test]
    fn classes_distinguishable() {
        // prototypes of different classes must differ substantially
        let mut a = vec![0.0; DIM];
        let mut b = vec![0.0; DIM];
        prototype(0, 42, &mut a);
        prototype(5, 42, &mut b);
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        assert!(d > 1.0, "prototypes too similar: {d}");
    }

    #[test]
    fn time_shift_moves_frames() {
        let mut x = vec![0.0f32; DIM];
        x[0] = 1.0; // frame 0, bin 0
        time_shift(&mut x, 2);
        assert_eq!(x[2 * BINS], 1.0);
        assert_eq!(x[0], 0.0);
    }
}
