//! Batching iterator over a [`Dataset`]: seeded shuffling per epoch,
//! fixed batch size (HLO artifacts have static shapes, so the dataset
//! sizes are chosen as batch multiples; a partial tail is dropped).

use super::Dataset;
use crate::util::Rng;

/// One batch: features flattened row-major [batch, dim], labels i32.
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
}

pub struct DataLoader<'a, D: Dataset> {
    ds: &'a D,
    batch: usize,
    shuffle: bool,
    seed: u64,
}

impl<'a, D: Dataset> DataLoader<'a, D> {
    pub fn new(ds: &'a D, batch: usize, shuffle: bool, seed: u64) -> Self {
        assert!(batch > 0 && ds.len() >= batch);
        DataLoader { ds, batch, shuffle, seed }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len() / self.batch
    }

    /// Iterate one epoch's batches.
    pub fn epoch(&self, epoch_idx: u64) -> EpochIter<'a, '_, D> {
        let mut order: Vec<usize> = (0..self.ds.len()).collect();
        if self.shuffle {
            let mut rng = Rng::new(self.seed ^ epoch_idx.wrapping_mul(0x2545_F491_4F6C_DD1D));
            rng.shuffle(&mut order);
        }
        EpochIter { loader: self, order, pos: 0 }
    }
}

pub struct EpochIter<'a, 'l, D: Dataset> {
    loader: &'l DataLoader<'a, D>,
    order: Vec<usize>,
    pos: usize,
}

impl<'a, 'l, D: Dataset> Iterator for EpochIter<'a, 'l, D> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let b = self.loader.batch;
        if self.pos + b > self.order.len() {
            return None;
        }
        let dim = self.loader.ds.dim();
        let mut x = vec![0.0f32; b * dim];
        let mut y = vec![0i32; b];
        for j in 0..b {
            let idx = self.order[self.pos + j];
            y[j] = self.loader.ds.sample_into(idx, &mut x[j * dim..(j + 1) * dim]);
        }
        self.pos += b;
        Some(Batch { x, y, batch: b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gsc::GscDataset;

    #[test]
    fn epoch_covers_dataset() {
        let ds = GscDataset::new(64, 1, true);
        let dl = DataLoader::new(&ds, 16, true, 0);
        assert_eq!(dl.batches_per_epoch(), 4);
        let n: usize = dl.epoch(0).map(|b| b.batch).sum();
        assert_eq!(n, 64);
    }

    #[test]
    fn shuffle_differs_across_epochs() {
        let ds = GscDataset::new(128, 1, true);
        let dl = DataLoader::new(&ds, 64, true, 0);
        let e0: Vec<i32> = dl.epoch(0).flat_map(|b| b.y).collect();
        let e1: Vec<i32> = dl.epoch(1).flat_map(|b| b.y).collect();
        assert_ne!(e0, e1);
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort();
        s1.sort();
        assert_eq!(s0, s1, "same multiset of labels");
    }

    #[test]
    fn no_shuffle_is_sequential_and_stable() {
        let ds = GscDataset::new(32, 1, false);
        let dl = DataLoader::new(&ds, 8, false, 0);
        let a: Vec<i32> = dl.epoch(0).flat_map(|b| b.y).collect();
        let b: Vec<i32> = dl.epoch(5).flat_map(|b| b.y).collect();
        assert_eq!(a, b);
    }
}
