//! Crash-safe filesystem writes: every durable artifact (results store,
//! `.ecqx` containers, FP baselines, CSV exports) goes through
//! tmp-file + atomic-rename, so an interrupted process never leaves a
//! truncated file at the destination path — a reader sees either the old
//! complete contents or the new complete contents, nothing in between.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Sibling temp path for `path`: same directory (rename must not cross a
/// filesystem boundary), suffixed with the pid so concurrent processes
/// writing the same destination don't stomp each other's temp file.
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(format!(".{}.tmp", std::process::id()));
    PathBuf::from(os)
}

/// Stream contents to `path` atomically: write to a sibling temp file,
/// flush + fsync, then rename over the destination. On any error the
/// temp file is removed and the destination is left untouched.
pub fn atomic_write_with<F>(path: &Path, write: F) -> Result<()>
where
    F: FnOnce(&mut std::io::BufWriter<std::fs::File>) -> Result<()>,
{
    let tmp = tmp_sibling(path);
    let result = (|| {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("create temp file {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(file);
        write(&mut w)?;
        w.flush()?;
        // fsync so a post-rename power loss cannot surface an empty file
        // where a complete one was promised (kill -9 alone would not need
        // this, but the store's durability claim includes the page cache)
        w.get_ref().sync_all()?;
        Ok(())
    })();
    match result {
        Ok(()) => std::fs::rename(&tmp, path).with_context(|| {
            format!("rename {} -> {}", tmp.display(), path.display())
        }),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

/// [`atomic_write_with`] for a ready-made byte buffer.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    atomic_write_with(path, |w| {
        w.write_all(bytes)?;
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ecqx-fsx-{}-{name}", std::process::id()))
    }

    #[test]
    fn writes_and_replaces() {
        let p = tmp("basic.txt");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer contents");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn failed_write_leaves_destination_and_no_temp() {
        let p = tmp("failed.txt");
        atomic_write(&p, b"intact").unwrap();
        let err = atomic_write_with(&p, |w| {
            w.write_all(b"partial")?;
            anyhow::bail!("mid-write failure")
        });
        assert!(err.is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"intact", "destination untouched");
        assert!(!tmp_sibling(&p).exists(), "temp file cleaned up");
        std::fs::remove_file(&p).ok();
    }
}
