//! Minimal property-testing driver (offline replacement for proptest).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases` seeded
//! random inputs; on failure it reports the failing seed so the case can
//! be replayed deterministically with `replay(seed, ...)`.

use super::rng::Rng;

/// Run `f` against `cases` independent seeded RNGs; panic with the failing
/// seed on the first reported failure (f returns Err(msg) to fail).
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    cases: u64,
    mut f: F,
) {
    for case in 0..cases {
        let seed = 0xECC5_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single failing seed.
pub fn replay<F: FnMut(&mut Rng) -> Result<(), String>>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay of seed {seed} failed: {msg}");
    }
}

/// Random vector of standard-normal f32 scaled by `std`.
pub fn normal_vec(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("len mismatch {} vs {}", a.len(), b.len()));
    }
    for i in 0..a.len() {
        let d = (a[i] - b[i]).abs();
        let scale = 1.0f32.max(a[i].abs()).max(b[i].abs());
        if d > tol * scale {
            return Err(format!(
                "elem {i}: {} vs {} (|d|={d}, tol={tol})",
                a[i], b[i]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes() {
        check("trivial", 10, |rng| {
            let x = rng.f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_seed() {
        check("fails", 5, |_| Err("always".into()));
    }

    #[test]
    fn close_tolerance() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
