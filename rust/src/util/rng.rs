//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Every stochastic component (datasets, init, shuffling, property tests)
//! derives from explicit seeds, so all experiments are reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. per-layer, per-trial).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free (bias negligible for our n)
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
