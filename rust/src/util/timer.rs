//! Wall-clock timing + per-phase accumulation (profiling the QAT loop).

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates wall-clock per named phase (step / lrp / assign / eval ...),
/// the profile that backs the §5.2.2 overhead experiment and §Perf.
#[derive(Default, Clone)]
pub struct PhaseProfile {
    totals: BTreeMap<String, (f64, u64)>,
}

impl PhaseProfile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, phase: &str, seconds: f64) {
        let e = self.totals.entry(phase.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    pub fn time<R>(&mut self, phase: &str, f: impl FnOnce() -> R) -> R {
        let t = Timer::start();
        let r = f();
        self.record(phase, t.elapsed_s());
        r
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.totals.get(phase).map(|e| e.1).unwrap_or(0)
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, f64, u64)> {
        self.totals.iter().map(|(k, (s, c))| (k.as_str(), *s, *c))
    }

    pub fn merge(&mut self, other: &PhaseProfile) {
        for (k, (s, c)) in &other.totals {
            let e = self.totals.entry(k.clone()).or_insert((0.0, 0));
            e.0 += s;
            e.1 += c;
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        let grand: f64 = self.totals.values().map(|e| e.0).sum();
        for (k, (s, c)) in &self.totals {
            out.push_str(&format!(
                "  {k:<12} {s:>9.3}s  n={c:<6} avg={:>8.3}ms  {:>5.1}%\n",
                s / (*c).max(1) as f64 * 1e3,
                if grand > 0.0 { s / grand * 100.0 } else { 0.0 }
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_accumulates() {
        let mut p = PhaseProfile::new();
        p.record("a", 1.0);
        p.record("a", 2.0);
        p.record("b", 0.5);
        assert_eq!(p.total("a"), 3.0);
        assert_eq!(p.count("a"), 2);
        assert_eq!(p.total("b"), 0.5);
        assert_eq!(p.total("missing"), 0.0);
        let mut q = PhaseProfile::new();
        q.record("a", 1.0);
        q.merge(&p);
        assert_eq!(q.total("a"), 4.0);
        assert!(q.report().contains('a'));
    }

    #[test]
    fn time_returns_value() {
        let mut p = PhaseProfile::new();
        let v = p.time("x", || 42);
        assert_eq!(v, 42);
        assert_eq!(p.count("x"), 1);
    }
}
