//! Basic statistics used by analyses and benches.

pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Pearson correlation coefficient (the `c` of Fig. 4).
pub fn pearson(xs: &[f32], ys: &[f32]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] as f64 - mx;
        let dy = ys[i] as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Histogram of `xs` into `bins` equal-width bins over [lo, hi].
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    if w <= 0.0 {
        return h;
    }
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        h[b] += 1;
    }
    h
}

/// First-order entropy (bits/symbol) of a discrete distribution given by
/// counts — Shannon's H, the theoretical coding limit (Sec. 3.1).
pub fn entropy_bits(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c == 0 {
            continue;
        }
        let p = c as f64 / total as f64;
        h -= p * p.log2();
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let xs: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let yn: Vec<f32> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &yn) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let xs = [1.0f32; 5];
        let ys = [0.0f32, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn entropy_uniform() {
        // 4 equally likely symbols -> 2 bits
        assert!((entropy_bits(&[5, 5, 5, 5]) - 2.0).abs() < 1e-12);
        // single symbol -> 0 bits
        assert_eq!(entropy_bits(&[7]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.0f32, 0.49, 0.5, 0.99, 1.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        // [0, 0.5) -> bin 0; [0.5, 1.0] -> bin 1 (hi lands in the last bin)
        assert_eq!(h, vec![2, 3]);
    }
}
