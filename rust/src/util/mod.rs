//! Small shared utilities: PRNG, statistics, property testing, timing,
//! and the scoped-thread worker-pool substrate ([`pool`]).
//!
//! The offline build has no `rand`/`proptest`/`criterion`/`rayon`, so this
//! module provides behaviour-equivalent replacements (see DESIGN.md
//! substitution table).

pub mod fsx;
pub mod jsonx;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;

/// FNV-1a 64-bit hash: the stable, dependency-free digest behind the
/// results store's working-point keys, grid fingerprints, and per-row
/// checksums. Stability across processes and platforms is load-bearing —
/// resume/shard matching compares these values between runs.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Parallel map over a slice using scoped threads (no external deps).
///
/// Thin wrapper over [`pool::par_map_indexed`]; used by the engine's
/// batched-call path to fan independent work across cores. Output order
/// matches input order.
pub fn par_map<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    pool::par_map_indexed(items, threads, |_, t| f(t))
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..100).collect();
        let par = par_map(&items, 8, |x| x * x);
        let ser: Vec<u64> = items.iter().map(|x| x * x).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // pinned reference values: the store's on-disk checksums and keys
        // must never drift between releases
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<u64> = vec![];
        assert!(par_map(&items, 4, |x| *x).is_empty());
    }
}
