//! Small shared utilities: PRNG, statistics, property testing, timing,
//! and the scoped-thread worker-pool substrate ([`pool`]).
//!
//! The offline build has no `rand`/`proptest`/`criterion`/`rayon`, so this
//! module provides behaviour-equivalent replacements (see DESIGN.md
//! substitution table).

pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;

/// Parallel map over a slice using scoped threads (no external deps).
///
/// Thin wrapper over [`pool::par_map_indexed`]; used by the engine's
/// batched-call path to fan independent work across cores. Output order
/// matches input order.
pub fn par_map<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    pool::par_map_indexed(items, threads, |_, t| f(t))
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..100).collect();
        let par = par_map(&items, 8, |x| x * x);
        let ser: Vec<u64> = items.iter().map(|x| x * x).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<u64> = vec![];
        assert!(par_map(&items, 4, |x| *x).is_empty());
    }
}
