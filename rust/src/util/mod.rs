//! Small shared utilities: PRNG, statistics, property testing, timing.
//!
//! The offline build has no `rand`/`proptest`/`criterion`, so this module
//! provides behaviour-equivalent replacements (see DESIGN.md
//! substitution table).

pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;

/// Parallel map over a slice using scoped threads (no external deps).
///
/// Used by the sweep runner to fan independent trials across cores.
pub fn par_map<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<u64> = (0..100).collect();
        let par = par_map(&items, 8, |x| x * x);
        let ser: Vec<u64> = items.iter().map(|x| x * x).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<u64> = vec![];
        assert!(par_map(&items, 4, |x| *x).is_empty());
    }
}
