//! Minimal flat-JSON helpers for the durable results store: exact
//! round-trip number formatting, string escaping, and a parser for
//! single-level objects (string / numeric-token values only).
//!
//! The offline build has no `serde_json`; the store's rows are flat
//! key→scalar objects, so a full JSON tree is deliberately out of scope.
//! Two properties matter here and are tested below:
//!
//! 1. **bitwise float round-trips** — finite `f32`/`f64` are written via
//!    Rust's shortest-round-trip `Display` and parsed back with
//!    `FromStr`, which recovers the exact bit pattern; non-finite values
//!    are written as the quoted tokens `"inf"`/`"-inf"`/`"NaN"`, which
//!    `FromStr` also parses exactly — so store rows never lose precision
//!    and the resume/shard bitwise-identity gate can compare serialized
//!    lines directly;
//! 2. **totality** — `parse_object` returns `Err(String)` on any
//!    malformed input (the store loader maps that to drop-the-torn-tail
//!    or fail-the-file), never panics.

use std::collections::BTreeMap;

/// A parsed scalar: a decoded JSON string or a raw (unquoted) token such
/// as `17`, `-0.5`, `1e-7`, `true`.
#[derive(Clone, Debug, PartialEq)]
pub enum Val {
    /// decoded string value
    Str(String),
    /// raw unquoted token, trimmed
    Raw(String),
}

impl Val {
    /// The string content if this is a JSON string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Val::Str(s) => Some(s),
            Val::Raw(_) => None,
        }
    }

    /// The token to parse scalars from: raw tokens as-is, strings by
    /// content (so `"inf"`/`"NaN"` parse as floats, `"17"` as u64).
    pub fn token(&self) -> &str {
        match self {
            Val::Str(s) => s,
            Val::Raw(r) => r,
        }
    }

    /// Parse the token as `T` (numbers, bools, ...).
    pub fn num<T: std::str::FromStr>(&self) -> Option<T> {
        self.token().parse().ok()
    }
}

/// Escape + quote a string for embedding in a JSON object. Control
/// characters become `\u00XX`, so any `error:` payload stays one line.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format an `f32` as a JSON value with an exact round-trip: shortest
/// `Display` for finite values, quoted `"inf"`/`"-inf"`/`"NaN"` otherwise.
pub fn num_f32(v: f32) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        format!("\"{v}\"")
    }
}

/// [`num_f32`] for `f64`.
pub fn num_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        format!("\"{v}\"")
    }
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<String, String> {
    if b.get(*i) != Some(&b'"') {
        return Err(format!("expected string at byte {i}", i = *i));
    }
    *i += 1;
    let mut out: Vec<u8> = Vec::new();
    loop {
        let c = *b.get(*i).ok_or("unterminated string")?;
        *i += 1;
        match c {
            b'"' => {
                return String::from_utf8(out).map_err(|_| "invalid utf-8 in string".into())
            }
            b'\\' => {
                let e = *b.get(*i).ok_or("unterminated escape")?;
                *i += 1;
                match e {
                    b'"' => out.push(b'"'),
                    b'\\' => out.push(b'\\'),
                    b'/' => out.push(b'/'),
                    b'n' => out.push(b'\n'),
                    b'r' => out.push(b'\r'),
                    b't' => out.push(b'\t'),
                    b'b' => out.push(0x08),
                    b'f' => out.push(0x0c),
                    b'u' => {
                        let hex = b
                            .get(*i..*i + 4)
                            .ok_or("truncated \\u escape")?;
                        *i += 4;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                            16,
                        )
                        .map_err(|_| "bad \\u escape")?;
                        let ch = char::from_u32(code)
                            .ok_or("\\u escape is not a scalar value")?;
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                    }
                    other => return Err(format!("unknown escape \\{}", other as char)),
                }
            }
            c => out.push(c),
        }
    }
}

/// Parse a single flat JSON object (`{"k":"v","n":1,...}`) into an
/// ordered map. Nested objects/arrays are rejected; trailing bytes after
/// the closing brace are an error.
pub fn parse_object(s: &str) -> Result<BTreeMap<String, Val>, String> {
    let b = s.as_bytes();
    let mut i = 0usize;
    skip_ws(b, &mut i);
    if b.get(i) != Some(&b'{') {
        return Err("expected '{'".into());
    }
    i += 1;
    let mut out = BTreeMap::new();
    skip_ws(b, &mut i);
    if b.get(i) == Some(&b'}') {
        i += 1;
    } else {
        loop {
            skip_ws(b, &mut i);
            let key = parse_string(b, &mut i)?;
            skip_ws(b, &mut i);
            if b.get(i) != Some(&b':') {
                return Err(format!("expected ':' after key {key:?}"));
            }
            i += 1;
            skip_ws(b, &mut i);
            let val = match b.get(i) {
                Some(b'"') => Val::Str(parse_string(b, &mut i)?),
                Some(b'{') | Some(b'[') => {
                    return Err("nested values are not supported".into())
                }
                Some(_) => {
                    let start = i;
                    while i < b.len() && !matches!(b[i], b',' | b'}') {
                        i += 1;
                    }
                    let tok = std::str::from_utf8(&b[start..i])
                        .map_err(|_| "invalid utf-8 token")?
                        .trim();
                    if tok.is_empty() {
                        return Err(format!("empty value for key {key:?}"));
                    }
                    Val::Raw(tok.to_string())
                }
                None => return Err("unterminated object".into()),
            };
            out.insert(key, val);
            skip_ws(b, &mut i);
            match b.get(i) {
                Some(b',') => i += 1,
                Some(b'}') => {
                    i += 1;
                    break;
                }
                _ => return Err("expected ',' or '}'".into()),
            }
        }
    }
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err("trailing bytes after object".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floats_roundtrip_bitwise() {
        for v in [0.0f32, -0.0, 0.02, 1e-7, f32::MAX, f32::MIN_POSITIVE, 1.0 / 3.0] {
            let s = num_f32(v);
            let back: f32 = s.parse().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{s}");
        }
        for v in [0.3f64, -1.0 / 3.0, 1e-300, f64::MAX] {
            let s = num_f64(v);
            let back: f64 = s.parse().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{s}");
        }
        // non-finite values go through the quoted-token path
        assert_eq!(num_f64(f64::INFINITY), "\"inf\"");
        assert_eq!(num_f32(f32::NEG_INFINITY), "\"-inf\"");
        let obj = parse_object("{\"a\":\"NaN\",\"b\":\"inf\"}").unwrap();
        assert!(obj["a"].num::<f64>().unwrap().is_nan());
        assert_eq!(obj["b"].num::<f32>(), Some(f32::INFINITY));
    }

    #[test]
    fn quote_escapes_and_parses_back() {
        let hostile = "a \"quoted\" \\ back\nslash\tand \u{1} ctrl";
        let q = quote(hostile);
        assert!(!q[1..q.len() - 1].contains('\n'), "must stay one line");
        let obj = parse_object(&format!("{{\"e\":{q}}}")).unwrap();
        assert_eq!(obj["e"].as_str(), Some(hostile));
    }

    #[test]
    fn object_parses_mixed_fields() {
        let obj =
            parse_object("{\"kind\":\"row\",\"id\":7,\"lambda\":0.02,\"neg\":-1e-5}")
                .unwrap();
        assert_eq!(obj["kind"].as_str(), Some("row"));
        assert_eq!(obj["id"].num::<usize>(), Some(7));
        assert_eq!(obj["lambda"].num::<f32>(), Some(0.02));
        assert_eq!(obj["neg"].num::<f64>(), Some(-1e-5));
        // strings are not numbers and vice versa
        assert_eq!(obj["id"].as_str(), None);
        assert_eq!(parse_object("{}").unwrap().len(), 0);
    }

    #[test]
    fn malformed_objects_error_not_panic() {
        for bad in [
            "",
            "{",
            "{\"a\"}",
            "{\"a\":}",
            "{\"a\":1",
            "{\"a\":1}x",
            "{\"a\":{\"n\":1}}",
            "{\"a\":[1]}",
            "{\"a\":\"unterminated}",
            "{\"a\":\"bad\\q\"}",
            "{\"a\":\"\\ud800\"}",
        ] {
            assert!(parse_object(bad).is_err(), "{bad:?} should fail");
        }
    }
}
