//! Scoped-thread worker-pool substrate: an order-preserving indexed
//! parallel map backing [`crate::util::par_map`] and the engine's batched
//! call path. ([`crate::coordinator::campaign`] runs its own claim loop —
//! same atomic-claim + channel shape, plus event streaming and fail-fast —
//! so a fix here does NOT automatically cover campaigns.)
//!
//! No external dependencies (the offline build has no rayon/crossbeam);
//! everything is built from `std::thread::scope`, atomics and channels.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Parallel map with item indices, preserving input order in the output.
///
/// `threads == 1` degrades to a plain serial loop (no thread or channel
/// overhead), which is also what makes serial-vs-parallel comparisons
/// exact: the closure sees identical `(index, item)` pairs either way.
pub fn par_map_indexed<T: Sync, R: Send, F: Fn(usize, &T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn indexed_map_preserves_order() {
        let items: Vec<u64> = (0..200).collect();
        let par = par_map_indexed(&items, 8, |i, x| (i as u64) * 1000 + x * x);
        let ser: Vec<u64> =
            items.iter().enumerate().map(|(i, x)| (i as u64) * 1000 + x * x).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn thread_cap_respected() {
        let inflight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let items: Vec<usize> = (0..64).collect();
        par_map_indexed(&items, 3, |_, _| {
            let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
            inflight.fetch_sub(1, Ordering::SeqCst);
        });
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 3, "peak={peak}");
    }

    #[test]
    fn single_thread_is_serial() {
        let order = Mutex::new(Vec::new());
        let items: Vec<usize> = (0..10).collect();
        par_map_indexed(&items, 1, |i, _| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }
}
