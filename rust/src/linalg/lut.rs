//! Sparse low-bit LUT matmul: codebook-index matrices executed as
//! per-centroid partial sums, structurally skipping the zero centroid.
//!
//! The deployment form of an ECQx dense layer is `a[m,k] @ dequant(idx)[k,n]`
//! where `idx` holds ≤31-entry codebook indices and — by construction of
//! entropy-constrained quantization — most entries are the zero centroid.
//! The gather-GEMM path ([`crate::linalg::gemm_gather_nn`]) dequantizes
//! indices into dense f32 panels and pays the full `2·m·k·n` FMA count
//! regardless of sparsity or bit-width. This module exploits both:
//!
//! 1. **Pack** (`pack::pack_index_csr`, buffers from
//!    [`Workspace::index_panels`][crate::linalg::Workspace]): per output
//!    column `j`, group contraction positions by centroid into CSR-style
//!    segments, omitting every position whose centroid value is exactly
//!    `0.0`. Zero weights are *structurally absent* — not multiplied by
//!    zero, simply never visited.
//! 2. **Accumulate** ([`lut_matmul`]): for output `(i, j)`, sum the input
//!    activations over each centroid's segment (`partial_s = Σ a[i, l]`,
//!    pure adds, no multiplies), then apply the codebook once per active
//!    centroid: `acc += codebook[s] · partial_s`.
//!
//! Per output element the arithmetic is `nnz_j` adds plus `2·actives_j`
//! mul/adds ([`lut_ops`] counts exactly this), versus `2k` FMAs for the
//! dense path — asymptotically less work whenever the layer is sparse
//! and/or low-bit (`actives_j ≤ min(2^bits − 1, k)`).
//!
//! ## Determinism and conformance (DESIGN.md §2.6 / §2.7)
//!
//! The LUT path is a **fast-tier** kernel. Its accumulation order differs
//! from both the naive reference and the gather-GEMM (it reassociates the
//! k-term dot product into per-centroid groups), so it is *not* bitwise
//! comparable to them — instead it is held to the same conformance
//! envelope. The bound: each product `a[i,l]·codebook[s]` passes through
//! at most `nnz_j` in-segment adds, one multiply, and `actives_j`
//! combining adds — at most `nnz_j + 1 + actives_j ≤ 2k + 1` roundings,
//! within the `2·(k+4)` depth the envelope
//! ([`crate::linalg::conformance::envelope`]) already grants the FMA
//! kernels (`actives_j ≤ nnz_j ≤ k`). Within one process the result is
//! still a pure function of `(a, idx, codebook, shape)`: segment order is
//! ascending centroid then ascending row, independent of workspace
//! history and thread count.
//!
//! The **deterministic tier** keeps its bitwise-to-naive promise by not
//! running the LUT kernel at all: [`lut_gather_nn_with`] routes
//! [`GemmOpts::deterministic`] (and any codebook wider than
//! [`MAX_LUT_CENTROIDS`]) to [`gemm_gather_nn_with`], exactly as
//! `--deterministic` / `$ECQX_DETERMINISTIC` demand. The gather path is
//! thereby retained as the LUT path's oracle.
//!
//! ## Non-finite inputs
//!
//! Because zero-centroid positions are structurally absent, a NaN/Inf
//! activation paired with a zero weight does **not** propagate (the dense
//! path would compute `NaN·0 = NaN`). This is the IEEE-754 cost of the
//! sparsity claim and is contractual for the fast tier, which promises
//! envelope conformance on finite inputs only; `tests/linalg_lut_props.rs`
//! pins the behavior.

use super::gemm::{epilogue_of_zero, finish, gemm_gather_nn_with, Epilogue};
use super::pack;
use super::simd::GemmOpts;
use super::workspace::Workspace;

/// Widest codebook the LUT kernel serves: 5-bit quantization (31 valid
/// centroids) plus one slack slot. Wider codebooks — nothing the paper's
/// 2–5-bit working points produce, but containers are untrusted — fall
/// back to the gather-GEMM path in [`lut_gather_nn_with`].
pub const MAX_LUT_CENTROIDS: usize = 32;

/// `out[m,n] = epilogue(a[m,k] @ dequant(idx)[k,n])` via per-centroid LUT
/// accumulation — always the LUT algorithm, no tier dispatch (the
/// conformance tests need to exercise it under any [`GemmOpts`]).
/// Production callers want [`lut_gather_nn`] / [`lut_gather_nn_with`].
///
/// An empty codebook (or `k == 0`) yields `out = epilogue(0)`, mirroring
/// `pack_b_gather`'s hardening; out-of-range indices clamp. Panics if
/// `codebook.len() > MAX_LUT_CENTROIDS` — the dispatching wrappers
/// reroute that case instead of calling here.
#[allow(clippy::too_many_arguments)]
pub fn lut_matmul(
    ws: &mut Workspace,
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "lut_matmul lhs shape");
    assert_eq!(idx.len(), k * n, "lut_matmul idx shape");
    assert_eq!(out.len(), m * n, "lut_matmul output shape");
    if codebook.is_empty() || k == 0 {
        epilogue_of_zero(out, m, n, &epi);
        return;
    }
    assert!(
        codebook.len() <= MAX_LUT_CENTROIDS,
        "lut_matmul: codebook has {} entries (> {MAX_LUT_CENTROIDS}); use lut_gather_nn",
        codebook.len()
    );
    let s_n = codebook.len();
    let (ptr, pos) = ws.index_panels(n * (s_n + 1), k * n);
    pack::pack_index_csr(idx, codebook, k, n, ptr, pos);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let pbase = j * (s_n + 1);
            let mut acc = 0.0f32;
            for (s, &cv) in codebook.iter().enumerate() {
                let lo = ptr[pbase + s] as usize;
                let hi = ptr[pbase + s + 1] as usize;
                if lo == hi {
                    continue;
                }
                let mut partial = 0.0f32;
                for &p in &pos[lo..hi] {
                    partial += arow[p as usize];
                }
                acc += cv * partial;
            }
            *o = finish(acc, i, j, n, &epi);
        }
    }
}

/// Tier-dispatching quantized dense layer: the LUT kernel in the fast
/// tier, the gather-GEMM oracle in the deterministic tier (preserving the
/// bitwise-to-naive contract of `--deterministic`) and for codebooks
/// wider than [`MAX_LUT_CENTROIDS`]. This is the entry point
/// `runtime::host::qdense_gather` evaluates quantized models through.
#[allow(clippy::too_many_arguments)]
pub fn lut_gather_nn_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    if opts == GemmOpts::deterministic() || codebook.len() > MAX_LUT_CENTROIDS {
        gemm_gather_nn_with(opts, ws, a, idx, codebook, m, k, n, epi, out);
    } else {
        lut_matmul(ws, a, idx, codebook, m, k, n, epi, out);
    }
}

/// [`lut_gather_nn_with`] under the process-wide execution mode
/// (`--deterministic` / `$ECQX_DETERMINISTIC` / `$ECQX_KERNEL`).
#[allow(clippy::too_many_arguments)]
pub fn lut_gather_nn(
    ws: &mut Workspace,
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    lut_gather_nn_with(GemmOpts::dispatch(), ws, a, idx, codebook, m, k, n, epi, out);
}

/// Exact arithmetic-op count of one LUT matmul: per output column `j`,
/// `nnz_j` in-segment adds plus one multiply and one combining add per
/// active (non-zero, non-empty) centroid, times `m` output rows. The
/// dense-path counterpart is [`crate::linalg::gemm_flops`]` = 2·m·k·n`;
/// the ratio is what `perf_micro`'s `lut_kernels` rows record and
/// bench-smoke enforces.
pub fn lut_ops(idx: &[i32], codebook: &[f32], m: usize, k: usize, n: usize) -> f64 {
    assert_eq!(idx.len(), k * n, "lut_ops idx shape");
    if codebook.is_empty() || k == 0 {
        return 0.0;
    }
    let top = (codebook.len() - 1) as i32;
    let mut col_ops: u64 = 0;
    for j in 0..n {
        let mut counts = vec![0u64; codebook.len()];
        for l in 0..k {
            let s = idx[l * n + j].clamp(0, top) as usize;
            if codebook[s] != 0.0 {
                counts[s] += 1;
            }
        }
        let nnz: u64 = counts.iter().sum();
        let actives = counts.iter().filter(|&&c| c > 0).count() as u64;
        col_ops += nnz + 2 * actives;
    }
    m as u64 as f64 * col_ops as f64
}

#[cfg(test)]
mod tests {
    use super::super::simd::Kernel;
    use super::*;

    const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };
    const FAST1: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 2 };

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i % 13) as f32 - 6.0) * scale).collect()
    }

    /// The LUT algorithm restated element-at-a-time in its documented
    /// accumulation order (ascending centroid, ascending row within a
    /// segment) — the bitwise oracle for `lut_matmul`'s packed kernel.
    fn lut_reference(
        a: &[f32],
        idx: &[i32],
        cb: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let top = (cb.len() - 1) as i32;
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for (s, &cv) in cb.iter().enumerate() {
                    if cv == 0.0 {
                        continue;
                    }
                    let mut partial = 0.0f32;
                    let mut any = false;
                    for l in 0..k {
                        if idx[l * n + j].clamp(0, top) as usize == s {
                            partial += a[i * k + l];
                            any = true;
                        }
                    }
                    if any {
                        acc += cv * partial;
                    }
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matches_segment_order_reference_bitwise() {
        let (m, k, n) = (5, 23, 9); // ragged on purpose
        let a = seq(m * k, 0.25);
        let cb = [0.0f32, 0.5, -0.75, 1.25];
        let idx: Vec<i32> = (0..k * n).map(|i| ((i * 7 + 3) % 9) as i32 - 2).collect();
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; m * n];
        lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut out);
        let want = lut_reference(&a, &idx, &cb, m, k, n);
        assert_eq!(out, want, "packed kernel must realize the documented order exactly");
    }

    #[test]
    fn zero_centroid_positions_are_never_read() {
        // NaN activations under the zero centroid must not propagate:
        // structural skip, not multiply-by-zero.
        let (m, k, n) = (2, 4, 3);
        let cb = [0.0f32, 2.0];
        // column j: rows {0, 2} are zero-centroid everywhere
        let idx = vec![0, 0, 0, 1, 1, 1, 0, 0, 0, 1, 1, 1];
        let mut a = seq(m * k, 1.0);
        for i in 0..m {
            a[i * k] = f32::NAN;
            a[i * k + 2] = f32::INFINITY;
        }
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; m * n];
        lut_matmul(&mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut out);
        assert!(out.iter().all(|v| v.is_finite()), "zero-centroid NaN/Inf leaked: {out:?}");
        for i in 0..m {
            let want = 2.0 * (a[i * k + 1] + a[i * k + 3]);
            for j in 0..n {
                assert_eq!(out[i * n + j], want);
            }
        }
    }

    #[test]
    fn empty_codebook_and_empty_k_are_epilogue_of_zero() {
        let bias = [1.5f32, -2.0];
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; 3 * 2];
        lut_matmul(&mut ws, &seq(3 * 4, 1.0), &[0; 8], &[], 3, 4, 2, Epilogue::Bias(&bias), &mut out);
        assert_eq!(out, vec![1.5, -2.0, 1.5, -2.0, 1.5, -2.0]);
        let mut out = vec![f32::NAN; 2 * 2];
        lut_matmul(&mut ws, &[], &[], &[0.0, 1.0], 2, 0, 2, Epilogue::BiasRelu(&bias), &mut out);
        assert_eq!(out, vec![1.5, 0.0, 1.5, 0.0]);
    }

    #[test]
    fn all_zero_centroid_matrix_is_epilogue_of_zero() {
        // p = 1 sparsity edge: every index hits the zero centroid
        let (m, k, n) = (3, 8, 4);
        let bias = seq(n, 0.5);
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; m * n];
        lut_matmul(&mut ws, &seq(m * k, 1.0), &vec![0; k * n], &[0.0, 0.5], m, k, n, Epilogue::Bias(&bias), &mut out);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(out[i * n + j], bias[j]);
            }
        }
    }

    #[test]
    fn out_of_range_indices_clamp_like_pack_b_gather() {
        let (m, k, n) = (2, 3, 2);
        let a = seq(m * k, 0.5);
        let cb = [0.0f32, 1.0, -2.0];
        let wild = vec![-9, 99, 1, 2, 0, 1]; // clamps to 0 and 2
        let tame = vec![0, 2, 1, 2, 0, 1];
        let mut ws = Workspace::new();
        let (mut o1, mut o2) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        lut_matmul(&mut ws, &a, &wild, &cb, m, k, n, Epilogue::None, &mut o1);
        lut_matmul(&mut ws, &a, &tame, &cb, m, k, n, Epilogue::None, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn deterministic_tier_routes_to_gather_bitwise() {
        let (m, k, n) = (4, 11, 6);
        let a = seq(m * k, 0.25);
        let cb = [0.0f32, 0.5, -0.5, 0.25];
        let idx: Vec<i32> = (0..k * n).map(|i| (i % 4) as i32).collect();
        let mut ws = Workspace::new();
        let (mut lut, mut gather) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        lut_gather_nn_with(DET, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut lut);
        gemm_gather_nn_with(DET, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut gather);
        assert_eq!(lut, gather, "--deterministic must be the gather oracle, bit for bit");
    }

    #[test]
    fn oversized_codebook_falls_back_to_gather() {
        let (m, k, n) = (2, 5, 3);
        let a = seq(m * k, 0.5);
        let cb: Vec<f32> = (0..MAX_LUT_CENTROIDS + 1).map(|i| i as f32 * 0.125).collect();
        let idx: Vec<i32> = (0..k * n).map(|i| (i % cb.len()) as i32).collect();
        let mut ws = Workspace::new();
        let (mut got, mut want) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        // scalar fast-tier opts: dispatch must reject the LUT kernel on
        // width alone and produce gather's exact bits
        lut_gather_nn_with(FAST1, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut got);
        gemm_gather_nn_with(FAST1, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::None, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn lut_ops_counts_adds_and_centroid_applies() {
        // col 0: centroids [1, 0, 1] -> nnz 2, actives 1 -> 2 + 2 = 4
        // col 1: centroids [2, 1, 0] -> nnz 2, actives 2 -> 2 + 4 = 6
        let idx = [1, 2, 0, 1, 1, 0];
        let cb = [0.0f32, 0.5, -0.5];
        assert_eq!(lut_ops(&idx, &cb, 7, 3, 2), 7.0 * (4.0 + 6.0));
        assert_eq!(lut_ops(&idx, &[], 7, 3, 2), 0.0);
        // dense comparison point: gemm does 2*m*k*n = 2*7*3*2 = 84 ops
        assert!(lut_ops(&idx, &cb, 7, 3, 2) < 84.0);
    }
}
