//! Dense linear-algebra core of the host backend: a cache-blocked GEMM
//! with runtime-dispatched vector micro-kernels, fused epilogues and
//! reusable per-worker workspaces.
//!
//! Every sweep trial on the host backend is dominated by three dense
//! contraction forms — NN (forward `a@w`), TN (`aᵀ@g` for dW and the LRP
//! weight relevance) and NT (`g@wᵀ` for input gradients / R_in) — plus
//! the elementwise passes that used to follow them (bias add, ReLU, the
//! `w ⊙ (aᵀ@s)` scaling, ReLU-backward masking). This module replaces
//! the scalar triple loops with one blocked core ([`gemm()`]) that packs
//! operand panels into a micro-kernel-friendly layout, fuses those
//! elementwise passes into the output store ([`Epilogue`]), dequantizes
//! codebook-indexed weights panel-by-panel ([`gemm_gather_nn`], never
//! materializing the dense matrix, skipping the zero centroid), and
//! reuses all packing scratch through a per-worker [`Workspace`].
//!
//! Module map:
//! * [`mod@gemm`] (+ the `gemm_nn`/`gemm_tn`/`gemm_nt`/`gemm_gather_nn`
//!   wrappers and their `*_with` variants) — the blocked core, its fixed
//!   blocking constants, and the intra-op MC-row split
//! * [`simd`] — the micro-kernels ([`Kernel`]: portable scalar, AVX2,
//!   NEON), runtime feature dispatch, and the process-wide execution
//!   mode ([`GemmOpts`], [`set_deterministic`], `$ECQX_DETERMINISTIC`,
//!   `$ECQX_KERNEL`, `$ECQX_GEMM_THREADS`)
//! * [`pack`] — strided [`pack::View`]s and panel packing (incl. the
//!   codebook gather, which zero-fills on an empty codebook instead of
//!   trusting callers to pre-validate)
//! * [`im2col`] — NHWC conv2d lowered onto the same core: virtual patch
//!   operands packed straight into A panels (forward / dW / LRP), the
//!   tiled col2im backward, and the codebook-gather conv
//! * [`pool`] — NHWC max/avg pooling (fwd / bwd / LRP routing: WTA for
//!   max, stabilized proportional for avg) as fixed-order scalar loops —
//!   deterministic-tier by construction
//! * [`bn`] — BatchNorm train fwd/bwd over channels-last rows, the
//!   inference affine, the fold-into-conv transform and the running-stat
//!   EMA (DESIGN.md §2.8)
//! * [`lrp_ab`] — the paper's α-β conv LRP rule (α=2, β=−1) composed
//!   from eight im2col VJPs with sign-split operands
//! * [`lut`] — the sparse low-bit LUT matmul: CSR index panels that
//!   structurally skip the zero centroid, per-centroid partial-sum
//!   accumulation, and the tier dispatch that keeps the gather-GEMM as
//!   the deterministic oracle (DESIGN.md §2.7)
//! * [`workspace`] — [`Workspace`] buffers + the thread-local instance
//!   behind `Engine::call`
//! * [`reference`] — the retained naive kernels (GEMM *and* direct
//!   conv), kept as the oracle for `tests/linalg_gemm_props.rs` /
//!   `tests/conv_props.rs` and the baseline rows of `BENCH_host.json`
//! * [`conformance`] — the fast-tier error envelope and its f64 oracle
//!   (`tests/linalg_simd_conformance.rs`)
//!
//! Two-tier determinism contract (DESIGN.md §2.6). Results are always a
//! pure function of operand values, shapes, and the selected
//! micro-kernel: blocking is compile-time fixed, each output element
//! accumulates in ascending contraction order, the intra-op row split
//! lands on MC block boundaries (changing no summation order), and
//! workspace contents cannot leak into results — so within one process,
//! outputs are identical run-to-run and for any `--jobs` count. The
//! *deterministic tier* ([`GemmOpts::deterministic`], selected
//! process-wide by `--deterministic` / `$ECQX_DETERMINISTIC`) pins the
//! scalar kernel and is additionally **bitwise-equal** to the naive
//! reference on finite inputs — and therefore bit-stable across machines.
//! The *fast tier* uses the best available FMA vector kernel (bitwise
//! inequality with scalar is inherent to FMA's single rounding) and is
//! held to the [`conformance`] envelope instead.

pub mod bn;
pub mod conformance;
pub mod gemm;
pub mod im2col;
pub mod lrp_ab;
pub mod lut;
pub mod pack;
pub mod pool;
pub mod reference;
pub mod simd;
pub mod workspace;

pub use gemm::{
    gemm, gemm_flops, gemm_gather_nn, gemm_gather_nn_with, gemm_nn, gemm_nn_with, gemm_nt,
    gemm_nt_with, gemm_tn, gemm_tn_with, gemm_with, AOperand, BOperand, Epilogue, MC, MR, NC, NR,
};
pub use im2col::{
    conv2d, conv2d_bwd_filter, conv2d_bwd_filter_with, conv2d_bwd_input, conv2d_bwd_input_with,
    conv2d_flops, conv2d_gather, conv2d_gather_with, conv2d_with, lrp_conv_rw, lrp_conv_rw_with,
    Conv2d, Pad,
};
pub use bn::{bn_fold, bn_infer, bn_train_bwd, bn_train_fwd, ema_update, BN_EPS};
pub use lrp_ab::{lrp_conv_ab, lrp_conv_ab_with, stabilize, LRP_ALPHA, LRP_BETA};
pub use lut::{lut_gather_nn, lut_gather_nn_with, lut_matmul, lut_ops, MAX_LUT_CENTROIDS};
pub use pool::{
    avgpool2d, avgpool2d_bwd, avgpool2d_lrp, maxpool2d, maxpool2d_bwd, Pool2d, PoolOp,
};
pub use pack::View;
pub use simd::{deterministic_mode, set_deterministic, GemmOpts, Kernel};
pub use workspace::{with_thread_workspace, Workspace};
