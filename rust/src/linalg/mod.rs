//! Dense linear-algebra core of the host backend: a cache-blocked,
//! SIMD-friendly GEMM with fused epilogues and reusable per-worker
//! workspaces.
//!
//! Every sweep trial on the host backend is dominated by three dense
//! contraction forms — NN (forward `a@w`), TN (`aᵀ@g` for dW and the LRP
//! weight relevance) and NT (`g@wᵀ` for input gradients / R_in) — plus
//! the elementwise passes that used to follow them (bias add, ReLU, the
//! `w ⊙ (aᵀ@s)` scaling, ReLU-backward masking). This module replaces
//! the scalar triple loops with one blocked core ([`gemm()`]) that packs
//! operand panels into a micro-kernel-friendly layout, fuses those
//! elementwise passes into the output store ([`Epilogue`]), dequantizes
//! codebook-indexed weights panel-by-panel ([`gemm_gather_nn`], never
//! materializing the dense matrix, skipping the zero centroid), and
//! reuses all packing scratch through a per-worker [`Workspace`].
//!
//! Module map:
//! * [`mod@gemm`] (+ the `gemm_nn`/`gemm_tn`/`gemm_nt`/`gemm_gather_nn`
//!   wrappers) — the blocked core and its fixed blocking constants
//! * [`pack`] — strided [`pack::View`]s and panel packing (incl. the
//!   codebook gather)
//! * [`im2col`] — NHWC conv2d lowered onto the same core: virtual patch
//!   operands packed straight into A panels (forward / dW / LRP), the
//!   tiled col2im backward, and the codebook-gather conv
//! * [`workspace`] — [`Workspace`] buffers + the thread-local instance
//!   behind `Engine::call`
//! * [`reference`] — the retained naive kernels (GEMM *and* direct
//!   conv), kept as the oracle for `tests/linalg_gemm_props.rs` /
//!   `tests/conv_props.rs` and the baseline rows of `BENCH_host.json`
//!
//! Determinism contract (relied on by the campaign serial-vs-parallel
//! tests): a GEMM or conv result is a pure function of operand values and
//! shapes. Blocking is compile-time fixed, each call is single-threaded,
//! each output element accumulates in ascending contraction order (the
//! col2im scatter adds in ascending `(m, tap)` order), and workspace
//! contents cannot leak into results — so outputs are identical for any
//! `--jobs` count and any workspace reuse pattern. See `DESIGN.md`
//! §2.2–2.3.

pub mod gemm;
pub mod im2col;
pub mod pack;
pub mod reference;
pub mod workspace;

pub use gemm::{
    gemm, gemm_flops, gemm_gather_nn, gemm_nn, gemm_nt, gemm_tn, AOperand, BOperand, Epilogue, MC,
    MR, NC, NR,
};
pub use im2col::{
    conv2d, conv2d_bwd_filter, conv2d_bwd_input, conv2d_flops, conv2d_gather, lrp_conv_rw, Conv2d,
    Pad,
};
pub use pack::View;
pub use workspace::{with_thread_workspace, Workspace};
