//! The fast-tier error envelope: what "close enough" means, precisely.
//!
//! The deterministic tier is held to bitwise equality against the naive
//! [`crate::linalg::reference`] loops — no envelope needed. The fast tier
//! (FMA vector kernels, optional intra-op split) computes each output
//! element as the same ascending-`k` chain of `k` products, but with FMA
//! contraction (one rounding per multiply-add instead of two). Standard
//! forward error analysis for such a chain bounds the deviation from the
//! exact sum by `γ_k · Σ_p |a_p·b_p|` with `γ_k = k·ε/(1−k·ε)` for f32
//! `ε = 2⁻²⁴`; the scalar/naive result obeys the same bound, so the
//! *difference* between any two tiers is at most twice it.
//!
//! [`envelope`] therefore allows `2·(k+4)·ε_f32 · Σ_p |a_p·b_p|` per
//! element, checked against a float64 oracle ([`matmul_f64`]) whose own
//! error is negligible at these depths. The `+4` slack headroom-covers
//! the epilogue rounding and future kernels that reassociate the `k` loop
//! into independent partial sums (pairwise/strip-mined reductions stay
//! well inside `γ_k`). The bound scales with the **magnitude sum**
//! `Σ|a||b|`, not the result — that is what makes it honest under
//! cancellation, where a relative-to-result bound would be vacuous or
//! impossibly tight; in ULP terms it is a bounded ULP count at the scale
//! of the summand magnitudes.
//!
//! Used by `tests/linalg_simd_conformance.rs` and documented as the
//! fast-tier acceptance gate in DESIGN.md §2.6.

/// Float64 matmul oracle: `(a[m,k] @ b[k,n])` accumulated in f64, plus
/// the per-element magnitude sums `Σ_p |a[i,p]·b[p,j]|` that scale the
/// envelope. Returns `(product, magnitude)`.
pub fn matmul_f64(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), m * k, "matmul_f64 lhs shape");
    assert_eq!(b.len(), k * n, "matmul_f64 rhs shape");
    let mut out = vec![0.0f64; m * n];
    let mut mag = vec![0.0f64; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p] as f64;
            for j in 0..n {
                let t = av * b[p * n + j] as f64;
                out[i * n + j] += t;
                mag[i * n + j] += t.abs();
            }
        }
    }
    (out, mag)
}

/// Maximum allowed deviation of a fast-tier f32 result from the f64
/// oracle for one output element of contraction depth `k` with magnitude
/// sum `mag`: `2·(k+4)·ε_f32·mag`. A zero magnitude sum means every
/// product is exactly zero, so any tier must produce (signed) zero —
/// the bound is exactly 0.0 there.
pub fn envelope(k: usize, mag: f64) -> f64 {
    2.0 * (k as f64 + 4.0) * (f32::EPSILON as f64) * mag
}

/// Assert `got` (a fast-tier `[m,n]` GEMM result) is inside the envelope
/// of the f64 oracle for `a @ b`. `ctx` labels the failing op/shape.
pub fn assert_matmul_within_envelope(
    got: &[f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ctx: &str,
) {
    assert_eq!(got.len(), m * n, "{ctx}: output shape");
    let (want, mag) = matmul_f64(a, b, m, k, n);
    for (i, (&g, (&w, &mg))) in got.iter().zip(want.iter().zip(mag.iter())).enumerate() {
        let err = (g as f64 - w).abs();
        let bound = envelope(k, mg);
        assert!(
            err <= bound,
            "{ctx}: element {i} out of envelope: got {g}, oracle {w}, \
             |err| {err:.3e} > bound {bound:.3e} (k={k}, mag={mg:.3e})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_matches_hand_computed_product() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let (out, mag) = matmul_f64(&a, &b, 2, 2, 2);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
        // all products positive here, so magnitude sums equal the product
        assert_eq!(mag, out);
    }

    #[test]
    fn magnitude_sum_survives_cancellation() {
        // 1·1 + (−1)·1 = 0 exactly, but the magnitude sum is 2 — the
        // envelope stays finite and meaningful where a relative bound
        // on the result would collapse to zero
        let a = [1.0, -1.0];
        let b = [1.0, 1.0];
        let (out, mag) = matmul_f64(&a, &b, 1, 2, 1);
        assert_eq!(out, vec![0.0]);
        assert_eq!(mag, vec![2.0]);
        assert!(envelope(2, mag[0]) > 0.0);
    }

    #[test]
    fn envelope_is_zero_only_for_zero_magnitude() {
        assert_eq!(envelope(1000, 0.0), 0.0);
        assert!(envelope(1, 1.0) > 0.0);
        // monotone in both k and magnitude
        assert!(envelope(100, 1.0) > envelope(10, 1.0));
        assert!(envelope(10, 2.0) > envelope(10, 1.0));
    }

    #[test]
    fn exact_result_passes_the_assertion() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2,3]
        let b = [1.0, 0.5, -1.0, 2.0, 0.25, -0.5]; // [3,2]
        let (want, _) = matmul_f64(&a, &b, 2, 3, 2);
        let got: Vec<f32> = want.iter().map(|&v| v as f32).collect();
        assert_matmul_within_envelope(&got, &a, &b, 2, 3, 2, "exact");
    }

    #[test]
    #[should_panic(expected = "out of envelope")]
    fn grossly_wrong_result_fails_the_assertion() {
        let a = [1.0, 2.0];
        let b = [3.0, 4.0];
        // true value is 11; 12 is far outside any k=2 envelope
        assert_matmul_within_envelope(&[12.0], &a, &b, 1, 2, 1, "wrong");
    }
}
