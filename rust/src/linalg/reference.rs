//! Retained naive reference kernels (the pre-linalg scalar triple loops).
//!
//! These are the exact contraction loops the host backend shipped with
//! before the blocked GEMM core existed, kept verbatim for two reasons:
//!
//! 1. they are the oracle of `tests/linalg_gemm_props.rs` — the blocked
//!    kernels must agree with them elementwise on every shape, ragged or
//!    not (and do so *exactly* on finite inputs, because the blocked
//!    micro-kernel accumulates each output element over `k` in the same
//!    ascending order; see the determinism notes in [`crate::linalg`]);
//! 2. `benches/perf_micro.rs` times them next to the blocked kernels so
//!    `BENCH_host.json` records the speedup instead of asserting it.
//!
//! They are re-exported as `runtime::host::{matmul, matmul_tn, matmul_nt}`
//! for backward compatibility with existing call sites and tests.

/// Row-major `a[m,k] @ b[k,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bkj) in orow.iter_mut().zip(brow) {
                *o += aik * bkj;
            }
        }
    }
    out
}

/// `a[m,k]ᵀ @ b[m,n]` -> `[k,n]` (the batch contraction of LRP / dW).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut out = vec![0.0f32; k * n];
    for s in 0..m {
        let arow = &a[s * k..(s + 1) * k];
        let brow = &b[s * n..(s + 1) * n];
        for (i, &asi) in arow.iter().enumerate() {
            if asi == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bsj) in orow.iter_mut().zip(brow) {
                *o += asi * bsj;
            }
        }
    }
    out
}

/// `g[m,n] @ w[k,n]ᵀ` -> `[m,k]` (the input-gradient / R_in contraction).
pub fn matmul_nt(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(g.len(), m * n);
    assert_eq!(w.len(), k * n);
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (gv, wv) in grow.iter().zip(wrow) {
                acc += gv * wv;
            }
            out[i * k + kk] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_manual() {
        // [2,3] @ [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![4.0, 5.0, 10.0, 11.0]);
        // transpose identities
        let tn = matmul_tn(&a, &a, 2, 3, 3); // aᵀa [3,3]
        assert_eq!(tn[0], 1.0 + 16.0);
        let nt = matmul_nt(&a, &a, 2, 3, 2); // a aᵀ [2,2]
        assert_eq!(nt[0], 1.0 + 4.0 + 9.0);
        assert_eq!(nt[1], 4.0 + 10.0 + 18.0);
    }
}
