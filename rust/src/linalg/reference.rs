//! Retained naive reference kernels (the pre-linalg scalar triple loops).
//!
//! These are the exact contraction loops the host backend shipped with
//! before the blocked GEMM core existed, kept verbatim for two reasons:
//!
//! 1. they are the oracle of `tests/linalg_gemm_props.rs` — the blocked
//!    kernels must agree with them elementwise on every shape, ragged or
//!    not (and do so *exactly* on finite inputs, because the blocked
//!    micro-kernel accumulates each output element over `k` in the same
//!    ascending order; see the determinism notes in [`crate::linalg`]);
//! 2. `benches/perf_micro.rs` times them next to the blocked kernels so
//!    `BENCH_host.json` records the speedup instead of asserting it.
//!
//! They are re-exported as `runtime::host::{matmul, matmul_tn, matmul_nt}`
//! for backward compatibility with existing call sites and tests.
//!
//! The naive *direct* conv kernels (`conv2d_naive`,
//! `conv2d_bwd_{filter,input}_naive`) play the same two roles for the
//! im2col-GEMM lowering in [`crate::linalg::im2col`]: exact-equality
//! oracle for `tests/conv_props.rs` and baseline rows of the
//! `conv_kernels` section in `BENCH_host.json`. Each accumulates in the
//! same order as the blocked path — ascending `(kh, kw, ci)` taps per
//! output element for the forward, ascending sample `m` for dW, and
//! ascending `(m, tap)` scatter for dX — so agreement is bitwise on
//! finite inputs.

use super::im2col::Conv2d;
use super::pool::{Pool2d, PoolOp};

/// Row-major `a[m,k] @ b[k,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bkj) in orow.iter_mut().zip(brow) {
                *o += aik * bkj;
            }
        }
    }
    out
}

/// `a[m,k]ᵀ @ b[m,n]` -> `[k,n]` (the batch contraction of LRP / dW).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut out = vec![0.0f32; k * n];
    for s in 0..m {
        let arow = &a[s * k..(s + 1) * k];
        let brow = &b[s * n..(s + 1) * n];
        for (i, &asi) in arow.iter().enumerate() {
            if asi == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bsj) in orow.iter_mut().zip(brow) {
                *o += asi * bsj;
            }
        }
    }
    out
}

/// `g[m,n] @ w[k,n]ᵀ` -> `[m,k]` (the input-gradient / R_in contraction).
pub fn matmul_nt(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(g.len(), m * n);
    assert_eq!(w.len(), k * n);
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (gv, wv) in grow.iter().zip(wrow) {
                acc += gv * wv;
            }
            out[i * k + kk] = acc;
        }
    }
    out
}

/// Naive direct NHWC conv (no epilogue): each output element accumulates
/// its taps in ascending `(kh, kw, ci)` order, skipping out-of-image taps
/// (which the im2col path packs as `0.0` — the same value).
pub fn conv2d_naive(x: &[f32], w: &[f32], g: &Conv2d) -> Vec<f32> {
    assert_eq!(x.len(), g.in_len(), "conv2d_naive input shape");
    assert_eq!(w.len(), g.filter_len(), "conv2d_naive filter shape");
    let (oh, ow) = g.out_hw();
    let (ph, pw) = g.pad_before();
    let mut out = vec![0.0f32; g.out_len()];
    for ni in 0..g.n {
        for ohi in 0..oh {
            for owi in 0..ow {
                let orow =
                    &mut out[((ni * oh + ohi) * ow + owi) * g.co..][..g.co];
                for khi in 0..g.kh {
                    let ih = (ohi * g.stride + khi) as isize - ph as isize;
                    if ih < 0 || ih as usize >= g.h {
                        continue;
                    }
                    for kwi in 0..g.kw {
                        let iw = (owi * g.stride + kwi) as isize - pw as isize;
                        if iw < 0 || iw as usize >= g.w {
                            continue;
                        }
                        let xbase =
                            ((ni * g.h + ih as usize) * g.w + iw as usize) * g.c;
                        for ci in 0..g.c {
                            let xv = x[xbase + ci];
                            let wrow =
                                &w[((khi * g.kw + kwi) * g.c + ci) * g.co..][..g.co];
                            for (o, &wv) in orow.iter_mut().zip(wrow) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Naive filter gradient `dW[kh,kw,ci,co] = Σ_m patch[m,·] · gout[m,·]`,
/// accumulating over samples `m` in ascending order.
pub fn conv2d_bwd_filter_naive(x: &[f32], gout: &[f32], g: &Conv2d) -> Vec<f32> {
    assert_eq!(x.len(), g.in_len(), "conv2d_bwd_filter_naive input shape");
    assert_eq!(gout.len(), g.out_len(), "conv2d_bwd_filter_naive gout shape");
    let (oh, ow) = g.out_hw();
    let (ph, pw) = g.pad_before();
    let mut out = vec![0.0f32; g.filter_len()];
    for mi in 0..g.rows() {
        let owi = mi % ow;
        let ohi = (mi / ow) % oh;
        let ni = mi / (ow * oh);
        let grow = &gout[mi * g.co..][..g.co];
        for khi in 0..g.kh {
            let ih = (ohi * g.stride + khi) as isize - ph as isize;
            if ih < 0 || ih as usize >= g.h {
                continue;
            }
            for kwi in 0..g.kw {
                let iw = (owi * g.stride + kwi) as isize - pw as isize;
                if iw < 0 || iw as usize >= g.w {
                    continue;
                }
                let xbase = ((ni * g.h + ih as usize) * g.w + iw as usize) * g.c;
                for ci in 0..g.c {
                    let xv = x[xbase + ci];
                    let orow =
                        &mut out[((khi * g.kw + kwi) * g.c + ci) * g.co..][..g.co];
                    for (o, &gv) in orow.iter_mut().zip(grow) {
                        *o += xv * gv;
                    }
                }
            }
        }
    }
    out
}

/// Naive input gradient (direct col2im): for each sample position `m` in
/// ascending order, each in-image tap in ascending order, scatter-add
/// `Σ_co gout[m,co]·w[tap,co]` (ascending `co`) into `dx` — the exact
/// accumulation order of the tiled im2col backward.
pub fn conv2d_bwd_input_naive(gout: &[f32], w: &[f32], g: &Conv2d) -> Vec<f32> {
    assert_eq!(gout.len(), g.out_len(), "conv2d_bwd_input_naive gout shape");
    assert_eq!(w.len(), g.filter_len(), "conv2d_bwd_input_naive filter shape");
    let (oh, ow) = g.out_hw();
    let (ph, pw) = g.pad_before();
    let mut dx = vec![0.0f32; g.in_len()];
    for mi in 0..g.rows() {
        let owi = mi % ow;
        let ohi = (mi / ow) % oh;
        let ni = mi / (ow * oh);
        let grow = &gout[mi * g.co..][..g.co];
        for khi in 0..g.kh {
            let ih = (ohi * g.stride + khi) as isize - ph as isize;
            if ih < 0 || ih as usize >= g.h {
                continue;
            }
            for kwi in 0..g.kw {
                let iw = (owi * g.stride + kwi) as isize - pw as isize;
                if iw < 0 || iw as usize >= g.w {
                    continue;
                }
                let base = ((ni * g.h + ih as usize) * g.w + iw as usize) * g.c;
                for ci in 0..g.c {
                    let wrow = &w[((khi * g.kw + kwi) * g.c + ci) * g.co..][..g.co];
                    let mut acc = 0.0f32;
                    for (&gv, &wv) in grow.iter().zip(wrow) {
                        acc += gv * wv;
                    }
                    dx[base + ci] += acc;
                }
            }
        }
    }
    dx
}

/// Independently-written max-pool oracle: per output element, collect the
/// window taps and reduce (versus the kernel's running-max scan). Same
/// first-index tie-breaking, so agreement with
/// [`crate::linalg::maxpool2d`] is bitwise.
pub fn maxpool2d_naive(g: &Pool2d, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), g.in_len(), "maxpool2d_naive input shape");
    assert_eq!(g.op, PoolOp::Max, "maxpool2d_naive on non-max geometry");
    let (oh, ow) = g.out_hw();
    let mut out = vec![0.0f32; g.out_len()];
    for b in 0..g.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..g.c {
                    let mut taps = Vec::with_capacity(g.kh * g.kw);
                    for ph in 0..g.kh {
                        for pw in 0..g.kw {
                            let iy = oy * g.stride + ph;
                            let ix = ox * g.stride + pw;
                            taps.push(x[((b * g.h + iy) * g.w + ix) * g.c + ch]);
                        }
                    }
                    let mut best = taps[0];
                    for &t in &taps[1..] {
                        if t > best {
                            best = t;
                        }
                    }
                    out[((b * oh + oy) * ow + ox) * g.c + ch] = best;
                }
            }
        }
    }
    out
}

/// Independently-written average-pool oracle (tap-collection form, same
/// ascending accumulation order ⇒ bitwise agreement with
/// [`crate::linalg::avgpool2d`]).
pub fn avgpool2d_naive(g: &Pool2d, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), g.in_len(), "avgpool2d_naive input shape");
    assert_eq!(g.op, PoolOp::Avg, "avgpool2d_naive on non-avg geometry");
    let (oh, ow) = g.out_hw();
    let mut out = vec![0.0f32; g.out_len()];
    for b in 0..g.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..g.c {
                    let mut acc = 0.0f32;
                    for ph in 0..g.kh {
                        for pw in 0..g.kw {
                            let iy = oy * g.stride + ph;
                            let ix = ox * g.stride + pw;
                            acc += x[((b * g.h + iy) * g.w + ix) * g.c + ch];
                        }
                    }
                    out[((b * oh + oy) * ow + ox) * g.c + ch] =
                        acc * (1.0 / (g.kh * g.kw) as f32);
                }
            }
        }
    }
    out
}

/// Independently-written BN-fold oracle: per-element double loop over
/// `(tap, co)` instead of the kernel's cycled-scale zip. Same per-element
/// expression ⇒ bitwise agreement with [`crate::linalg::bn_fold`].
#[allow(clippy::too_many_arguments)]
pub fn bn_fold_naive(
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
    w: &[f32],
    b: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let c = gamma.len();
    assert_eq!(w.len() % c, 0, "bn_fold_naive filter not a multiple of co");
    let taps = w.len() / c;
    let mut wf = vec![0.0f32; w.len()];
    let mut bf = vec![0.0f32; c];
    for co in 0..c {
        let s = gamma[co] / (var[co] + eps).sqrt();
        for t in 0..taps {
            wf[t * c + co] = w[t * c + co] * s;
        }
        bf[co] = (b[co] - mean[co]) * s + beta[co];
    }
    (wf, bf)
}

#[cfg(test)]
mod tests {
    use super::super::im2col::Pad;
    use super::*;

    #[test]
    fn matmul_matches_manual() {
        // [2,3] @ [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![4.0, 5.0, 10.0, 11.0]);
        // transpose identities
        let tn = matmul_tn(&a, &a, 2, 3, 3); // aᵀa [3,3]
        assert_eq!(tn[0], 1.0 + 16.0);
        let nt = matmul_nt(&a, &a, 2, 3, 2); // a aᵀ [2,2]
        assert_eq!(nt[0], 1.0 + 4.0 + 9.0);
        assert_eq!(nt[1], 4.0 + 10.0 + 18.0);
    }

    #[test]
    fn naive_conv_identity_kernel_passes_input_through() {
        // 1x1 identity filter: conv is a per-pixel copy
        let g = Conv2d { n: 1, h: 2, w: 2, c: 1, kh: 1, kw: 1, co: 1, stride: 1, pad: Pad::Valid };
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(conv2d_naive(&x, &[1.0], &g), x.to_vec());
        // dX of the identity conv is the output gradient itself
        assert_eq!(conv2d_bwd_input_naive(&x, &[1.0], &g), x.to_vec());
        // dW aggregates x ⊙ g over all positions
        assert_eq!(conv2d_bwd_filter_naive(&x, &x, &g), vec![1.0 + 4.0 + 9.0 + 16.0]);
    }
}
