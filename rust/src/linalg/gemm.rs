//! Cache-blocked GEMM with fused epilogues and runtime-dispatched
//! micro-kernels.
//!
//! One core loop nest serves all three contraction forms of the host
//! backend (NN for the forward pass, TN for dW and LRP weight relevance,
//! NT for input gradients / R_in) by viewing transposed operands through
//! strided [`View`]s. Blocking is fixed at compile time:
//!
//! ```text
//! for jc in steps of NC over n:        pack B[:, jc..jc+nc]   (NR strips)
//!   for ic in steps of MC over m:      pack A[ic..ic+mc, :]   (MR strips)
//!     for each NR-column strip jr:
//!       for each MR-row strip ir:
//!         acc[MR][NR] = 0
//!         for p in 0..k: acc[r][c] += apanel[p*MR+r] * bpanel[p*NR+c]
//!         out tile = epilogue(acc)     (bias / bias+relu / scale / mask)
//! ```
//!
//! The register-tile inner loop is one of the micro-kernels of
//! [`super::simd`], selected per call by [`GemmOpts`]: the portable
//! scalar kernel (the *deterministic tier* — bitwise-equal to the naive
//! [`super::reference`] loops, since both accumulate each element's `k`
//! products in ascending order with separate mul/add roundings), or a
//! hand-vectorized AVX2/NEON FMA kernel (the *fast tier* — same ascending
//! order, but FMA's single rounding per step breaks bitwise equality; it
//! is instead held to the error envelope of [`super::conformance`]).
//! Large dense-A GEMMs may additionally split their MC row blocks across
//! scoped threads ([`GemmOpts::threads`]); the split lands exactly on MC
//! block boundaries and re-bases row-indexed epilogues, so it changes no
//! summation order and is bitwise-identical to the same kernel run
//! serially. Plain [`gemm()`] and the wrappers resolve the process-wide
//! mode ([`GemmOpts::dispatch`]); `*_with` variants pin it per call.

use super::im2col::{pack_patches, pack_patches_t, Conv2d};
use super::pack::{pack_a, pack_b, pack_b_gather, View};
use super::simd::{self, GemmOpts, Kernel};
use super::workspace::{with_thread_workspace, Workspace};

/// Micro-kernel rows (broadcast axis).
pub const MR: usize = 4;
/// Micro-kernel columns (vector axis; two 8-lane f32 vectors on AVX2,
/// four 4-lane vectors on NEON).
pub const NR: usize = 16;
/// Rows of A packed per block (A panel = MC·k floats, L2-resident for the
/// layer sizes of the paper's models).
pub const MC: usize = 64;
/// Columns of B packed per block.
pub const NC: usize = 256;

// The block loops step by MC/NC and index panels by MR/NR strips, so the
// cache blocks must be whole numbers of register strips.
const _: () = assert!(MC % MR == 0 && NC % NR == 0, "blocks must align to strips");

/// Epilogue fused into the output-tile store: what the host backend used
/// to do as separate full-tensor passes after each contraction.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// plain store
    None,
    /// `out[i,j] = acc + bias[j]` (dense layer bias add)
    Bias(&'a [f32]),
    /// `out[i,j] = max(acc + bias[j], 0)` (hidden dense layer)
    BiasRelu(&'a [f32]),
    /// `out[i,j] = acc * scale[i*n + j]` — `scale` is row-major `[m, n]`
    /// like the output (the LRP `w ⊙ (aᵀ@s)` weight-relevance scaling,
    /// and `a ⊙ (s@wᵀ)` for R_in)
    Scale(&'a [f32]),
    /// `out[i,j] = if mask[i*n + j] > 0 { acc } else { 0 }` (ReLU
    /// backward masking by the forward activation)
    ReluMask(&'a [f32]),
}

impl<'a> Epilogue<'a> {
    /// The same epilogue as seen from output row `i0` of a row-split
    /// chunk: row-indexed buffers (`Scale`, `ReluMask`) are re-based so
    /// the chunk's local row `i` reads global row `i0 + i`; column-indexed
    /// (`Bias`, `BiasRelu`) and empty epilogues pass through unchanged.
    pub(crate) fn offset_rows(self, i0: usize, n: usize) -> Epilogue<'a> {
        match self {
            Epilogue::Scale(s) => Epilogue::Scale(&s[i0 * n..]),
            Epilogue::ReluMask(m) => Epilogue::ReluMask(&m[i0 * n..]),
            other => other,
        }
    }
}

/// Right-hand operand: a strided dense view, or centroid indices
/// dequantized through a codebook at pack time (`qdense_gather`).
#[derive(Clone, Copy, Debug)]
pub enum BOperand<'a> {
    Dense(View<'a>),
    /// row-major `[k, n]` int32 centroid indices + codebook; out-of-range
    /// indices clamp, and an empty codebook packs as an all-zero weight
    /// matrix (`pack_b_gather` handles both — no caller pre-validation).
    Gather { idx: &'a [i32], codebook: &'a [f32] },
}

/// Left-hand operand: a strided dense view, or the *virtual* im2col
/// matrix of a conv input — patches are extracted straight into the A
/// panel at pack time, so the `[n·oh·ow, kh·kw·c]` matrix is never
/// materialized (see [`crate::linalg::im2col`]).
#[derive(Clone, Copy, Debug)]
pub enum AOperand<'a> {
    Dense(View<'a>),
    /// im2col patch matrix `[geom.rows(), geom.taps()]` over NHWC `x`
    Patches { x: &'a [f32], geom: Conv2d },
    /// its transpose `[geom.taps(), geom.rows()]` (the dW / `lrp_conv_rw`
    /// contraction)
    PatchesT { x: &'a [f32], geom: Conv2d },
}

/// Apply the fused epilogue to one accumulated output element. Shared
/// with the LUT kernel ([`crate::linalg::lut`]) so both quantized eval
/// paths finish an element with bit-identical epilogue arithmetic.
#[inline(always)]
pub(crate) fn finish(acc: f32, i: usize, j: usize, n: usize, epi: &Epilogue) -> f32 {
    match *epi {
        Epilogue::None => acc,
        Epilogue::Bias(b) => acc + b[j],
        Epilogue::BiasRelu(b) => {
            let z = acc + b[j];
            if z < 0.0 {
                0.0
            } else {
                z
            }
        }
        Epilogue::Scale(s) => acc * s[i * n + j],
        Epilogue::ReluMask(m) => {
            if m[i * n + j] > 0.0 {
                acc
            } else {
                0.0
            }
        }
    }
}

/// `out = epilogue(0)` — shared early-out for an empty contraction
/// (`k == 0`) and an empty gather codebook (all-zero weights).
pub(crate) fn epilogue_of_zero(out: &mut [f32], m: usize, n: usize, epi: &Epilogue) {
    assert_eq!(out.len(), m * n, "gemm: output buffer shape");
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] = finish(0.0, i, j, n, epi);
        }
    }
}

#[inline(always)]
fn store_tile(
    acc: &[[f32; NR]; MR],
    out: &mut [f32],
    n: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    epi: &Epilogue,
) {
    for r in 0..mr {
        let i = i0 + r;
        let orow = &mut out[i * n + j0..i * n + j0 + nr];
        for (c, o) in orow.iter_mut().enumerate() {
            *o = finish(acc[r][c], i, j0 + c, n, epi);
        }
    }
}

/// Blocked GEMM core: `out[m,n] = epilogue(A[m,k] · B[k,n])`, where A and
/// B are arbitrary strided views or virtual operands (so TN/NT and the
/// im2col conv forms are the same code path) and `out` is fully
/// overwritten. Runs under the process-wide mode ([`GemmOpts::dispatch`]);
/// see [`gemm_with`] to pin the kernel/threads per call.
pub fn gemm(
    ws: &mut Workspace,
    m: usize,
    n: usize,
    k: usize,
    a: AOperand,
    b: BOperand,
    epi: Epilogue,
    out: &mut [f32],
) {
    gemm_with(GemmOpts::dispatch(), ws, m, n, k, a, b, epi, out);
}

/// [`gemm()`] with explicit execution options. The intra-op row split
/// engages only for dense-A GEMMs spanning at least two MC blocks with
/// `opts.threads > 1` (virtual patch operands address rows globally, so
/// conv forms always run their blocks serially).
pub fn gemm_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    m: usize,
    n: usize,
    k: usize,
    a: AOperand,
    b: BOperand,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(out.len(), m * n, "gemm: output buffer shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // empty contraction: the accumulator is zero everywhere, but the
        // epilogue still applies (a k=0 dense layer is bias-only)
        epilogue_of_zero(out, m, n, &epi);
        return;
    }
    if opts.threads > 1 && m >= 2 * MC {
        if let AOperand::Dense(av) = a {
            gemm_split_rows(opts.kernel, opts.threads, m, n, k, av, b, epi, out);
            return;
        }
    }
    let (apack, bpack) = ws.panels(panel_rows(m, MC, MR) * k, panel_rows(n, NC, NR) * k);
    gemm_core(opts.kernel, apack, bpack, m, n, k, a, b, epi, out);
}

/// Split one dense-A GEMM's rows across scoped threads, each chunk a
/// whole number of MC blocks. Because the serial core already restarts
/// its A-block loop at every MC boundary (re-packing B per NC block
/// either way), a chunk computes exactly the tiles the serial run would,
/// in the same per-element order — the split is bitwise-identical to
/// `threads = 1` with the same kernel, it only reassigns blocks to
/// threads. Each thread packs into its own thread-local workspace; the
/// output is partitioned disjointly via `chunks_mut`.
fn gemm_split_rows(
    kernel: Kernel,
    threads: usize,
    m: usize,
    n: usize,
    k: usize,
    av: View,
    b: BOperand,
    epi: Epilogue,
    out: &mut [f32],
) {
    let chunks = threads.min(m.div_ceil(MC));
    let chunk_rows = m.div_ceil(chunks).div_ceil(MC) * MC;
    std::thread::scope(|scope| {
        for (ci, ochunk) in out.chunks_mut(chunk_rows * n).enumerate() {
            let i0 = ci * chunk_rows;
            scope.spawn(move || {
                let rows = ochunk.len() / n;
                with_thread_workspace(|ws| {
                    let (apack, bpack) =
                        ws.panels(panel_rows(rows, MC, MR) * k, panel_rows(n, NC, NR) * k);
                    gemm_core(
                        kernel,
                        apack,
                        bpack,
                        rows,
                        n,
                        k,
                        AOperand::Dense(av.at(i0, 0)),
                        b,
                        epi.offset_rows(i0, n),
                        ochunk,
                    );
                });
            });
        }
    });
}

/// Strip-rounded panel extent for a matrix dimension: the largest block
/// the core will pack is `min(block, dim)` rows, rounded up to whole
/// `strip`-wide strips. Sizing panels by this instead of a flat
/// `block·k` matters for skewed shapes — the conv dW form has a huge
/// contraction depth `k` but tiny `n = co`, where a flat `NC·k` B panel
/// would reserve `NC/co`× more scratch than the pack ever touches.
pub(crate) fn panel_rows(dim: usize, block: usize, strip: usize) -> usize {
    block.min(dim.div_ceil(strip) * strip)
}

/// [`gemm_with`] over caller-held packing panels, sized at least
/// `panel_rows(m, MC, MR)·k` / `panel_rows(n, NC, NR)·k` floats.
/// [`crate::linalg::conv2d_bwd_input`] uses this to run its per-tile
/// GEMM while also holding the workspace's dCol tile. Always serial
/// (one thread's worth of blocks); `kernel` picks the micro-kernel.
pub(crate) fn gemm_core(
    kernel: Kernel,
    apack: &mut [f32],
    bpack: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    a: AOperand,
    b: BOperand,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(out.len(), m * n, "gemm: output buffer shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        epilogue_of_zero(out, m, n, &epi);
        return;
    }
    // panel capacity is implicitly bounds-checked by the pack routines'
    // slice indexing; callers size apack/bpack at MC·k / NC·k
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        match b {
            BOperand::Dense(bv) => pack_b(bv.at(0, jc), k, nc, bpack),
            BOperand::Gather { idx, codebook } => {
                pack_b_gather(idx, codebook, n, jc, k, nc, bpack)
            }
        }
        let mut ic = 0;
        while ic < m {
            let mc = MC.min(m - ic);
            match a {
                AOperand::Dense(av) => pack_a(av.at(ic, 0), mc, k, apack),
                AOperand::Patches { x, geom } => pack_patches(x, &geom, ic, mc, apack),
                AOperand::PatchesT { x, geom } => pack_patches_t(x, &geom, ic, mc, apack),
            }
            let mut jr = 0;
            while jr < nc {
                let nr = NR.min(nc - jr);
                let bpanel = &bpack[(jr / NR) * NR * k..(jr / NR) * NR * k + NR * k];
                let mut ir = 0;
                while ir < mc {
                    let mr = MR.min(mc - ir);
                    let apanel = &apack[(ir / MR) * MR * k..(ir / MR) * MR * k + MR * k];
                    let mut acc = [[0.0f32; NR]; MR];
                    simd::microkernel(kernel, k, apanel, bpanel, &mut acc);
                    store_tile(&acc, out, n, ic + ir, jc + jr, mr, nr, &epi);
                    ir += MR;
                }
                jr += NR;
            }
            ic += MC;
        }
        jc += NC;
    }
}

/// `out[m,n] = epilogue(a[m,k] @ b[k,n])` (row-major operands).
pub fn gemm_nn(
    ws: &mut Workspace,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    gemm_nn_with(GemmOpts::dispatch(), ws, a, b, m, k, n, epi, out);
}

/// [`gemm_nn`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "gemm_nn lhs shape");
    assert_eq!(b.len(), k * n, "gemm_nn rhs shape");
    gemm_with(
        opts,
        ws,
        m,
        n,
        k,
        AOperand::Dense(View::nn(a, k)),
        BOperand::Dense(View::nn(b, n)),
        epi,
        out,
    );
}

/// `out[k,n] = epilogue(a[m,k]ᵀ @ b[m,n])` — the dW / LRP contraction.
pub fn gemm_tn(
    ws: &mut Workspace,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    gemm_tn_with(GemmOpts::dispatch(), ws, a, b, m, k, n, epi, out);
}

/// [`gemm_tn`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "gemm_tn lhs shape");
    assert_eq!(b.len(), m * n, "gemm_tn rhs shape");
    gemm_with(
        opts,
        ws,
        k,
        n,
        m,
        AOperand::Dense(View::t(a, k)),
        BOperand::Dense(View::nn(b, n)),
        epi,
        out,
    );
}

/// `out[m,k] = epilogue(g[m,n] @ w[k,n]ᵀ)` — the input-gradient / R_in
/// contraction.
pub fn gemm_nt(
    ws: &mut Workspace,
    g: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    gemm_nt_with(GemmOpts::dispatch(), ws, g, w, m, n, k, epi, out);
}

/// [`gemm_nt`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    g: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(g.len(), m * n, "gemm_nt lhs shape");
    assert_eq!(w.len(), k * n, "gemm_nt rhs shape");
    gemm_with(
        opts,
        ws,
        m,
        k,
        n,
        AOperand::Dense(View::nn(g, n)),
        BOperand::Dense(View::t(w, n)),
        epi,
        out,
    );
}

/// `out[m,n] = epilogue(a[m,k] @ dequant(idx)[k,n])` — the deployment-form
/// dense layer. Centroid indices are dequantized panel-by-panel at pack
/// time (never materializing the dense weight matrix) with the zero
/// centroid skipped. An empty codebook yields an all-zero weight matrix
/// (`out = epilogue(0)`) at every layer — here via the early-out, and in
/// the pack layer itself (`pack_b_gather` zero-fills); the host backend
/// additionally reports it as a corrupt-container error up front (see
/// `runtime::host::qdense_gather`).
pub fn gemm_gather_nn(
    ws: &mut Workspace,
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    gemm_gather_nn_with(GemmOpts::dispatch(), ws, a, idx, codebook, m, k, n, epi, out);
}

/// [`gemm_gather_nn`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn gemm_gather_nn_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    a: &[f32],
    idx: &[i32],
    codebook: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "gemm_gather_nn lhs shape");
    assert_eq!(idx.len(), k * n, "gemm_gather_nn idx shape");
    if codebook.is_empty() {
        epilogue_of_zero(out, m, n, &epi);
        return;
    }
    let av = AOperand::Dense(View::nn(a, k));
    gemm_with(opts, ws, m, n, k, av, BOperand::Gather { idx, codebook }, epi, out);
}

/// FLOP count of one `m×k×n` GEMM (multiply + add), for GFLOP/s rows in
/// `BENCH_host.json`.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;

    // The unit tests assert exact equality against the naive reference,
    // which is the *deterministic-tier* contract — so they pin the scalar
    // kernel explicitly instead of inheriting the process dispatch (which
    // would pick an FMA kernel on most CI hosts and break `==`). The fast
    // tier is covered by tests/linalg_simd_conformance.rs.
    const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i % 13) as f32 - 6.0) * scale).collect()
    }

    #[test]
    fn nn_matches_reference_on_ragged_shape() {
        let (m, k, n) = (5, 7, 19); // none a multiple of any block size
        let a = seq(m * k, 0.25);
        let b = seq(k * n, 0.5);
        let mut ws = Workspace::new();
        let mut out = vec![0.0; m * n];
        gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
        assert_eq!(out, reference::matmul(&a, &b, m, k, n));
    }

    #[test]
    fn tn_and_nt_match_reference() {
        let (m, k, n) = (9, 4, 21);
        let a = seq(m * k, 0.1);
        let b = seq(m * n, 0.3);
        let w = seq(k * n, 0.2);
        let g = seq(m * n, 0.7);
        let mut ws = Workspace::new();
        let mut tn = vec![0.0; k * n];
        gemm_tn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut tn);
        assert_eq!(tn, reference::matmul_tn(&a, &b, m, k, n));
        let mut nt = vec![0.0; m * k];
        gemm_nt_with(DET, &mut ws, &g, &w, m, n, k, Epilogue::None, &mut nt);
        assert_eq!(nt, reference::matmul_nt(&g, &w, m, n, k));
    }

    #[test]
    fn block_boundary_shapes_match_reference() {
        // exactly MC/NC, one past, one short
        for &(m, n) in &[(MC, NC), (MC + 1, NC + 1), (MC - 1, NR), (MR, NC - 1), (1, 1)] {
            let k = 33;
            let a = seq(m * k, 0.05);
            let b = seq(k * n, 0.02);
            let mut ws = Workspace::new();
            let mut out = vec![0.0; m * n];
            gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::None, &mut out);
            assert_eq!(out, reference::matmul(&a, &b, m, k, n), "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn panel_rows_is_strip_rounded_and_block_capped() {
        assert_eq!(panel_rows(1, MC, MR), MR);
        assert_eq!(panel_rows(MR + 1, MC, MR), 2 * MR);
        assert_eq!(panel_rows(MC - 1, MC, MR), MC);
        assert_eq!(panel_rows(MC, MC, MR), MC);
        assert_eq!(panel_rows(10 * MC, MC, MR), MC);
        // the skewed conv-dW shape: tiny n never reserves a full NC panel
        assert_eq!(panel_rows(5, NC, NR), NR);
    }

    #[test]
    fn k_zero_is_epilogue_of_zero() {
        let bias = [1.0, -2.0, 3.0];
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; 2 * 3];
        gemm_nn_with(DET, &mut ws, &[], &[], 2, 0, 3, Epilogue::BiasRelu(&bias), &mut out);
        assert_eq!(out, vec![1.0, 0.0, 3.0, 1.0, 0.0, 3.0]);
    }

    #[test]
    fn fused_bias_relu_matches_unfused() {
        let (m, k, n) = (6, 11, 10);
        let a = seq(m * k, 0.2);
        let b = seq(k * n, 0.15);
        let bias = seq(n, 0.9);
        let mut ws = Workspace::new();
        let mut fused = vec![0.0; m * n];
        gemm_nn_with(DET, &mut ws, &a, &b, m, k, n, Epilogue::BiasRelu(&bias), &mut fused);
        let mut unfused = reference::matmul(&a, &b, m, k, n);
        for row in unfused.chunks_exact_mut(n) {
            for (z, &bv) in row.iter_mut().zip(&bias) {
                *z = (*z + bv).max(0.0);
            }
        }
        assert_eq!(fused, unfused);
    }

    #[test]
    fn gather_skips_zero_centroid_but_matches_dense() {
        let (m, k, n) = (3, 4, 5);
        let a = seq(m * k, 0.3);
        let cb = [0.0, 0.75, -0.75];
        let idx: Vec<i32> = (0..k * n).map(|i| (i % 3) as i32).collect();
        let dense: Vec<f32> = idx.iter().map(|&i| cb[i as usize]).collect();
        let bias = seq(n, 0.4);
        let mut ws = Workspace::new();
        let mut out = vec![0.0; m * n];
        gemm_gather_nn_with(DET, &mut ws, &a, &idx, &cb, m, k, n, Epilogue::Bias(&bias), &mut out);
        let mut want = vec![0.0; m * n];
        gemm_nn_with(DET, &mut ws, &a, &dense, m, k, n, Epilogue::Bias(&bias), &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn empty_codebook_is_bias_only_zero_output() {
        let (m, k, n) = (2, 3, 2);
        let a = seq(m * k, 1.0);
        let idx = vec![0i32; k * n];
        let bias = [0.5, -0.5];
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; m * n];
        gemm_gather_nn_with(DET, &mut ws, &a, &idx, &[], m, k, n, Epilogue::Bias(&bias), &mut out);
        assert_eq!(out, vec![0.5, -0.5, 0.5, -0.5]);
    }

    #[test]
    fn empty_codebook_through_the_pack_layer_is_zero_weights() {
        // bypass gemm_gather_nn's early-out: hand the core a Gather
        // operand with an empty codebook directly — the pack layer must
        // zero-fill, not underflow-panic (the PR 8 bugfix)
        let (m, k, n) = (2, 3, 2);
        let a = seq(m * k, 1.0);
        let idx = vec![1i32; k * n];
        let bias = [0.5, -0.5];
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; m * n];
        gemm_with(
            DET,
            &mut ws,
            m,
            n,
            k,
            AOperand::Dense(View::nn(&a, k)),
            BOperand::Gather { idx: &idx, codebook: &[] },
            Epilogue::Bias(&bias),
            &mut out,
        );
        assert_eq!(out, vec![0.5, -0.5, 0.5, -0.5]);
    }

    #[test]
    fn dirty_workspace_does_not_change_results() {
        let (m, k, n) = (17, 23, 9);
        let a = seq(m * k, 0.11);
        let b = seq(k * n, 0.07);
        let mut fresh = Workspace::new();
        let mut clean = vec![0.0; m * n];
        gemm_nn_with(DET, &mut fresh, &a, &b, m, k, n, Epilogue::None, &mut clean);
        // pollute a workspace with a larger, unrelated GEMM first
        let mut dirty = Workspace::new();
        let big = seq(64 * 64, 3.3);
        let mut sink = vec![0.0; 64 * 64];
        gemm_nn_with(DET, &mut dirty, &big, &big, 64, 64, 64, Epilogue::None, &mut sink);
        let mut out = vec![0.0; m * n];
        gemm_nn_with(DET, &mut dirty, &a, &b, m, k, n, Epilogue::None, &mut out);
        assert_eq!(out, clean);
    }

    #[test]
    fn row_split_is_bitwise_identical_to_serial_per_kernel() {
        // enough rows for several MC blocks, ragged on every axis; Scale
        // epilogue exercises the row re-basing
        let (m, k, n) = (3 * MC + 5, 19, NR + 3);
        let a = seq(m * k, 0.13);
        let b = seq(k * n, 0.21);
        let scale = seq(m * n, 0.33);
        for kern in Kernel::available() {
            let mut ws = Workspace::new();
            let mut serial = vec![0.0; m * n];
            let one = GemmOpts { kernel: kern, threads: 1 };
            gemm_nn_with(one, &mut ws, &a, &b, m, k, n, Epilogue::Scale(&scale), &mut serial);
            let mut split = vec![0.0; m * n];
            let four = GemmOpts { kernel: kern, threads: 4 };
            gemm_nn_with(four, &mut ws, &a, &b, m, k, n, Epilogue::Scale(&scale), &mut split);
            assert_eq!(split, serial, "kernel {}", kern.name());
        }
    }
}
