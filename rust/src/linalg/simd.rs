//! Vectorized micro-kernels and the two-tier execution mode behind them.
//!
//! The blocked core of [`crate::linalg::gemm`] consumes packed `MR`/`NR`
//! strips through exactly one inner loop — the micro-kernel. This module
//! holds every implementation of that loop plus the runtime dispatch that
//! picks one:
//!
//! * [`Kernel::Scalar`] — the portable loop the autovectorizer already
//!   handles well. Each output element accumulates its `k` products in
//!   ascending order with a separate multiply and add per step, which is
//!   the same arithmetic the naive [`crate::linalg::reference`] loops
//!   perform — so its results are **bitwise-equal** to the oracle.
//! * [`Kernel::Avx2`] (x86_64) — hand-written `core::arch` kernel holding
//!   the full `MR×NR` accumulator tile in eight 8-lane `ymm` registers
//!   and issuing one broadcast + two FMAs per `k` step. The `k` order is
//!   still ascending per lane, but FMA contracts each multiply-add into a
//!   single rounding, so results are *not* bitwise-equal to scalar — they
//!   are (weakly) more accurate, and held to the envelope of
//!   [`crate::linalg::conformance`].
//! * [`Kernel::Neon`] (aarch64) — the same tile in sixteen 4-lane `q`
//!   registers via `vfmaq_f32`, with the same contract as AVX2.
//!
//! **Two-tier contract.** The *deterministic tier* (scalar kernel, serial
//! blocks — [`GemmOpts::deterministic`]) stays bitwise-equal to the naive
//! reference, preserving the campaign serial≡parallel row identity and
//! the durable-store byte-equality gates. The *fast tier* (best available
//! vector kernel, optional intra-op row split) is held to a bounded error
//! envelope asserted per-op in `tests/linalg_simd_conformance.rs`. Within
//! one process the fast tier is still run-to-run and `--jobs`-invariant
//! deterministic — the kernel is fixed per process and the row split does
//! not change any summation order — but it is *not* bit-stable across
//! machines with different vector units, which is exactly what
//! `--deterministic` / `$ECQX_DETERMINISTIC` is for. See DESIGN.md §2.6.
//!
//! Mode resolution is process-global and set-once (a mid-run flip would
//! silently mix tiers inside one store): the first of
//! [`set_deterministic`] (CLI `--deterministic`, campaign options) or the
//! `$ECQX_DETERMINISTIC` env var wins. `$ECQX_KERNEL`
//! (`scalar`/`avx2`/`neon`) forces a specific kernel in the fast tier and
//! `$ECQX_GEMM_THREADS` enables the intra-op row split; both are perf
//! knobs, never correctness knobs — an unavailable forced kernel falls
//! back to the best available one.

use super::gemm::{MR, NR};
use std::sync::OnceLock;

/// Micro-kernel implementation selector. Constructing a variant is always
/// safe: the dispatcher re-checks availability and falls back to
/// [`Kernel::Scalar`] rather than executing an illegal instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar loop; the deterministic tier (bitwise-equal to the
    /// naive reference).
    Scalar,
    /// 8-lane f32 FMA kernel (x86_64 with AVX2+FMA).
    Avx2,
    /// 4-lane f32 FMA kernel (aarch64 with NEON).
    Neon,
}

impl Kernel {
    /// Stable lowercase name (used by `$ECQX_KERNEL` and the
    /// `BENCH_host.json` `kernel`/`dispatch` fields).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Parse a `$ECQX_KERNEL` value; `None` for unknown names.
    pub fn from_name(name: &str) -> Option<Kernel> {
        match name {
            "scalar" => Some(Kernel::Scalar),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            _ => None,
        }
    }

    /// Whether this kernel can execute on the current host (runtime CPU
    /// feature detection; `std` caches the CPUID/auxval probe, so this is
    /// an atomic load per call).
    pub fn is_available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"),
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Best kernel available on this host (the fast-tier default).
    pub fn detect() -> Kernel {
        if Kernel::Avx2.is_available() {
            Kernel::Avx2
        } else if Kernel::Neon.is_available() {
            Kernel::Neon
        } else {
            Kernel::Scalar
        }
    }

    /// Every kernel the current host can execute, scalar first. This is
    /// what the conformance suite and the `simd_kernels` bench section
    /// iterate over.
    pub fn available() -> Vec<Kernel> {
        [Kernel::Scalar, Kernel::Avx2, Kernel::Neon]
            .into_iter()
            .filter(|k| k.is_available())
            .collect()
    }
}

/// Dispatch one micro-kernel invocation: `acc[r][c] += Σ_p A[r,p]·B[p,c]`
/// over packed strips of exactly `k·MR` / `k·NR` floats. Falls back to
/// the scalar kernel when `kernel` cannot run on this host, so a
/// hand-constructed [`Kernel`] value is never undefined behavior.
#[inline]
pub(crate) fn microkernel(
    kernel: Kernel,
    k: usize,
    apanel: &[f32],
    bpanel: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert_eq!(apanel.len(), k * MR);
    debug_assert_eq!(bpanel.len(), k * NR);
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability re-checked here, immediately before the call
        Kernel::Avx2 if Kernel::Avx2.is_available() => unsafe {
            microkernel_avx2(k, apanel, bpanel, acc)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: availability re-checked here, immediately before the call
        Kernel::Neon if Kernel::Neon.is_available() => unsafe {
            microkernel_neon(k, apanel, bpanel, acc)
        },
        _ => microkernel_scalar(k, apanel, bpanel, acc),
    }
}

/// The portable register-tile loop: a broadcast-multiply-add per `k` step
/// with constant `NR` bounds and **no reduction reassociation**, so the
/// autovectorizer emits SIMD without `unsafe` and results stay
/// bitwise-equal to the naive reference (separate mul + add roundings in
/// ascending-`k` order, exactly like the oracle).
#[inline(always)]
pub(crate) fn microkernel_scalar(
    k: usize,
    apanel: &[f32],
    bpanel: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert_eq!(apanel.len(), k * MR);
    debug_assert_eq!(bpanel.len(), k * NR);
    for (arow, brow) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        for (r, &av) in arow.iter().enumerate() {
            let accr = &mut acc[r];
            for (a, &bv) in accr.iter_mut().zip(brow.iter()) {
                *a += av * bv;
            }
        }
    }
}

/// AVX2+FMA kernel: the `MR×NR = 4×16` accumulator tile lives in eight
/// `ymm` registers (4 rows × two 8-lane vectors); each `k` step is two
/// contiguous B loads, `MR` scalar broadcasts from the A strip, and eight
/// `vfmadd231ps`. Ascending-`k` order per lane is preserved — the only
/// deviation from scalar is the FMA's single rounding per step.
///
/// # Safety
/// Requires AVX2 and FMA at runtime (checked by the dispatcher).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn microkernel_avx2(k: usize, apanel: &[f32], bpanel: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    let mut c: [[__m256; 2]; MR] = [[_mm256_setzero_ps(); 2]; MR];
    for (r, row) in acc.iter().enumerate() {
        c[r][0] = _mm256_loadu_ps(row.as_ptr());
        c[r][1] = _mm256_loadu_ps(row.as_ptr().add(8));
    }
    for p in 0..k {
        let bp = bpanel.as_ptr().add(p * NR);
        let b0 = _mm256_loadu_ps(bp);
        let b1 = _mm256_loadu_ps(bp.add(8));
        let ap = apanel.as_ptr().add(p * MR);
        for (r, cr) in c.iter_mut().enumerate() {
            let a = _mm256_set1_ps(*ap.add(r));
            cr[0] = _mm256_fmadd_ps(a, b0, cr[0]);
            cr[1] = _mm256_fmadd_ps(a, b1, cr[1]);
        }
    }
    for (r, row) in acc.iter_mut().enumerate() {
        _mm256_storeu_ps(row.as_mut_ptr(), c[r][0]);
        _mm256_storeu_ps(row.as_mut_ptr().add(8), c[r][1]);
    }
}

/// NEON kernel: the `4×16` tile in sixteen `q` registers (4 rows × four
/// 4-lane vectors — aarch64 has 32, so B's four vectors and the broadcast
/// still fit); `vfmaq_f32` per step with the same contract as AVX2.
///
/// # Safety
/// Requires NEON at runtime (checked by the dispatcher).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn microkernel_neon(k: usize, apanel: &[f32], bpanel: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::aarch64::*;
    let mut c: [[float32x4_t; 4]; MR] = [[vdupq_n_f32(0.0); 4]; MR];
    for (r, row) in acc.iter().enumerate() {
        for v in 0..4 {
            c[r][v] = vld1q_f32(row.as_ptr().add(4 * v));
        }
    }
    for p in 0..k {
        let bp = bpanel.as_ptr().add(p * NR);
        let b = [
            vld1q_f32(bp),
            vld1q_f32(bp.add(4)),
            vld1q_f32(bp.add(8)),
            vld1q_f32(bp.add(12)),
        ];
        let ap = apanel.as_ptr().add(p * MR);
        for (r, cr) in c.iter_mut().enumerate() {
            let a = vdupq_n_f32(*ap.add(r));
            for (v, cv) in cr.iter_mut().enumerate() {
                *cv = vfmaq_f32(*cv, a, b[v]);
            }
        }
    }
    for (r, row) in acc.iter_mut().enumerate() {
        for v in 0..4 {
            vst1q_f32(row.as_mut_ptr().add(4 * v), c[r][v]);
        }
    }
}

// ---------------------------------------------------------------------------
// Process-global execution mode
// ---------------------------------------------------------------------------

static DETERMINISTIC: OnceLock<bool> = OnceLock::new();
static FORCED_KERNEL: OnceLock<Option<Kernel>> = OnceLock::new();
static GEMM_THREADS: OnceLock<usize> = OnceLock::new();

/// Select the deterministic tier for the rest of the process (CLI
/// `--deterministic`, `CampaignOptions::deterministic`). Set-once: the
/// first call (or the first mode query, which reads
/// `$ECQX_DETERMINISTIC`) wins, so one process can never mix tiers —
/// a later call with a different value is ignored.
pub fn set_deterministic(on: bool) {
    let _ = DETERMINISTIC.set(on);
}

/// Whether the process runs the deterministic tier (scalar kernel, serial
/// blocks, bitwise-equal to the naive reference). Defaults to the
/// `$ECQX_DETERMINISTIC` env var (unset/empty/`0` = fast tier) unless
/// [`set_deterministic`] ran first.
pub fn deterministic_mode() -> bool {
    *DETERMINISTIC.get_or_init(|| {
        std::env::var("ECQX_DETERMINISTIC")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// `$ECQX_KERNEL` as a kernel, if set to a known name. Unknown names are
/// ignored here (the library must not panic on env noise); the CLI
/// validates the value up front and errors politely.
fn forced_kernel() -> Option<Kernel> {
    *FORCED_KERNEL
        .get_or_init(|| std::env::var("ECQX_KERNEL").ok().and_then(|v| Kernel::from_name(&v)))
}

/// `$ECQX_GEMM_THREADS`, clamped to at least 1. The default of 1 keeps
/// single GEMMs serial — campaign parallelism across trials is the
/// first-choice use of cores, and the warm hot loop stays allocation-free
/// (`tests/alloc_steady_state.rs`); the intra-op split is for wide
/// machines running few concurrent trials.
fn env_threads() -> usize {
    *GEMM_THREADS.get_or_init(|| {
        std::env::var("ECQX_GEMM_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1)
    })
}

/// Per-call GEMM execution options: which micro-kernel runs the register
/// tiles and how many threads may split one GEMM's MC row blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmOpts {
    /// Micro-kernel (falls back to scalar if unavailable on this host).
    pub kernel: Kernel,
    /// Max threads for the intra-op row split (1 = serial; only dense-A
    /// GEMMs with at least two MC blocks ever split).
    pub threads: usize,
}

impl GemmOpts {
    /// The process-wide mode: deterministic tier if selected, otherwise
    /// the best available (or `$ECQX_KERNEL`-forced) kernel with
    /// `$ECQX_GEMM_THREADS` intra-op threads. This is what the plain
    /// `gemm()` / conv entry points use.
    pub fn dispatch() -> GemmOpts {
        GemmOpts::resolve(deterministic_mode(), forced_kernel(), env_threads())
    }

    /// The deterministic tier: scalar kernel, serial blocks —
    /// bitwise-equal to the naive reference.
    pub fn deterministic() -> GemmOpts {
        GemmOpts { kernel: Kernel::Scalar, threads: 1 }
    }

    /// A specific kernel, serial blocks (conformance tests, benches).
    pub fn with_kernel(kernel: Kernel) -> GemmOpts {
        GemmOpts { kernel, threads: 1 }
    }

    /// Pure mode-resolution logic (unit-testable without touching the
    /// process globals): deterministic wins outright; otherwise a forced
    /// kernel is honored only if the host can run it.
    pub fn resolve(deterministic: bool, forced: Option<Kernel>, threads: usize) -> GemmOpts {
        if deterministic {
            return GemmOpts::deterministic();
        }
        let kernel = match forced {
            Some(k) if k.is_available() => k,
            _ => Kernel::detect(),
        };
        GemmOpts { kernel, threads: threads.max(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_roundtrip() {
        for k in [Kernel::Scalar, Kernel::Avx2, Kernel::Neon] {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        assert_eq!(Kernel::from_name("avx512"), None);
        assert_eq!(Kernel::from_name(""), None);
    }

    #[test]
    fn scalar_is_always_available_and_listed_first() {
        assert!(Kernel::Scalar.is_available());
        let ks = Kernel::available();
        assert_eq!(ks[0], Kernel::Scalar);
        assert!(ks.contains(&Kernel::detect()));
    }

    #[test]
    fn resolve_deterministic_wins_over_everything() {
        let opts = GemmOpts::resolve(true, Some(Kernel::Avx2), 8);
        assert_eq!(opts, GemmOpts::deterministic());
        assert_eq!(opts.kernel, Kernel::Scalar);
        assert_eq!(opts.threads, 1);
    }

    #[test]
    fn resolve_honors_available_forced_kernel_and_clamps_threads() {
        let opts = GemmOpts::resolve(false, Some(Kernel::Scalar), 0);
        assert_eq!(opts.kernel, Kernel::Scalar);
        assert_eq!(opts.threads, 1, "threads clamp to >= 1");
        let opts = GemmOpts::resolve(false, None, 4);
        assert_eq!(opts.kernel, Kernel::detect());
        assert_eq!(opts.threads, 4);
    }

    #[test]
    fn resolve_ignores_unavailable_forced_kernel() {
        // at most one of AVX2/NEON can be available on any given host, so
        // the other must fall back to detect()
        for k in [Kernel::Avx2, Kernel::Neon] {
            if !k.is_available() {
                assert_eq!(GemmOpts::resolve(false, Some(k), 1).kernel, Kernel::detect());
            }
        }
    }

    #[test]
    fn unavailable_kernel_dispatch_falls_back_to_scalar() {
        let k = 7;
        let apanel: Vec<f32> = (0..k * MR).map(|i| i as f32 * 0.25 - 3.0).collect();
        let bpanel: Vec<f32> = (0..k * NR).map(|i| 2.0 - i as f32 * 0.125).collect();
        let mut want = [[0.0f32; NR]; MR];
        microkernel_scalar(k, &apanel, &bpanel, &mut want);
        for kern in [Kernel::Avx2, Kernel::Neon] {
            if !kern.is_available() {
                let mut got = [[0.0f32; NR]; MR];
                microkernel(kern, k, &apanel, &bpanel, &mut got);
                assert_eq!(got, want, "{} must fall back to scalar bitwise", kern.name());
            }
        }
    }
}
