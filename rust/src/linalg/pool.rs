//! NHWC max/average pooling: forward, backward, and the LRP
//! redistribution rules the host CNN ladder composes (DESIGN.md §2.8).
//!
//! Pooling windows are VALID-style (`out = (in - k)/stride + 1`, windows
//! never read outside the image), which covers every token the manifest
//! `conv_pool` attr can carry: `max2`/`avg2` (2×2, stride 2) and `gap`
//! (global average = a full-image window). The kernels are plain scalar
//! loops with a fixed ascending accumulation/scan order and first-index
//! tie-breaking for max, so they sit in the deterministic tier by
//! construction — there is no vectorized variant to hold to an envelope.
//! [`crate::linalg::reference`] keeps independently-written oracles
//! (`maxpool2d_naive`, `avgpool2d_naive`) that the property suite
//! compares bitwise.

/// Pooling reduction applied over each window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolOp {
    /// window max, winner-takes-all backward/LRP routing
    Max,
    /// window mean, uniform backward, proportional (stabilized) LRP
    Avg,
}

/// Pooling geometry over an NHWC `[n, h, w, c]` input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool2d {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// window height (VALID: `kh <= h`)
    pub kh: usize,
    /// window width (VALID: `kw <= w`)
    pub kw: usize,
    pub stride: usize,
    pub op: PoolOp,
}

impl Pool2d {
    /// Output spatial dims (VALID windows: `(in - k)/stride + 1`).
    pub fn out_hw(&self) -> (usize, usize) {
        assert!(self.kh <= self.h && self.kw <= self.w, "pool window exceeds image");
        assert!(self.stride > 0, "pool stride 0");
        ((self.h - self.kh) / self.stride + 1, (self.w - self.kw) / self.stride + 1)
    }

    /// Input element count `n*h*w*c`.
    pub fn in_len(&self) -> usize {
        self.n * self.h * self.w * self.c
    }

    /// Output element count.
    pub fn out_len(&self) -> usize {
        let (oh, ow) = self.out_hw();
        self.n * oh * ow * self.c
    }
}

/// Iterate output positions in row-major NHWC order, handing each
/// `(flat output index, window top-left flat input offset of channel ch)`
/// to `f` — the single definition of the window walk shared by every
/// kernel here, which is what keeps forward, backward and LRP scatter
/// orders identical (and therefore deterministic).
fn for_each_window(g: &Pool2d, mut f: impl FnMut(usize, usize, usize)) {
    let (oh, ow) = g.out_hw();
    let mut j = 0usize;
    for b in 0..g.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..g.c {
                    let base = ((b * g.h + oy * g.stride) * g.w + ox * g.stride) * g.c + ch;
                    f(j, base, ch);
                    j += 1;
                }
            }
        }
    }
}

/// Max-pool forward. `argmax[j]` records the flat input index of the
/// winning tap for output `j` (first window index wins ties — the scan is
/// ascending `(ph, pw)`), giving the backward/LRP passes an O(1) scatter.
pub fn maxpool2d(g: &Pool2d, x: &[f32], argmax: &mut [usize], out: &mut [f32]) {
    assert_eq!(x.len(), g.in_len(), "maxpool2d input shape");
    assert_eq!(out.len(), g.out_len(), "maxpool2d output shape");
    assert_eq!(argmax.len(), out.len(), "maxpool2d argmax shape");
    assert_eq!(g.op, PoolOp::Max, "maxpool2d on non-max geometry");
    for_each_window(g, |j, base, _ch| {
        let mut best = x[base];
        let mut best_i = base;
        for ph in 0..g.kh {
            for pw in 0..g.kw {
                let i = base + (ph * g.w + pw) * g.c;
                if x[i] > best {
                    best = x[i];
                    best_i = i;
                }
            }
        }
        out[j] = best;
        argmax[j] = best_i;
    });
}

/// Max-pool backward: route `dy[j]` to the recorded winner (the same
/// winner-takes-all scatter is the max-pool LRP rule). Ascending output
/// scan, so overlapping windows accumulate in a fixed order.
pub fn maxpool2d_bwd(g: &Pool2d, argmax: &[usize], dy: &[f32], dx: &mut [f32]) {
    assert_eq!(dy.len(), g.out_len(), "maxpool2d_bwd dy shape");
    assert_eq!(dx.len(), g.in_len(), "maxpool2d_bwd dx shape");
    assert_eq!(argmax.len(), dy.len(), "maxpool2d_bwd argmax shape");
    dx.fill(0.0);
    for (j, &i) in argmax.iter().enumerate() {
        dx[i] += dy[j];
    }
}

/// Average-pool forward: window mean (VALID windows are always fully
/// in-image, so the divisor is the constant `kh·kw`). Taps accumulate in
/// ascending `(ph, pw)` order.
pub fn avgpool2d(g: &Pool2d, x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), g.in_len(), "avgpool2d input shape");
    assert_eq!(out.len(), g.out_len(), "avgpool2d output shape");
    assert_eq!(g.op, PoolOp::Avg, "avgpool2d on non-avg geometry");
    let inv = 1.0f32 / (g.kh * g.kw) as f32;
    for_each_window(g, |j, base, _ch| {
        let mut acc = 0.0f32;
        for ph in 0..g.kh {
            for pw in 0..g.kw {
                acc += x[base + (ph * g.w + pw) * g.c];
            }
        }
        out[j] = acc * inv;
    });
}

/// Average-pool backward: `dy[j]/(kh·kw)` to every tap of window `j`,
/// ascending scatter order.
pub fn avgpool2d_bwd(g: &Pool2d, dy: &[f32], dx: &mut [f32]) {
    assert_eq!(dy.len(), g.out_len(), "avgpool2d_bwd dy shape");
    assert_eq!(dx.len(), g.in_len(), "avgpool2d_bwd dx shape");
    dx.fill(0.0);
    let inv = 1.0f32 / (g.kh * g.kw) as f32;
    for_each_window(g, |j, base, _ch| {
        let d = dy[j] * inv;
        for ph in 0..g.kh {
            for pw in 0..g.kw {
                dx[base + (ph * g.w + pw) * g.c] += d;
            }
        }
    });
}

/// Average-pool LRP: redistribute each output's relevance over its window
/// proportionally to the tap values — `R_i += x_i · R_j / stab(Σ window)`
/// — the stabilized z-rule on the (unnormalized) window sum. Conserves
/// `Σ R_in ≈ Σ R` away from stabilizer-dominated windows; on an all-ReLU
/// ladder the taps are non-negative, so the shares lie in `[0, 1]`.
pub fn avgpool2d_lrp(g: &Pool2d, x: &[f32], r: &[f32], rin: &mut [f32]) {
    assert_eq!(x.len(), g.in_len(), "avgpool2d_lrp input shape");
    assert_eq!(r.len(), g.out_len(), "avgpool2d_lrp relevance shape");
    assert_eq!(rin.len(), g.in_len(), "avgpool2d_lrp rin shape");
    rin.fill(0.0);
    for_each_window(g, |j, base, _ch| {
        let mut z = 0.0f32;
        for ph in 0..g.kh {
            for pw in 0..g.kw {
                z += x[base + (ph * g.w + pw) * g.c];
            }
        }
        let s = r[j] / super::lrp_ab::stabilize(z);
        for ph in 0..g.kh {
            for pw in 0..g.kw {
                let i = base + (ph * g.w + pw) * g.c;
                rin[i] += x[i] * s;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g2(n: usize, h: usize, w: usize, c: usize, op: PoolOp) -> Pool2d {
        Pool2d { n, h, w, c, kh: 2, kw: 2, stride: 2, op }
    }

    #[test]
    fn maxpool_picks_window_max_and_first_index_ties() {
        let g = g2(1, 2, 4, 1, PoolOp::Max);
        let x = [1.0, 3.0, 2.0, 2.0, 0.5, -1.0, 2.0, 2.0];
        let mut out = vec![0.0; 2];
        let mut am = vec![0usize; 2];
        maxpool2d(&g, &x, &mut am, &mut out);
        assert_eq!(out, vec![3.0, 2.0]);
        assert_eq!(am[0], 1);
        // four-way tie in the second window: the ascending scan keeps the
        // first tap (flat index 2)
        assert_eq!(am[1], 2);
    }

    #[test]
    fn maxpool_bwd_routes_to_winner() {
        let g = g2(1, 2, 2, 1, PoolOp::Max);
        let x = [0.0, 4.0, 1.0, 2.0];
        let (mut out, mut am) = (vec![0.0; 1], vec![0usize; 1]);
        maxpool2d(&g, &x, &mut am, &mut out);
        let mut dx = vec![9.0; 4];
        maxpool2d_bwd(&g, &am, &[5.0], &mut dx);
        assert_eq!(dx, vec![0.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn avgpool_and_gap_mean_the_window() {
        let g = g2(1, 2, 2, 2, PoolOp::Avg);
        // NHWC: channel 0 = [1,2,3,4], channel 1 = [10,20,30,40]
        let x = [1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut out = vec![0.0; 2];
        avgpool2d(&g, &x, &mut out);
        assert_eq!(out, vec![2.5, 25.0]);
        // gap == avg with a full-image window
        let gap = Pool2d { kh: 2, kw: 2, stride: 1, ..g };
        let mut out2 = vec![0.0; 2];
        avgpool2d(&gap, &x, &mut out2);
        assert_eq!(out2, out);
    }

    #[test]
    fn avgpool_bwd_spreads_uniformly() {
        let g = g2(1, 2, 2, 1, PoolOp::Avg);
        let mut dx = vec![0.0; 4];
        avgpool2d_bwd(&g, &[8.0], &mut dx);
        assert_eq!(dx, vec![2.0; 4]);
    }

    #[test]
    fn avgpool_lrp_is_proportional_and_conserving() {
        let g = g2(1, 2, 2, 1, PoolOp::Avg);
        let x = [1.0, 3.0, 0.0, 4.0];
        let mut rin = vec![0.0; 4];
        avgpool2d_lrp(&g, &x, &[8.0], &mut rin);
        let total: f32 = rin.iter().sum();
        assert!((total - 8.0).abs() < 1e-4, "conservation, got {total}");
        assert_eq!(rin[2], 0.0, "zero tap gets zero relevance");
        assert!(rin[3] > rin[1] && rin[1] > rin[0], "proportional shares");
    }

    #[test]
    fn valid_window_arithmetic_drops_the_ragged_edge() {
        let g = Pool2d { n: 1, h: 5, w: 7, c: 1, kh: 2, kw: 2, stride: 2, op: PoolOp::Max };
        assert_eq!(g.out_hw(), (2, 3));
        assert_eq!(g.out_len(), 6);
    }
}
