//! BatchNorm kernels for the host CNN ladder: train-mode forward/backward
//! over channels-last rows, inference-mode affine application, the
//! fold-into-conv transform for FP eval, and the running-stat EMA
//! (DESIGN.md §2.8).
//!
//! All tensors are channels-last: a conv output `[n, oh, ow, co]` is
//! treated as `rows = n·oh·ow` rows of `c = co` channels, which is
//! exactly the im2col GEMM's row-major output layout — BN slots between
//! the conv GEMM and the ReLU with no data movement.
//!
//! Determinism: every per-channel reduction walks rows in ascending order
//! into an f64 accumulator (scalar loops, no vector variant), so the
//! kernels are bitwise run-to-run stable and land in the deterministic
//! tier unchanged. [`crate::linalg::reference::bn_fold_naive`] keeps an
//! independently-written fold oracle for the bitwise property suite.

/// BatchNorm variance stabilizer (torch's `BatchNorm2d` default).
pub const BN_EPS: f32 = 1e-5;

/// Fold inference-mode BN into the preceding conv's weights and bias:
/// with `s = γ/√(σ²+ε)`, `w'[...,co] = w[...,co]·s[co]` and
/// `b' = (b − μ)·s + β`, so `bn(conv(x, w) + b) == conv(x, w') + b'`
/// exactly in real arithmetic (the equivalence suite bounds the f32
/// rounding difference). `w` is HWIO with `co` innermost.
pub fn bn_fold(
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
    w: &[f32],
    b: &[f32],
    wf: &mut [f32],
    bf: &mut [f32],
) {
    let c = gamma.len();
    assert!(
        beta.len() == c && mean.len() == c && var.len() == c && b.len() == c && bf.len() == c,
        "bn_fold channel shapes"
    );
    assert_eq!(w.len(), wf.len(), "bn_fold filter shape");
    assert_eq!(w.len() % c, 0, "bn_fold filter not a multiple of co");
    let mut s = vec![0.0f32; c];
    for ch in 0..c {
        s[ch] = gamma[ch] / (var[ch] + eps).sqrt();
        bf[ch] = (b[ch] - mean[ch]) * s[ch] + beta[ch];
    }
    for (wo, (wi, &sc)) in wf.iter_mut().zip(w.iter().zip(s.iter().cycle())) {
        *wo = wi * sc;
    }
}

/// Inference-mode BN as a per-channel affine over `[rows, c]` (the
/// quantized-eval path, where the per-channel fold scale cannot enter a
/// shared codebook): `z ← (z − μ)·γ/√(σ²+ε) + β`.
pub fn bn_infer(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32], eps: f32, z: &mut [f32]) {
    let c = gamma.len();
    assert!(beta.len() == c && mean.len() == c && var.len() == c, "bn_infer channel shapes");
    assert_eq!(z.len() % c, 0, "bn_infer rows not a multiple of c");
    let mut s = vec![0.0f32; c];
    let mut t = vec![0.0f32; c];
    for ch in 0..c {
        s[ch] = gamma[ch] / (var[ch] + eps).sqrt();
        t[ch] = beta[ch] - mean[ch] * s[ch];
    }
    for row in z.chunks_exact_mut(c) {
        for (v, (&sc, &tc)) in row.iter_mut().zip(s.iter().zip(&t)) {
            *v = *v * sc + tc;
        }
    }
}

/// Train-mode BN forward over `[rows, c]`: biased batch statistics
/// (`var = Σ(z−μ)²/rows`), `y = γ·(z−μ)/√(σ²+ε) + β`. Writes the batch
/// `mean`/`var` out for the backward pass and the running-stat EMA.
pub fn bn_train_fwd(
    z: &[f32],
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    eps: f32,
    y: &mut [f32],
    mean: &mut [f32],
    var: &mut [f32],
) {
    assert!(c > 0 && z.len() % c == 0, "bn_train_fwd rows not a multiple of c");
    assert_eq!(y.len(), z.len(), "bn_train_fwd output shape");
    assert!(
        gamma.len() == c && beta.len() == c && mean.len() == c && var.len() == c,
        "bn_train_fwd channel shapes"
    );
    let rows = z.len() / c;
    assert!(rows > 0, "bn_train_fwd needs at least one row");
    let inv_n = 1.0f64 / rows as f64;
    // two-pass, ascending rows, f64 accumulators: deterministic and stable
    let mut acc = vec![0.0f64; c];
    for row in z.chunks_exact(c) {
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += v as f64;
        }
    }
    for (m, &a) in mean.iter_mut().zip(&acc) {
        *m = (a * inv_n) as f32;
    }
    acc.fill(0.0);
    for row in z.chunks_exact(c) {
        for ((a, &v), &m) in acc.iter_mut().zip(row).zip(mean.iter()) {
            let d = (v - m) as f64;
            *a += d * d;
        }
    }
    for (s, &a) in var.iter_mut().zip(&acc) {
        *s = (a * inv_n) as f32;
    }
    let mut ivar = vec![0.0f32; c];
    for (iv, &v) in ivar.iter_mut().zip(var.iter()) {
        *iv = 1.0 / (v + eps).sqrt();
    }
    for (yrow, zrow) in y.chunks_exact_mut(c).zip(z.chunks_exact(c)) {
        for ch in 0..c {
            yrow[ch] = gamma[ch] * (zrow[ch] - mean[ch]) * ivar[ch] + beta[ch];
        }
    }
}

/// Train-mode BN backward over `[rows, c]` given the forward's batch
/// `mean`/`var`: the full batch-coupled gradient (including the `Σ x̂`
/// terms), `dγ = Σ dy·x̂`, `dβ = Σ dy`. Reductions walk rows ascending
/// into f64 accumulators, matching the forward's determinism.
#[allow(clippy::too_many_arguments)]
pub fn bn_train_bwd(
    z: &[f32],
    c: usize,
    gamma: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
    dy: &[f32],
    dz: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    assert!(c > 0 && z.len() % c == 0, "bn_train_bwd rows not a multiple of c");
    assert!(dy.len() == z.len() && dz.len() == z.len(), "bn_train_bwd grad shapes");
    assert!(
        gamma.len() == c && mean.len() == c && var.len() == c,
        "bn_train_bwd channel shapes"
    );
    assert!(dgamma.len() == c && dbeta.len() == c, "bn_train_bwd dparam shapes");
    let rows = z.len() / c;
    let inv_n = 1.0f64 / rows as f64;
    let mut ivar = vec![0.0f64; c];
    for (iv, &v) in ivar.iter_mut().zip(var.iter()) {
        *iv = 1.0 / ((v + eps) as f64).sqrt();
    }
    // per-channel reductions: Σdy, Σdy·x̂ (ascending rows)
    let mut sum_dy = vec![0.0f64; c];
    let mut sum_dy_xh = vec![0.0f64; c];
    for (zrow, dyrow) in z.chunks_exact(c).zip(dy.chunks_exact(c)) {
        for ch in 0..c {
            let xh = (zrow[ch] - mean[ch]) as f64 * ivar[ch];
            sum_dy[ch] += dyrow[ch] as f64;
            sum_dy_xh[ch] += dyrow[ch] as f64 * xh;
        }
    }
    for ch in 0..c {
        dgamma[ch] = sum_dy_xh[ch] as f32;
        dbeta[ch] = sum_dy[ch] as f32;
    }
    // dz = (γ·ivar/N) · (N·dy − Σdy − x̂·Σdy·x̂)
    for ((zrow, dyrow), dzrow) in
        z.chunks_exact(c).zip(dy.chunks_exact(c)).zip(dz.chunks_exact_mut(c))
    {
        for ch in 0..c {
            let xh = (zrow[ch] - mean[ch]) as f64 * ivar[ch];
            let g = gamma[ch] as f64 * ivar[ch];
            dzrow[ch] =
                (g * (dyrow[ch] as f64 - inv_n * (sum_dy[ch] + xh * sum_dy_xh[ch]))) as f32;
        }
    }
}

/// Running-stat EMA: `running ← (1−m)·running + m·batch` (torch
/// convention — `m` weights the new batch statistic).
pub fn ema_update(running: &mut [f32], batch: &[f32], momentum: f32) {
    assert_eq!(running.len(), batch.len(), "ema_update shapes");
    for (r, &b) in running.iter_mut().zip(batch) {
        *r = (1.0 - momentum) * *r + momentum * b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwd_normalizes_each_channel() {
        // 4 rows × 2 channels; identity affine
        let z = [1.0, 10.0, 3.0, 30.0, 5.0, 50.0, 7.0, 70.0];
        let (g, b) = ([1.0, 1.0], [0.0, 0.0]);
        let mut y = [0.0; 8];
        let (mut m, mut v) = ([0.0; 2], [0.0; 2]);
        bn_train_fwd(&z, 2, &g, &b, 0.0, &mut y, &mut m, &mut v);
        assert_eq!(m, [4.0, 40.0]);
        assert_eq!(v, [5.0, 500.0]);
        for ch in 0..2 {
            let mean: f32 = (0..4).map(|r| y[r * 2 + ch]).sum::<f32>() / 4.0;
            let var: f32 = (0..4).map(|r| (y[r * 2 + ch] - mean).powi(2)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-6 && (var - 1.0).abs() < 1e-5, "ch{ch}: {mean} {var}");
        }
    }

    #[test]
    fn bwd_is_orthogonal_to_shift_and_scale() {
        // y is invariant under per-channel affine re-parameterizations of
        // z, so dz must satisfy Σ_rows dz = 0 and Σ_rows dz·z = 0
        let z = [0.3, -1.0, 1.7, 2.0, -0.4, 0.5, 2.2, -3.0];
        let dy = [1.0, 0.2, -0.7, 0.5, 0.1, -0.2, 0.9, 1.1];
        let gamma = [1.3, 0.7];
        let (mut y, mut m, mut v) = ([0.0; 8], [0.0; 2], [0.0; 2]);
        bn_train_fwd(&z, 2, &gamma, &[0.0, 0.0], BN_EPS, &mut y, &mut m, &mut v);
        let (mut dz, mut dg, mut db) = ([0.0; 8], [0.0; 2], [0.0; 2]);
        bn_train_bwd(&z, 2, &gamma, &m, &v, BN_EPS, &dy, &mut dz, &mut dg, &mut db);
        for ch in 0..2 {
            let s: f32 = (0..4).map(|r| dz[r * 2 + ch]).sum();
            let sz: f32 = (0..4).map(|r| dz[r * 2 + ch] * z[r * 2 + ch]).sum();
            assert!(s.abs() < 1e-5, "Σdz ch{ch} = {s}");
            assert!(sz.abs() < 1e-4, "Σdz·z ch{ch} = {sz}");
        }
        assert!((db[0] - 1.0).abs() < 1e-6 && (db[1] - 1.6).abs() < 1e-6, "dβ = Σdy");
    }

    #[test]
    fn fold_matches_affine_composition() {
        let (gamma, beta) = ([2.0f32, 0.5], [0.1f32, -0.3]);
        let (mean, var) = ([1.0f32, -2.0], [4.0f32, 0.25]);
        let w = [0.5, -1.0, 2.0, 0.0, 1.5, -0.5, 0.25, 1.0]; // 4 taps × 2 co
        let b = [0.2f32, -0.1];
        let (mut wf, mut bf) = ([0.0; 8], [0.0; 2]);
        bn_fold(&gamma, &beta, &mean, &var, 0.0, &w, &b, &mut wf, &mut bf);
        let s = [gamma[0] / var[0].sqrt(), gamma[1] / var[1].sqrt()];
        for (i, &v) in wf.iter().enumerate() {
            assert_eq!(v, w[i] * s[i % 2]);
        }
        assert_eq!(bf[0], (b[0] - mean[0]) * s[0] + beta[0]);
        // bn_infer over a 1-tap "conv output" agrees with the folded bias
        let mut z = vec![b[0], b[1]];
        bn_infer(&gamma, &beta, &mean, &var, 0.0, &mut z);
        assert!((z[0] - bf[0]).abs() < 1e-6 && (z[1] - bf[1]).abs() < 1e-6);
    }

    #[test]
    fn ema_moves_toward_batch() {
        let mut r = [0.0f32, 10.0];
        ema_update(&mut r, &[1.0, 0.0], 0.1);
        assert_eq!(r, [0.1, 9.0]);
    }
}
