//! Conv2d lowered onto the blocked GEMM core via *virtual* im2col.
//!
//! A NHWC convolution `out[n,oh,ow,co] = Σ_{kh,kw,ci} x[n, oh·s+kh-ph,
//! ow·s+kw-pw, ci] · w[kh,kw,ci,co]` is a GEMM between the im2col patch
//! matrix `P[n·oh·ow, kh·kw·c]` and the HWIO filter flattened row-major
//! to `[kh·kw·c, co]` — and because the output rows `[n·oh·ow, co]` are
//! exactly NHWC layout, no reshapes ever move data. Instead of
//! materializing `P`, the pack stage of the GEMM extracts patches
//! directly into the `MR`-strip A panel (`pack_patches`), so conv
//! costs one panel's worth of scratch from the per-worker
//! [`Workspace`] — the same buffers every dense layer already reuses.
//! Out-of-image taps pack `0.0`, which contributes exactly nothing, so
//! SAME padding needs no input copy either.
//!
//! The three conv contraction forms map onto the core as:
//!
//! * forward — `P @ W` ([`AOperand::Patches`]), bias/ReLU fused in the
//!   epilogue; [`conv2d_gather`] swaps in the codebook-gather B operand
//!   so quantized conv weights dequantize at pack time like
//!   `qdense_gather` (zero centroid skipped, dense `[k,co]` matrix never
//!   materialized)
//! * dW / per-weight LRP — `Pᵀ @ G` ([`AOperand::PatchesT`]), the
//!   `w ⊙ ·` LRP scaling fused in the epilogue ([`lrp_conv_rw`])
//! * dX — `G @ Wᵀ` per `MC`-row tile into the workspace's dCol buffer,
//!   then a col2im scatter-add ([`conv2d_bwd_input`]); the full
//!   `[n·oh·ow, kh·kw·c]` dCol matrix is never materialized
//!
//! Two-tier determinism (see [`super::simd`] and DESIGN.md §2.6): every
//! GEMM accumulates in ascending contraction order (gemm.rs invariant)
//! and the col2im scatter adds tile rows in ascending `(m, tap)` order
//! with a compile-time-fixed tile height, so conv results are pure
//! functions of the operand values and the selected micro-kernel —
//! identical for any `--jobs` count or workspace reuse pattern. Under
//! the deterministic tier (scalar kernel) they are additionally
//! bitwise-equal to the retained naive direct kernels
//! ([`crate::linalg::reference`]) on finite inputs; the fast tier's FMA
//! kernels are held to the [`super::conformance`] envelope instead.
//! Conv GEMMs always run their blocks serially (the virtual patch
//! operands address rows globally, so the intra-op row split of dense
//! GEMMs does not apply).

use super::gemm::{gemm_core, gemm_with, AOperand, BOperand, Epilogue, MC, MR};
use super::pack::View;
use super::simd::GemmOpts;
use super::workspace::Workspace;

/// Spatial padding mode (XLA conventions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pad {
    /// output spatial dims = `ceil(in / stride)`; total padding
    /// `max((out-1)·stride + k - in, 0)`, split low-before
    Same,
    /// no padding; output = `floor((in - k)/stride) + 1` (0 if `in < k`)
    Valid,
}

/// Geometry of one NHWC × HWIO convolution (batch baked in).
#[derive(Clone, Copy, Debug)]
pub struct Conv2d {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    /// input channels
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    /// output channels
    pub co: usize,
    pub stride: usize,
    pub pad: Pad,
}

fn out_dim(input: usize, k: usize, stride: usize, pad: Pad) -> usize {
    match pad {
        Pad::Same => input.div_ceil(stride),
        Pad::Valid => {
            if input >= k {
                (input - k) / stride + 1
            } else {
                0
            }
        }
    }
}

impl Conv2d {
    /// Output spatial dims `(oh, ow)`.
    pub fn out_hw(&self) -> (usize, usize) {
        (
            out_dim(self.h, self.kh, self.stride, self.pad),
            out_dim(self.w, self.kw, self.stride, self.pad),
        )
    }

    /// Padding applied before the first row/column (XLA SAME splits the
    /// total low-before: `before = total / 2`).
    pub fn pad_before(&self) -> (usize, usize) {
        match self.pad {
            Pad::Valid => (0, 0),
            Pad::Same => {
                let (oh, ow) = self.out_hw();
                let total = |o: usize, k: usize, i: usize| {
                    if o == 0 {
                        0
                    } else {
                        ((o - 1) * self.stride + k).saturating_sub(i)
                    }
                };
                (total(oh, self.kh, self.h) / 2, total(ow, self.kw, self.w) / 2)
            }
        }
    }

    /// Rows of the virtual im2col matrix (= output spatial positions).
    pub fn rows(&self) -> usize {
        let (oh, ow) = self.out_hw();
        self.n * oh * ow
    }

    /// Columns of the virtual im2col matrix (= filter taps).
    pub fn taps(&self) -> usize {
        self.kh * self.kw * self.c
    }

    /// Element count of the NHWC input.
    pub fn in_len(&self) -> usize {
        self.n * self.h * self.w * self.c
    }

    /// Element count of the NHWC output.
    pub fn out_len(&self) -> usize {
        self.rows() * self.co
    }

    /// Element count of the HWIO filter.
    pub fn filter_len(&self) -> usize {
        self.taps() * self.co
    }
}

/// FLOP count of one conv (multiply + add over the im2col GEMM), for the
/// GFLOP/s rows of `BENCH_host.json`.
pub fn conv2d_flops(g: &Conv2d) -> f64 {
    2.0 * g.rows() as f64 * g.taps() as f64 * g.co as f64
}

/// Pack rows `[row0, row0+rows)` of the virtual im2col matrix into
/// `MR`-strip A-panel layout (same layout as `pack::pack_a`), extracting
/// patches straight from the NHWC input. Out-of-image taps and rows past
/// the last strip's edge pack `0.0` — every slot in use is overwritten,
/// so dirty workspace reuse stays inert.
pub(crate) fn pack_patches(x: &[f32], g: &Conv2d, row0: usize, rows: usize, out: &mut [f32]) {
    let k = g.taps();
    let (oh, ow) = g.out_hw();
    let (ph, pw) = g.pad_before();
    let strips = rows.div_ceil(MR);
    for s in 0..strips {
        let strip = &mut out[s * MR * k..(s + 1) * MR * k];
        let full = MR.min(rows - s * MR);
        // decompose each strip row's output position once
        let mut ni = [0usize; MR];
        let mut ih0 = [0isize; MR];
        let mut iw0 = [0isize; MR];
        for r in 0..full {
            let m = row0 + s * MR + r;
            let owi = m % ow;
            let ohi = (m / ow) % oh;
            ni[r] = m / (ow * oh);
            ih0[r] = (ohi * g.stride) as isize - ph as isize;
            iw0[r] = (owi * g.stride) as isize - pw as isize;
        }
        let mut p = 0usize;
        for khi in 0..g.kh {
            for kwi in 0..g.kw {
                for ci in 0..g.c {
                    let dst = &mut strip[p * MR..p * MR + MR];
                    for (r, d) in dst.iter_mut().enumerate() {
                        *d = if r < full {
                            let ih = ih0[r] + khi as isize;
                            let iw = iw0[r] + kwi as isize;
                            if ih >= 0
                                && (ih as usize) < g.h
                                && iw >= 0
                                && (iw as usize) < g.w
                            {
                                x[((ni[r] * g.h + ih as usize) * g.w + iw as usize) * g.c
                                    + ci]
                            } else {
                                0.0
                            }
                        } else {
                            0.0
                        };
                    }
                    p += 1;
                }
            }
        }
    }
}

/// Pack rows `[row0, row0+rows)` of the *transposed* virtual im2col
/// matrix `[taps, rows]` into `MR`-strip layout — the A operand of the
/// dW / `lrp_conv_rw` contraction `Pᵀ @ G`.
pub(crate) fn pack_patches_t(x: &[f32], g: &Conv2d, row0: usize, rows: usize, out: &mut [f32]) {
    let m = g.rows(); // the contraction depth of this form
    let (oh, ow) = g.out_hw();
    let (ph, pw) = g.pad_before();
    let strips = rows.div_ceil(MR);
    for s in 0..strips {
        let strip = &mut out[s * MR * m..(s + 1) * MR * m];
        let full = MR.min(rows - s * MR);
        // decompose each strip row's filter tap once
        let mut ci = [0usize; MR];
        let mut khi = [0isize; MR];
        let mut kwi = [0isize; MR];
        for r in 0..full {
            let t = row0 + s * MR + r;
            ci[r] = t % g.c;
            kwi[r] = ((t / g.c) % g.kw) as isize;
            khi[r] = (t / (g.c * g.kw)) as isize;
        }
        // walk the sample positions incrementally (no div/mod per slot)
        let (mut ni, mut ohi, mut owi) = (0usize, 0usize, 0usize);
        for p in 0..m {
            let ihb = (ohi * g.stride) as isize - ph as isize;
            let iwb = (owi * g.stride) as isize - pw as isize;
            let dst = &mut strip[p * MR..p * MR + MR];
            for (r, d) in dst.iter_mut().enumerate() {
                *d = if r < full {
                    let ih = ihb + khi[r];
                    let iw = iwb + kwi[r];
                    if ih >= 0 && (ih as usize) < g.h && iw >= 0 && (iw as usize) < g.w {
                        x[((ni * g.h + ih as usize) * g.w + iw as usize) * g.c + ci[r]]
                    } else {
                        0.0
                    }
                } else {
                    0.0
                };
            }
            owi += 1;
            if owi == ow {
                owi = 0;
                ohi += 1;
                if ohi == oh {
                    ohi = 0;
                    ni += 1;
                }
            }
        }
    }
}

/// NHWC conv forward: `out[g.rows(), co] = epilogue(P(x) @ w)`, with `w`
/// the HWIO filter flattened row-major to `[taps, co]`. Output layout is
/// NHWC `[n, oh, ow, co]` (identical memory). Bias/ReLU fuse via `epi`
/// exactly like a dense layer.
pub fn conv2d(
    ws: &mut Workspace,
    x: &[f32],
    w: &[f32],
    g: &Conv2d,
    epi: Epilogue,
    out: &mut [f32],
) {
    conv2d_with(GemmOpts::dispatch(), ws, x, w, g, epi, out);
}

/// [`conv2d`] with explicit execution options (micro-kernel selection;
/// conv blocks always run serially).
pub fn conv2d_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    x: &[f32],
    w: &[f32],
    g: &Conv2d,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(x.len(), g.in_len(), "conv2d input shape");
    assert_eq!(w.len(), g.filter_len(), "conv2d filter shape");
    assert_eq!(out.len(), g.out_len(), "conv2d output shape");
    gemm_with(
        opts,
        ws,
        g.rows(),
        g.co,
        g.taps(),
        AOperand::Patches { x, geom: *g },
        BOperand::Dense(View::nn(w, g.co)),
        epi,
        out,
    );
}

/// Deployment-form conv: int32 centroid indices (flattened HWIO
/// `[taps, co]`) dequantized through `codebook` at pack time, zero
/// centroid skipped — the conv twin of `gemm_gather_nn`. An empty
/// codebook yields `out = epilogue(0)` — here via the early-out and in
/// the pack layer itself (`pack_b_gather` zero-fills); the host backend
/// additionally reports it as a corrupt-container error up front.
pub fn conv2d_gather(
    ws: &mut Workspace,
    x: &[f32],
    idx: &[i32],
    codebook: &[f32],
    g: &Conv2d,
    epi: Epilogue,
    out: &mut [f32],
) {
    conv2d_gather_with(GemmOpts::dispatch(), ws, x, idx, codebook, g, epi, out);
}

/// [`conv2d_gather`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gather_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    x: &[f32],
    idx: &[i32],
    codebook: &[f32],
    g: &Conv2d,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(x.len(), g.in_len(), "conv2d_gather input shape");
    assert_eq!(idx.len(), g.filter_len(), "conv2d_gather idx shape");
    assert_eq!(out.len(), g.out_len(), "conv2d_gather output shape");
    if codebook.is_empty() {
        super::gemm::epilogue_of_zero(out, g.rows(), g.co, &epi);
        return;
    }
    gemm_with(
        opts,
        ws,
        g.rows(),
        g.co,
        g.taps(),
        AOperand::Patches { x, geom: *g },
        BOperand::Gather { idx, codebook },
        epi,
        out,
    );
}

/// Filter gradient: `out[taps, co] = epilogue(P(x)ᵀ @ gout)` — the conv
/// analogue of the dense TN contraction. `out` is the HWIO gradient
/// flattened row-major; `Epilogue::Scale(w)` turns this into the
/// per-weight LRP aggregation (see [`lrp_conv_rw`]).
pub fn conv2d_bwd_filter(
    ws: &mut Workspace,
    x: &[f32],
    gout: &[f32],
    g: &Conv2d,
    epi: Epilogue,
    out: &mut [f32],
) {
    conv2d_bwd_filter_with(GemmOpts::dispatch(), ws, x, gout, g, epi, out);
}

/// [`conv2d_bwd_filter`] with explicit execution options.
pub fn conv2d_bwd_filter_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    x: &[f32],
    gout: &[f32],
    g: &Conv2d,
    epi: Epilogue,
    out: &mut [f32],
) {
    assert_eq!(x.len(), g.in_len(), "conv2d_bwd_filter input shape");
    assert_eq!(gout.len(), g.out_len(), "conv2d_bwd_filter gout shape");
    assert_eq!(out.len(), g.filter_len(), "conv2d_bwd_filter output shape");
    gemm_with(
        opts,
        ws,
        g.taps(),
        g.co,
        g.rows(),
        AOperand::PatchesT { x, geom: *g },
        BOperand::Dense(View::nn(gout, g.co)),
        epi,
        out,
    );
}

/// Per-weight epsilon-rule conv relevance `R_w = w ⊙ (P(a)ᵀ @ s)` — the
/// conv twin of `runtime::host::lrp_dense_rw`, with the `w ⊙ ·` scaling
/// fused into the GEMM store.
pub fn lrp_conv_rw(
    ws: &mut Workspace,
    a: &[f32],
    s: &[f32],
    w: &[f32],
    g: &Conv2d,
    out: &mut [f32],
) {
    lrp_conv_rw_with(GemmOpts::dispatch(), ws, a, s, w, g, out);
}

/// [`lrp_conv_rw`] with explicit execution options.
pub fn lrp_conv_rw_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    a: &[f32],
    s: &[f32],
    w: &[f32],
    g: &Conv2d,
    out: &mut [f32],
) {
    assert_eq!(w.len(), g.filter_len(), "lrp_conv_rw filter shape");
    conv2d_bwd_filter_with(opts, ws, a, s, g, Epilogue::Scale(w), out);
}

/// Input gradient: `dx[n,h,w,c] = col2im(gout @ wᵀ)`. The dCol matrix is
/// produced `MC` rows at a time into the workspace's tile buffer (one
/// blocked GEMM per tile), then scatter-added into `dx` in ascending
/// `(m, tap)` order — fixed tiling, fixed order, so the result is
/// deterministic per kernel (and bitwise-equal to the naive reference
/// under the scalar kernel).
pub fn conv2d_bwd_input(ws: &mut Workspace, gout: &[f32], w: &[f32], g: &Conv2d, dx: &mut [f32]) {
    conv2d_bwd_input_with(GemmOpts::dispatch(), ws, gout, w, g, dx);
}

/// [`conv2d_bwd_input`] with explicit execution options.
pub fn conv2d_bwd_input_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    gout: &[f32],
    w: &[f32],
    g: &Conv2d,
    dx: &mut [f32],
) {
    assert_eq!(gout.len(), g.out_len(), "conv2d_bwd_input gout shape");
    assert_eq!(w.len(), g.filter_len(), "conv2d_bwd_input filter shape");
    assert_eq!(dx.len(), g.in_len(), "conv2d_bwd_input dx shape");
    dx.fill(0.0);
    let m = g.rows();
    let k = g.taps();
    if m == 0 || k == 0 {
        return;
    }
    let (oh, ow) = g.out_hw();
    let (ph, pw) = g.pad_before();
    let (apack, bpack, tile) = ws.panels_and_tile(
        super::gemm::panel_rows(MC.min(m), MC, MR) * g.co,
        super::gemm::panel_rows(k, super::gemm::NC, super::gemm::NR) * g.co,
        MC * k,
    );
    let mut m0 = 0;
    while m0 < m {
        let rows = MC.min(m - m0);
        let t = &mut tile[..rows * k];
        // dCol tile: t[r, tap] = Σ_co gout[m0+r, co] · w[tap, co]
        gemm_core(
            opts.kernel,
            apack,
            bpack,
            rows,
            k,
            g.co,
            AOperand::Dense(View::nn(gout, g.co).at(m0, 0)),
            BOperand::Dense(View::t(w, g.co)),
            Epilogue::None,
            t,
        );
        for r in 0..rows {
            let mi = m0 + r;
            let owi = mi % ow;
            let ohi = (mi / ow) % oh;
            let ni = mi / (ow * oh);
            let ih0 = (ohi * g.stride) as isize - ph as isize;
            let iw0 = (owi * g.stride) as isize - pw as isize;
            let trow = &t[r * k..(r + 1) * k];
            let mut p = 0usize;
            for khi in 0..g.kh {
                let ih = ih0 + khi as isize;
                if ih < 0 || ih as usize >= g.h {
                    p += g.kw * g.c;
                    continue;
                }
                for kwi in 0..g.kw {
                    let iw = iw0 + kwi as isize;
                    if iw < 0 || iw as usize >= g.w {
                        p += g.c;
                        continue;
                    }
                    let base = ((ni * g.h + ih as usize) * g.w + iw as usize) * g.c;
                    for (d, &v) in dx[base..base + g.c].iter_mut().zip(&trow[p..p + g.c]) {
                        *d += v;
                    }
                    p += g.c;
                }
            }
        }
        m0 += MC;
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::super::simd::Kernel;
    use super::*;

    // Exact-equality comparisons against the naive reference pin the
    // deterministic tier (scalar kernel); gather-vs-dense comparisons run
    // under the process dispatch on purpose — packed panels are identical
    // either way, so they must agree bitwise under *any* kernel. The fast
    // tier's envelope is covered by tests/linalg_simd_conformance.rs.
    const DET: GemmOpts = GemmOpts { kernel: Kernel::Scalar, threads: 1 };

    fn geom() -> Conv2d {
        Conv2d { n: 2, h: 5, w: 6, c: 3, kh: 3, kw: 3, co: 4, stride: 1, pad: Pad::Same }
    }

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i % 17) as f32 - 8.0) * scale).collect()
    }

    #[test]
    fn same_and_valid_output_dims() {
        let mut g = geom();
        assert_eq!(g.out_hw(), (5, 6));
        assert_eq!(g.pad_before(), (1, 1));
        g.stride = 2;
        assert_eq!(g.out_hw(), (3, 3)); // ceil(5/2), ceil(6/2)
        g.pad = Pad::Valid;
        assert_eq!(g.out_hw(), (2, 2)); // floor((5-3)/2)+1, floor((6-3)/2)+1
        g.h = 2; // smaller than the kernel
        assert_eq!(g.out_hw().0, 0);
    }

    #[test]
    fn forward_matches_naive_direct() {
        for stride in [1, 2] {
            for pad in [Pad::Same, Pad::Valid] {
                let g = Conv2d { stride, pad, ..geom() };
                let x = seq(g.in_len(), 0.25);
                let w = seq(g.filter_len(), 0.125);
                let mut ws = Workspace::new();
                let mut out = vec![0.0f32; g.out_len()];
                conv2d_with(DET, &mut ws, &x, &w, &g, Epilogue::None, &mut out);
                assert_eq!(out, reference::conv2d_naive(&x, &w, &g), "s={stride} {pad:?}");
            }
        }
    }

    #[test]
    fn backward_kernels_match_naive() {
        let g = Conv2d { stride: 2, ..geom() };
        let x = seq(g.in_len(), 0.2);
        let w = seq(g.filter_len(), 0.1);
        let gout = seq(g.out_len(), 0.3);
        let mut ws = Workspace::new();
        let mut dw = vec![0.0f32; g.filter_len()];
        conv2d_bwd_filter_with(DET, &mut ws, &x, &gout, &g, Epilogue::None, &mut dw);
        assert_eq!(dw, reference::conv2d_bwd_filter_naive(&x, &gout, &g));
        let mut dx = vec![f32::NAN; g.in_len()];
        conv2d_bwd_input_with(DET, &mut ws, &gout, &w, &g, &mut dx);
        assert_eq!(dx, reference::conv2d_bwd_input_naive(&gout, &w, &g));
    }

    #[test]
    fn gather_matches_dense_conv() {
        let g = geom();
        let x = seq(g.in_len(), 0.2);
        let cb = [0.0f32, 0.5, -0.5, 0.25];
        let idx: Vec<i32> = (0..g.filter_len()).map(|i| (i % 4) as i32).collect();
        let dense: Vec<f32> = idx.iter().map(|&i| cb[i as usize]).collect();
        let bias = seq(g.co, 0.4);
        let mut ws = Workspace::new();
        let mut got = vec![0.0f32; g.out_len()];
        conv2d_gather(&mut ws, &x, &idx, &cb, &g, Epilogue::Bias(&bias), &mut got);
        let mut want = vec![0.0f32; g.out_len()];
        conv2d(&mut ws, &x, &dense, &g, Epilogue::Bias(&bias), &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn empty_codebook_is_epilogue_of_zero() {
        let g = Conv2d { n: 1, h: 2, w: 2, c: 1, kh: 1, kw: 1, co: 2, stride: 1, pad: Pad::Valid };
        let x = [1.0f32; 4];
        let idx = [0i32; 2];
        let bias = [0.5f32, -0.5];
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; g.out_len()];
        conv2d_gather(&mut ws, &x, &idx, &[], &g, Epilogue::Bias(&bias), &mut out);
        assert_eq!(out, vec![0.5, -0.5, 0.5, -0.5, 0.5, -0.5, 0.5, -0.5]);
    }

    #[test]
    fn zero_channel_input_is_bias_only() {
        // c = 0 ⇒ taps = 0 ⇒ the conv is an empty contraction; the
        // epilogue still applies, exactly like a k=0 dense layer
        let g = Conv2d { n: 1, h: 3, w: 3, c: 0, kh: 3, kw: 3, co: 2, stride: 1, pad: Pad::Same };
        let bias = [1.0f32, -2.0];
        let mut ws = Workspace::new();
        let mut out = vec![f32::NAN; g.out_len()];
        conv2d(&mut ws, &[], &[], &g, Epilogue::BiasRelu(&bias), &mut out);
        for pair in out.chunks_exact(2) {
            assert_eq!(pair, [1.0, 0.0]);
        }
    }
}
