//! Reusable per-worker scratch buffers for the blocked GEMM core.
//!
//! Packing A/B panels on every GEMM call would make each dense layer pay
//! two heap allocations per forward — the dominant allocation source of
//! steady-state host-backend training. A [`Workspace`] owns those panel
//! buffers and grows them monotonically: after the first call at a given
//! shape class the GEMM hot loop performs **zero** heap allocations
//! (asserted by `tests/alloc_steady_state.rs` with a counting allocator).
//!
//! Lifecycle: one workspace per worker thread. [`crate::runtime::Engine`]
//! keeps one in thread-local storage (so `call_batch` fan-out across
//! `util::pool` workers gets a private workspace per thread for free), and
//! long-running loops like the QAT trainer hold an explicit workspace and
//! use `Engine::call_with` to skip even the TLS lookup.
//!
//! Determinism: workspace contents never influence results — the pack
//! routines fully overwrite every panel slot they hand to the
//! micro-kernel (including zero padding), so a dirty buffer reused across
//! calls of different shapes is indistinguishable from a fresh one. This
//! is property-tested in `tests/linalg_gemm_props.rs`.

use std::cell::RefCell;

/// Reusable packing buffers for [`crate::linalg::gemm`]. Cheap to create
/// (no allocation until first use); grows to the high-water mark of the
/// shapes it has served and never shrinks.
#[derive(Debug, Default)]
pub struct Workspace {
    apack: Vec<f32>,
    bpack: Vec<f32>,
    /// dCol tile scratch of the conv backward-input pass
    /// ([`crate::linalg::conv2d_bwd_input`]); unused by plain GEMMs
    tile: Vec<f32>,
    /// CSR column pointers of the LUT index panels
    /// ([`crate::linalg::lut`]); unused by dense GEMMs
    iptr: Vec<u32>,
    /// CSR row positions of the LUT index panels
    ipos: Vec<u32>,
}

impl Workspace {
    /// Empty workspace (allocation-free; `const` so it can seed TLS).
    pub const fn new() -> Workspace {
        Workspace {
            apack: Vec::new(),
            bpack: Vec::new(),
            tile: Vec::new(),
            iptr: Vec::new(),
            ipos: Vec::new(),
        }
    }

    /// Bytes currently reserved across all scratch buffers.
    pub fn reserved_bytes(&self) -> usize {
        (self.apack.capacity() + self.bpack.capacity() + self.tile.capacity())
            * std::mem::size_of::<f32>()
            + (self.iptr.capacity() + self.ipos.capacity()) * std::mem::size_of::<u32>()
    }

    /// Borrow the A/B panel buffers for [`crate::linalg::gemm()`], grown
    /// to at least the requested lengths. Contents are unspecified —
    /// callers must overwrite every slot they read (the pack routines do,
    /// padding included).
    pub(crate) fn panels(&mut self, a_len: usize, b_len: usize) -> (&mut [f32], &mut [f32]) {
        if self.apack.len() < a_len {
            self.apack.resize(a_len, 0.0);
        }
        if self.bpack.len() < b_len {
            self.bpack.resize(b_len, 0.0);
        }
        (&mut self.apack[..a_len], &mut self.bpack[..b_len])
    }

    /// [`Workspace::panels`] plus the conv dCol tile buffer, borrowed
    /// disjointly so `conv2d_bwd_input` can run its per-tile GEMM into the
    /// tile while holding the packing panels. Same contract: contents are
    /// unspecified, every slot read must first be overwritten.
    pub(crate) fn panels_and_tile(
        &mut self,
        a_len: usize,
        b_len: usize,
        t_len: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        if self.apack.len() < a_len {
            self.apack.resize(a_len, 0.0);
        }
        if self.bpack.len() < b_len {
            self.bpack.resize(b_len, 0.0);
        }
        if self.tile.len() < t_len {
            self.tile.resize(t_len, 0.0);
        }
        (
            &mut self.apack[..a_len],
            &mut self.bpack[..b_len],
            &mut self.tile[..t_len],
        )
    }

    /// Borrow the CSR index-panel buffers for [`crate::linalg::lut`],
    /// grown to at least the requested lengths. Same contract as
    /// [`Workspace::panels`]: contents are unspecified, and the pack
    /// routine (`pack_index_csr`) overwrites every slot it makes
    /// reachable, so dirty reuse cannot change results.
    pub(crate) fn index_panels(&mut self, ptr_len: usize, pos_len: usize) -> (&mut [u32], &mut [u32]) {
        if self.iptr.len() < ptr_len {
            self.iptr.resize(ptr_len, 0);
        }
        if self.ipos.len() < pos_len {
            self.ipos.resize(pos_len, 0);
        }
        (&mut self.iptr[..ptr_len], &mut self.ipos[..pos_len])
    }
}

thread_local! {
    static TLS_WORKSPACE: RefCell<Workspace> = const { RefCell::new(Workspace::new()) };
}

/// Run `f` with this thread's shared [`Workspace`].
///
/// This is what makes every worker thread of `Engine::call_batch` (and any
/// plain `Engine::call` site) reuse panel buffers without API changes: the
/// workspace is keyed by thread, so concurrent workers never share one.
/// Falls back to a fresh workspace if the thread-local one is already
/// borrowed (re-entrant use) — results are identical either way, only the
/// reuse is lost.
pub fn with_thread_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    TLS_WORKSPACE.with(|ws| match ws.try_borrow_mut() {
        Ok(mut ws) => f(&mut ws),
        Err(_) => f(&mut Workspace::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_monotonically_and_never_shrinks() {
        let mut ws = Workspace::new();
        assert_eq!(ws.reserved_bytes(), 0, "no allocation before first use");
        {
            let (a, b) = ws.panels(128, 256);
            assert_eq!((a.len(), b.len()), (128, 256));
        }
        let high = ws.reserved_bytes();
        assert!(high >= (128 + 256) * 4);
        // a smaller request reuses the same storage
        let _ = ws.panels(16, 16);
        assert_eq!(ws.reserved_bytes(), high);
        // index panels grow the same way, accounted in u32 units
        {
            let (p, q) = ws.index_panels(33, 512);
            assert_eq!((p.len(), q.len()), (33, 512));
        }
        let high2 = ws.reserved_bytes();
        assert!(high2 >= high + (33 + 512) * 4);
        let _ = ws.index_panels(4, 4);
        assert_eq!(ws.reserved_bytes(), high2);
    }

    #[test]
    fn tls_workspace_is_reentrant_safe() {
        let outer = with_thread_workspace(|ws| {
            let _ = ws.panels(64, 64);
            // nested borrow must not panic; it just gets a fresh workspace
            with_thread_workspace(|inner| inner.reserved_bytes())
        });
        assert_eq!(outer, 0, "nested workspace starts empty");
        let reused = with_thread_workspace(|ws| ws.reserved_bytes());
        assert!(reused >= 64 * 2 * 4, "outer TLS workspace kept its buffers");
    }
}
