//! The paper's α-β conv LRP rule (α=2, β=−1), composed from the im2col
//! conv kernels (DESIGN.md §2.8).
//!
//! Per conv layer with input activations `a` and HWIO filter `w`, split
//! both operands by sign (`a = a⁺ + a⁻`, `w = w⁺ + w⁻`) and form the
//! signed pre-activation parts
//!
//! ```text
//! z⁺ = conv(a⁺, w⁺) + conv(a⁻, w⁻)      (every positive product)
//! z⁻ = conv(a⁺, w⁻) + conv(a⁻, w⁺)      (every negative product)
//! ```
//!
//! then with `s⁺ = α·R/stab(z⁺)` and `s⁻ = β·R/stab(z⁻)`:
//!
//! ```text
//! R_in = a⁺ ⊙ (bwdᵢ(s⁺,w⁺) + bwdᵢ(s⁻,w⁻)) + a⁻ ⊙ (bwdᵢ(s⁺,w⁻) + bwdᵢ(s⁻,w⁺))
//! R_w  = w⁺ ⊙ (Pᵀ(a⁺)s⁺ + Pᵀ(a⁻)s⁻)     + w⁻ ⊙ (Pᵀ(a⁺)s⁻ + Pᵀ(a⁻)s⁺)
//! ```
//!
//! where `bwdᵢ` is the conv input-VJP (`conv2d_bwd_input`) and `Pᵀ(·)` the
//! transposed-patch filter-VJP (`conv2d_bwd_filter`) — eight conv-shaped
//! VJPs per layer versus the epsilon rule's two. Both views of one layer
//! sum the same product terms, so `Σ R_in = Σ R_w`, and because
//! `z⁺ + z⁻ = z` and `α + β = 1`, each output's redistributed total is
//! `R_j·(α·z⁺/stab(z⁺) + β·z⁻/stab(z⁻)) ≈ R_j` — conservation holds up to
//! the stabilizer, mirroring the epsilon suite
//! (`tests/conv_props.rs::alpha_beta_*`).
//!
//! Bias is deliberately left out of the splits: relevance attaches to
//! weighted input contributions only (the common LRP convention), and the
//! conservation statement above is exact for it. Determinism: the
//! composition only calls the tier-dispatched conv kernels plus fixed
//! elementwise loops, so the deterministic tier stays bitwise
//! reproducible with no new kernel surface.

use super::gemm::Epilogue;
use super::im2col::{conv2d_bwd_filter_with, conv2d_bwd_input_with, conv2d_with, Conv2d};
use super::simd::GemmOpts;
use super::workspace::Workspace;

/// The paper's α (Sec. 4.1: α=2, β=−1, α+β=1).
pub const LRP_ALPHA: f32 = 2.0;
/// The paper's β.
pub const LRP_BETA: f32 = -1.0;

/// Epsilon-rule stabilizer `z + eps·sign(z)` with `sign(0) := 1`
/// (paper Sec. 4.1; the single definition shared by the dense epsilon
/// ladder, the avg-pool LRP redistribution and the α-β rule).
pub fn stabilize(z: f32) -> f32 {
    const EPS: f32 = 1e-6;
    if z >= 0.0 {
        z + EPS
    } else {
        z - EPS
    }
}

fn split_signs(v: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let pos: Vec<f32> = v.iter().map(|&x| if x > 0.0 { x } else { 0.0 }).collect();
    let neg: Vec<f32> = v.iter().map(|&x| if x < 0.0 { x } else { 0.0 }).collect();
    (pos, neg)
}

/// α-β conv LRP with explicit execution options: per-weight relevance
/// into `r_w` (HWIO, like the filter) and per-input relevance into
/// `r_in`. `r` is the layer-output relevance `[n·oh·ow, co]` row-major.
#[allow(clippy::too_many_arguments)]
pub fn lrp_conv_ab_with(
    opts: GemmOpts,
    ws: &mut Workspace,
    a: &[f32],
    w: &[f32],
    r: &[f32],
    g: &Conv2d,
    alpha: f32,
    beta: f32,
    r_w: &mut [f32],
    r_in: &mut [f32],
) {
    assert_eq!(a.len(), g.in_len(), "lrp_conv_ab input shape");
    assert_eq!(w.len(), g.filter_len(), "lrp_conv_ab filter shape");
    assert_eq!(r.len(), g.out_len(), "lrp_conv_ab relevance shape");
    assert_eq!(r_w.len(), g.filter_len(), "lrp_conv_ab r_w shape");
    assert_eq!(r_in.len(), g.in_len(), "lrp_conv_ab r_in shape");
    let (ap, an) = split_signs(a);
    let (wp, wn) = split_signs(w);

    // signed pre-activation parts, then the scaled relevances in place
    let mut sp = vec![0.0f32; g.out_len()];
    let mut sn = vec![0.0f32; g.out_len()];
    let mut tmp = vec![0.0f32; g.out_len()];
    conv2d_with(opts, ws, &ap, &wp, g, Epilogue::None, &mut sp);
    conv2d_with(opts, ws, &an, &wn, g, Epilogue::None, &mut tmp);
    for (z, &t) in sp.iter_mut().zip(&tmp) {
        *z += t;
    }
    conv2d_with(opts, ws, &ap, &wn, g, Epilogue::None, &mut sn);
    conv2d_with(opts, ws, &an, &wp, g, Epilogue::None, &mut tmp);
    for (z, &t) in sn.iter_mut().zip(&tmp) {
        *z += t;
    }
    for j in 0..r.len() {
        sp[j] = alpha * r[j] / stabilize(sp[j]);
        sn[j] = beta * r[j] / stabilize(sn[j]);
    }

    // R_in: two VJP pairs, gated by the input sign masks
    let mut t1 = vec![0.0f32; g.in_len()];
    let mut t2 = vec![0.0f32; g.in_len()];
    conv2d_bwd_input_with(opts, ws, &sp, &wp, g, &mut t1);
    conv2d_bwd_input_with(opts, ws, &sn, &wn, g, &mut t2);
    for i in 0..r_in.len() {
        r_in[i] = ap[i] * (t1[i] + t2[i]);
    }
    conv2d_bwd_input_with(opts, ws, &sp, &wn, g, &mut t1);
    conv2d_bwd_input_with(opts, ws, &sn, &wp, g, &mut t2);
    for i in 0..r_in.len() {
        r_in[i] += an[i] * (t1[i] + t2[i]);
    }

    // R_w: two transposed-patch pairs, gated by the weight sign masks
    let mut f1 = vec![0.0f32; g.filter_len()];
    let mut f2 = vec![0.0f32; g.filter_len()];
    conv2d_bwd_filter_with(opts, ws, &ap, &sp, g, Epilogue::None, &mut f1);
    conv2d_bwd_filter_with(opts, ws, &an, &sn, g, Epilogue::None, &mut f2);
    for i in 0..r_w.len() {
        r_w[i] = wp[i] * (f1[i] + f2[i]);
    }
    conv2d_bwd_filter_with(opts, ws, &ap, &sn, g, Epilogue::None, &mut f1);
    conv2d_bwd_filter_with(opts, ws, &an, &sp, g, Epilogue::None, &mut f2);
    for i in 0..r_w.len() {
        r_w[i] += wn[i] * (f1[i] + f2[i]);
    }
}

/// [`lrp_conv_ab_with`] under the process-wide execution mode.
#[allow(clippy::too_many_arguments)]
pub fn lrp_conv_ab(
    ws: &mut Workspace,
    a: &[f32],
    w: &[f32],
    r: &[f32],
    g: &Conv2d,
    alpha: f32,
    beta: f32,
    r_w: &mut [f32],
    r_in: &mut [f32],
) {
    lrp_conv_ab_with(GemmOpts::dispatch(), ws, a, w, r, g, alpha, beta, r_w, r_in);
}

#[cfg(test)]
mod tests {
    use super::super::im2col::Pad;
    use super::*;

    #[test]
    fn positive_only_operands_reduce_to_the_z_plus_rule() {
        // all-positive a and w: z⁻ = 0, so R_in = α·a⊙bwdᵢ(R/stab(z),w)
        // (β's share hits the stabilizer alone and vanishes)
        let g = Conv2d { n: 1, h: 2, w: 2, c: 1, kh: 1, kw: 1, co: 1, stride: 1, pad: Pad::Valid };
        let a = [1.0, 2.0, 3.0, 4.0];
        let w = [0.5];
        let r = [1.0, 1.0, 1.0, 1.0];
        let mut ws = Workspace::new();
        let (mut rw, mut rin) = ([0.0; 1], [0.0; 4]);
        lrp_conv_ab_with(
            GemmOpts::deterministic(),
            &mut ws,
            &a,
            &w,
            &r,
            &g,
            LRP_ALPHA,
            LRP_BETA,
            &mut rw,
            &mut rin,
        );
        // each 1×1 window: R_in = a·w⁺·s⁺ = a·0.5·α/stab(0.5·a) ≈ α = 2;
        // the β share routes through w⁻ = 0 and vanishes, so the totals
        // are α·ΣR = 8 for both the R_in and R_w views (z⁻ = 0 is the
        // stabilizer-dominated case the conservation test excludes)
        for &v in &rin {
            assert!((v - 2.0).abs() < 1e-3, "{rin:?}");
        }
        let total: f32 = rw.iter().sum();
        assert!((total - 8.0).abs() < 1e-2, "R_w total {total}");
    }

    #[test]
    fn rw_and_rin_views_sum_identically() {
        let g = Conv2d { n: 1, h: 3, w: 3, c: 2, kh: 2, kw: 2, co: 2, stride: 1, pad: Pad::Valid };
        let a: Vec<f32> = (0..g.in_len()).map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.3).collect();
        let w: Vec<f32> = (0..g.filter_len()).map(|i| ((i * 5 % 13) as f32 - 6.0) * 0.2).collect();
        let r: Vec<f32> = (0..g.out_len()).map(|i| (i as f32 - 3.0) * 0.5).collect();
        let mut ws = Workspace::new();
        let mut rw = vec![0.0; g.filter_len()];
        let mut rin = vec![0.0; g.in_len()];
        lrp_conv_ab_with(
            GemmOpts::deterministic(),
            &mut ws,
            &a,
            &w,
            &r,
            &g,
            LRP_ALPHA,
            LRP_BETA,
            &mut rw,
            &mut rin,
        );
        let sw: f32 = rw.iter().sum();
        let si: f32 = rin.iter().sum();
        assert!(
            (sw - si).abs() < 1e-3 * (1.0 + sw.abs()),
            "both views sum the same products: {sw} vs {si}"
        );
    }
}
