//! Panel packing: reorder operand blocks into the contiguous, zero-padded
//! strip layout the micro-kernel consumes.
//!
//! * A panels are `MR`-row strips: strip `s` holds rows
//!   `[s·MR, s·MR+MR)`, stored `p`-major (`panel[s·MR·k + p·MR + r]` =
//!   `A[s·MR + r, p]`), so the micro-kernel reads `MR` broadcast values
//!   per `k` step from one cache line.
//! * B panels are `NR`-column strips stored the same way
//!   (`panel[s·NR·k + p·NR + c]` = `B[p, s·NR + c]`), giving the
//!   micro-kernel a contiguous `NR`-wide vector load per `k` step.
//!
//! Rows/columns past the matrix edge are packed as `0.0`, which
//! contributes exactly nothing to valid output elements — the edge tiles
//! need no special-case kernel. Every slot of the panel region in use is
//! overwritten on every pack (padding included), so reusing a dirty
//! [`crate::linalg::Workspace`] buffer cannot change results.
//!
//! A strided [`View`] abstracts the source layout, so the same two pack
//! routines serve all three contraction forms (NN / TN / NT) — a
//! transposed operand is just a view with swapped strides, never a
//! materialized transpose. `pack_b_gather` additionally serves the
//! codebook-gather form of `qdense_gather`: it dequantizes int32 centroid
//! indices directly into the packed panel (no `[k,n]` dense weight copy)
//! and skips stores for the zero centroid, which the paper's sparse
//! networks make the dominant one.

use super::gemm::{MR, NR};
use super::lut::MAX_LUT_CENTROIDS;

/// Borrowed strided matrix view: element `(i, j)` lives at
/// `data[i*rs + j*cs]`. `View::nn` wraps a row-major matrix;
/// `View::t` wraps its transpose without moving data.
#[derive(Clone, Copy, Debug)]
pub struct View<'a> {
    pub data: &'a [f32],
    /// stride between consecutive rows (first index)
    pub rs: usize,
    /// stride between consecutive columns (second index)
    pub cs: usize,
}

impl<'a> View<'a> {
    /// Row-major `[rows, cols]` view: element `(i, j)` = `data[i*cols + j]`.
    pub fn nn(data: &'a [f32], cols: usize) -> View<'a> {
        View { data, rs: cols, cs: 1 }
    }

    /// Transposed view of a row-major `[rows, cols]` matrix: element
    /// `(i, j)` of the view is `data[j*cols + i]`.
    pub fn t(data: &'a [f32], cols: usize) -> View<'a> {
        View { data, rs: 1, cs: cols }
    }

    /// Sub-view starting at element `(i, j)`.
    pub(crate) fn at(self, i: usize, j: usize) -> View<'a> {
        View { data: &self.data[i * self.rs + j * self.cs..], rs: self.rs, cs: self.cs }
    }

    #[inline(always)]
    fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.rs + j * self.cs]
    }
}

/// Pack `rows × k` of the A operand into `MR`-strip layout, zero-padding
/// the last strip. Writes exactly `ceil(rows/MR)·MR·k` slots of `out`.
pub(crate) fn pack_a(a: View, rows: usize, k: usize, out: &mut [f32]) {
    let strips = (rows + MR - 1) / MR;
    for s in 0..strips {
        let strip = &mut out[s * MR * k..(s + 1) * MR * k];
        let r0 = s * MR;
        let full = MR.min(rows - r0);
        for p in 0..k {
            let dst = &mut strip[p * MR..p * MR + MR];
            for (r, d) in dst.iter_mut().enumerate() {
                *d = if r < full { a.get(r0 + r, p) } else { 0.0 };
            }
        }
    }
}

/// Pack `k × cols` of the B operand into `NR`-strip layout, zero-padding
/// the last strip. Writes exactly `ceil(cols/NR)·NR·k` slots of `out`.
pub(crate) fn pack_b(b: View, k: usize, cols: usize, out: &mut [f32]) {
    let strips = (cols + NR - 1) / NR;
    for s in 0..strips {
        let strip = &mut out[s * NR * k..(s + 1) * NR * k];
        let j0 = s * NR;
        let full = NR.min(cols - j0);
        for p in 0..k {
            let dst = &mut strip[p * NR..p * NR + NR];
            for (c, d) in dst.iter_mut().enumerate() {
                *d = if c < full { b.get(p, j0 + c) } else { 0.0 };
            }
        }
    }
}

/// Pack columns `[j0, j0+cols)` of the codebook-gather B operand — a
/// row-major `[k, n]` int32 index matrix dequantized through `codebook` —
/// into `NR`-strip layout.
///
/// Out-of-range indices clamp into the codebook (XLA gather semantics on
/// the PJRT backend; a corrupt container must not panic the host path).
/// The strip is zero-filled first and only non-zero centroid values are
/// stored, so the per-element cost in the paper's sparse networks (zero
/// centroid dominant) is one load + one branch, and the full dense
/// `[k, n]` dequantized weight matrix is never materialized.
///
/// An empty codebook dequantizes every index to `0.0` — the pack layer
/// zero-fills the strips and returns, mirroring the "all weights are the
/// zero centroid" reading of the container. This is handled *here*, not
/// by caller pre-validation: the old `codebook.len() - 1` underflow meant
/// any entry point that skipped its own check panicked in debug builds
/// and indexed with a wrapped clamp bound in release builds. (The host
/// backend still reports an empty codebook as a corrupt-container error
/// up front — see `runtime::host::qdense_gather` — but that is policy,
/// not a soundness precondition of this layer.)
pub(crate) fn pack_b_gather(
    idx: &[i32],
    codebook: &[f32],
    n: usize,
    j0: usize,
    k: usize,
    cols: usize,
    out: &mut [f32],
) {
    let strips = (cols + NR - 1) / NR;
    if codebook.is_empty() {
        out[..strips * NR * k].fill(0.0);
        return;
    }
    let top = (codebook.len() - 1) as i32;
    for s in 0..strips {
        let strip = &mut out[s * NR * k..(s + 1) * NR * k];
        strip.fill(0.0);
        let jj = j0 + s * NR;
        let full = NR.min(cols - s * NR);
        for p in 0..k {
            let src = &idx[p * n + jj..p * n + jj + full];
            let dst = &mut strip[p * NR..p * NR + full];
            for (d, &iv) in dst.iter_mut().zip(src) {
                let v = codebook[iv.clamp(0, top) as usize];
                if v != 0.0 {
                    *d = v;
                }
            }
        }
    }
}

/// Pack a row-major `[k, n]` int32 index matrix into the per-column CSR
/// index panels of the LUT kernel ([`crate::linalg::lut`]): for each
/// output column `j`, group the contraction positions `l` by centroid,
/// **omitting every position whose centroid value is exactly `0.0`** —
/// the structural zero-skip that makes LUT arithmetic scale with nnz.
///
/// Layout (`s_n = codebook.len()`, global `u32` offsets into `pos`):
/// * `ptr[j*(s_n+1) + s] .. ptr[j*(s_n+1) + s + 1]` is column `j`'s
///   segment for centroid `s` — a run of row positions `l` in ascending
///   order (the fill pass walks `l` upward, so segment order is a pure
///   function of `idx`/`codebook` and never of workspace history).
/// * Zero-valued centroids get an empty segment (`lo == hi`), so the
///   kernel never touches their positions — not even to multiply by zero.
///
/// Out-of-range indices clamp into the codebook, matching
/// [`pack_b_gather`] (XLA gather semantics; corrupt containers must not
/// panic). Every `ptr` slot in use and every `pos` slot below the
/// returned nnz count is overwritten, so dirty workspace reuse cannot
/// change results. Returns the total position count (Σ_j nnz_j).
///
/// Caller contract: `codebook` is non-empty and at most
/// [`MAX_LUT_CENTROIDS`] entries (the LUT entry points early-out /
/// fall back before packing), `ptr.len() >= n*(s_n+1)`,
/// `pos.len() >= k*n`.
pub(crate) fn pack_index_csr(
    idx: &[i32],
    codebook: &[f32],
    k: usize,
    n: usize,
    ptr: &mut [u32],
    pos: &mut [u32],
) -> usize {
    let s_n = codebook.len();
    debug_assert!(s_n >= 1 && s_n <= MAX_LUT_CENTROIDS, "pack_index_csr codebook size");
    debug_assert!(k * n <= u32::MAX as usize, "pack_index_csr: index panel offsets are u32");
    let top = (s_n - 1) as i32;
    let mut base: u32 = 0;
    for j in 0..n {
        let pbase = j * (s_n + 1);
        // count pass: nnz per centroid in this column
        let mut counts = [0u32; MAX_LUT_CENTROIDS];
        for l in 0..k {
            let s = idx[l * n + j].clamp(0, top) as usize;
            if codebook[s] != 0.0 {
                counts[s] += 1;
            }
        }
        ptr[pbase] = base;
        for s in 0..s_n {
            ptr[pbase + s + 1] = ptr[pbase + s] + counts[s];
        }
        // fill pass: ascending l within each segment
        let mut cur = [0u32; MAX_LUT_CENTROIDS];
        cur[..s_n].copy_from_slice(&ptr[pbase..pbase + s_n]);
        for l in 0..k {
            let s = idx[l * n + j].clamp(0, top) as usize;
            if codebook[s] != 0.0 {
                pos[cur[s] as usize] = l as u32;
                cur[s] += 1;
            }
        }
        base = ptr[pbase + s_n];
    }
    base as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_strips_and_pads() {
        // 3x2 row-major matrix, MR-padded to one strip (MR >= 3 assumed
        // false in general, so index formula is exercised directly)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = View::nn(&a, 2);
        let rows = 3;
        let k = 2;
        let strips = (rows + MR - 1) / MR;
        let mut out = vec![f32::NAN; strips * MR * k];
        pack_a(v, rows, k, &mut out);
        // element (r, p) of strip s sits at s*MR*k + p*MR + r
        for p in 0..k {
            for r in 0..rows {
                let s = r / MR;
                assert_eq!(out[s * MR * k + p * MR + (r % MR)], a[r * 2 + p]);
            }
        }
        // padding slots are zero, not stale NaN
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pack_b_transposed_view_matches_direct() {
        // w is [k=2, n=3]; transposed view (element (p, j) = w[j, p])
        // must equal packing the explicit transpose
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let wt = [1.0, 4.0, 2.0, 5.0, 3.0, 6.0]; // [3, 2]
        let k = 3; // contraction length of the NT form
        let cols = 2;
        let strips = (cols + NR - 1) / NR;
        let mut a_t = vec![0.0; strips * NR * k];
        let mut a_d = vec![0.0; strips * NR * k];
        pack_b(View::t(&w, 3), k, cols, &mut a_t);
        pack_b(View::nn(&wt, 2), k, cols, &mut a_d);
        assert_eq!(a_t, a_d);
    }

    #[test]
    fn pack_b_gather_clamps_and_overwrites_stale() {
        let cb = [0.0, 0.5, -1.5];
        let idx = [1, -7, 99, 0]; // [k=2, n=2]; -7 and 99 clamp
        let k = 2;
        let cols = 2;
        let strips = (cols + NR - 1) / NR;
        let mut out = vec![f32::NAN; strips * NR * k];
        pack_b_gather(&idx, &cb, 2, 0, k, cols, &mut out);
        assert_eq!(out[0], 0.5); // (p=0, c=0) -> cb[1]
        assert_eq!(out[1], 0.0); // clamp(-7) -> cb[0] = 0.0 (skipped store)
        assert_eq!(out[NR], -1.5); // (p=1, c=0) -> clamp(99) -> cb[2]
        assert_eq!(out[NR + 1], 0.0); // cb[0]
        assert!(out.iter().all(|v| v.is_finite()), "stale NaN survived fill");
    }

    #[test]
    fn pack_b_gather_empty_codebook_zero_fills_instead_of_panicking() {
        // regression: `(codebook.len() - 1)` underflowed on an empty
        // codebook when a caller skipped its pre-validation
        let idx = [3, -1, 0, 7]; // [k=2, n=2]; values are irrelevant
        let k = 2;
        let cols = 2;
        let strips = (cols + NR - 1) / NR;
        let mut out = vec![f32::NAN; strips * NR * k];
        pack_b_gather(&idx, &[], 2, 0, k, cols, &mut out);
        assert!(out.iter().all(|&v| v == 0.0), "empty codebook packs all-zero strips");
    }

    #[test]
    fn pack_index_csr_groups_skips_zero_and_clamps() {
        let cb = [0.0, 0.5, -1.5];
        // [k=4, n=2] column-wise:
        //   col 0: centroids 1, 0, 2, 1  -> seg1 = {0, 3}, seg2 = {2}
        //   col 1: centroids 0(-7 clamp), 2(99 clamp), 0, 0 -> seg2 = {1}
        let idx = [1, -7, 0, 99, 2, 0, 1, 0];
        let (k, n) = (4, 2);
        let s_n = cb.len();
        let mut ptr = vec![u32::MAX; n * (s_n + 1)];
        let mut pos = vec![u32::MAX; k * n];
        let nnz = pack_index_csr(&idx, &cb, k, n, &mut ptr, &mut pos);
        assert_eq!(nnz, 4, "zero-centroid positions are structurally absent");
        // column 0: ptr = [0, 0, 2, 3] (centroid 0 empty, 1 has two, 2 one)
        assert_eq!(&ptr[0..4], &[0, 0, 2, 3]);
        assert_eq!(&pos[0..2], &[0, 3], "segment positions ascend by row");
        assert_eq!(pos[2], 2);
        // column 1: ptr = [3, 3, 3, 4]
        assert_eq!(&ptr[4..8], &[3, 3, 3, 4]);
        assert_eq!(pos[3], 1);
    }
}
