//! `ecqx` — CLI of the ECQ^x reproduction.
//!
//! Subcommands:
//!   smoke                      PJRT + artifact sanity check
//!   pretrain <model>           train + cache the FP32 baseline
//!   quantize <model> [opts]    one QAT run (ECQ or ECQx)
//!   sweep <model> [opts]       lambda sweep -> working points CSV
//!                              (--jobs N fans trials over N workers;
//!                              rows are identical for any N)
//!   compress <model>           quantize + write/reload a .ecqx container
//!                              (--jobs N fans the entropy coding over N
//!                              workers; the file is identical for any N)
//!   eval <model> <file.ecqx>   evaluate a compressed container
//!
//! Options: --backend auto|host|pjrt --model mlp|cnn --method ecq|ecqx
//!          --bits N --lambda F --p F --epochs N --lr F --seed N
//!          --jobs N --paper-scale --out PATH
//!
//! `--backend host` runs the whole pipeline on the pure-rust reference
//! backend (no artifacts/, no PJRT); `auto` (default) picks PJRT when the
//! artifacts + real bindings are present and falls back to host.
//! `--model mlp|cnn` selects the host workload family (aliases for the
//! `mlp_gsc` / `cnn_cifar` model names; the positional `<model>` argument
//! still accepts any manifest model name).
//!
//! Full per-flag documentation lives in README.md.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::sweep::{select, SweepConfig, SweepRunner};
use ecqx::coordinator::trainer::{evaluate, QatConfig, QatTrainer};
use ecqx::coordinator::{compressed_size, compression_ratio, AssignConfig, Method};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::metrics::WorkingPoint;
use ecqx::nn::checkpoint;

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn engine_of(args: &Args) -> Result<ecqx::runtime::Engine> {
    match args.flags.get("backend") {
        // explicit flag wins over $ECQX_BACKEND
        Some(v) => exp::engine_with(v.parse()?),
        None => exp::engine(),
    }
}

fn method_of(args: &Args) -> Result<Method> {
    match args.get::<String>("method", "ecqx".into()).as_str() {
        "ecq" => Ok(Method::Ecq),
        "ecqx" => Ok(Method::Ecqx),
        other => bail!("unknown method {other} (use ecq|ecqx)"),
    }
}

fn qat_config(args: &Args, exp_: &exp::ModelExp, method: Method) -> QatConfig {
    QatConfig {
        assign: AssignConfig {
            method,
            bits: args.get("bits", 4u32),
            lambda: args.get("lambda", 0.02f32),
            p: args.get("p", 0.3f64),
            momentum: args.get("momentum", 0.95f32),
            beta0: args.get("beta0", 1.0f32),
            ..Default::default()
        },
        epochs: args.get("epochs", exp_.qat_epochs),
        lr: args.get("lr", exp_.qat_lr),
        lrp_every: args.get("lrp-every", 2),
        retune_every: args.get("retune-every", 8),
        lrp_warmup: args.get("lrp-warmup", 12),
        assign_every: args.get("assign-every", 2),
        grad_scale: !args.has("no-grad-scale"),
        lrp_equal_weight: args.has("lrp-equal-weight"),
        verbose: true,
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "smoke" => cmd_smoke(&args),
        "pretrain" => cmd_pretrain(&args),
        "quantize" => cmd_quantize(&args),
        "sweep" => cmd_sweep(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        _ => {
            println!(
                "ecqx — Explainability-Driven Quantization (paper reproduction)\n\n\
                 usage: ecqx <smoke|pretrain|quantize|sweep|compress|eval> [args]\n\
                 see `ecqx <cmd> --help` comments in rust/src/main.rs and README.md"
            );
            Ok(())
        }
    }
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let eng = engine_of(args)?;
    // probe the PJRT client only when it is the backend actually in use —
    // `--backend host` must work even where PJRT cannot initialize
    if eng.backend_name() == "pjrt" {
        println!("{}", ecqx::runtime::smoke()?);
    }
    println!(
        "backend {} — manifest hash {} — {} models, {} artifacts",
        eng.backend_name(),
        eng.manifest.hash,
        eng.manifest.models.len(),
        eng.manifest.artifacts.len()
    );
    Ok(())
}

fn model_arg(args: &Args) -> Result<exp::ModelExp> {
    // `--model mlp|cnn` selects a host workload family by alias; the
    // positional argument still takes any manifest model name
    if let Some(m) = args.flags.get("model") {
        let name = match m.as_str() {
            "mlp" => "mlp_gsc",
            "cnn" => "cnn_cifar",
            other => other,
        };
        return exp::model_exp(name);
    }
    let name = args.positional.get(1).context(
        "missing model: pass --model mlp|cnn or a model name \
         (mlp_gsc|cnn_cifar|vgg_cifar|vgg_cifar_bn|resnet_voc)",
    )?;
    exp::model_exp(name)
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64);
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    println!(
        "pretrained {}: baseline val acc {:.4} ({} params, {:.1} kB fp32)",
        exp_.name,
        pre.baseline_acc,
        pre.state.spec.total_params(),
        pre.state.fp32_bytes() as f64 / 1000.0
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64);
    let method = method_of(args)?;
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let mut state = pre.state;
    let cfg = qat_config(args, &exp_, method);
    let trainer = QatTrainer::new(cfg);
    let out = trainer.run(&eng, &mut state, &train_dl, &val_dl)?;
    let ev = evaluate(&eng, &state, &val_dl, ParamSource::Quantized)?;
    println!("\nphase profile:\n{}", out.profile.report());
    println!(
        "final: acc={:.4} (baseline {:.4}, drop {:+.4}) sparsity={:.4} \
         size={:.1}kB CR={:.1}x",
        ev.accuracy,
        pre.baseline_acc,
        ev.accuracy - pre.baseline_acc,
        state.quantized_sparsity(),
        compressed_size(&state) as f64 / 1000.0,
        compression_ratio(&state)
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64);
    let method = method_of(args)?;
    let scale = if args.has("paper-scale") { exp::Scale::Paper } else { exp::Scale::Bench };
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let baseline = pre.baseline_acc;
    let jobs = args.get("jobs", 1usize).max(1);
    let runner = SweepRunner::new(&eng, pre.state);
    let cfg = SweepConfig {
        model: exp_.name.to_string(),
        method,
        bits: args.get("bits", 4u32),
        lambdas: exp::lambda_grid(scale),
        p: args.get("p", 0.3f64),
        qat: qat_config(args, &exp_, method),
        baseline_acc: baseline,
        seed,
    };
    if jobs > 1 {
        println!(
            "[sweep] fanning {} trials over {jobs} workers (rows are \
             deterministic; identical to --jobs 1)",
            cfg.lambdas.len()
        );
    }
    let points = runner.run_parallel(&cfg, &train_dl, &val_dl, jobs)?;
    println!("\n{}", WorkingPoint::csv_header());
    for p in &points {
        println!("{}", p.to_csv());
    }
    if let Some(best) = select::best_accuracy(&points) {
        println!("\nbest accuracy:        {}", best.to_csv());
    }
    if let Some(best) = select::best_cr_no_degradation(&points) {
        println!("best CR (no drop):    {}", best.to_csv());
    }
    if let Some(best) = select::best_cr_negligible(&points, 0.01) {
        println!("best CR (negligible): {}", best.to_csv());
    }
    if let Some(out) = args.flags.get("out") {
        let mut csv = WorkingPoint::csv_header().to_string() + "\n";
        for p in &points {
            csv += &(p.to_csv() + "\n");
        }
        std::fs::write(out, csv)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64);
    let method = method_of(args)?;
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let mut state = pre.state;
    let trainer = QatTrainer::new(qat_config(args, &exp_, method));
    trainer.run(&eng, &mut state, &train_dl, &val_dl)?;
    let out = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}.ecqx", exp_.name));
    let jobs = args.get("jobs", 1usize).max(1);
    let size = checkpoint::save_quantized_jobs(std::path::Path::new(&out), &state, jobs)?;
    println!(
        "wrote {out}: {:.1} kB on disk (CR {:.1}x vs {:.1} kB fp32)",
        size as f64 / 1000.0,
        state.fp32_bytes() as f64 / size as f64,
        state.fp32_bytes() as f64 / 1000.0
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    // `eval <model> <file>`, or with --model the file is the last
    // positional (`eval <file> --model mlp|cnn`; a redundant positional
    // model name may precede it) — `eval <model>` alone still errors
    let path = if args.has("model") {
        args.positional.last().filter(|_| args.positional.len() >= 2)
    } else {
        args.positional.get(2)
    }
    .context("missing <file.ecqx>")?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64);
    let qm = checkpoint::load_quantized(std::path::Path::new(path))?;
    if qm.model != exp_.name {
        bail!("container is for model {} not {}", qm.model, exp_.name);
    }
    let spec = eng.manifest.model(exp_.name)?.clone();
    let mut state = ecqx::nn::ModelState::init(&spec, seed);
    for (name, t) in qm.other {
        state.params.insert(name, t);
    }
    for (name, (idx, cb)) in qm.layers {
        let qw: Vec<f32> = idx.data.iter().map(|&s| cb.values[s as usize]).collect();
        let shape = idx.shape.clone();
        state.qlayers.insert(
            name,
            ecqx::nn::QLayer {
                qw: ecqx::tensor::Tensor::new(shape, qw),
                idx,
                codebook: cb,
            },
        );
    }
    let (_, val) = exp::datasets(&exp_, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let ev = evaluate(&eng, &state, &val_dl, ParamSource::Quantized)?;
    println!(
        "{path}: val acc {:.4}, loss {:.4}, sparsity {:.4}",
        ev.accuracy,
        ev.loss,
        state.quantized_sparsity()
    );
    Ok(())
}
