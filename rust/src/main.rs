//! `ecqx` — CLI of the ECQ^x reproduction.
//!
//! Subcommands:
//!   smoke                      PJRT + artifact sanity check
//!   pretrain <model>           train + cache the FP32 baseline
//!   quantize <model> [opts]    one QAT run (ECQ or ECQx)
//!   sweep <model> [opts]       lambda sweep -> working points CSV
//!                              (--jobs N fans trials over N workers;
//!                              rows are identical for any N; --store /
//!                              --resume / --shard make it crash-safe)
//!   report <store...>          aggregate durable store(s) -> CSV +
//!                              candidate selection (shards are merged)
//!   compress <model>           quantize + write/reload a .ecqx container
//!                              (--jobs N fans the entropy coding over N
//!                              workers; the file is identical for any N)
//!   eval <model> <file.ecqx>   evaluate a compressed container
//!   serve <model> [opts]       HTTP loopback inference server over the
//!                              worker pool: GET /eval?lambda=... builds
//!                              (and caches) the requested working point
//!                              and scores it through the microbatched
//!                              LUT eval path; --bench measures req/s at
//!                              p50/p99 latency into BENCH JSON
//!
//! Options: --backend auto|host|pjrt --model mlp|cnn --method ecq|ecqx
//!          --bits N --lambda F --p F --epochs N --lr F --seed N
//!          --jobs N --paper-scale --out PATH --deterministic
//! Durable sweeps: --store PATH --resume PATH --shard i/n --retries N
//!          --backoff-ms N --heartbeat N --max-trials N
//! Serving: --port N (0 = ephemeral) --max-batch N --bench
//!          --clients N --requests N
//!
//! `--deterministic` pins the scalar GEMM micro-kernel and serial block
//! schedule (DESIGN.md §2.6): results become bitwise-reproducible across
//! machines, at the cost of the vectorized fast path. The mode is also
//! recorded in durable store metadata, so a store written in one tier
//! refuses to resume in the other.
//!
//! Flag values are validated strictly: an unparseable value
//! (`--bits four`) or an unknown/typo'd flag (`--resme`) is an error
//! with a usage hint, never a silent fallback to the default.
//!
//! `--backend host` runs the whole pipeline on the pure-rust reference
//! backend (no artifacts/, no PJRT); `auto` (default) picks PJRT when the
//! artifacts + real bindings are present and falls back to host.
//! `--model mlp|cnn` selects the host workload family (aliases for the
//! `mlp_gsc` / `cnn_cifar` model names; the positional `<model>` argument
//! still accepts any manifest model name).
//!
//! Full per-flag documentation lives in README.md.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use ecqx::coordinator::binder::ParamSource;
use ecqx::coordinator::serve;
use ecqx::coordinator::store::{self, ResultStore};
use ecqx::coordinator::sweep::{select, StoreSweepOptions, SweepConfig, SweepRunner};
use ecqx::coordinator::trainer::{evaluate, QatConfig, QatTrainer};
use ecqx::coordinator::{
    compressed_size, compression_ratio, AssignConfig, Grid, Method, RetryPolicy,
};
use ecqx::data::DataLoader;
use ecqx::exp;
use ecqx::metrics::WorkingPoint;
use ecqx::nn::checkpoint;
use ecqx::util::fsx;

/// Flags that never take a value. Everything else consumes the next
/// token — and *requires* one, so `--seed` at the end of the line is an
/// error rather than a silently-adopted `"true"`.
const BOOL_FLAGS: &[&str] =
    &["paper-scale", "no-grad-scale", "lrp-equal-weight", "deterministic", "bench", "help"];

/// QAT hyperparameter flags shared by quantize / sweep / compress.
const QAT_FLAGS: &[&str] = &[
    "method",
    "bits",
    "lambda",
    "p",
    "momentum",
    "beta0",
    "epochs",
    "lr",
    "lrp-every",
    "retune-every",
    "lrp-warmup",
    "assign-every",
    "no-grad-scale",
    "lrp-equal-weight",
];

const COMMON_FLAGS: &[&str] = &["backend", "model", "seed", "deterministic", "help"];

/// Durable-campaign flags of `ecqx sweep`.
const STORE_FLAGS: &[&str] = &[
    "store",
    "resume",
    "shard",
    "retries",
    "backoff-ms",
    "heartbeat",
    "max-trials",
];

fn allowed_flags(cmd: &str) -> Vec<&'static str> {
    let mut out: Vec<&'static str> = COMMON_FLAGS.to_vec();
    match cmd {
        "smoke" | "pretrain" | "eval" => {}
        "quantize" => out.extend(QAT_FLAGS),
        "sweep" => {
            out.extend(QAT_FLAGS);
            out.extend(["jobs", "paper-scale", "out"]);
            out.extend(STORE_FLAGS);
        }
        "compress" => {
            out.extend(QAT_FLAGS);
            out.extend(["jobs", "out"]);
        }
        "serve" => {
            out.extend(QAT_FLAGS);
            out.extend(["jobs", "port", "max-batch", "bench", "clients", "requests"]);
        }
        "report" => out.extend(["out"]),
        _ => {}
    }
    out
}

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            // --name=value is always unambiguous
            if let Some((name, val)) = name.split_once('=') {
                flags.insert(name.to_string(), val.to_string());
                continue;
            }
            if BOOL_FLAGS.contains(&name) {
                flags.insert(name.to_string(), "true".to_string());
                continue;
            }
            let val = it
                .peek()
                .filter(|n| !n.starts_with("--"))
                .with_context(|| format!("flag --{name} requires a value"))?;
            flags.insert(name.to_string(), val.to_string());
            it.next();
        } else {
            positional.push(a.clone());
        }
    }
    Ok(Args { positional, flags })
}

fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// Reject unknown flags, with a did-you-mean hint for near misses —
/// `--resme` must be an error, never a silently ignored no-op.
fn validate_flags(args: &Args, cmd: &str) -> Result<()> {
    let allowed = allowed_flags(cmd);
    for name in args.flags.keys() {
        if allowed.contains(&name.as_str()) {
            continue;
        }
        let near = allowed
            .iter()
            .map(|c| (levenshtein(name, c), *c))
            .min()
            .filter(|(d, _)| *d <= 2)
            .map(|(_, c)| format!(" (did you mean --{c}?)"))
            .unwrap_or_default();
        bail!(
            "unknown flag --{name} for `ecqx {cmd}`{near}\n  allowed flags: {}",
            allowed
                .iter()
                .map(|f| format!("--{f}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    Ok(())
}

impl Args {
    /// Flag value parsed as `T`, or `default` when absent. An *unparseable*
    /// value is an error — never a silent fallback to the default.
    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!(
                    "invalid value for --{name}: {v:?} (expected {})",
                    std::any::type_name::<T>()
                )
            }),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn engine_of(args: &Args) -> Result<ecqx::runtime::Engine> {
    match args.flags.get("backend") {
        // explicit flag wins over $ECQX_BACKEND
        Some(v) => exp::engine_with(v.parse()?),
        None => exp::engine(),
    }
}

fn method_of(args: &Args) -> Result<Method> {
    match args.get::<String>("method", "ecqx".into())?.as_str() {
        "ecq" => Ok(Method::Ecq),
        "ecqx" => Ok(Method::Ecqx),
        other => bail!("unknown method {other} (use ecq|ecqx)"),
    }
}

fn qat_config(args: &Args, exp_: &exp::ModelExp, method: Method) -> Result<QatConfig> {
    Ok(QatConfig {
        assign: AssignConfig {
            method,
            bits: args.get("bits", 4u32)?,
            lambda: args.get("lambda", 0.02f32)?,
            p: args.get("p", 0.3f64)?,
            momentum: args.get("momentum", 0.95f32)?,
            beta0: args.get("beta0", 1.0f32)?,
            ..Default::default()
        },
        epochs: args.get("epochs", exp_.qat_epochs)?,
        lr: args.get("lr", exp_.qat_lr)?,
        lrp_every: args.get("lrp-every", 2)?,
        retune_every: args.get("retune-every", 8)?,
        lrp_warmup: args.get("lrp-warmup", 12)?,
        assign_every: args.get("assign-every", 2)?,
        grad_scale: !args.has("no-grad-scale"),
        lrp_equal_weight: args.has("lrp-equal-weight"),
        verbose: true,
    })
}

fn usage() -> &'static str {
    "ecqx — Explainability-Driven Quantization (paper reproduction)\n\n\
     usage: ecqx <smoke|pretrain|quantize|sweep|report|compress|eval|serve> [args]\n\
     serving: ecqx serve mlp_gsc --backend host --port 8737\n\
              ecqx serve mlp_gsc --bench --clients 4 --requests 64\n\
     durable sweeps: ecqx sweep ... --store run.jsonl [--shard i/n]\n\
                     ecqx sweep ... --resume run.jsonl\n\
                     ecqx report run.jsonl [more-shards.jsonl ...]\n\
     see README.md for full per-flag documentation"
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse_args(&argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    if cmd == "help" || args.has("help") {
        println!("{}", usage());
        return Ok(());
    }
    validate_flags(&args, cmd)?;
    // select the linalg tier before any GEMM runs: the mode is set-once
    // process-wide (DESIGN.md §2.6), so it must be pinned here, not
    // lazily inside whichever subsystem queries it first
    if args.has("deterministic") {
        ecqx::linalg::set_deterministic(true);
    }
    if let Ok(k) = std::env::var("ECQX_KERNEL") {
        if ecqx::linalg::Kernel::from_name(&k).is_none() {
            eprintln!(
                "warning: $ECQX_KERNEL={k:?} is not a known kernel \
                 (scalar|avx2|neon) — using runtime dispatch instead"
            );
        }
    }
    match cmd {
        "smoke" => cmd_smoke(&args),
        "pretrain" => cmd_pretrain(&args),
        "quantize" => cmd_quantize(&args),
        "sweep" => cmd_sweep(&args),
        "report" => cmd_report(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let eng = engine_of(args)?;
    // probe the PJRT client only when it is the backend actually in use —
    // `--backend host` must work even where PJRT cannot initialize
    if eng.backend_name() == "pjrt" {
        println!("{}", ecqx::runtime::smoke()?);
    }
    println!(
        "backend {} — manifest hash {} — {} models, {} artifacts",
        eng.backend_name(),
        eng.manifest.hash,
        eng.manifest.models.len(),
        eng.manifest.artifacts.len()
    );
    Ok(())
}

fn model_arg(args: &Args) -> Result<exp::ModelExp> {
    // `--model mlp|cnn` selects a host workload family by alias; the
    // positional argument still takes any manifest model name
    if let Some(m) = args.flags.get("model") {
        let name = match m.as_str() {
            "mlp" => "mlp_gsc",
            "cnn" => "cnn_cifar",
            other => other,
        };
        return exp::model_exp(name);
    }
    let name = args.positional.get(1).context(
        "missing model: pass --model mlp|cnn or a model name \
         (mlp_gsc|cnn_cifar|vgg_cifar|vgg_cifar_bn|resnet_voc)",
    )?;
    exp::model_exp(name)
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64)?;
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    println!(
        "pretrained {}: baseline val acc {:.4} ({} params, {:.1} kB fp32)",
        exp_.name,
        pre.baseline_acc,
        pre.state.spec.total_params(),
        pre.state.fp32_bytes() as f64 / 1000.0
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64)?;
    let method = method_of(args)?;
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let mut state = pre.state;
    let cfg = qat_config(args, &exp_, method)?;
    let trainer = QatTrainer::new(cfg);
    let out = trainer.run(&eng, &mut state, &train_dl, &val_dl)?;
    let ev = evaluate(&eng, &state, &val_dl, ParamSource::Quantized)?;
    println!("\nphase profile:\n{}", out.profile.report());
    println!(
        "final: acc={:.4} (baseline {:.4}, drop {:+.4}) sparsity={:.4} \
         size={:.1}kB CR={:.1}x",
        ev.accuracy,
        pre.baseline_acc,
        ev.accuracy - pre.baseline_acc,
        state.quantized_sparsity(),
        compressed_size(&state) as f64 / 1000.0,
        compression_ratio(&state)
    );
    Ok(())
}

fn print_points(points: &[WorkingPoint]) {
    println!("\n{}", WorkingPoint::csv_header());
    for p in points {
        println!("{}", p.to_csv());
    }
    if let Some(best) = select::best_accuracy(points) {
        println!("\nbest accuracy:        {}", best.to_csv());
    }
    if let Some(best) = select::best_cr_no_degradation(points) {
        println!("best CR (no drop):    {}", best.to_csv());
    }
    if let Some(best) = select::best_cr_negligible(points, 0.01) {
        println!("best CR (negligible): {}", best.to_csv());
    }
}

fn write_csv(out: &str, points: &[WorkingPoint]) -> Result<()> {
    let mut csv = WorkingPoint::csv_header().to_string() + "\n";
    for p in points {
        csv += &(p.to_csv() + "\n");
    }
    fsx::atomic_write(Path::new(out), csv.as_bytes())?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64)?;
    let method = method_of(args)?;
    let scale = if args.has("paper-scale") { exp::Scale::Paper } else { exp::Scale::Bench };
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let baseline = pre.baseline_acc;
    let jobs = args.get("jobs", 1usize)?.max(1);
    let runner = SweepRunner::new(&eng, pre.state);
    let cfg = SweepConfig {
        model: exp_.name.to_string(),
        method,
        bits: args.get("bits", 4u32)?,
        lambdas: exp::lambda_grid(scale),
        p: args.get("p", 0.3f64)?,
        qat: qat_config(args, &exp_, method)?,
        baseline_acc: baseline,
        seed,
    };
    // durable path: --store creates-or-resumes, --resume requires the file
    let store_path = match (args.flags.get("store"), args.flags.get("resume")) {
        (Some(_), Some(_)) => bail!("--store and --resume are mutually exclusive"),
        (Some(s), None) => Some((s.clone(), false)),
        (None, Some(r)) => Some((r.clone(), true)),
        (None, None) => None,
    };
    if store_path.is_none() {
        for f in STORE_FLAGS.iter().filter(|f| !matches!(**f, "store" | "resume")) {
            if args.has(f) {
                bail!("--{f} requires a durable campaign (--store or --resume)");
            }
        }
        if jobs > 1 {
            println!(
                "[sweep] fanning {} trials over {jobs} workers (rows are \
                 deterministic; identical to --jobs 1)",
                cfg.lambdas.len()
            );
        }
        let points = runner.run_parallel(&cfg, &train_dl, &val_dl, jobs)?;
        print_points(&points);
        if let Some(out) = args.flags.get("out") {
            write_csv(out, &points)?;
        }
        return Ok(());
    }
    let (path, must_exist) = store_path.unwrap();
    let mut rs = if must_exist {
        ResultStore::open_existing(Path::new(&path))?
    } else {
        ResultStore::open_or_create(Path::new(&path))?
    };
    let shard = args
        .flags
        .get("shard")
        .map(|s| store::parse_shard(s))
        .transpose()?;
    let opts = StoreSweepOptions {
        jobs,
        shard,
        retry: RetryPolicy {
            retries: args.get("retries", 0u32)?,
            backoff_ms: args.get("backoff-ms", 0u64)?,
        },
        heartbeat_every: args.get("heartbeat", 10usize)?,
        max_trials: args.get("max-trials", 0usize)?,
        deterministic: args.has("deterministic"),
    };
    let grid = Grid::lambda_sweep(cfg.method, cfg.bits, &cfg.lambdas, cfg.p);
    println!(
        "[sweep] durable campaign -> {path} ({} trials{}, jobs={jobs})",
        grid.len(),
        shard
            .map(|(i, n)| format!(", shard {i}/{n}"))
            .unwrap_or_default()
    );
    let outcome = runner.run_store(&cfg, &grid, &train_dl, &val_dl, &mut rs, &opts, None)?;
    println!(
        "[sweep] ran {} trial(s), skipped {} already-complete, {} quarantined",
        outcome.ran, outcome.skipped, outcome.quarantined
    );
    for (id, error, attempts) in rs.quarantined() {
        eprintln!(
            "[sweep] quarantined trial {id} ({attempts} attempt(s)): {}",
            error.lines().next().unwrap_or("")
        );
    }
    if outcome.cancelled {
        eprintln!(
            "[sweep] campaign interrupted before completion — all finished \
             trials are safe in {path}; resume with:\n  ecqx sweep {} --resume {path}",
            exp_.name
        );
        std::process::exit(3);
    }
    let points: Vec<WorkingPoint> = rs.done_points().into_iter().map(|(_, p)| p).collect();
    print_points(&points);
    if let Some(out) = args.flags.get("out") {
        write_csv(out, &points)?;
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let paths = &args.positional[1..];
    if paths.is_empty() {
        bail!("usage: ecqx report <store.jsonl> [more-shards.jsonl ...] [--out csv]");
    }
    let stores: Vec<ResultStore> = paths
        .iter()
        .map(|p| ResultStore::open_existing(Path::new(p)))
        .collect::<Result<_>>()?;
    let (meta, rows) = store::merge(&stores)?;
    let mut points: Vec<WorkingPoint> = Vec::new();
    let mut quarantined: Vec<(usize, String, u32)> = Vec::new();
    for r in &rows {
        match &r.result {
            ecqx::coordinator::campaign::TrialResult::Done(p) => points.push(p.clone()),
            ecqx::coordinator::campaign::TrialResult::Failed { error, attempts } => {
                quarantined.push((r.id, error.clone(), *attempts))
            }
        }
    }
    println!(
        "campaign {} on {} (seed {}): {}/{} trials complete, {} quarantined, \
         {} missing",
        meta.model,
        meta.backend,
        meta.seed,
        points.len(),
        meta.n_trials,
        quarantined.len(),
        meta.n_trials - points.len() - quarantined.len()
    );
    for (id, error, attempts) in &quarantined {
        eprintln!(
            "quarantined trial {id} ({attempts} attempt(s)): {}",
            error.lines().next().unwrap_or("")
        );
    }
    print_points(&points);
    if let Some(out) = args.flags.get("out") {
        write_csv(out, &points)?;
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64)?;
    let method = method_of(args)?;
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let mut state = pre.state;
    let trainer = QatTrainer::new(qat_config(args, &exp_, method)?);
    trainer.run(&eng, &mut state, &train_dl, &val_dl)?;
    let out = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}.ecqx", exp_.name));
    let jobs = args.get("jobs", 1usize)?.max(1);
    let size = checkpoint::save_quantized_jobs(Path::new(&out), &state, jobs)?;
    println!(
        "wrote {out}: {:.1} kB on disk (CR {:.1}x vs {:.1} kB fp32)",
        size as f64 / 1000.0,
        state.fp32_bytes() as f64 / size as f64,
        state.fp32_bytes() as f64 / 1000.0
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    // `eval <model> <file>`, or with --model the file is the last
    // positional (`eval <file> --model mlp|cnn`; a redundant positional
    // model name may precede it) — `eval <model>` alone still errors
    let path = if args.has("model") {
        args.positional.last().filter(|_| args.positional.len() >= 2)
    } else {
        args.positional.get(2)
    }
    .context("missing <file.ecqx>")?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64)?;
    let qm = checkpoint::load_quantized(Path::new(path))?;
    if qm.model != exp_.name {
        bail!("container is for model {} not {}", qm.model, exp_.name);
    }
    let spec = eng.manifest.model(exp_.name)?.clone();
    let mut state = ecqx::nn::ModelState::init(&spec, seed);
    for (name, t) in qm.other {
        state.params.insert(name, t);
    }
    for (name, (idx, cb)) in qm.layers {
        let qw: Vec<f32> = idx.data.iter().map(|&s| cb.values[s as usize]).collect();
        let shape = idx.shape.clone();
        state.qlayers.insert(
            name,
            ecqx::nn::QLayer {
                qw: ecqx::tensor::Tensor::new(shape, qw),
                idx,
                codebook: cb,
            },
        );
    }
    let (_, val) = exp::datasets(&exp_, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let ev = evaluate(&eng, &state, &val_dl, ParamSource::Quantized)?;
    println!(
        "{path}: val acc {:.4}, loss {:.4}, sparsity {:.4}",
        ev.accuracy,
        ev.loss,
        state.quantized_sparsity()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let exp_ = model_arg(args)?;
    let eng = engine_of(args)?;
    let seed = args.get("seed", 17u64)?;
    let method = method_of(args)?;
    let pre = exp::pretrained(&eng, &exp_, seed)?;
    let (train, val) = exp::datasets(&exp_, seed);
    let spec = eng.manifest.model(exp_.name)?;
    let train_dl = DataLoader::new(&train, spec.batch, true, seed);
    let val_dl = DataLoader::new(&val, spec.batch, false, seed);
    let runner = SweepRunner::new(&eng, pre.state);
    // defaults mirror qat_config/sweep exactly, so `GET /eval` with no
    // parameters serves the same working point `ecqx sweep` would produce
    // for these flags — that identity is what serve-smoke diffs
    let mut qat = qat_config(args, &exp_, method)?;
    qat.verbose = false; // concurrent builds would interleave epoch logs
    let cfg = SweepConfig {
        model: exp_.name.to_string(),
        method,
        bits: args.get("bits", 4u32)?,
        lambdas: vec![args.get("lambda", 0.02f32)?],
        p: args.get("p", 0.3f64)?,
        qat,
        baseline_acc: pre.baseline_acc,
        seed,
    };
    let opts = serve::ServeOptions {
        port: args.get("port", 8737u16)?,
        jobs: args.get("jobs", 1usize)?.max(1),
        max_batch: args.get("max-batch", 8usize)?.max(1),
        verbose: true,
    };
    let server = serve::Server::bind(&runner, cfg, &train_dl, &val_dl, opts)?;
    if !args.has("bench") {
        return server.run();
    }
    // --bench: saturating-throughput measurement against the real HTTP
    // path, recorded into BENCH JSON so serve participates in the
    // perf-regression job
    let clients = args.get("clients", 4usize)?.max(1);
    let per_client = args.get("requests", 16usize)?.max(1);
    let addr = server.local_addr();
    let mname = match method {
        Method::Ecq => "ecq",
        Method::Ecqx => "ecqx",
    };
    let query = format!(
        "/eval?method={mname}&bits={}&lambda={}&p={}",
        args.get("bits", 4u32)?,
        args.get("lambda", 0.02f32)?,
        args.get("p", 0.3f64)?
    );
    let summary = std::thread::scope(|scope| -> Result<serve::BenchSummary> {
        let srv = scope.spawn(|| server.run());
        let bench = serve::run_bench(addr, &query, clients, per_client);
        // always attempt the shutdown and join before propagating any
        // bench error — an early `?` would leave the scope blocked on
        // the still-serving thread
        let shutdown = serve::http_get(addr, "/shutdown");
        let ran = srv.join().expect("server thread panicked");
        let (code, _) = shutdown?;
        if code != 200 {
            bail!("shutdown returned {code}");
        }
        ran?;
        bench
    })?;
    println!(
        "serve bench: {} requests x {} clients: {:.1} req/s \
         (p50 {:.1} ms, p99 {:.1} ms, wall {:.2}s)",
        summary.requests,
        summary.clients,
        summary.req_s,
        summary.p50_s * 1e3,
        summary.p99_s * 1e3,
        summary.wall_s
    );
    let mut log = ecqx::bench::PerfLog::new(eng.backend_name());
    let shape = [summary.clients, summary.requests];
    let mk = |mean_s: f64| ecqx::bench::BenchResult {
        name: "serve_eval".into(),
        iters: summary.requests,
        mean_s,
        median_s: summary.p50_s,
        std_s: 0.0,
        min_s: summary.p50_s,
    };
    let req_s = format!("{:.1}", summary.req_s);
    let model_kv = ("model", exp_.name);
    log.push_kv("serve_eval", &shape, &mk(summary.p50_s), None, &[("variant", "p50"), model_kv]);
    log.push_kv("serve_eval", &shape, &mk(summary.p99_s), None, &[("variant", "p99"), model_kv]);
    log.push_kv(
        "serve_eval",
        &shape,
        &mk(summary.wall_s / summary.requests.max(1) as f64),
        None,
        &[("variant", "throughput"), ("req_s", &req_s), model_kv],
    );
    let path = log.write_default()?;
    println!("wrote {} ({} serve rows)", path.display(), log.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flags_parse_values_and_bools() {
        let a = parse_args(&argv(&[
            "sweep",
            "mlp_gsc",
            "--bits",
            "2",
            "--paper-scale",
            "--out=points.csv",
            "--jobs",
            "4",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["sweep", "mlp_gsc"]);
        assert_eq!(a.get("bits", 4u32).unwrap(), 2);
        assert_eq!(a.get("jobs", 1usize).unwrap(), 4);
        assert!(a.has("paper-scale"));
        assert_eq!(a.flags.get("out").map(|s| s.as_str()), Some("points.csv"));
        // bool flags must not swallow the token after them
        let a = parse_args(&argv(&["sweep", "--paper-scale", "mlp_gsc"])).unwrap();
        assert_eq!(a.positional, vec!["sweep", "mlp_gsc"]);
    }

    #[test]
    fn unparseable_values_error_not_default() {
        let a = parse_args(&argv(&["sweep", "--bits", "four"])).unwrap();
        let err = a.get("bits", 4u32).unwrap_err();
        assert!(format!("{err}").contains("--bits"), "{err}");
        // absent flag still yields the default
        assert_eq!(a.get("seed", 17u64).unwrap(), 17);
    }

    #[test]
    fn value_flags_require_a_value() {
        let err = parse_args(&argv(&["sweep", "--seed"])).unwrap_err();
        assert!(format!("{err:#}").contains("--seed"), "{err:#}");
        let err = parse_args(&argv(&["sweep", "--seed", "--jobs", "2"])).unwrap_err();
        assert!(format!("{err:#}").contains("requires a value"), "{err:#}");
    }

    #[test]
    fn unknown_flags_get_a_suggestion() {
        let a = parse_args(&argv(&["sweep", "mlp_gsc", "--resme", "x.jsonl"])).unwrap();
        let err = validate_flags(&a, "sweep").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--resme"), "{msg}");
        assert!(msg.contains("did you mean --resume"), "{msg}");
        // and flags valid for one command are rejected for another
        let a = parse_args(&argv(&["pretrain", "mlp_gsc", "--shard", "0/2"])).unwrap();
        assert!(validate_flags(&a, "pretrain").is_err());
        let a = parse_args(&argv(&["sweep", "mlp_gsc", "--shard", "0/2"])).unwrap();
        assert!(validate_flags(&a, "sweep").is_ok());
    }

    #[test]
    fn serve_flags_validate_strictly() {
        // the serve allow-list accepts its own flags plus QAT flags...
        let a = parse_args(&argv(&[
            "serve",
            "mlp_gsc",
            "--port=0",
            "--max-batch",
            "4",
            "--bench",
            "--clients",
            "2",
            "--requests",
            "8",
            "--lambda",
            "0.08",
            "--deterministic",
        ]))
        .unwrap();
        validate_flags(&a, "serve").unwrap();
        assert_eq!(a.get("port", 8737u16).unwrap(), 0); // ephemeral port
        assert!(a.has("bench")); // --bench is a bool flag...
        assert_eq!(a.positional, vec!["serve", "mlp_gsc"]); // ...and swallows nothing
        // ...but rejects sweep-only campaign flags, with a suggestion
        let a = parse_args(&argv(&["serve", "mlp_gsc", "--store", "x.jsonl"])).unwrap();
        let msg = format!("{}", validate_flags(&a, "serve").unwrap_err());
        assert!(msg.contains("--store"), "{msg}");
        let a = parse_args(&argv(&["serve", "mlp_gsc", "--prot", "8080"])).unwrap();
        let msg = format!("{}", validate_flags(&a, "serve").unwrap_err());
        assert!(msg.contains("did you mean --port"), "{msg}");
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("resme", "resume"), 1);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("same", "same"), 0);
        assert!(levenshtein("bits", "backend") > 2);
    }
}
