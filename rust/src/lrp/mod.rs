//! Pure-rust LRP reference for dense networks + the Fig. 4 analysis.
//!
//! This is the *third* implementation of epsilon-rule LRP in the stack
//! (after the Pallas kernel and the jnp oracle); integration tests use it
//! to cross-check the `<model>_lrp` HLO artifact end-to-end on MLP_GSC.
//! It also powers host-side analyses (relevance-vs-magnitude correlation).
//!
//! Deliberately NOT routed through the blocked [`crate::linalg`] core the
//! host backend runs on: keeping these loops naive and self-contained is
//! what makes the host-vs-reference cross-checks in
//! `tests/integration_runtime.rs` meaningful — they would prove nothing
//! if both sides shared one GEMM implementation.

pub mod analysis;

pub const EPS: f32 = 1e-6;

/// A dense layer's weights in row-major [in, out] plus bias.
#[derive(Clone, Debug)]
pub struct DenseLayer {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

impl DenseLayer {
    pub fn new(din: usize, dout: usize, w: Vec<f32>, b: Vec<f32>) -> Self {
        assert_eq!(w.len(), din * dout);
        assert_eq!(b.len(), dout);
        DenseLayer { w, b, din, dout }
    }

    /// z = a @ w + b for a batch of activations [n, din].
    pub fn forward(&self, a: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(a.len(), n * self.din);
        let mut z = vec![0.0f32; n * self.dout];
        for s in 0..n {
            let ar = &a[s * self.din..(s + 1) * self.din];
            let zr = &mut z[s * self.dout..(s + 1) * self.dout];
            zr.copy_from_slice(&self.b);
            for (i, &ai) in ar.iter().enumerate() {
                if ai == 0.0 {
                    continue;
                }
                let wrow = &self.w[i * self.dout..(i + 1) * self.dout];
                for (j, &wij) in wrow.iter().enumerate() {
                    zr[j] += ai * wij;
                }
            }
        }
        z
    }
}

pub fn relu(z: &mut [f32]) {
    for v in z.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn stabilize(z: f32, eps: f32) -> f32 {
    if z >= 0.0 {
        z + eps
    } else {
        z - eps
    }
}

/// An MLP as a stack of dense layers with ReLU between (none after last).
pub struct Mlp {
    pub layers: Vec<DenseLayer>,
}

impl Mlp {
    /// Forward pass keeping every layer input (for LRP).
    pub fn forward_collect(&self, x: &[f32], n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts = vec![x.to_vec()];
        let mut a = x.to_vec();
        for (li, l) in self.layers.iter().enumerate() {
            let mut z = l.forward(&a, n);
            if li + 1 < self.layers.len() {
                relu(&mut z);
                acts.push(z.clone());
            }
            a = z;
        }
        (acts, a)
    }

    /// Epsilon-rule LRP -> per-weight relevances, batch-aggregated, signed.
    ///
    /// `eqw` selects equally-weighted samples (R_n = 1, the Fig. 4 mode)
    /// vs target-score weighting.
    pub fn lrp(&self, x: &[f32], y: &[i32], n: usize, eqw: bool) -> Vec<Vec<f32>> {
        let (acts, logits) = self.forward_collect(x, n);
        let classes = self.layers.last().unwrap().dout;
        // initial relevance at the output
        let mut r: Vec<f32> = vec![0.0; n * classes];
        for s in 0..n {
            let yc = y[s] as usize;
            let score = logits[s * classes + yc];
            r[s * classes + yc] = if eqw { 1.0 } else { score };
        }
        let mut rws: Vec<Vec<f32>> = Vec::with_capacity(self.layers.len());
        for (li, l) in self.layers.iter().enumerate().rev() {
            let a = &acts[li];
            let z = l.forward(a, n);
            // s = R / stabilize(z)
            let mut sv = vec![0.0f32; n * l.dout];
            for i in 0..sv.len() {
                sv[i] = r[i] / stabilize(z[i], EPS);
            }
            // R_w = w * (a^T s); R_in = a * (s w^T)
            let mut rw = vec![0.0f32; l.din * l.dout];
            let mut rin = vec![0.0f32; n * l.din];
            for smp in 0..n {
                let ar = &a[smp * l.din..(smp + 1) * l.din];
                let sr = &sv[smp * l.dout..(smp + 1) * l.dout];
                for (i, &ai) in ar.iter().enumerate() {
                    if ai == 0.0 {
                        continue;
                    }
                    let wrow = &l.w[i * l.dout..(i + 1) * l.dout];
                    let mut acc = 0.0f32;
                    for (j, &wij) in wrow.iter().enumerate() {
                        rw[i * l.dout + j] += ai * wij * sr[j];
                        acc += sr[j] * wij;
                    }
                    rin[smp * l.din + i] = ai * acc;
                }
            }
            rws.push(rw);
            r = rin;
        }
        rws.reverse();
        rws
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_mlp(dims: &[usize], seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let layers = dims
            .windows(2)
            .map(|w| {
                let (din, dout) = (w[0], w[1]);
                let std = (2.0 / din as f32).sqrt();
                DenseLayer::new(
                    din,
                    dout,
                    (0..din * dout).map(|_| rng.normal_f32(0.0, std)).collect(),
                    vec![0.0; dout],
                )
            })
            .collect();
        Mlp { layers }
    }

    #[test]
    fn forward_matches_manual() {
        let l = DenseLayer::new(2, 2, vec![1.0, 2.0, 3.0, 4.0], vec![0.5, -0.5]);
        // a = [1, 1]: z = [1+3+0.5, 2+4-0.5] = [4.5, 5.5]
        let z = l.forward(&[1.0, 1.0], 1);
        assert_eq!(z, vec![4.5, 5.5]);
    }

    #[test]
    fn lrp_conservation_per_sample() {
        // With zero biases and small eps, sum of weight relevances over a
        // single linear layer equals the initial relevance.
        let mlp = toy_mlp(&[6, 4], 3);
        let mut rng = Rng::new(4);
        let n = 5;
        let x: Vec<f32> = (0..n * 6).map(|_| rng.normal_f32(0.5, 1.0)).collect();
        let y: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
        let rws = mlp.lrp(&x, &y, n, true);
        let total: f32 = rws[0].iter().sum();
        // initial relevance = 1 per sample
        assert!(
            (total - n as f32).abs() / (n as f32) < 1e-3,
            "conservation violated: {total} vs {n}"
        );
    }

    #[test]
    fn lrp_deep_conservation_approx() {
        let mlp = toy_mlp(&[8, 16, 8, 4], 7);
        let mut rng = Rng::new(8);
        let n = 4;
        let x: Vec<f32> = (0..n * 8).map(|_| rng.normal_f32(0.2, 1.0)).collect();
        let y: Vec<i32> = vec![0, 1, 2, 3];
        let rws = mlp.lrp(&x, &y, n, true);
        // relevance entering each layer should be (approximately, biases
        // are zero) conserved into its weight relevances
        for rw in &rws {
            let total: f32 = rw.iter().sum();
            assert!(
                (total - n as f32).abs() / (n as f32) < 0.05,
                "layer total {total}"
            );
        }
    }

    #[test]
    fn score_weighting_scales_relevance() {
        let mlp = toy_mlp(&[6, 4], 11);
        let mut rng = Rng::new(12);
        let x: Vec<f32> = (0..6).map(|_| rng.normal_f32(0.5, 1.0)).collect();
        let y = vec![1i32];
        let r_eq = mlp.lrp(&x, &y, 1, true);
        let r_sc = mlp.lrp(&x, &y, 1, false);
        let (_, logits) = mlp.forward_collect(&x, 1);
        let score = logits[1];
        let se: f32 = r_eq[0].iter().sum();
        let ss: f32 = r_sc[0].iter().sum();
        assert!((ss - se * score).abs() < 1e-3 * score.abs().max(1.0));
    }
}
