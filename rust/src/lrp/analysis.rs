//! Relevance-vs-magnitude analysis (Fig. 4): Pearson correlation between
//! |weight| and |relevance| per layer, plus the marginal histograms shown
//! in the paper's panels.

use crate::util::stats;

/// One layer's Fig. 4 panel data.
#[derive(Clone, Debug)]
pub struct CorrelationPanel {
    pub layer: String,
    /// Pearson c between weight value and relevance (the paper's `c`)
    pub c_value: f64,
    /// Pearson between |weight| and relevance (saliency assumption probe)
    pub c_magnitude: f64,
    /// weight histogram (bins over [-wmax, wmax])
    pub weight_hist: Vec<usize>,
    /// relevance histogram (bins over [0, rmax])
    pub relevance_hist: Vec<usize>,
    /// summed relevance per weight-histogram bin (the blue overlay)
    pub relevance_by_weight_bin: Vec<f64>,
    pub wmax: f32,
    pub rmax: f32,
}

/// Build the Fig. 4 panel for one layer.
pub fn correlation_panel(
    layer: &str,
    weights: &[f32],
    relevances: &[f32],
    bins: usize,
) -> CorrelationPanel {
    assert_eq!(weights.len(), relevances.len());
    let rel_abs: Vec<f32> = relevances.iter().map(|r| r.abs()).collect();
    let w_abs: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    let wmax = weights.iter().fold(0.0f32, |m, &w| m.max(w.abs())).max(1e-12);
    let rmax = rel_abs.iter().fold(0.0f32, |m, &r| m.max(r)).max(1e-12);
    let mut rel_by_bin = vec![0.0f64; bins];
    let binw = 2.0 * wmax / bins as f32;
    for (&w, &r) in weights.iter().zip(rel_abs.iter()) {
        let b = (((w + wmax) / binw) as usize).min(bins - 1);
        rel_by_bin[b] += r as f64;
    }
    CorrelationPanel {
        layer: layer.to_string(),
        c_value: stats::pearson(weights, &rel_abs),
        c_magnitude: stats::pearson(&w_abs, &rel_abs),
        weight_hist: stats::histogram(weights, -wmax, wmax, bins),
        relevance_hist: stats::histogram(&rel_abs, 0.0, rmax, bins),
        relevance_by_weight_bin: rel_by_bin,
        wmax,
        rmax,
    }
}

/// Fraction of the top-q relevance mass carried by weights *below* the
/// median magnitude — the paper's qualitative claim that "a weight of high
/// magnitude is not necessarily also a relevant weight".
pub fn small_weight_relevance_share(weights: &[f32], relevances: &[f32]) -> f64 {
    assert_eq!(weights.len(), relevances.len());
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = mags[mags.len() / 2];
    let total: f64 = relevances.iter().map(|r| r.abs() as f64).sum();
    if total == 0.0 {
        return 0.0;
    }
    let small: f64 = weights
        .iter()
        .zip(relevances.iter())
        .filter(|(w, _)| w.abs() < median)
        .map(|(_, r)| r.abs() as f64)
        .sum();
    small / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn panel_shapes() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..1000).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let r: Vec<f32> = w.iter().map(|&x| x.abs() + rng.normal_f32(0.0, 0.01)).collect();
        let p = correlation_panel("l0", &w, &r, 32);
        assert_eq!(p.weight_hist.len(), 32);
        assert_eq!(p.relevance_hist.len(), 32);
        // relevance built from |w| -> strong magnitude correlation
        assert!(p.c_magnitude > 0.8, "c_mag={}", p.c_magnitude);
        // but value correlation near zero by symmetry
        assert!(p.c_value.abs() < 0.2, "c_val={}", p.c_value);
    }

    #[test]
    fn share_detects_decorrelation() {
        let mut rng = Rng::new(2);
        let n = 2000;
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        // relevance independent of magnitude -> small weights carry ~half
        let r: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let share = small_weight_relevance_share(&w, &r);
        assert!((share - 0.5).abs() < 0.1, "share={share}");
        // relevance == magnitude -> small weights carry much less
        let r2: Vec<f32> = w.iter().map(|x| x.abs()).collect();
        let share2 = small_weight_relevance_share(&w, &r2);
        assert!(share2 < 0.35, "share2={share2}");
    }
}
