//! Mini-criterion: the bench harness used by `cargo bench` targets
//! (criterion is unavailable offline; every `[[bench]]` sets
//! `harness = false` and drives this module).
//!
//! Provides warmup + N timed iterations with mean/median/σ reporting, a
//! `Series` helper for the figure-regeneration benches that print the
//! paper's accuracy/sparsity/size rows, and [`PerfLog`] — the
//! machine-readable `BENCH_host.json` writer that records the repo's perf
//! trajectory (op, shape, ns/iter, GFLOP/s) next to the human output.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::stats;

/// Timing result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        fn fmt(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.1} µs", s * 1e6)
            }
        }
        format!(
            "{:<40} {:>12}/iter (median {}, σ {}, n={})",
            self.name,
            fmt(self.mean_s),
            fmt(self.median_s),
            fmt(self.std_s),
            self.iters
        )
    }
}

/// Run `f` with `warmup` unmeasured + `iters` measured iterations.
///
/// Degenerate parameters are clamped rather than propagated: `iters == 0`
/// used to produce an empty sample vector, whose mean divided into the
/// ns-per-iter rows as NaN — now at least one iteration is always
/// measured (`warmup == 0` is fine as-is; the warmup loop simply doesn't
/// run). The clamp is pinned by `bench_clamps_zero_iters`.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    let iters = iters.max(1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let times_f32: Vec<f32> = times.iter().map(|&t| t as f32).collect();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&times_f32),
        median_s: stats::median(&times),
        std_s: stats::std_dev(&times_f32),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    println!("{}", r.report());
    r
}

/// Print a figure header in a stable, grep-able format.
pub fn figure_header(id: &str, title: &str) {
    println!("\n=== {id}: {title} ===");
}

/// Print one series row (a data point of a paper figure).
pub fn series_row(series: &str, xs: &[(&str, String)]) {
    let cells: Vec<String> = xs.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("[{series}] {}", cells.join(" "));
}

/// Machine-readable perf rows, serialized as `BENCH_host.json` so the
/// repo's perf trajectory is recorded run-over-run instead of living only
/// in scrollback. Serialization is hand-rolled (the offline build has no
/// serde); the schema is flat on purpose:
///
/// ```json
/// {"schema": 1, "backend": "host",
///  "rows": [{"op": "gemm_nn_blocked", "shape": "256x256x256",
///            "ns_per_iter": 81234.5, "gflops": 413.1}, ...]}
/// ```
///
/// `gflops` is present only for rows with a known FLOP count and is
/// `null` otherwise. CI's bench-smoke step fails if the file is missing
/// or malformed (see `.github/workflows/ci.yml`).
#[derive(Debug)]
pub struct PerfLog {
    backend: String,
    rows: Vec<String>,
}

impl PerfLog {
    /// Empty log for one backend's run.
    pub fn new(backend: &str) -> PerfLog {
        PerfLog { backend: backend.to_string(), rows: Vec::new() }
    }

    /// Record one benchmark result. `shape` is the op's dimension tuple
    /// (e.g. `[m, k, n]` for a GEMM, `[n]` for a 1-D kernel); `flops`
    /// (per iteration) enables the GFLOP/s column.
    pub fn push(&mut self, op: &str, shape: &[usize], r: &BenchResult, flops: Option<f64>) {
        self.push_kv(op, shape, r, flops, &[]);
    }

    /// [`PerfLog::push`] with extra string fields appended to the row —
    /// the `simd_kernels` section uses this for `"kernel"` (the variant
    /// being timed) and `"dispatch"` (what `GemmOpts::dispatch` would
    /// pick on this host). Keys and values must be plain identifiers
    /// (they are embedded in hand-rolled JSON unescaped).
    pub fn push_kv(
        &mut self,
        op: &str,
        shape: &[usize],
        r: &BenchResult,
        flops: Option<f64>,
        extras: &[(&str, &str)],
    ) {
        let shape_s = shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let gflops = match flops {
            Some(f) if r.mean_s > 0.0 => format!("{:.3}", f / r.mean_s / 1e9),
            _ => "null".to_string(),
        };
        let extra_s: String = extras
            .iter()
            .map(|(k, v)| format!(", \"{k}\": \"{v}\""))
            .collect();
        self.rows.push(format!(
            "{{\"op\": \"{op}\", \"shape\": \"{shape_s}\", \"ns_per_iter\": {:.1}, \"gflops\": {gflops}{extra_s}}}",
            r.mean_s * 1e9
        ));
    }

    /// Number of recorded rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the full JSON document.
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"schema\": 1,\n  \"backend\": \"{}\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
            self.backend,
            self.rows.join(",\n    ")
        )
    }

    /// Write the log to `path`.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().as_bytes())
    }

    /// Write to `$ECQX_BENCH_JSON` if set, else `BENCH_host.json` in the
    /// working directory; returns the path written.
    pub fn write_default(&self) -> std::io::Result<PathBuf> {
        let path = std::env::var_os("ECQX_BENCH_JSON")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("BENCH_host.json"));
        self.write(&path)?;
        Ok(path)
    }
}

/// Throughput helper: elements per second.
pub fn throughput(result: &BenchResult, elems: usize) -> String {
    let eps = elems as f64 / result.mean_s;
    if eps > 1e9 {
        format!("{:.2} Gelem/s", eps / 1e9)
    } else if eps > 1e6 {
        format!("{:.2} Melem/s", eps / 1e6)
    } else {
        format!("{:.2} Kelem/s", eps / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let r = bench("noop-spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s * 1.5 + 1e-9);
        assert_eq!(r.iters, 5);
        assert!(r.report().contains("noop-spin"));
    }

    #[test]
    fn bench_clamps_zero_iters() {
        // regression: iters == 0 produced an empty sample vector and NaN
        // ns-per-iter; the harness must always measure at least once
        let mut calls = 0usize;
        let r = bench("degenerate", 0, 0, || calls += 1);
        assert_eq!(r.iters, 1, "iters clamp to 1");
        assert_eq!(calls, 1, "exactly one measured call, no warmup");
        assert!(r.mean_s.is_finite() && r.mean_s >= 0.0);
        assert!(r.median_s.is_finite());
        // the ns-per-iter a PerfLog row would serialize is finite too
        assert!((r.mean_s * 1e9).is_finite());
    }

    #[test]
    fn perflog_renders_valid_flat_json() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_s: 1e-3,
            median_s: 1e-3,
            std_s: 0.0,
            min_s: 1e-3,
        };
        let mut log = PerfLog::new("host");
        assert!(log.is_empty());
        log.push("gemm_nn_blocked", &[256, 256, 256], &r, Some(2.0 * 256.0f64.powi(3)));
        log.push("cabac_encode", &[65536], &r, None);
        assert_eq!(log.len(), 2);
        let js = log.to_json();
        // structural sanity a JSON parser would enforce
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        assert!(js.contains("\"backend\": \"host\""));
        assert!(js.contains("\"shape\": \"256x256x256\""));
        assert!(js.contains("\"gflops\": null"), "no-flop rows serialize null");
        // 2*256^3 flops in 1ms -> ~33.6 GFLOP/s
        assert!(js.contains("\"gflops\": 33.554"));
    }

    #[test]
    fn perflog_push_kv_appends_string_fields() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_s: 1e-3,
            median_s: 1e-3,
            std_s: 0.0,
            min_s: 1e-3,
        };
        let mut log = PerfLog::new("host");
        log.push_kv(
            "simd_gemm_nn",
            &[256, 256, 256],
            &r,
            None,
            &[("kernel", "scalar"), ("dispatch", "avx2")],
        );
        let js = log.to_json();
        assert_eq!(js.matches('{').count(), js.matches('}').count());
        assert!(js.contains("\"kernel\": \"scalar\""));
        assert!(js.contains("\"dispatch\": \"avx2\""));
    }

    #[test]
    fn throughput_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 1.0,
            median_s: 1.0,
            std_s: 0.0,
            min_s: 1.0,
        };
        assert!(throughput(&r, 2_000_000_000).contains("Gelem"));
        assert!(throughput(&r, 2_000_000).contains("Melem"));
        assert!(throughput(&r, 2_000).contains("Kelem"));
    }
}
