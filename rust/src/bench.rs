//! Mini-criterion: the bench harness used by `cargo bench` targets
//! (criterion is unavailable offline; every `[[bench]]` sets
//! `harness = false` and drives this module).
//!
//! Provides warmup + N timed iterations with mean/median/σ reporting, and
//! a `Series` helper for the figure-regeneration benches that print the
//! paper's accuracy/sparsity/size rows.

use std::time::Instant;

use crate::util::stats;

/// Timing result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        fn fmt(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.1} µs", s * 1e6)
            }
        }
        format!(
            "{:<40} {:>12}/iter (median {}, σ {}, n={})",
            self.name,
            fmt(self.mean_s),
            fmt(self.median_s),
            fmt(self.std_s),
            self.iters
        )
    }
}

/// Run `f` with `warmup` unmeasured + `iters` measured iterations.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let times_f32: Vec<f32> = times.iter().map(|&t| t as f32).collect();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&times_f32),
        median_s: stats::median(&times),
        std_s: stats::std_dev(&times_f32),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    println!("{}", r.report());
    r
}

/// Print a figure header in a stable, grep-able format.
pub fn figure_header(id: &str, title: &str) {
    println!("\n=== {id}: {title} ===");
}

/// Print one series row (a data point of a paper figure).
pub fn series_row(series: &str, xs: &[(&str, String)]) {
    let cells: Vec<String> = xs.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("[{series}] {}", cells.join(" "));
}

/// Throughput helper: elements per second.
pub fn throughput(result: &BenchResult, elems: usize) -> String {
    let eps = elems as f64 / result.mean_s;
    if eps > 1e9 {
        format!("{:.2} Gelem/s", eps / 1e9)
    } else if eps > 1e6 {
        format!("{:.2} Melem/s", eps / 1e6)
    } else {
        format!("{:.2} Kelem/s", eps / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let r = bench("noop-spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s * 1.5 + 1e-9);
        assert_eq!(r.iters, 5);
        assert!(r.report().contains("noop-spin"));
    }

    #[test]
    fn throughput_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 1.0,
            median_s: 1.0,
            std_s: 0.0,
            min_s: 1.0,
        };
        assert!(throughput(&r, 2_000_000_000).contains("Gelem"));
        assert!(throughput(&r, 2_000_000).contains("Melem"));
        assert!(throughput(&r, 2_000).contains("Kelem"));
    }
}
