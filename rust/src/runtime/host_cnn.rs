//! Conv-ladder (CNN) execution paths of the host backend.
//!
//! The host backend recovers a CNN from an artifact signature the same
//! way it recovers an MLP: the conv chain from the 4D HWIO `p_c<i>` /
//! `idx_c<i>` slots (strides and padding travel in the `conv_strides` /
//! `conv_pads` artifact attrs, since tensor shapes cannot carry them),
//! and the dense head from the `p_w<i>` / `idx_w<i>` slots chaining off
//! the flattened conv output. Because NHWC output rows are exactly the
//! im2col GEMM's row-major layout, the flatten between the conv stack
//! and the dense head never moves data.
//!
//! All convolutions run on the im2col lowering in
//! [`crate::linalg::im2col`]: forward with bias/ReLU fused into the GEMM
//! epilogue, dW via the transposed-patch GEMM, dX via the tiled col2im,
//! and quantized conv weights dequantized at pack time
//! ([`crate::linalg::conv2d_gather`]) exactly like `qdense_gather`.
//!
//! LRP: the host CNN uses the epsilon rule uniformly — per-weight
//! relevance `R_w = w ⊙ (P(a)ᵀ @ s)` and `R_in = a ⊙ col2im(s @ wᵀ)`,
//! the direct conv generalization of the dense path. This is a
//! documented substitution for the paper's alpha-beta conv rule
//! (DESIGN.md §2.3): it keeps the same conservation structure (asserted
//! by `tests/conv_props.rs`) with one bwd_filter + one bwd_input per
//! layer instead of eight conv VJPs.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::host::{
    act_fake_quant, adam_emit, backward, correct_count, dense_params, emit, eval_dense_ladder,
    forward_collect, lrp_dense_ladder, q_slots, qdense_gather_ws, relu_inplace, scalar_out,
    softmax_xent_grad, softmax_xent_loss, stabilize, ste_scale_grads, MlpSig, Slots,
};
use super::ArtifactSpec;
use crate::linalg::{self, Conv2d, Epilogue, Pad, Workspace};
use crate::tensor::{Tensor, Value};

/// Conv ladder + dense head recovered from an artifact's signature.
pub(crate) struct CnnSig {
    pub(crate) batch: usize,
    /// per-conv-layer geometry (batch baked into `n`)
    pub(crate) convs: Vec<Conv2d>,
    /// the dense head, starting at the flattened conv output
    pub(crate) dense: MlpSig,
}

fn parse_pads(spec: &ArtifactSpec) -> Result<Vec<Pad>> {
    match spec.attrs.get("conv_pads") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|p| match p {
                "same" => Ok(Pad::Same),
                "valid" => Ok(Pad::Valid),
                other => Err(anyhow::anyhow!(
                    "artifact {}: unknown conv pad {other}",
                    spec.name
                )),
            })
            .collect(),
    }
}

fn parse_strides(spec: &ArtifactSpec) -> Result<Vec<usize>> {
    match spec.attrs.get("conv_strides") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|v| {
                v.parse::<usize>()
                    .with_context(|| format!("artifact {}: bad conv stride {v}", spec.name))
            })
            .collect(),
    }
}

/// Recover the conv ladder from `<conv_prefix><i>` slots and the dense
/// head from `<w_prefix><i>` slots. A manifest without the
/// `conv_strides`/`conv_pads` attrs defaults every layer to stride 1 /
/// SAME; an attr that is *present* must carry one entry per conv layer
/// (and strides must be ≥ 1) or the signature is rejected — geometry
/// mistakes fail loudly at `prepare` instead of surfacing as a
/// confusing dense-chain mismatch later.
pub(crate) fn cnn_sig(spec: &ArtifactSpec, conv_prefix: &str, w_prefix: &str) -> Result<CnnSig> {
    let shape_of = |name: &str| -> Option<&Vec<usize>> {
        spec.inputs.iter().find(|s| s.name == name).map(|s| &s.shape)
    };
    let x = shape_of("x").with_context(|| format!("artifact {}: no x input", spec.name))?;
    if x.len() != 4 {
        bail!(
            "artifact {}: conv models need NHWC [batch, h, w, c] inputs, got {:?}",
            spec.name,
            x
        );
    }
    let (batch, mut h, mut w, mut c) = (x[0], x[1], x[2], x[3]);
    let strides = parse_strides(spec)?;
    let pads = parse_pads(spec)?;
    let mut convs = Vec::new();
    let mut i = 0usize;
    while let Some(shape) = shape_of(&format!("{conv_prefix}{i}")) {
        if shape.len() != 4 || shape[2] != c {
            bail!(
                "artifact {}: {conv_prefix}{i} shape {:?} does not chain from {c} channels \
                 (HWIO filters expected)",
                spec.name,
                shape
            );
        }
        let stride = if strides.is_empty() {
            1
        } else {
            *strides.get(i).with_context(|| {
                format!(
                    "artifact {}: conv_strides has no entry for conv layer {i}",
                    spec.name
                )
            })?
        };
        if stride == 0 {
            bail!("artifact {}: conv layer {i} has stride 0", spec.name);
        }
        let pad = if pads.is_empty() {
            Pad::Same
        } else {
            *pads.get(i).with_context(|| {
                format!(
                    "artifact {}: conv_pads has no entry for conv layer {i}",
                    spec.name
                )
            })?
        };
        let g = Conv2d {
            n: batch,
            h,
            w,
            c,
            kh: shape[0],
            kw: shape[1],
            co: shape[3],
            stride,
            pad,
        };
        let (oh, ow) = g.out_hw();
        if oh == 0 || ow == 0 {
            bail!(
                "artifact {}: conv layer {i} collapses the spatial dims to zero",
                spec.name
            );
        }
        h = oh;
        w = ow;
        c = g.co;
        convs.push(g);
        i += 1;
    }
    if i == 0 {
        bail!(
            "artifact {}: no {conv_prefix}0 slot — not a conv signature",
            spec.name
        );
    }
    let flat = h * w * c;
    let mut dims = vec![flat];
    let mut din = flat;
    let mut j = 0usize;
    while let Some(shape) = shape_of(&format!("{w_prefix}{j}")) {
        if shape.len() != 2 || shape[0] != din {
            bail!(
                "artifact {}: {w_prefix}{j} shape {:?} does not chain from the flattened conv \
                 output of width {din}",
                spec.name,
                shape
            );
        }
        din = shape[1];
        dims.push(din);
        j += 1;
    }
    if j == 0 {
        bail!("artifact {}: conv model has no dense head", spec.name);
    }
    Ok(CnnSig { batch, convs, dense: MlpSig { dims, batch } })
}

/// Collect the per-conv-layer `c`/`cb` slices from `p_c<i>` / `p_cb<i>`.
fn conv_params<'a>(slots: &Slots<'a>, nc: usize) -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>)> {
    let mut cs = Vec::with_capacity(nc);
    let mut cbs = Vec::with_capacity(nc);
    for i in 0..nc {
        cs.push(slots.f32(&format!("p_c{i}"))?);
        cbs.push(slots.f32(&format!("p_cb{i}"))?);
    }
    Ok((cs, cbs))
}

/// Conv-stack forward keeping every layer input (the backward pass needs
/// them): `acts[0] = x`, `acts[i>0] = relu(conv_i-1 + bias)` with the
/// ReLU fused into the GEMM epilogue.
fn conv_forward_collect(
    scratch: &mut Workspace,
    sig: &CnnSig,
    cws: &[&[f32]],
    cbs: &[&[f32]],
    x: &[f32],
) -> Vec<Vec<f32>> {
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(sig.convs.len() + 1);
    acts.push(x.to_vec());
    for (i, g) in sig.convs.iter().enumerate() {
        let mut z = vec![0.0f32; g.out_len()];
        linalg::conv2d(scratch, &acts[i], cws[i], g, Epilogue::BiasRelu(cbs[i]), &mut z);
        acts.push(z);
    }
    acts
}

/// Shared CNN train-step core: conv + dense forward/backward at the
/// (optionally STE-substituted) weights, Adam applied to the `p_`
/// background parameters — the conv twin of `host::train_step`.
pub(crate) fn train_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    ste: bool,
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "p_c", "p_w")?;
    let nc = sig.convs.len();
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let (cws, cbs) = conv_params(&slots, nc)?;
    let (dws_p, dbs_p) = dense_params(&slots, nd)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let t = slots.scalar("t")?;
    let lr = slots.scalar("lr")?;
    let gs = if ste { slots.scalar("gs")? } else { 0.0 };

    // STE: quantized copies occupy the weight slots of the forward pass
    let qcs = if ste { q_slots(&slots, "c", nc)? } else { vec![None; nc] };
    let qds = if ste { q_slots(&slots, "w", nd)? } else { vec![None; nd] };
    let eval_cw: Vec<&[f32]> =
        cws.iter().zip(qcs.iter()).map(|(&w, q)| q.unwrap_or(w)).collect();
    let eval_dw: Vec<&[f32]> =
        dws_p.iter().zip(qds.iter()).map(|(&w, q)| q.unwrap_or(w)).collect();

    // forward: conv stack (ReLU fused), then the dense head
    let conv_acts = conv_forward_collect(scratch, &sig, &eval_cw, &cbs, x);
    let (dacts, logits) =
        forward_collect(scratch, &sig.dense, &eval_dw, &dbs_p, conv_acts.last().unwrap());
    let classes = sig.dense.classes();
    let (loss, g0) = softmax_xent_grad(&logits, y, sig.batch, classes);
    let correct = correct_count(&logits, y, sig.batch, classes);

    // dense backward, handing the flattened gradient back to the convs
    let (mut d_dw, mut d_db, gflat) =
        backward(scratch, &sig.dense, &eval_dw, &dacts, g0, true);
    let mut g = gflat.expect("input_grad requested");

    // conv backward: dW via the transposed-patch GEMM, dX via col2im
    let mut d_cw: Vec<Vec<f32>> = vec![Vec::new(); nc];
    let mut d_cb: Vec<Vec<f32>> = vec![Vec::new(); nc];
    for i in (0..nc).rev() {
        let geom = &sig.convs[i];
        let mut dw = vec![0.0f32; geom.filter_len()];
        linalg::conv2d_bwd_filter(scratch, &conv_acts[i], &g, geom, Epilogue::None, &mut dw);
        d_cw[i] = dw;
        let mut db = vec![0.0f32; geom.co];
        for row in g.chunks_exact(geom.co) {
            for (d, &gv) in db.iter_mut().zip(row) {
                *d += gv;
            }
        }
        d_cb[i] = db;
        if i > 0 {
            let mut gin = vec![0.0f32; geom.in_len()];
            linalg::conv2d_bwd_input(scratch, &g, eval_cw[i], geom, &mut gin);
            // relu backward: conv_acts[i] is the previous layer's fused
            // ReLU output, so the mask is act > 0
            for (gv, &av) in gin.iter_mut().zip(conv_acts[i].iter()) {
                if av <= 0.0 {
                    *gv = 0.0;
                }
            }
            g = gin;
        }
    }

    // Fig. 5 step 3: scale quantized-weight gradients by |centroid|
    if ste && gs > 0.5 {
        ste_scale_grads(&mut d_cw, &qcs);
        ste_scale_grads(&mut d_dw, &qds);
    }

    let mut grads = Vec::with_capacity(2 * (nc + nd));
    for i in 0..nc {
        grads.push((format!("c{i}"), std::mem::take(&mut d_cw[i])));
        grads.push((format!("cb{i}"), std::mem::take(&mut d_cb[i])));
    }
    for i in 0..nd {
        grads.push((format!("w{i}"), std::mem::take(&mut d_dw[i])));
        grads.push((format!("b{i}"), std::mem::take(&mut d_db[i])));
    }
    let mut out: HashMap<String, Value> = HashMap::new();
    adam_emit(spec, &slots, &grads, t, lr, &mut out)?;
    out.insert("loss".into(), scalar_out(loss));
    out.insert("correct".into(), scalar_out(correct));
    emit(spec, out)
}

/// Composite epsilon-LRP through the dense head and the conv stack:
/// per-weight relevances, batch-aggregated, signed — the conv twin of
/// `host::lrp_step` (see the module docs on the epsilon-rule
/// substitution for conv layers).
pub(crate) fn lrp_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "p_c", "p_w")?;
    let nc = sig.convs.len();
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let (cws, cbs) = conv_params(&slots, nc)?;
    let (dws_p, dbs_p) = dense_params(&slots, nd)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let eqw = slots.scalar("eqw")?;

    // conv forward keeping both the layer inputs and the pre-activations
    // (the epsilon rule needs z itself, so ReLU cannot fuse here)
    let mut cacts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut czs: Vec<Vec<f32>> = Vec::with_capacity(nc);
    for (i, g) in sig.convs.iter().enumerate() {
        let mut z = vec![0.0f32; g.out_len()];
        linalg::conv2d(scratch, &cacts[i], cws[i], g, Epilogue::Bias(cbs[i]), &mut z);
        let mut h = z.clone();
        relu_inplace(&mut h);
        czs.push(z);
        cacts.push(h);
    }
    // dense head: shared epsilon-rule ladder, handing the relevance at
    // the flatten boundary back to the conv stack
    let mut out: HashMap<String, Value> = HashMap::new();
    let mut r = lrp_dense_ladder(
        scratch,
        &sig.dense,
        &dws_p,
        &dbs_p,
        cacts.last().unwrap(),
        y,
        eqw,
        true,
        &mut out,
    )
    .expect("input_relevance requested");
    // conv stack backward (epsilon rule on the im2col lowering)
    for i in (0..nc).rev() {
        let geom = &sig.convs[i];
        let a = &cacts[i];
        let z = &czs[i];
        let s: Vec<f32> =
            r.iter().zip(z.iter()).map(|(&rv, &zv)| rv / stabilize(zv)).collect();
        let mut rw = vec![0.0f32; geom.filter_len()];
        linalg::lrp_conv_rw(scratch, a, &s, cws[i], geom, &mut rw);
        out.insert(
            format!("r_c{i}"),
            Value::F32(Tensor::new(vec![geom.kh, geom.kw, geom.c, geom.co], rw)),
        );
        if i > 0 {
            let mut rin = vec![0.0f32; geom.in_len()];
            linalg::conv2d_bwd_input(scratch, &s, cws[i], geom, &mut rin);
            for (rv, &av) in rin.iter_mut().zip(a.iter()) {
                *rv *= av;
            }
            r = rin;
        }
    }
    emit(spec, out)
}

/// Plain CNN eval (optionally with fake-quantized activations) — the conv
/// twin of `host::eval_step`.
pub(crate) fn eval_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    actq: bool,
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "p_c", "p_w")?;
    let nc = sig.convs.len();
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let (cws, cbs) = conv_params(&slots, nc)?;
    let (dws_p, dbs_p) = dense_params(&slots, nd)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let levels = if actq { Some(2.0f32.powf(slots.scalar("abits")?)) } else { None };

    // rolling activation buffer: eval never needs earlier conv outputs
    let mut a = x.to_vec();
    for (i, g) in sig.convs.iter().enumerate() {
        let mut z = vec![0.0f32; g.out_len()];
        linalg::conv2d(scratch, &a, cws[i], g, Epilogue::BiasRelu(cbs[i]), &mut z);
        if let Some(lv) = levels {
            act_fake_quant(&mut z, lv);
        }
        a = z;
    }
    let a = eval_dense_ladder(scratch, &sig.dense, &dws_p, &dbs_p, &a, levels);
    let classes = sig.dense.classes();
    let loss = softmax_xent_loss(&a, y, sig.batch, classes);
    let correct = correct_count(&a, y, sig.batch, classes);
    let mut out = HashMap::new();
    out.insert("loss".to_string(), scalar_out(loss));
    out.insert("correct".to_string(), scalar_out(correct));
    emit(spec, out)
}

/// Deployment-form gather eval, the conv twin of
/// `host::eval_gather_step`: conv layers dequantize centroid indices at
/// im2col pack time ([`crate::linalg::conv2d_gather`] — patch extraction
/// dominates, so the LUT form buys little there), while the dense head
/// goes through `qdense_gather_ws` and thus takes the sparse LUT fast
/// path (gather-GEMM oracle under `--deterministic`).
pub(crate) fn eval_gather_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "idx_c", "idx_w")?;
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;

    let mut a = x.to_vec();
    for (i, g) in sig.convs.iter().enumerate() {
        let idx = slots.i32(&format!("idx_c{i}"))?;
        let cb = slots.f32(&format!("cb_c{i}"))?;
        let bias = slots.f32(&format!("p_cb{i}"))?;
        if cb.is_empty() {
            bail!(
                "artifact {}: conv layer {i}: empty codebook (corrupt container)",
                spec.name
            );
        }
        let mut z = vec![0.0f32; g.out_len()];
        linalg::conv2d_gather(scratch, &a, idx, cb, g, Epilogue::BiasRelu(bias), &mut z);
        a = z;
    }
    for i in 0..nd {
        let idx = slots.i32(&format!("idx_w{i}"))?;
        let cb = slots.f32(&format!("cb_w{i}"))?;
        let bias = slots.f32(&format!("p_b{i}"))?;
        let z = qdense_gather_ws(
            scratch,
            &a,
            idx,
            cb,
            bias,
            sig.batch,
            sig.dense.dims[i],
            sig.dense.dims[i + 1],
            i + 1 < nd,
        )
        .with_context(|| format!("artifact {}: dense layer {i}", spec.name))?;
        a = z;
    }
    let classes = sig.dense.classes();
    let loss = softmax_xent_loss(&a, y, sig.batch, classes);
    let correct = correct_count(&a, y, sig.batch, classes);
    let mut out = HashMap::new();
    out.insert("loss".to_string(), scalar_out(loss));
    out.insert("correct".to_string(), scalar_out(correct));
    emit(spec, out)
}

#[cfg(test)]
mod tests {
    use super::super::Manifest;
    use super::*;

    fn tiny() -> Manifest {
        Manifest::synthetic_cnn("t", (8, 8), 3, &[(4, 2), (8, 2)], &[16, 5], 2)
    }

    #[test]
    fn cnn_sig_recovers_geometry_from_signature_and_attrs() {
        let m = tiny();
        let spec = m.artifact("t_fp_train").unwrap();
        let sig = cnn_sig(spec, "p_c", "p_w").unwrap();
        assert_eq!(sig.batch, 2);
        assert_eq!(sig.convs.len(), 2);
        assert_eq!(sig.convs[0].stride, 2);
        assert_eq!(sig.convs[0].pad, Pad::Same);
        assert_eq!(sig.convs[1].c, 4);
        assert_eq!(sig.convs[1].out_hw(), (2, 2));
        assert_eq!(sig.dense.dims, vec![2 * 2 * 8, 16, 5]);
        // gather signature recovers the same ladder from idx_ slots
        let evq = m.artifact("t_eval_q").unwrap();
        let gsig = cnn_sig(evq, "idx_c", "idx_w").unwrap();
        assert_eq!(gsig.dense.dims, sig.dense.dims);
    }

    #[test]
    fn cnn_sig_rejects_broken_chains() {
        let m = tiny();
        let mut spec = m.artifact("t_eval").unwrap().clone();
        // flat [batch, dim] input is an MLP signature, not a CNN one
        spec.inputs.iter_mut().find(|s| s.name == "x").unwrap().shape = vec![2, 192];
        assert!(cnn_sig(&spec, "p_c", "p_w").is_err());
        // channel-chain mismatch fails loudly
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.inputs.iter_mut().find(|s| s.name == "p_c1").unwrap().shape = vec![3, 3, 7, 8];
        assert!(cnn_sig(&spec, "p_c", "p_w").is_err());
        // a conv_strides attr that is present but short fails loudly at
        // signature recovery, not as a later dense-chain mismatch
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_strides".into(), "2".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("no entry for conv layer 1"), "{err:?}");
        // stride 0 is rejected instead of silently clamped
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_strides".into(), "0,2".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("stride 0"), "{err:?}");
    }
}
