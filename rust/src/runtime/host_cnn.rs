//! Conv-ladder (CNN) execution paths of the host backend.
//!
//! The host backend recovers a CNN from an artifact signature the same
//! way it recovers an MLP: the conv chain from the 4D HWIO `p_c<i>` /
//! `idx_c<i>` slots, and the dense head from the `p_w<i>` / `idx_w<i>`
//! slots chaining off the flattened conv output. Everything tensor
//! shapes cannot carry travels in artifact attrs: `conv_strides` /
//! `conv_pads` (geometry) and, when the model uses them, `conv_bn`
//! (BatchNorm after the conv), `conv_pool` (`max2`/`avg2`/`gap`
//! downsampling) and `conv_res` (identity residual spans). Per-block op
//! order is `conv+bias → BN → +skip → ReLU → pool`; because NHWC output
//! rows are exactly the im2col GEMM's row-major layout, BN slots in as a
//! per-channel pass over GEMM rows and the flatten before the dense head
//! never moves data.
//!
//! All convolutions run on the im2col lowering in
//! [`crate::linalg::im2col`]: forward with bias (and, when no BN or skip
//! intervenes, ReLU) fused into the GEMM epilogue, dW via the
//! transposed-patch GEMM, dX via the tiled col2im, and quantized conv
//! weights dequantized at pack time ([`crate::linalg::conv2d_gather`]).
//!
//! BatchNorm (DESIGN.md §2.8): training uses batch statistics with the
//! full batch-coupled backward ([`crate::linalg::bn_train_bwd`]) and
//! emits the running-stat EMA through the `p_bnm<i>` / `p_bnv<i>` slots
//! (γ/β are ordinary Adam-trained params). FP eval folds inference-mode
//! BN into the conv weights ([`crate::linalg::bn_fold`]); quantized eval
//! cannot rescale codebook weights per channel, so it applies the
//! equivalent post-conv affine ([`crate::linalg::bn_infer`]) instead.
//!
//! LRP is the composite ladder the paper's Fig. 8/10 scenarios need:
//! the dense head keeps the epsilon rule, conv layers use the paper's
//! α-β rule (α=2, β=−1, [`crate::linalg::lrp_conv_ab`]), BN layers pass
//! relevance through unchanged at inference-mode statistics, max-pool
//! routes winner-takes-all through the recorded argmax, avg/global-avg
//! pool redistributes proportionally ([`crate::linalg::avgpool2d_lrp`]),
//! and a residual add splits relevance between branches in proportion to
//! their stabilized contributions. Conservation is asserted by
//! `tests/conv_props.rs`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::host::{
    act_fake_quant, adam_emit, backward, correct_count, dense_params, emit, eval_dense_ladder,
    forward_collect, lrp_dense_ladder, q_slots, qdense_gather_ws, relu_inplace, scalar_out,
    softmax_xent_grad, softmax_xent_loss, ste_scale_grads, MlpSig, Slots,
};
use super::ArtifactSpec;
use crate::linalg::{
    self, stabilize, Conv2d, Epilogue, Pad, Pool2d, PoolOp, Workspace, BN_EPS, LRP_ALPHA, LRP_BETA,
};
use crate::tensor::{Tensor, Value};

/// Running-stat EMA momentum (torch's `BatchNorm2d` default: the new
/// batch statistic gets weight 0.1).
const BN_MOMENTUM: f32 = 0.1;

/// One conv block recovered from the signature: the conv geometry plus
/// the attr-carried topology around it (op order: conv+bias → BN →
/// +skip → ReLU → pool).
pub(crate) struct ConvBlock {
    pub(crate) geom: Conv2d,
    /// BatchNorm after the conv (`conv_bn` attr)
    pub(crate) bn: bool,
    /// pooling stage after the ReLU (`conv_pool` attr)
    pub(crate) pool: Option<Pool2d>,
    /// residual span `r` (`conv_res` attr; 0 = none): this block's
    /// pre-ReLU sum adds the *input* of block `i+1−r` (identity skips
    /// only — the signature rejects shape mismatches)
    pub(crate) res: usize,
}

impl ConvBlock {
    /// Output element count of the whole block (post-pool).
    fn out_len(&self) -> usize {
        self.pool.as_ref().map_or(self.geom.out_len(), |p| p.out_len())
    }
}

/// Conv ladder + dense head recovered from an artifact's signature.
pub(crate) struct CnnSig {
    pub(crate) batch: usize,
    pub(crate) blocks: Vec<ConvBlock>,
    /// the dense head, starting at the flattened conv output
    pub(crate) dense: MlpSig,
}

fn parse_pads(spec: &ArtifactSpec) -> Result<Vec<Pad>> {
    match spec.attrs.get("conv_pads") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|p| match p {
                "same" => Ok(Pad::Same),
                "valid" => Ok(Pad::Valid),
                other => Err(anyhow::anyhow!(
                    "artifact {}: unknown conv pad {other}",
                    spec.name
                )),
            })
            .collect(),
    }
}

fn parse_strides(spec: &ArtifactSpec) -> Result<Vec<usize>> {
    match spec.attrs.get("conv_strides") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|v| {
                v.parse::<usize>()
                    .with_context(|| format!("artifact {}: bad conv stride {v}", spec.name))
            })
            .collect(),
    }
}

fn parse_bn(spec: &ArtifactSpec) -> Result<Vec<bool>> {
    match spec.attrs.get("conv_bn") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|v| match v {
                "0" => Ok(false),
                "1" => Ok(true),
                other => Err(anyhow::anyhow!(
                    "artifact {}: bad conv_bn token {other}",
                    spec.name
                )),
            })
            .collect(),
    }
}

fn parse_res(spec: &ArtifactSpec) -> Result<Vec<usize>> {
    match spec.attrs.get("conv_res") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|v| {
                v.parse::<usize>()
                    .with_context(|| format!("artifact {}: bad conv_res span {v}", spec.name))
            })
            .collect(),
    }
}

fn parse_pool(spec: &ArtifactSpec) -> Vec<&str> {
    spec.attrs
        .get("conv_pool")
        .map(|s| s.split(',').collect())
        .unwrap_or_default()
}

/// One entry of a present-must-cover attr list (an attr that exists must
/// carry one entry per conv layer, or the signature is rejected).
fn attr_at<'a, T>(list: &'a [T], i: usize, spec: &ArtifactSpec, key: &str) -> Result<&'a T> {
    list.get(i).with_context(|| {
        format!("artifact {}: {key} has no entry for conv layer {i}", spec.name)
    })
}

/// Recover the conv ladder from `<conv_prefix><i>` slots and the dense
/// head from `<w_prefix><i>` slots. A manifest without the conv attrs
/// defaults every layer to stride 1 / SAME / no BN / no pool / no skip;
/// an attr that is *present* must carry one entry per conv layer (and
/// strides must be ≥ 1, pool tokens known, residual spans in range with
/// shape-matched identity sources) or the signature is rejected —
/// geometry and topology mistakes fail loudly at `prepare` instead of
/// surfacing as a confusing dense-chain mismatch later.
pub(crate) fn cnn_sig(spec: &ArtifactSpec, conv_prefix: &str, w_prefix: &str) -> Result<CnnSig> {
    let shape_of = |name: &str| -> Option<&Vec<usize>> {
        spec.inputs.iter().find(|s| s.name == name).map(|s| &s.shape)
    };
    let x = shape_of("x").with_context(|| format!("artifact {}: no x input", spec.name))?;
    if x.len() != 4 {
        bail!(
            "artifact {}: conv models need NHWC [batch, h, w, c] inputs, got {:?}",
            spec.name,
            x
        );
    }
    let (batch, mut h, mut w, mut c) = (x[0], x[1], x[2], x[3]);
    let strides = parse_strides(spec)?;
    let pads = parse_pads(spec)?;
    let bns = parse_bn(spec)?;
    let pools = parse_pool(spec);
    let ress = parse_res(spec)?;
    let mut blocks = Vec::new();
    // (h, w, c) feeding each block — residual skip validation
    let mut block_ins: Vec<(usize, usize, usize)> = Vec::new();
    let mut i = 0usize;
    while let Some(shape) = shape_of(&format!("{conv_prefix}{i}")) {
        if shape.len() != 4 || shape[2] != c {
            bail!(
                "artifact {}: {conv_prefix}{i} shape {:?} does not chain from {c} channels \
                 (HWIO filters expected)",
                spec.name,
                shape
            );
        }
        block_ins.push((h, w, c));
        let stride = if strides.is_empty() {
            1
        } else {
            *attr_at(&strides, i, spec, "conv_strides")?
        };
        if stride == 0 {
            bail!("artifact {}: conv layer {i} has stride 0", spec.name);
        }
        let pad = if pads.is_empty() { Pad::Same } else { *attr_at(&pads, i, spec, "conv_pads")? };
        let g = Conv2d {
            n: batch,
            h,
            w,
            c,
            kh: shape[0],
            kw: shape[1],
            co: shape[3],
            stride,
            pad,
        };
        let (oh, ow) = g.out_hw();
        if oh == 0 || ow == 0 {
            bail!(
                "artifact {}: conv layer {i} collapses the spatial dims to zero",
                spec.name
            );
        }
        h = oh;
        w = ow;
        c = g.co;
        let bn = if bns.is_empty() { false } else { *attr_at(&bns, i, spec, "conv_bn")? };
        let res = if ress.is_empty() { 0 } else { *attr_at(&ress, i, spec, "conv_res")? };
        if res > 0 {
            if res < 2 || res > i + 1 {
                bail!(
                    "artifact {}: conv layer {i} residual span {res} out of range \
                     (need 2 <= r <= layer index + 1)",
                    spec.name
                );
            }
            let src = block_ins[i + 1 - res];
            if src != (h, w, c) {
                bail!(
                    "artifact {}: conv layer {i} residual skip shape mismatch \
                     ({src:?} vs {:?} — identity skips only)",
                    spec.name,
                    (h, w, c)
                );
            }
        }
        let pool = match if pools.is_empty() {
            "0"
        } else {
            *attr_at(&pools, i, spec, "conv_pool")?
        } {
            "0" => None,
            tok @ ("max2" | "avg2") => {
                if h < 2 || w < 2 {
                    bail!(
                        "artifact {}: conv layer {i} is {h}x{w} — too small for a 2x2 pool",
                        spec.name
                    );
                }
                let op = if tok == "max2" { PoolOp::Max } else { PoolOp::Avg };
                Some(Pool2d { n: batch, h, w, c, kh: 2, kw: 2, stride: 2, op })
            }
            "gap" => Some(Pool2d { n: batch, h, w, c, kh: h, kw: w, stride: 1, op: PoolOp::Avg }),
            other => bail!(
                "artifact {}: conv layer {i} unknown conv_pool token {other}",
                spec.name
            ),
        };
        if let Some(p) = &pool {
            let (ph, pw) = p.out_hw();
            h = ph;
            w = pw;
        }
        blocks.push(ConvBlock { geom: g, bn, pool, res });
        i += 1;
    }
    if i == 0 {
        bail!(
            "artifact {}: no {conv_prefix}0 slot — not a conv signature",
            spec.name
        );
    }
    let flat = h * w * c;
    let mut dims = vec![flat];
    let mut din = flat;
    let mut j = 0usize;
    while let Some(shape) = shape_of(&format!("{w_prefix}{j}")) {
        if shape.len() != 2 || shape[0] != din {
            bail!(
                "artifact {}: {w_prefix}{j} shape {:?} does not chain from the flattened conv \
                 output of width {din}",
                spec.name,
                shape
            );
        }
        din = shape[1];
        dims.push(din);
        j += 1;
    }
    if j == 0 {
        bail!("artifact {}: conv model has no dense head", spec.name);
    }
    Ok(CnnSig { batch, blocks, dense: MlpSig { dims, batch } })
}

/// Collect the per-conv-layer `c`/`cb` slices from `p_c<i>` / `p_cb<i>`.
fn conv_params<'a>(slots: &Slots<'a>, nc: usize) -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>)> {
    let mut cs = Vec::with_capacity(nc);
    let mut cbs = Vec::with_capacity(nc);
    for i in 0..nc {
        cs.push(slots.f32(&format!("p_c{i}"))?);
        cbs.push(slots.f32(&format!("p_cb{i}"))?);
    }
    Ok((cs, cbs))
}

/// The four BN param slices of layer `i`: `(γ, β, running μ, running σ²)`.
type BnParams<'a> = (&'a [f32], &'a [f32], &'a [f32], &'a [f32]);

fn bn_params<'a>(slots: &Slots<'a>, i: usize) -> Result<BnParams<'a>> {
    Ok((
        slots.f32(&format!("p_bng{i}"))?,
        slots.f32(&format!("p_bnb{i}"))?,
        slots.f32(&format!("p_bnm{i}"))?,
        slots.f32(&format!("p_bnv{i}"))?,
    ))
}

fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Pool forward; records the max winners into `argmax` (resized) so the
/// backward/LRP passes get their O(1) scatter.
fn pool_fwd(p: &Pool2d, u: &[f32], argmax: &mut Vec<usize>) -> Vec<f32> {
    let mut o = vec![0.0f32; p.out_len()];
    match p.op {
        PoolOp::Max => {
            argmax.resize(p.out_len(), 0);
            linalg::maxpool2d(p, u, argmax, &mut o);
        }
        PoolOp::Avg => linalg::avgpool2d(p, u, &mut o),
    }
    o
}

/// Per-block forward state the training backward pass consumes.
struct TrainState {
    /// conv + bias (pre-BN); left empty for non-BN blocks (the backward
    /// only needs it for `bn_train_bwd`)
    z: Vec<f32>,
    /// batch statistics (BN blocks only)
    mean: Vec<f32>,
    var: Vec<f32>,
    /// post-ReLU, pre-pool (the ReLU backward mask)
    act: Vec<f32>,
    /// max-pool winners (max blocks only)
    argmax: Vec<usize>,
}

/// Shared CNN train-step core: conv + dense forward/backward at the
/// (optionally STE-substituted) weights, Adam applied to the `p_`
/// background parameters, BN running stats EMA-updated — the conv twin
/// of `host::train_step`.
pub(crate) fn train_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    ste: bool,
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "p_c", "p_w")?;
    let nc = sig.blocks.len();
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let (cws, cbs) = conv_params(&slots, nc)?;
    let (dws_p, dbs_p) = dense_params(&slots, nd)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let t = slots.scalar("t")?;
    let lr = slots.scalar("lr")?;
    let gs = if ste { slots.scalar("gs")? } else { 0.0 };

    // STE: quantized copies occupy the weight slots of the forward pass
    let qcs = if ste { q_slots(&slots, "c", nc)? } else { vec![None; nc] };
    let qds = if ste { q_slots(&slots, "w", nd)? } else { vec![None; nd] };
    let eval_cw: Vec<&[f32]> =
        cws.iter().zip(qcs.iter()).map(|(&w, q)| q.unwrap_or(w)).collect();
    let eval_dw: Vec<&[f32]> =
        dws_p.iter().zip(qds.iter()).map(|(&w, q)| q.unwrap_or(w)).collect();

    // forward: conv blocks (batch-stat BN, skips, pooling), keeping every
    // block input plus the state the backward needs
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nc + 1);
    acts.push(x.to_vec());
    let mut states: Vec<TrainState> = Vec::with_capacity(nc);
    for (i, blk) in sig.blocks.iter().enumerate() {
        let g = &blk.geom;
        let mut st = TrainState {
            z: vec![0.0f32; g.out_len()],
            mean: Vec::new(),
            var: Vec::new(),
            act: Vec::new(),
            argmax: Vec::new(),
        };
        linalg::conv2d(scratch, &acts[i], eval_cw[i], g, Epilogue::Bias(cbs[i]), &mut st.z);
        let mut u = if blk.bn {
            let (gamma, beta, _, _) = bn_params(&slots, i)?;
            st.mean = vec![0.0f32; g.co];
            st.var = vec![0.0f32; g.co];
            let mut y_bn = vec![0.0f32; st.z.len()];
            linalg::bn_train_fwd(&st.z, g.co, gamma, beta, BN_EPS, &mut y_bn, &mut st.mean, &mut st.var);
            y_bn
        } else {
            // z is not needed again without BN — move it out
            std::mem::take(&mut st.z)
        };
        if blk.res > 0 {
            add_assign(&mut u, &acts[i + 1 - blk.res]);
        }
        relu_inplace(&mut u);
        let out = match &blk.pool {
            Some(p) => pool_fwd(p, &u, &mut st.argmax),
            None => u.clone(),
        };
        st.act = u;
        states.push(st);
        acts.push(out);
    }
    let (dacts, logits) =
        forward_collect(scratch, &sig.dense, &eval_dw, &dbs_p, acts.last().unwrap());
    let classes = sig.dense.classes();
    let (loss, g0) = softmax_xent_grad(&logits, y, sig.batch, classes);
    let correct = correct_count(&logits, y, sig.batch, classes);

    // dense backward, handing the flattened gradient back to the convs
    let (mut d_dw, mut d_db, gflat) =
        backward(scratch, &sig.dense, &eval_dw, &dacts, g0, true);
    let mut g = gflat.expect("input_grad requested");

    // conv backward: pool scatter → ReLU mask → (residual fan-out) → BN →
    // dW via the transposed-patch GEMM, dX via col2im. `pending[j]` holds
    // skip-branch gradients addressed to the *input* of block j, merged
    // when the main path reaches that tensor.
    let mut d_cw: Vec<Vec<f32>> = vec![Vec::new(); nc];
    let mut d_cb: Vec<Vec<f32>> = vec![Vec::new(); nc];
    let mut d_bng: Vec<Vec<f32>> = vec![Vec::new(); nc];
    let mut d_bnb: Vec<Vec<f32>> = vec![Vec::new(); nc];
    let mut pending: Vec<Option<Vec<f32>>> = (0..nc).map(|_| None).collect();
    for i in (0..nc).rev() {
        let blk = &sig.blocks[i];
        let geom = &blk.geom;
        let st = &states[i];
        let mut gu = match &blk.pool {
            Some(p) => {
                let mut d = vec![0.0f32; p.in_len()];
                match p.op {
                    PoolOp::Max => linalg::maxpool2d_bwd(p, &st.argmax, &g, &mut d),
                    PoolOp::Avg => linalg::avgpool2d_bwd(p, &g, &mut d),
                }
                d
            }
            None => std::mem::take(&mut g),
        };
        // ReLU backward: act is the block's fused ReLU output
        for (gv, &av) in gu.iter_mut().zip(st.act.iter()) {
            if av <= 0.0 {
                *gv = 0.0;
            }
        }
        // the pre-ReLU gradient flows to the skip source unchanged
        if blk.res > 0 {
            let j = i + 1 - blk.res;
            match &mut pending[j] {
                Some(p) => add_assign(p, &gu),
                slot => *slot = Some(gu.clone()),
            }
        }
        let dz = if blk.bn {
            let (gamma, _, _, _) = bn_params(&slots, i)?;
            let mut dz = vec![0.0f32; gu.len()];
            let (mut dg, mut db) = (vec![0.0f32; geom.co], vec![0.0f32; geom.co]);
            linalg::bn_train_bwd(
                &st.z, geom.co, gamma, &st.mean, &st.var, BN_EPS, &gu, &mut dz, &mut dg, &mut db,
            );
            d_bng[i] = dg;
            d_bnb[i] = db;
            dz
        } else {
            gu
        };
        let mut dw = vec![0.0f32; geom.filter_len()];
        linalg::conv2d_bwd_filter(scratch, &acts[i], &dz, geom, Epilogue::None, &mut dw);
        d_cw[i] = dw;
        let mut db = vec![0.0f32; geom.co];
        for row in dz.chunks_exact(geom.co) {
            add_assign(&mut db, row);
        }
        d_cb[i] = db;
        if i > 0 {
            let mut gin = vec![0.0f32; geom.in_len()];
            linalg::conv2d_bwd_input(scratch, &dz, eval_cw[i], geom, &mut gin);
            // merge skip-branch gradients addressed to this tensor; a
            // pending[0] entry (skip from x) would be the unused x grad
            if let Some(p) = pending[i].take() {
                add_assign(&mut gin, &p);
            }
            g = gin;
        }
    }

    // Fig. 5 step 3: scale quantized-weight gradients by |centroid|
    if ste && gs > 0.5 {
        ste_scale_grads(&mut d_cw, &qcs);
        ste_scale_grads(&mut d_dw, &qds);
    }

    let mut grads = Vec::with_capacity(2 * (nc + nd));
    for i in 0..nc {
        grads.push((format!("c{i}"), std::mem::take(&mut d_cw[i])));
        grads.push((format!("cb{i}"), std::mem::take(&mut d_cb[i])));
        if sig.blocks[i].bn {
            grads.push((format!("bng{i}"), std::mem::take(&mut d_bng[i])));
            grads.push((format!("bnb{i}"), std::mem::take(&mut d_bnb[i])));
        }
    }
    for i in 0..nd {
        grads.push((format!("w{i}"), std::mem::take(&mut d_dw[i])));
        grads.push((format!("b{i}"), std::mem::take(&mut d_db[i])));
    }
    let mut out: HashMap<String, Value> = HashMap::new();
    adam_emit(spec, &slots, &grads, t, lr, &mut out)?;
    // BN running stats bypass Adam: EMA toward this batch's statistics,
    // Adam moments echoed unchanged (they are dead slots for bnm/bnv)
    for (i, blk) in sig.blocks.iter().enumerate() {
        if !blk.bn {
            continue;
        }
        let (_, _, rmean, rvar) = bn_params(&slots, i)?;
        let co = blk.geom.co;
        let (mut rm, mut rv) = (rmean.to_vec(), rvar.to_vec());
        linalg::ema_update(&mut rm, &states[i].mean, BN_MOMENTUM);
        linalg::ema_update(&mut rv, &states[i].var, BN_MOMENTUM);
        out.insert(format!("p_bnm{i}"), Value::F32(Tensor::new(vec![co], rm)));
        out.insert(format!("p_bnv{i}"), Value::F32(Tensor::new(vec![co], rv)));
        for name in [format!("bnm{i}"), format!("bnv{i}")] {
            for prefix in ["m_", "v_"] {
                let slot = format!("{prefix}{name}");
                let echo = slots.f32(&slot)?.to_vec();
                out.insert(slot, Value::F32(Tensor::new(vec![co], echo)));
            }
        }
    }
    out.insert("loss".into(), scalar_out(loss));
    out.insert("correct".into(), scalar_out(correct));
    emit(spec, out)
}

/// Per-block forward state the LRP backward ladder consumes.
struct LrpState {
    /// post-BN, pre-skip (the main-branch value at the residual add)
    zb: Vec<f32>,
    /// post-ReLU, pre-pool (the avg-pool LRP input)
    act: Vec<f32>,
    /// max-pool winners (max blocks only)
    argmax: Vec<usize>,
}

/// Composite LRP through the dense head and the conv stack: epsilon rule
/// on the dense ladder, the paper's α-β rule on every conv, BN as an
/// inference-mode identity for relevance, winner-takes-all max-pool /
/// proportional avg-pool routing, and stabilized proportional splits at
/// residual adds. Per-weight relevances, batch-aggregated, signed — the
/// conv twin of `host::lrp_step`.
pub(crate) fn lrp_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "p_c", "p_w")?;
    let nc = sig.blocks.len();
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let (cws, cbs) = conv_params(&slots, nc)?;
    let (dws_p, dbs_p) = dense_params(&slots, nd)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let eqw = slots.scalar("eqw")?;

    // forward at inference-mode BN statistics, keeping the block inputs
    // (the α-β rule re-derives its own signed pre-activations from them)
    // plus the residual/pool routing state
    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut states: Vec<LrpState> = Vec::with_capacity(nc);
    for (i, blk) in sig.blocks.iter().enumerate() {
        let g = &blk.geom;
        let mut zb = vec![0.0f32; g.out_len()];
        linalg::conv2d(scratch, &acts[i], cws[i], g, Epilogue::Bias(cbs[i]), &mut zb);
        if blk.bn {
            let (gamma, beta, rmean, rvar) = bn_params(&slots, i)?;
            linalg::bn_infer(gamma, beta, rmean, rvar, BN_EPS, &mut zb);
        }
        let mut u = zb.clone();
        if blk.res > 0 {
            add_assign(&mut u, &acts[i + 1 - blk.res]);
        }
        relu_inplace(&mut u);
        let mut st = LrpState { zb, act: Vec::new(), argmax: Vec::new() };
        let out = match &blk.pool {
            Some(p) => pool_fwd(p, &u, &mut st.argmax),
            None => u.clone(),
        };
        st.act = u;
        states.push(st);
        acts.push(out);
    }
    // dense head: shared epsilon-rule ladder, handing the relevance at
    // the flatten boundary back to the conv stack
    let mut out: HashMap<String, Value> = HashMap::new();
    let mut r = lrp_dense_ladder(
        scratch,
        &sig.dense,
        &dws_p,
        &dbs_p,
        acts.last().unwrap(),
        y,
        eqw,
        true,
        &mut out,
    )
    .expect("input_relevance requested");
    // conv stack: pool routing → ReLU pass-through → residual split →
    // (BN identity) → α-β conv rule. `pending[j]` holds skip-branch
    // relevance addressed to the input of block j.
    let mut pending: Vec<Option<Vec<f32>>> = (0..nc).map(|_| None).collect();
    for i in (0..nc).rev() {
        let blk = &sig.blocks[i];
        let geom = &blk.geom;
        let st = &states[i];
        // relevance at the post-ReLU act; ReLU itself passes it through
        let mut ru = match &blk.pool {
            Some(p) => {
                let mut d = vec![0.0f32; p.in_len()];
                match p.op {
                    // winner-takes-all: the max-pool LRP rule is its
                    // gradient scatter
                    PoolOp::Max => linalg::maxpool2d_bwd(p, &st.argmax, &r, &mut d),
                    PoolOp::Avg => linalg::avgpool2d_lrp(p, &st.act, &r, &mut d),
                }
                d
            }
            None => std::mem::take(&mut r),
        };
        // residual add u = zb + skip: split R proportionally to the
        // stabilized branch contributions
        if blk.res > 0 {
            let j = i + 1 - blk.res;
            let skip = &acts[j];
            let mut rskip = vec![0.0f32; ru.len()];
            for k in 0..ru.len() {
                let s = ru[k] / stabilize(st.zb[k] + skip[k]);
                rskip[k] = skip[k] * s;
                ru[k] = st.zb[k] * s;
            }
            match &mut pending[j] {
                Some(p) => add_assign(p, &rskip),
                slot => *slot = Some(rskip),
            }
        }
        // BN is identity for relevance; α-β redistributes through the conv
        let mut rw = vec![0.0f32; geom.filter_len()];
        let mut rin = vec![0.0f32; geom.in_len()];
        linalg::lrp_conv_ab(
            scratch, &acts[i], cws[i], &ru, geom, LRP_ALPHA, LRP_BETA, &mut rw, &mut rin,
        );
        out.insert(
            format!("r_c{i}"),
            Value::F32(Tensor::new(vec![geom.kh, geom.kw, geom.c, geom.co], rw)),
        );
        if i > 0 {
            // merge skip-branch relevance addressed to this tensor; a
            // pending[0] entry (skip from x) would be the unemitted
            // input-image relevance
            if let Some(p) = pending[i].take() {
                add_assign(&mut rin, &p);
            }
            r = rin;
        }
    }
    emit(spec, out)
}

/// FP-weight eval (optionally with fake-quantized activations) — the conv
/// twin of `host::eval_step`. Inference-mode BN folds into the conv
/// weights ([`crate::linalg::bn_fold`]), so a BN block costs exactly one
/// conv; blocks without a residual add keep ReLU fused in the GEMM
/// epilogue. Block outputs are kept (not rolled) because residual spans
/// reach back across layers.
pub(crate) fn eval_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    actq: bool,
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "p_c", "p_w")?;
    let nc = sig.blocks.len();
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let (cws, cbs) = conv_params(&slots, nc)?;
    let (dws_p, dbs_p) = dense_params(&slots, nd)?;
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;
    let levels = if actq { Some(2.0f32.powf(slots.scalar("abits")?)) } else { None };

    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut scratch_am: Vec<usize> = Vec::new();
    for (i, blk) in sig.blocks.iter().enumerate() {
        let g = &blk.geom;
        // fold BN (running stats) into the conv weights + bias
        let folded = if blk.bn {
            let (gamma, beta, rmean, rvar) = bn_params(&slots, i)?;
            let mut wf = vec![0.0f32; g.filter_len()];
            let mut bf = vec![0.0f32; g.co];
            linalg::bn_fold(gamma, beta, rmean, rvar, BN_EPS, cws[i], cbs[i], &mut wf, &mut bf);
            Some((wf, bf))
        } else {
            None
        };
        let (w_eff, b_eff): (&[f32], &[f32]) = match &folded {
            Some((wf, bf)) => (wf, bf),
            None => (cws[i], cbs[i]),
        };
        let mut u = vec![0.0f32; g.out_len()];
        if blk.res > 0 {
            // the skip lands between bias and ReLU, so ReLU cannot fuse
            linalg::conv2d(scratch, &acts[i], w_eff, g, Epilogue::Bias(b_eff), &mut u);
            add_assign(&mut u, &acts[i + 1 - blk.res]);
            relu_inplace(&mut u);
        } else {
            linalg::conv2d(scratch, &acts[i], w_eff, g, Epilogue::BiasRelu(b_eff), &mut u);
        }
        let mut out = match &blk.pool {
            Some(p) => pool_fwd(p, &u, &mut scratch_am),
            None => u,
        };
        if let Some(lv) = levels {
            act_fake_quant(&mut out, lv);
        }
        acts.push(out);
    }
    let a = eval_dense_ladder(scratch, &sig.dense, &dws_p, &dbs_p, acts.last().unwrap(), levels);
    let classes = sig.dense.classes();
    let loss = softmax_xent_loss(&a, y, sig.batch, classes);
    let correct = correct_count(&a, y, sig.batch, classes);
    let mut out = HashMap::new();
    out.insert("loss".to_string(), scalar_out(loss));
    out.insert("correct".to_string(), scalar_out(correct));
    emit(spec, out)
}

/// Deployment-form gather eval, the conv twin of
/// `host::eval_gather_step`: conv layers dequantize centroid indices at
/// im2col pack time ([`crate::linalg::conv2d_gather`] — patch extraction
/// dominates, so the LUT form buys little there), while the dense head
/// goes through `qdense_gather_ws` and thus takes the sparse LUT fast
/// path (gather-GEMM oracle under `--deterministic`). BN cannot fold
/// into codebook-indexed weights (the per-channel scale would leave the
/// shared codebook), so it applies as the equivalent post-conv affine
/// ([`crate::linalg::bn_infer`]) at running statistics.
pub(crate) fn eval_gather_step(
    spec: &ArtifactSpec,
    inputs: &[Value],
    scratch: &mut Workspace,
) -> Result<Vec<Value>> {
    let sig = cnn_sig(spec, "idx_c", "idx_w")?;
    let nd = sig.dense.layers();
    let slots = Slots::new(spec, inputs);
    let x = slots.f32("x")?;
    let y = slots.i32("y")?;

    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut scratch_am: Vec<usize> = Vec::new();
    for (i, blk) in sig.blocks.iter().enumerate() {
        let g = &blk.geom;
        let idx = slots.i32(&format!("idx_c{i}"))?;
        let cb = slots.f32(&format!("cb_c{i}"))?;
        let bias = slots.f32(&format!("p_cb{i}"))?;
        if cb.is_empty() {
            bail!(
                "artifact {}: conv layer {i}: empty codebook (corrupt container)",
                spec.name
            );
        }
        let mut u = vec![0.0f32; g.out_len()];
        if !blk.bn && blk.res == 0 {
            linalg::conv2d_gather(scratch, &acts[i], idx, cb, g, Epilogue::BiasRelu(bias), &mut u);
        } else {
            linalg::conv2d_gather(scratch, &acts[i], idx, cb, g, Epilogue::Bias(bias), &mut u);
            if blk.bn {
                let (gamma, beta, rmean, rvar) = bn_params(&slots, i)?;
                linalg::bn_infer(gamma, beta, rmean, rvar, BN_EPS, &mut u);
            }
            if blk.res > 0 {
                add_assign(&mut u, &acts[i + 1 - blk.res]);
            }
            relu_inplace(&mut u);
        }
        let out = match &blk.pool {
            Some(p) => pool_fwd(p, &u, &mut scratch_am),
            None => u,
        };
        acts.push(out);
    }
    let mut a = acts.pop().expect("at least the input activation");
    for i in 0..nd {
        let idx = slots.i32(&format!("idx_w{i}"))?;
        let cb = slots.f32(&format!("cb_w{i}"))?;
        let bias = slots.f32(&format!("p_b{i}"))?;
        let z = qdense_gather_ws(
            scratch,
            &a,
            idx,
            cb,
            bias,
            sig.batch,
            sig.dense.dims[i],
            sig.dense.dims[i + 1],
            i + 1 < nd,
        )
        .with_context(|| format!("artifact {}: dense layer {i}", spec.name))?;
        a = z;
    }
    let classes = sig.dense.classes();
    let loss = softmax_xent_loss(&a, y, sig.batch, classes);
    let correct = correct_count(&a, y, sig.batch, classes);
    let mut out = HashMap::new();
    out.insert("loss".to_string(), scalar_out(loss));
    out.insert("correct".to_string(), scalar_out(correct));
    emit(spec, out)
}

#[cfg(test)]
mod tests {
    use super::super::{ConvLayer, DType, Manifest};
    use super::*;

    fn tiny() -> Manifest {
        Manifest::synthetic_cnn("t", (8, 8), 3, &[(4, 2), (8, 2)], &[16, 5], 2)
    }

    /// A residual + BN + pool ladder small enough for unit tests: stem,
    /// then a shape-preserving pair whose second conv skips from the
    /// pair's input, max-pooled down, then gap → dense.
    fn tiny_topo() -> Manifest {
        let l = |co: usize, bn: bool, pool: &'static str, res: usize| ConvLayer {
            co,
            stride: 1,
            bn,
            pool,
            res,
        };
        Manifest::synthetic_convnet(
            "tt",
            (8, 8),
            3,
            &[l(4, true, "0", 0), l(4, false, "0", 0), l(4, true, "max2", 2), l(6, true, "gap", 0)],
            &[5],
            2,
        )
    }

    /// Deterministic small-magnitude inputs for every slot of an
    /// artifact, with the named scalars pinned to sane values.
    fn dummy_inputs(spec: &ArtifactSpec) -> Vec<Value> {
        spec.inputs
            .iter()
            .map(|t| {
                let n: usize = t.shape.iter().product();
                match t.dtype {
                    DType::I32 => {
                        // y labels (or idx slots) stay in range as zeros
                        Value::I32(crate::tensor::TensorI32::new(t.shape.clone(), vec![0; n]))
                    }
                    DType::F32 => {
                        let v = match t.name.as_str() {
                            "t" => vec![1.0],
                            "lr" => vec![1e-3],
                            "gs" | "eqw" => vec![0.0],
                            "abits" => vec![4.0],
                            name if name.starts_with("p_bng") || name.starts_with("p_bnv") => {
                                vec![1.0; n]
                            }
                            name if name.starts_with("cb_") => {
                                (0..n).map(|k| 0.1 + 0.05 * (k % 7) as f32).collect()
                            }
                            _ => (0..n)
                                .map(|k| ((k * 37 + 11) % 23) as f32 * 0.02 - 0.2)
                                .collect(),
                        };
                        Value::F32(Tensor::new(t.shape.clone(), v))
                    }
                }
            })
            .collect()
    }

    #[test]
    fn cnn_sig_recovers_geometry_from_signature_and_attrs() {
        let m = tiny();
        let spec = m.artifact("t_fp_train").unwrap();
        let sig = cnn_sig(spec, "p_c", "p_w").unwrap();
        assert_eq!(sig.batch, 2);
        assert_eq!(sig.blocks.len(), 2);
        assert_eq!(sig.blocks[0].geom.stride, 2);
        assert_eq!(sig.blocks[0].geom.pad, Pad::Same);
        assert!(!sig.blocks[0].bn && sig.blocks[0].pool.is_none() && sig.blocks[0].res == 0);
        assert_eq!(sig.blocks[1].geom.c, 4);
        assert_eq!(sig.blocks[1].geom.out_hw(), (2, 2));
        assert_eq!(sig.dense.dims, vec![2 * 2 * 8, 16, 5]);
        // gather signature recovers the same ladder from idx_ slots
        let evq = m.artifact("t_eval_q").unwrap();
        let gsig = cnn_sig(evq, "idx_c", "idx_w").unwrap();
        assert_eq!(gsig.dense.dims, sig.dense.dims);
    }

    #[test]
    fn cnn_sig_recovers_bn_pool_and_residual_topology() {
        let m = tiny_topo();
        let spec = m.artifact("tt_fp_train").unwrap();
        let sig = cnn_sig(spec, "p_c", "p_w").unwrap();
        assert_eq!(sig.blocks.len(), 4);
        assert!(sig.blocks[0].bn && !sig.blocks[1].bn);
        assert_eq!(sig.blocks[2].res, 2);
        let p2 = sig.blocks[2].pool.as_ref().unwrap();
        assert_eq!((p2.op, p2.kh, p2.stride), (PoolOp::Max, 2, 2));
        assert_eq!(p2.out_hw(), (4, 4));
        // gap = full-window average over the 4×4 map
        let p3 = sig.blocks[3].pool.as_ref().unwrap();
        assert_eq!((p3.op, p3.kh, p3.kw, p3.stride), (PoolOp::Avg, 4, 4, 1));
        assert_eq!(sig.dense.dims, vec![6, 5]);
        assert_eq!(sig.blocks[3].out_len(), 2 * 6);
    }

    #[test]
    fn cnn_sig_rejects_broken_chains() {
        let m = tiny();
        let mut spec = m.artifact("t_eval").unwrap().clone();
        // flat [batch, dim] input is an MLP signature, not a CNN one
        spec.inputs.iter_mut().find(|s| s.name == "x").unwrap().shape = vec![2, 192];
        assert!(cnn_sig(&spec, "p_c", "p_w").is_err());
        // channel-chain mismatch fails loudly
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.inputs.iter_mut().find(|s| s.name == "p_c1").unwrap().shape = vec![3, 3, 7, 8];
        assert!(cnn_sig(&spec, "p_c", "p_w").is_err());
        // a conv_strides attr that is present but short fails loudly at
        // signature recovery, not as a later dense-chain mismatch
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_strides".into(), "2".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("no entry for conv layer 1"), "{err:?}");
        // stride 0 is rejected instead of silently clamped
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_strides".into(), "0,2".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("stride 0"), "{err:?}");
    }

    #[test]
    fn cnn_sig_rejects_broken_topology_attrs() {
        let m = tiny();
        // present-but-short conv_pool
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_pool".into(), "max2".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("conv_pool has no entry"), "{err:?}");
        // unknown pool token
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_pool".into(), "0,max3".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("unknown conv_pool token"), "{err:?}");
        // present-but-short conv_bn
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_bn".into(), "1".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("conv_bn has no entry"), "{err:?}");
        // residual span 1 is out of range (r ≥ 2: a block cannot skip to
        // its own input twice)
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_res".into(), "0,1".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("residual span 1 out of range"), "{err:?}");
        // residual across a shape change (stride-2 convs) is rejected
        let mut spec = m.artifact("t_eval").unwrap().clone();
        spec.attrs.insert("conv_res".into(), "0,2".into());
        let err = cnn_sig(&spec, "p_c", "p_w").unwrap_err();
        assert!(format!("{err:?}").contains("residual skip shape mismatch"), "{err:?}");
    }

    #[test]
    fn topo_train_step_runs_and_moves_running_stats() {
        let m = tiny_topo();
        let spec = m.artifact("tt_fp_train").unwrap();
        let inputs = dummy_inputs(spec);
        let mut ws = Workspace::new();
        let outs = train_step(spec, &inputs, false, &mut ws).unwrap();
        assert_eq!(outs.len(), spec.outputs.len());
        let by_name: HashMap<&str, &Value> = spec
            .outputs
            .iter()
            .map(|t| t.name.as_str())
            .zip(outs.iter())
            .collect();
        let loss = by_name["loss"].as_f32().as_scalar();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // the EMA moved the running variance off its all-ones init
        // (batch variance of a non-constant conv output is not 1)
        let rv = by_name["p_bnv0"].as_f32();
        assert!(rv.data.iter().any(|&v| (v - 1.0).abs() > 1e-6), "{:?}", rv.data);
        // γ picked up a gradient through Adam
        let g0 = by_name["p_bng0"].as_f32();
        assert!(g0.data.iter().any(|&v| (v - 1.0).abs() > 1e-9));
        // Adam moment slots for the EMA-updated stats are echoed, not NaN
        assert!(by_name["m_bnm0"].as_f32().data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn topo_lrp_step_emits_conv_relevances() {
        let m = tiny_topo();
        let spec = m.artifact("tt_lrp").unwrap();
        let inputs = dummy_inputs(spec);
        let mut ws = Workspace::new();
        let outs = lrp_step(spec, &inputs, &mut ws).unwrap();
        assert_eq!(outs.len(), spec.outputs.len());
        for (t, v) in spec.outputs.iter().zip(outs.iter()) {
            let f = v.as_f32();
            assert_eq!(f.shape, t.shape, "{}", t.name);
            assert!(f.data.iter().all(|x| x.is_finite()), "{} not finite", t.name);
        }
        // the conv relevances are not all dead
        let rc0 = outs[spec.outputs.iter().position(|t| t.name == "r_c0").unwrap()].as_f32();
        assert!(rc0.data.iter().any(|&x| x != 0.0), "r_c0 all zero");
    }

    #[test]
    fn topo_eval_paths_run() {
        let m = tiny_topo();
        let mut ws = Workspace::new();
        for art in ["tt_eval", "tt_eval_actq", "tt_eval_q"] {
            let spec = m.artifact(art).unwrap();
            let inputs = dummy_inputs(spec);
            let outs = match art {
                "tt_eval" => eval_step(spec, &inputs, false, &mut ws).unwrap(),
                "tt_eval_actq" => eval_step(spec, &inputs, true, &mut ws).unwrap(),
                _ => eval_gather_step(spec, &inputs, &mut ws).unwrap(),
            };
            let loss = outs[0].as_f32().as_scalar();
            assert!(loss.is_finite(), "{art} loss {loss}");
        }
    }
}
