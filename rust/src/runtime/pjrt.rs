//! PJRT backend: load AOT-compiled HLO-text artifacts and execute them —
//! concurrently — behind the [`crate::runtime::Backend`] trait.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables live in a sharded reader-writer cache keyed by
//! artifact name, so concurrent `execute` calls from sweep workers take
//! uncontended read locks while a cold artifact compiles under a single
//! shard's write lock.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use super::{ArtifactSpec, Backend, BackendStats, DType, TensorSpec, Workspace};
use crate::tensor::{Tensor, TensorI32, Value};

/// Shard count of the executable cache. Power of two, comfortably above
/// the artifact count of one model family so name collisions are rare.
const CACHE_SHARDS: usize = 16;

/// Smoke check that the PJRT CPU client can be constructed.
pub fn smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(format!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    ))
}

fn literal_from_value(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
    let lit = match v {
        Value::F32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
        Value::I32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
    };
    Ok(lit)
}

fn value_from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
    Ok(match spec.dtype {
        DType::F32 => {
            let data = lit.to_vec::<f32>()?;
            Value::F32(Tensor::new(spec.shape.clone(), data))
        }
        DType::I32 => {
            let data = lit.to_vec::<i32>()?;
            Value::I32(TensorI32::new(spec.shape.clone(), data))
        }
    })
}

/// Sharded executable cache: readers (the execute hot path) only contend
/// within one shard, and only while a cold artifact on that shard compiles.
struct ShardedCache {
    shards: Vec<RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>>,
}

impl ShardedCache {
    fn new() -> Self {
        ShardedCache {
            shards: (0..CACHE_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, name: &str) -> &RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        name.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }
}

/// The PJRT execution backend: one CPU client + a sharded
/// compiled-executable cache. Safe to share by reference across threads.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: ShardedCache,
    /// wall-clock spent compiling (for §Perf accounting)
    compile_s: Mutex<f64>,
}

impl PjrtBackend {
    /// Construct the CPU client with an empty executable cache.
    pub fn new() -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            client: xla::PjRtClient::cpu()?,
            cache: ShardedCache::new(),
            compile_s: Mutex::new(0.0),
        })
    }

    /// Get (compile-on-demand) the executable for an artifact.
    ///
    /// The compile runs under the owning shard's write lock, so a cold
    /// artifact is compiled exactly once even when many workers race for
    /// it; cached artifacts on other shards stay readable throughout.
    fn executable(&self, spec: &ArtifactSpec) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let shard = self.cache.shard(&spec.name);
        if let Some(exe) = shard.read().unwrap().get(&spec.name) {
            return Ok(exe.clone());
        }
        let mut cache = shard.write().unwrap();
        // a racing worker may have compiled while we waited for the lock
        if let Some(exe) = cache.get(&spec.name) {
            return Ok(exe.clone());
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        *self.compile_s.lock().unwrap() += t0.elapsed().as_secs_f64();
        cache.insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, spec: &ArtifactSpec) -> Result<()> {
        self.executable(spec)?;
        Ok(())
    }

    /// Execute one artifact. (Artifacts are lowered with
    /// return_tuple=True, so the single device output is a tuple literal
    /// that we decompose against the manifest output signature.) All math
    /// runs on the device, so the host-side GEMM workspace is unused.
    fn execute(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Value],
        _scratch: &mut Workspace,
    ) -> Result<Vec<Value>> {
        let exe = self.executable(spec)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(literal_from_value)
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                spec.name,
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(spec.outputs.iter())
            .map(|(l, s)| value_from_literal(l, s))
            .collect()
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            compile_s: *self.compile_s.lock().unwrap(),
            cached_executables: self.cache.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_client() {
        let s = smoke().unwrap();
        assert!(s.contains("cpu"));
    }

    #[test]
    fn literal_roundtrip_shapes() {
        let v = Value::F32(Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        let lit = literal_from_value(&v).unwrap();
        let spec = TensorSpec { name: "t".into(), dtype: DType::F32, shape: vec![2, 2] };
        let back = value_from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_f32().data, v.as_f32().data);
    }
}
