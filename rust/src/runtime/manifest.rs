//! Parser for `artifacts/manifest.txt` — the contract between the python
//! compile path (aot.py) and the rust coordinator. The manifest describes
//! every model's parameter table and every artifact's input/output
//! signature; rust binds tensors by name and order from here, so python
//! remains the single source of truth for shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Tensor element type crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float
    F32,
    /// 32-bit signed integer
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

/// One tensor slot in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Element count of the slot.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Initialization kind of a parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    HeIn,
    Zeros,
    Ones,
}

/// One model parameter (from `param` lines).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
    pub quantize: bool,
}

impl ParamSpec {
    /// Element count of the parameter.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model section.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub batch: usize,
    pub classes: usize,
    pub input_dim: usize,
    pub params: Vec<ParamSpec>,
}

impl ModelSpec {
    /// Parameters flagged `quant=1`, in spec order.
    pub fn quantized_params(&self) -> impl Iterator<Item = &ParamSpec> {
        self.params.iter().filter(|p| p.quantize)
    }

    /// Total parameter count of the model.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Parameter count over the quantized layers only.
    pub fn quantized_numel(&self) -> usize {
        self.quantized_params().map(|p| p.numel()).sum()
    }
}

/// One HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub hash: String,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub kmax: usize,
    pub buckets: Vec<usize>,
    pub dir: PathBuf,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad shape dim"))
        .collect()
}

fn kv<'a>(tok: &'a str, key: &str) -> Option<&'a str> {
    tok.strip_prefix(key).and_then(|r| r.strip_prefix('='))
}

impl Manifest {
    /// The power-of-two element-count buckets served by the shared assign
    /// artifact (python/compile/aot.py `ASSIGN_BUCKETS`).
    pub const ASSIGN_BUCKETS: [usize; 9] =
        [1024, 2048, 4096, 16384, 32768, 65536, 131072, 262144, 524288];
    /// Codebook capacity (python/compile/kernels/ecqx_assign.py `K_MAX`;
    /// single source of truth is [`crate::quant::K_MAX`]).
    pub const K_MAX: usize = crate::quant::K_MAX;
    /// The paper's MLP_GSC layer ladder (python/compile/model.py
    /// `MLP_DIMS`).
    pub const MLP_GSC_DIMS: [usize; 8] = [360, 512, 512, 256, 256, 128, 128, 12];

    /// Synthesize the manifest of a pure dense-MLP model, mirroring what
    /// `python -m compile.aot` would write for it: the param table, the
    /// `fp_train`/`ste_train`/`lrp`/`eval`/`eval_actq`/`eval_q` artifact
    /// signatures and the shared `assign_<bucket>` artifacts. This is the
    /// contract the host backend executes, so the full pipeline runs with
    /// no `artifacts/` directory present.
    pub fn synthetic_mlp(model: &str, dims: &[usize], batch: usize) -> Manifest {
        assert!(dims.len() >= 2, "an MLP needs at least one layer");
        let nl = dims.len() - 1;
        let classes = dims[nl];
        let mut params = Vec::with_capacity(2 * nl);
        for i in 0..nl {
            params.push(ParamSpec {
                name: format!("w{i}"),
                shape: vec![dims[i], dims[i + 1]],
                init: Init::HeIn,
                quantize: true,
            });
            params.push(ParamSpec {
                name: format!("b{i}"),
                shape: vec![dims[i + 1]],
                init: Init::Zeros,
                quantize: false,
            });
        }
        let spec = ModelSpec {
            name: model.to_string(),
            batch,
            classes,
            input_dim: dims[0],
            params,
        };

        let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            dtype: DType::F32,
            shape,
        };
        let i32s = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            dtype: DType::I32,
            shape,
        };
        let param_ins = |prefix: &str| -> Vec<TensorSpec> {
            spec.params
                .iter()
                .map(|p| f32s(&format!("{prefix}{}", p.name), p.shape.clone()))
                .collect()
        };
        let x_in = f32s("x", vec![batch, dims[0]]);
        let y_in = i32s("y", vec![batch]);
        let train_outs = |_: ()| -> Vec<TensorSpec> {
            let mut outs = Vec::new();
            for prefix in ["p_", "m_", "v_"] {
                outs.extend(param_ins(prefix));
            }
            outs.push(f32s("loss", vec![]));
            outs.push(f32s("correct", vec![]));
            outs
        };
        let eval_outs = vec![f32s("loss", vec![]), f32s("correct", vec![])];

        let mut artifacts = BTreeMap::new();
        let mut add = |name: String, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: PathBuf::from(format!("<host:{name}>")),
                    name,
                    inputs,
                    outputs,
                },
            );
        };

        // fp_train: p_* m_* v_* x y t lr -> p_* m_* v_* loss correct
        let mut ins = param_ins("p_");
        ins.extend(param_ins("m_"));
        ins.extend(param_ins("v_"));
        ins.extend([x_in.clone(), y_in.clone(), f32s("t", vec![]), f32s("lr", vec![])]);
        add(format!("{model}_fp_train"), ins, train_outs(()));

        // ste_train: p_* q_w* m_* v_* x y t lr gs -> p_* m_* v_* loss correct
        let mut ins = param_ins("p_");
        for i in 0..nl {
            ins.push(f32s(&format!("q_w{i}"), vec![dims[i], dims[i + 1]]));
        }
        ins.extend(param_ins("m_"));
        ins.extend(param_ins("v_"));
        ins.extend([
            x_in.clone(),
            y_in.clone(),
            f32s("t", vec![]),
            f32s("lr", vec![]),
            f32s("gs", vec![]),
        ]);
        add(format!("{model}_ste_train"), ins, train_outs(()));

        // lrp: p_* x y eqw -> r_w*
        let mut ins = param_ins("p_");
        ins.extend([x_in.clone(), y_in.clone(), f32s("eqw", vec![])]);
        let outs = (0..nl)
            .map(|i| f32s(&format!("r_w{i}"), vec![dims[i], dims[i + 1]]))
            .collect();
        add(format!("{model}_lrp"), ins, outs);

        // eval / eval_actq: p_* x y [abits] -> loss correct
        let mut ins = param_ins("p_");
        ins.extend([x_in.clone(), y_in.clone()]);
        add(format!("{model}_eval"), ins.clone(), eval_outs.clone());
        ins.push(f32s("abits", vec![]));
        add(format!("{model}_eval_actq"), ins, eval_outs.clone());

        // eval_q: idx_w* cb_w* p_b* x y -> loss correct
        let mut ins = Vec::new();
        for i in 0..nl {
            ins.push(i32s(&format!("idx_w{i}"), vec![dims[i], dims[i + 1]]));
        }
        for i in 0..nl {
            ins.push(f32s(&format!("cb_w{i}"), vec![Self::K_MAX]));
        }
        for i in 0..nl {
            ins.push(f32s(&format!("p_b{i}"), vec![dims[i + 1]]));
        }
        ins.extend([x_in, y_in]);
        add(format!("{model}_eval_q"), ins, eval_outs);

        // assign_<bucket>: w r mask centroids cvalid lam -> idx qw counts
        for &n in &Self::ASSIGN_BUCKETS {
            add(
                format!("assign_{n}"),
                vec![
                    f32s("w", vec![n]),
                    f32s("r", vec![n]),
                    f32s("mask", vec![n]),
                    f32s("centroids", vec![Self::K_MAX]),
                    f32s("cvalid", vec![Self::K_MAX]),
                    f32s("lam", vec![]),
                ],
                vec![
                    i32s("idx", vec![n]),
                    f32s("qw", vec![n]),
                    f32s("counts", vec![Self::K_MAX]),
                ],
            );
        }

        Manifest {
            hash: format!("host-synthetic-{model}"),
            models: BTreeMap::from([(model.to_string(), spec)]),
            artifacts,
            kmax: Self::K_MAX,
            buckets: Self::ASSIGN_BUCKETS.to_vec(),
            dir: PathBuf::from("<host>"),
        }
    }

    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = Manifest { dir: dir.to_path_buf(), ..Default::default() };
        let mut cur_model: Option<String> = None;
        let mut cur_art: Option<String> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {line}", ln + 1);
            match toks[0] {
                "hash" => m.hash = toks[1].to_string(),
                "kmax" => m.kmax = toks[1].parse().with_context(ctx)?,
                "buckets" => {
                    m.buckets = toks[1]
                        .split(',')
                        .map(|b| b.parse().unwrap())
                        .collect()
                }
                "model" => {
                    let name = toks[1].to_string();
                    let mut batch = 0;
                    let mut classes = 0;
                    let mut input_dim = 0;
                    for t in &toks[2..] {
                        if let Some(v) = kv(t, "batch") {
                            batch = v.parse().with_context(ctx)?;
                        } else if let Some(v) = kv(t, "classes") {
                            classes = v.parse().with_context(ctx)?;
                        } else if let Some(v) = kv(t, "input") {
                            input_dim = parse_shape(v)?.iter().product();
                        }
                    }
                    m.models.insert(
                        name.clone(),
                        ModelSpec { name: name.clone(), batch, classes, input_dim, params: vec![] },
                    );
                    cur_model = Some(name);
                }
                "param" => {
                    let model = cur_model.as_ref().context("param outside model")?;
                    let mut init = Init::Zeros;
                    let mut quant = false;
                    for t in &toks[4..] {
                        if let Some(v) = kv(t, "init") {
                            init = match v {
                                "he_in" => Init::HeIn,
                                "zeros" => Init::Zeros,
                                "ones" => Init::Ones,
                                other => bail!("unknown init {other}"),
                            };
                        } else if let Some(v) = kv(t, "quant") {
                            quant = v == "1";
                        }
                    }
                    m.models.get_mut(model).unwrap().params.push(ParamSpec {
                        name: toks[1].to_string(),
                        shape: parse_shape(toks[3])?,
                        init,
                        quantize: quant,
                    });
                }
                "artifact" => {
                    let name = toks[1].to_string();
                    let file = toks[2]
                        .strip_prefix("file=")
                        .context("artifact missing file=")?;
                    m.artifacts.insert(
                        name.clone(),
                        ArtifactSpec {
                            name: name.clone(),
                            file: dir.join(file),
                            inputs: vec![],
                            outputs: vec![],
                        },
                    );
                    cur_art = Some(name);
                }
                "in" | "out" => {
                    let art = cur_art.as_ref().context("in/out outside artifact")?;
                    let spec = TensorSpec {
                        name: toks[1].to_string(),
                        dtype: DType::parse(toks[2])?,
                        shape: parse_shape(toks[3])?,
                    };
                    let a = m.artifacts.get_mut(art).unwrap();
                    if toks[0] == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "end" => cur_art = None,
                other => bail!("unknown manifest directive {other} at line {}", ln + 1),
            }
        }
        Ok(m)
    }

    /// Look up a model section by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| format!("model {name} not in manifest"))
    }

    /// Look up an artifact signature by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    /// Smallest assign bucket that fits `numel` elements.
    pub fn bucket_for(&self, numel: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= numel)
            .with_context(|| format!("no assign bucket fits {numel} elements"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ecqx-manifest-test-{}",
            std::process::id() as u64 + text.len() as u64
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        dir
    }

    #[test]
    fn parses_minimal() {
        let dir = write_tmp(
            "hash abc\n\
             model m batch=4 classes=2 input=8\n\
             param w0 f32 8x2 init=he_in quant=1\n\
             param b0 f32 2 init=zeros quant=0\n\
             kmax 32\n\
             buckets 1024,2048\n\
             artifact m_eval file=m_eval.hlo.txt\n\
             in p_w0 f32 8x2\n\
             in x f32 4x8\n\
             in y i32 4\n\
             out loss f32 scalar\n\
             end\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.hash, "abc");
        let model = m.model("m").unwrap();
        assert_eq!(model.batch, 4);
        assert_eq!(model.params.len(), 2);
        assert!(model.params[0].quantize);
        assert_eq!(model.params[0].init, Init::HeIn);
        assert_eq!(model.total_params(), 18);
        assert_eq!(model.quantized_numel(), 16);
        let art = m.artifact("m_eval").unwrap();
        assert_eq!(art.inputs.len(), 3);
        assert_eq!(art.inputs[2].dtype, DType::I32);
        assert_eq!(art.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(m.bucket_for(900).unwrap(), 1024);
        assert_eq!(m.bucket_for(1500).unwrap(), 2048);
        assert!(m.bucket_for(99999).is_err());
    }

    #[test]
    fn missing_model_errors() {
        let dir = write_tmp("hash x\n");
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn synthetic_mlp_mirrors_aot_contract() {
        let m = Manifest::synthetic_mlp("tiny", &[6, 4, 3], 2);
        let spec = m.model("tiny").unwrap();
        assert_eq!(spec.batch, 2);
        assert_eq!(spec.classes, 3);
        assert_eq!(spec.input_dim, 6);
        assert_eq!(spec.params.len(), 4);
        assert_eq!(spec.quantized_numel(), 6 * 4 + 4 * 3);
        // every artifact kind + one assign artifact per bucket
        for art in ["tiny_fp_train", "tiny_ste_train", "tiny_lrp", "tiny_eval", "tiny_eval_actq", "tiny_eval_q"] {
            assert!(m.artifact(art).is_ok(), "{art} missing");
        }
        assert_eq!(
            m.artifacts.len(),
            6 + Manifest::ASSIGN_BUCKETS.len(),
            "artifact count"
        );
        // fp_train signature: 3 param groups + x y t lr in, +loss/correct out
        let fp = m.artifact("tiny_fp_train").unwrap();
        assert_eq!(fp.inputs.len(), 3 * 4 + 4);
        assert_eq!(fp.outputs.len(), 3 * 4 + 2);
        assert_eq!(fp.inputs[0].name, "p_w0");
        assert_eq!(fp.outputs.last().unwrap().name, "correct");
        // lrp outputs one relevance tensor per quantized layer
        let lrp = m.artifact("tiny_lrp").unwrap();
        assert_eq!(lrp.outputs.len(), 2);
        assert_eq!(lrp.outputs[0].shape, vec![6, 4]);
        // gather eval carries idx/cb/bias slots
        let evq = m.artifact("tiny_eval_q").unwrap();
        assert_eq!(evq.inputs[0].dtype, DType::I32);
        assert_eq!(m.bucket_for(6 * 4).unwrap(), 1024);
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mlp_gsc"));
            assert!(!m.buckets.is_empty());
            assert_eq!(m.kmax, 32);
        }
    }
}
