//! Parser for `artifacts/manifest.txt` — the contract between the python
//! compile path (aot.py) and the rust coordinator. The manifest describes
//! every model's parameter table and every artifact's input/output
//! signature; rust binds tensors by name and order from here, so python
//! remains the single source of truth for shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Tensor element type crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float
    F32,
    /// 32-bit signed integer
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

/// One tensor slot in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Element count of the slot.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Initialization kind of a parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    HeIn,
    Zeros,
    Ones,
}

/// One model parameter (from `param` lines).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
    pub quantize: bool,
}

impl ParamSpec {
    /// Element count of the parameter.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model section.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub batch: usize,
    pub classes: usize,
    pub input_dim: usize,
    pub params: Vec<ParamSpec>,
}

impl ModelSpec {
    /// Parameters flagged `quant=1`, in spec order.
    pub fn quantized_params(&self) -> impl Iterator<Item = &ParamSpec> {
        self.params.iter().filter(|p| p.quantize)
    }

    /// Total parameter count of the model.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Parameter count over the quantized layers only.
    pub fn quantized_numel(&self) -> usize {
        self.quantized_params().map(|p| p.numel()).sum()
    }
}

/// One HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form key/value attributes (`attr <key> <value>` lines).
    /// Carries execution metadata tensor shapes cannot: the host backend
    /// reads `conv_strides` / `conv_pads` (comma-separated, one entry per
    /// conv layer) to recover conv geometry. The PJRT backend ignores
    /// attrs — geometry is baked into its lowered HLO.
    pub attrs: BTreeMap<String, String>,
}

/// One conv layer of a [`Manifest::synthetic_convnet`] ladder: a 3×3
/// SAME conv to `co` channels at `stride`, optionally BatchNormed
/// (`bn`), followed by a pooling stage (`pool`: `"0"` none, `"max2"` /
/// `"avg2"` 2×2 stride-2, `"gap"` global average) and optionally fed an
/// identity residual skip (`res = 0` none, else the span `r ≥ 2`: this
/// layer's pre-ReLU output adds the *input* of conv layer `i−r+1`).
/// Per-layer op order: conv+bias → BN → +skip → ReLU → pool.
#[derive(Clone, Copy, Debug)]
pub struct ConvLayer {
    pub co: usize,
    pub stride: usize,
    pub bn: bool,
    pub pool: &'static str,
    pub res: usize,
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub hash: String,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub kmax: usize,
    pub buckets: Vec<usize>,
    pub dir: PathBuf,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad shape dim"))
        .collect()
}

fn kv<'a>(tok: &'a str, key: &str) -> Option<&'a str> {
    tok.strip_prefix(key).and_then(|r| r.strip_prefix('='))
}

impl Manifest {
    /// The power-of-two element-count buckets served by the shared assign
    /// artifact (python/compile/aot.py `ASSIGN_BUCKETS`).
    pub const ASSIGN_BUCKETS: [usize; 9] =
        [1024, 2048, 4096, 16384, 32768, 65536, 131072, 262144, 524288];
    /// Codebook capacity (python/compile/kernels/ecqx_assign.py `K_MAX`;
    /// single source of truth is [`crate::quant::K_MAX`]).
    pub const K_MAX: usize = crate::quant::K_MAX;
    /// The paper's MLP_GSC layer ladder (python/compile/model.py
    /// `MLP_DIMS`).
    pub const MLP_GSC_DIMS: [usize; 8] = [360, 512, 512, 256, 256, 128, 128, 12];

    /// Synthesize the manifest of a pure dense-MLP model, mirroring what
    /// `python -m compile.aot` would write for it: the param table, the
    /// `fp_train`/`ste_train`/`lrp`/`eval`/`eval_actq`/`eval_q` artifact
    /// signatures and the shared `assign_<bucket>` artifacts. This is the
    /// contract the host backend executes, so the full pipeline runs with
    /// no `artifacts/` directory present.
    pub fn synthetic_mlp(model: &str, dims: &[usize], batch: usize) -> Manifest {
        assert!(dims.len() >= 2, "an MLP needs at least one layer");
        let nl = dims.len() - 1;
        let classes = dims[nl];
        let mut params = Vec::with_capacity(2 * nl);
        for i in 0..nl {
            params.push(ParamSpec {
                name: format!("w{i}"),
                shape: vec![dims[i], dims[i + 1]],
                init: Init::HeIn,
                quantize: true,
            });
            params.push(ParamSpec {
                name: format!("b{i}"),
                shape: vec![dims[i + 1]],
                init: Init::Zeros,
                quantize: false,
            });
        }
        let spec = ModelSpec {
            name: model.to_string(),
            batch,
            classes,
            input_dim: dims[0],
            params,
        };

        let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            dtype: DType::F32,
            shape,
        };
        let i32s = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            dtype: DType::I32,
            shape,
        };
        let param_ins = |prefix: &str| -> Vec<TensorSpec> {
            spec.params
                .iter()
                .map(|p| f32s(&format!("{prefix}{}", p.name), p.shape.clone()))
                .collect()
        };
        let x_in = f32s("x", vec![batch, dims[0]]);
        let y_in = i32s("y", vec![batch]);
        let train_outs = |_: ()| -> Vec<TensorSpec> {
            let mut outs = Vec::new();
            for prefix in ["p_", "m_", "v_"] {
                outs.extend(param_ins(prefix));
            }
            outs.push(f32s("loss", vec![]));
            outs.push(f32s("correct", vec![]));
            outs
        };
        let eval_outs = vec![f32s("loss", vec![]), f32s("correct", vec![])];

        let mut artifacts = BTreeMap::new();
        let mut add = |name: String, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: PathBuf::from(format!("<host:{name}>")),
                    name,
                    inputs,
                    outputs,
                    attrs: BTreeMap::new(),
                },
            );
        };

        // fp_train: p_* m_* v_* x y t lr -> p_* m_* v_* loss correct
        let mut ins = param_ins("p_");
        ins.extend(param_ins("m_"));
        ins.extend(param_ins("v_"));
        ins.extend([x_in.clone(), y_in.clone(), f32s("t", vec![]), f32s("lr", vec![])]);
        add(format!("{model}_fp_train"), ins, train_outs(()));

        // ste_train: p_* q_w* m_* v_* x y t lr gs -> p_* m_* v_* loss correct
        let mut ins = param_ins("p_");
        for i in 0..nl {
            ins.push(f32s(&format!("q_w{i}"), vec![dims[i], dims[i + 1]]));
        }
        ins.extend(param_ins("m_"));
        ins.extend(param_ins("v_"));
        ins.extend([
            x_in.clone(),
            y_in.clone(),
            f32s("t", vec![]),
            f32s("lr", vec![]),
            f32s("gs", vec![]),
        ]);
        add(format!("{model}_ste_train"), ins, train_outs(()));

        // lrp: p_* x y eqw -> r_w*
        let mut ins = param_ins("p_");
        ins.extend([x_in.clone(), y_in.clone(), f32s("eqw", vec![])]);
        let outs = (0..nl)
            .map(|i| f32s(&format!("r_w{i}"), vec![dims[i], dims[i + 1]]))
            .collect();
        add(format!("{model}_lrp"), ins, outs);

        // eval / eval_actq: p_* x y [abits] -> loss correct
        let mut ins = param_ins("p_");
        ins.extend([x_in.clone(), y_in.clone()]);
        add(format!("{model}_eval"), ins.clone(), eval_outs.clone());
        ins.push(f32s("abits", vec![]));
        add(format!("{model}_eval_actq"), ins, eval_outs.clone());

        // eval_q: idx_w* cb_w* p_b* x y -> loss correct
        let mut ins = Vec::new();
        for i in 0..nl {
            ins.push(i32s(&format!("idx_w{i}"), vec![dims[i], dims[i + 1]]));
        }
        for i in 0..nl {
            ins.push(f32s(&format!("cb_w{i}"), vec![Self::K_MAX]));
        }
        for i in 0..nl {
            ins.push(f32s(&format!("p_b{i}"), vec![dims[i + 1]]));
        }
        ins.extend([x_in, y_in]);
        add(format!("{model}_eval_q"), ins, eval_outs);

        // assign_<bucket>: w r mask centroids cvalid lam -> idx qw counts
        for &n in &Self::ASSIGN_BUCKETS {
            add(
                format!("assign_{n}"),
                vec![
                    f32s("w", vec![n]),
                    f32s("r", vec![n]),
                    f32s("mask", vec![n]),
                    f32s("centroids", vec![Self::K_MAX]),
                    f32s("cvalid", vec![Self::K_MAX]),
                    f32s("lam", vec![]),
                ],
                vec![
                    i32s("idx", vec![n]),
                    f32s("qw", vec![n]),
                    f32s("counts", vec![Self::K_MAX]),
                ],
            );
        }

        Manifest {
            hash: format!("host-synthetic-{model}"),
            models: BTreeMap::from([(model.to_string(), spec)]),
            artifacts,
            kmax: Self::K_MAX,
            buckets: Self::ASSIGN_BUCKETS.to_vec(),
            dir: PathBuf::from("<host>"),
        }
    }

    /// Conv ladder of the host CNN workload (`cnn_cifar`): `(cout,
    /// stride)` per 3×3 SAME conv layer. Downsampling is by strided convs
    /// (32→16→8→4), keeping the kernel set to conv + dense — the
    /// CIFAR-shaped plain-ladder workload alongside the pooled/BN models
    /// below (DESIGN.md §2.3).
    pub const CNN_CIFAR_CONVS: [(usize, usize); 4] = [(16, 1), (32, 2), (64, 2), (64, 2)];
    /// Dense head of the host CNN workload: hidden width + classes.
    pub const CNN_CIFAR_FC: [usize; 2] = [128, 10];

    /// Synthesize the manifest of a plain conv-ladder + dense-head CNN:
    /// 3×3 SAME conv layers `convs = [(cout, stride), ..]` over an
    /// `hw.0 × hw.1 × cin` NHWC input, flattened into the dense ladder
    /// `fc = [hidden.., classes]` — [`Manifest::synthetic_convnet`] with
    /// no BN, pooling or residual topology (and therefore no
    /// `conv_bn`/`conv_pool`/`conv_res` attrs).
    pub fn synthetic_cnn(
        model: &str,
        hw: (usize, usize),
        cin: usize,
        convs: &[(usize, usize)],
        fc: &[usize],
        batch: usize,
    ) -> Manifest {
        let layers: Vec<ConvLayer> = convs
            .iter()
            .map(|&(co, stride)| ConvLayer { co, stride, bn: false, pool: "0", res: 0 })
            .collect();
        Self::synthetic_convnet(model, hw, cin, &layers, fc, batch)
    }

    /// The paper's VGG-slim CIFAR ladder (Fig. 10, and Fig. 8 with BN):
    /// stride-1 3×3 SAME convs with 2×2 max-pool downsampling
    /// (32→16→8→4), flattened into a `[128, 10]` dense head.
    pub fn synthetic_vgg(model: &str, bn: bool, batch: usize) -> Manifest {
        let l = |co: usize, pool: &'static str| ConvLayer { co, stride: 1, bn, pool, res: 0 };
        let layers =
            [l(16, "0"), l(16, "max2"), l(32, "0"), l(32, "max2"), l(64, "max2")];
        Self::synthetic_convnet(model, (32, 32), 3, &layers, &[128, 10], batch)
    }

    /// [`Manifest::synthetic_vgg`] with BatchNorm after every conv — the
    /// Fig. 8 `vgg_cifar_bn` workload.
    pub fn synthetic_vgg_bn(model: &str, batch: usize) -> Manifest {
        Self::synthetic_vgg(model, true, batch)
    }

    /// The Fig. 8 ResNet-style Pascal-VOC workload (`resnet_voc`): a BN
    /// stem, three stages of identity-skip residual pairs (`res = 2`: the
    /// block's second conv adds the first conv's input) with 2×2 max-pool
    /// transitions, global average pooling, and a single 20-class dense
    /// head.
    pub fn synthetic_resnet(model: &str, batch: usize) -> Manifest {
        let c = |co: usize, pool: &'static str, res: usize| ConvLayer {
            co,
            stride: 1,
            bn: true,
            pool,
            res,
        };
        let layers = [
            c(16, "0", 0), // stem
            c(16, "0", 0),
            c(16, "max2", 2), // stage 1 residual pair, then downsample
            c(32, "0", 0), // transition
            c(32, "0", 0),
            c(32, "max2", 2), // stage 2
            c(64, "0", 0), // transition
            c(64, "0", 0),
            c(64, "gap", 2), // stage 3, then global average pool
        ];
        Self::synthetic_convnet(model, (32, 32), 3, &layers, &[20], batch)
    }

    /// Synthesize the manifest of a general conv-net (the conv twin of
    /// [`Manifest::synthetic_mlp`]): 3×3 SAME conv layers over an
    /// `hw.0 × hw.1 × cin` NHWC input — each optionally BatchNormed,
    /// pooled and/or fed an identity residual skip — flattened into the
    /// dense ladder `fc = [hidden.., classes]`. Emits the same six
    /// artifact kinds plus the shared `assign_<bucket>` artifacts.
    ///
    /// Geometry and topology that tensor shapes cannot carry travel in
    /// artifact attrs, which is what makes the host backend's
    /// signature-driven execution work for CNNs: `conv_strides` /
    /// `conv_pads` always, and — only when some layer uses the feature,
    /// so plain-ladder manifests are byte-identical to what
    /// [`Manifest::synthetic_cnn`] always produced — `conv_bn`
    /// (`0`/`1`), `conv_pool` (`0`/`max2`/`avg2`/`gap`) and `conv_res`
    /// (`0` or the residual span `r ≥ 2`; the skip source is the *input*
    /// of conv layer `i−r+1`, identity skips only).
    ///
    /// A BN layer `i` contributes four non-quantized `[co]` params:
    /// `bng<i>`/`bnb<i>` (γ init 1, β init 0 — Adam-trained) and
    /// `bnm<i>`/`bnv<i>` (running mean/var, init 0/1 — EMA-updated by the
    /// train artifacts, consumed by eval/LRP and the fold-into-conv
    /// inference path).
    pub fn synthetic_convnet(
        model: &str,
        hw: (usize, usize),
        cin: usize,
        layers: &[ConvLayer],
        fc: &[usize],
        batch: usize,
    ) -> Manifest {
        assert!(!layers.is_empty(), "a CNN needs at least one conv layer");
        assert!(!fc.is_empty(), "a CNN needs a dense head");
        let (mut h, mut w) = hw;
        let mut c = cin;
        let mut params = Vec::new();
        // (h, w, c) feeding each conv layer — residual shape validation
        let mut in_dims: Vec<(usize, usize, usize)> = Vec::with_capacity(layers.len());
        for (i, l) in layers.iter().enumerate() {
            in_dims.push((h, w, c));
            params.push(ParamSpec {
                name: format!("c{i}"),
                shape: vec![3, 3, c, l.co],
                init: Init::HeIn,
                quantize: true,
            });
            params.push(ParamSpec {
                name: format!("cb{i}"),
                shape: vec![l.co],
                init: Init::Zeros,
                quantize: false,
            });
            if l.bn {
                for (name, init) in [
                    (format!("bng{i}"), Init::Ones),
                    (format!("bnb{i}"), Init::Zeros),
                    (format!("bnm{i}"), Init::Zeros),
                    (format!("bnv{i}"), Init::Ones),
                ] {
                    params.push(ParamSpec {
                        name,
                        shape: vec![l.co],
                        init,
                        quantize: false,
                    });
                }
            }
            let g = crate::linalg::Conv2d {
                n: batch,
                h,
                w,
                c,
                kh: 3,
                kw: 3,
                co: l.co,
                stride: l.stride,
                pad: crate::linalg::Pad::Same,
            };
            let (oh, ow) = g.out_hw();
            assert!(oh > 0 && ow > 0, "conv ladder collapsed the spatial dims");
            h = oh;
            w = ow;
            c = l.co;
            if l.res > 0 {
                assert!(l.res >= 2 && l.res <= i + 1, "layer {i}: bad residual span {}", l.res);
                let src = in_dims[i + 1 - l.res];
                assert_eq!(
                    src,
                    (h, w, c),
                    "layer {i}: residual skip shape mismatch (identity skips only)"
                );
            }
            match l.pool {
                "0" => {}
                "max2" | "avg2" => {
                    assert!(h >= 2 && w >= 2, "layer {i}: 2×2 pool needs h,w >= 2");
                    h = (h - 2) / 2 + 1;
                    w = (w - 2) / 2 + 1;
                }
                "gap" => {
                    h = 1;
                    w = 1;
                }
                other => panic!("layer {i}: unknown pool token {other}"),
            }
        }
        let flat = h * w * c;
        let mut dims = vec![flat];
        dims.extend_from_slice(fc);
        for i in 0..dims.len() - 1 {
            params.push(ParamSpec {
                name: format!("w{i}"),
                shape: vec![dims[i], dims[i + 1]],
                init: Init::HeIn,
                quantize: true,
            });
            params.push(ParamSpec {
                name: format!("b{i}"),
                shape: vec![dims[i + 1]],
                init: Init::Zeros,
                quantize: false,
            });
        }
        let spec = ModelSpec {
            name: model.to_string(),
            batch,
            classes: *fc.last().unwrap(),
            input_dim: hw.0 * hw.1 * cin,
            params,
        };

        let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            dtype: DType::F32,
            shape,
        };
        let i32s = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            dtype: DType::I32,
            shape,
        };
        let param_ins = |prefix: &str| -> Vec<TensorSpec> {
            spec.params
                .iter()
                .map(|p| f32s(&format!("{prefix}{}", p.name), p.shape.clone()))
                .collect()
        };
        let x_in = f32s("x", vec![batch, hw.0, hw.1, cin]);
        let y_in = i32s("y", vec![batch]);
        let train_outs = || -> Vec<TensorSpec> {
            let mut outs = Vec::new();
            for prefix in ["p_", "m_", "v_"] {
                outs.extend(param_ins(prefix));
            }
            outs.push(f32s("loss", vec![]));
            outs.push(f32s("correct", vec![]));
            outs
        };
        let eval_outs = vec![f32s("loss", vec![]), f32s("correct", vec![])];

        let join = |f: &dyn Fn(&ConvLayer) -> String| {
            layers.iter().map(f).collect::<Vec<_>>().join(",")
        };
        let mut conv_attrs = BTreeMap::from([
            ("conv_strides".to_string(), join(&|l| l.stride.to_string())),
            ("conv_pads".to_string(), vec!["same"; layers.len()].join(",")),
        ]);
        // topology attrs only when some layer uses the feature, so plain
        // ladders stay byte-identical to the historical synthetic_cnn form
        if layers.iter().any(|l| l.bn) {
            let v = join(&|l| if l.bn { "1" } else { "0" }.to_string());
            conv_attrs.insert("conv_bn".to_string(), v);
        }
        if layers.iter().any(|l| l.pool != "0") {
            conv_attrs.insert("conv_pool".to_string(), join(&|l| l.pool.to_string()));
        }
        if layers.iter().any(|l| l.res > 0) {
            conv_attrs.insert("conv_res".to_string(), join(&|l| l.res.to_string()));
        }
        let mut artifacts = BTreeMap::new();
        let mut add = |name: String, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: PathBuf::from(format!("<host:{name}>")),
                    name,
                    inputs,
                    outputs,
                    attrs: conv_attrs.clone(),
                },
            );
        };

        // fp_train: p_* m_* v_* x y t lr -> p_* m_* v_* loss correct
        let mut ins = param_ins("p_");
        ins.extend(param_ins("m_"));
        ins.extend(param_ins("v_"));
        ins.extend([x_in.clone(), y_in.clone(), f32s("t", vec![]), f32s("lr", vec![])]);
        add(format!("{model}_fp_train"), ins, train_outs());

        // ste_train: p_* q_<quantized>* m_* v_* x y t lr gs
        let mut ins = param_ins("p_");
        for p in spec.quantized_params() {
            ins.push(f32s(&format!("q_{}", p.name), p.shape.clone()));
        }
        ins.extend(param_ins("m_"));
        ins.extend(param_ins("v_"));
        ins.extend([
            x_in.clone(),
            y_in.clone(),
            f32s("t", vec![]),
            f32s("lr", vec![]),
            f32s("gs", vec![]),
        ]);
        add(format!("{model}_ste_train"), ins, train_outs());

        // lrp: p_* x y eqw -> r_<quantized>*
        let mut ins = param_ins("p_");
        ins.extend([x_in.clone(), y_in.clone(), f32s("eqw", vec![])]);
        let outs = spec
            .quantized_params()
            .map(|p| f32s(&format!("r_{}", p.name), p.shape.clone()))
            .collect();
        add(format!("{model}_lrp"), ins, outs);

        // eval / eval_actq: p_* x y [abits] -> loss correct
        let mut ins = param_ins("p_");
        ins.extend([x_in.clone(), y_in.clone()]);
        add(format!("{model}_eval"), ins.clone(), eval_outs.clone());
        ins.push(f32s("abits", vec![]));
        add(format!("{model}_eval_actq"), ins, eval_outs.clone());

        // eval_q: idx_<q>* cb_<q>* p_<biases>* x y -> loss correct
        let mut ins = Vec::new();
        for p in spec.quantized_params() {
            ins.push(i32s(&format!("idx_{}", p.name), p.shape.clone()));
        }
        for p in spec.quantized_params() {
            ins.push(f32s(&format!("cb_{}", p.name), vec![Self::K_MAX]));
        }
        for p in spec.params.iter().filter(|p| !p.quantize) {
            ins.push(f32s(&format!("p_{}", p.name), p.shape.clone()));
        }
        ins.extend([x_in, y_in]);
        add(format!("{model}_eval_q"), ins, eval_outs);

        // assign_<bucket>: shared with the dense models (no conv attrs)
        for &n in &Self::ASSIGN_BUCKETS {
            let name = format!("assign_{n}");
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: PathBuf::from(format!("<host:{name}>")),
                    name,
                    inputs: vec![
                        f32s("w", vec![n]),
                        f32s("r", vec![n]),
                        f32s("mask", vec![n]),
                        f32s("centroids", vec![Self::K_MAX]),
                        f32s("cvalid", vec![Self::K_MAX]),
                        f32s("lam", vec![]),
                    ],
                    outputs: vec![
                        i32s("idx", vec![n]),
                        f32s("qw", vec![n]),
                        f32s("counts", vec![Self::K_MAX]),
                    ],
                    attrs: BTreeMap::new(),
                },
            );
        }

        Manifest {
            hash: format!("host-synthetic-{model}"),
            models: BTreeMap::from([(model.to_string(), spec)]),
            artifacts,
            kmax: Self::K_MAX,
            buckets: Self::ASSIGN_BUCKETS.to_vec(),
            dir: PathBuf::from("<host>"),
        }
    }

    /// Merge another manifest's models and artifacts into this one (the
    /// host backend serves the MLP and CNN workloads from one merged
    /// manifest). Same-name entries — e.g. the shared `assign_<bucket>`
    /// artifacts — are taken from `other`.
    pub fn merge(mut self, other: Manifest) -> Manifest {
        self.models.extend(other.models);
        self.artifacts.extend(other.artifacts);
        if self.kmax == 0 {
            self.kmax = other.kmax;
        }
        if self.buckets.is_empty() {
            self.buckets = other.buckets;
        }
        self.hash = format!("{}+{}", self.hash, other.hash);
        self
    }

    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = Manifest { dir: dir.to_path_buf(), ..Default::default() };
        let mut cur_model: Option<String> = None;
        let mut cur_art: Option<String> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {line}", ln + 1);
            match toks[0] {
                "hash" => m.hash = toks[1].to_string(),
                "kmax" => m.kmax = toks[1].parse().with_context(ctx)?,
                "buckets" => {
                    m.buckets = toks[1]
                        .split(',')
                        .map(|b| b.parse().unwrap())
                        .collect()
                }
                "model" => {
                    let name = toks[1].to_string();
                    let mut batch = 0;
                    let mut classes = 0;
                    let mut input_dim = 0;
                    for t in &toks[2..] {
                        if let Some(v) = kv(t, "batch") {
                            batch = v.parse().with_context(ctx)?;
                        } else if let Some(v) = kv(t, "classes") {
                            classes = v.parse().with_context(ctx)?;
                        } else if let Some(v) = kv(t, "input") {
                            input_dim = parse_shape(v)?.iter().product();
                        }
                    }
                    m.models.insert(
                        name.clone(),
                        ModelSpec { name: name.clone(), batch, classes, input_dim, params: vec![] },
                    );
                    cur_model = Some(name);
                }
                "param" => {
                    let model = cur_model.as_ref().context("param outside model")?;
                    let mut init = Init::Zeros;
                    let mut quant = false;
                    for t in &toks[4..] {
                        if let Some(v) = kv(t, "init") {
                            init = match v {
                                "he_in" => Init::HeIn,
                                "zeros" => Init::Zeros,
                                "ones" => Init::Ones,
                                other => bail!("unknown init {other}"),
                            };
                        } else if let Some(v) = kv(t, "quant") {
                            quant = v == "1";
                        }
                    }
                    m.models.get_mut(model).unwrap().params.push(ParamSpec {
                        name: toks[1].to_string(),
                        shape: parse_shape(toks[3])?,
                        init,
                        quantize: quant,
                    });
                }
                "artifact" => {
                    let name = toks[1].to_string();
                    let file = toks[2]
                        .strip_prefix("file=")
                        .context("artifact missing file=")?;
                    m.artifacts.insert(
                        name.clone(),
                        ArtifactSpec {
                            name: name.clone(),
                            file: dir.join(file),
                            inputs: vec![],
                            outputs: vec![],
                            attrs: BTreeMap::new(),
                        },
                    );
                    cur_art = Some(name);
                }
                "attr" => {
                    let art = cur_art.as_ref().context("attr outside artifact")?;
                    if toks.len() < 3 {
                        bail!("attr needs <key> <value> ({})", ctx());
                    }
                    m.artifacts
                        .get_mut(art)
                        .unwrap()
                        .attrs
                        .insert(toks[1].to_string(), toks[2].to_string());
                }
                "in" | "out" => {
                    let art = cur_art.as_ref().context("in/out outside artifact")?;
                    let spec = TensorSpec {
                        name: toks[1].to_string(),
                        dtype: DType::parse(toks[2])?,
                        shape: parse_shape(toks[3])?,
                    };
                    let a = m.artifacts.get_mut(art).unwrap();
                    if toks[0] == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "end" => cur_art = None,
                other => bail!("unknown manifest directive {other} at line {}", ln + 1),
            }
        }
        Ok(m)
    }

    /// Look up a model section by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| format!("model {name} not in manifest"))
    }

    /// Look up an artifact signature by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    /// Smallest assign bucket that fits `numel` elements.
    pub fn bucket_for(&self, numel: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= numel)
            .with_context(|| format!("no assign bucket fits {numel} elements"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ecqx-manifest-test-{}",
            std::process::id() as u64 + text.len() as u64
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        dir
    }

    #[test]
    fn parses_minimal() {
        let dir = write_tmp(
            "hash abc\n\
             model m batch=4 classes=2 input=8\n\
             param w0 f32 8x2 init=he_in quant=1\n\
             param b0 f32 2 init=zeros quant=0\n\
             kmax 32\n\
             buckets 1024,2048\n\
             artifact m_eval file=m_eval.hlo.txt\n\
             in p_w0 f32 8x2\n\
             in x f32 4x8\n\
             in y i32 4\n\
             out loss f32 scalar\n\
             end\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.hash, "abc");
        let model = m.model("m").unwrap();
        assert_eq!(model.batch, 4);
        assert_eq!(model.params.len(), 2);
        assert!(model.params[0].quantize);
        assert_eq!(model.params[0].init, Init::HeIn);
        assert_eq!(model.total_params(), 18);
        assert_eq!(model.quantized_numel(), 16);
        let art = m.artifact("m_eval").unwrap();
        assert_eq!(art.inputs.len(), 3);
        assert_eq!(art.inputs[2].dtype, DType::I32);
        assert_eq!(art.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(m.bucket_for(900).unwrap(), 1024);
        assert_eq!(m.bucket_for(1500).unwrap(), 2048);
        assert!(m.bucket_for(99999).is_err());
    }

    #[test]
    fn missing_model_errors() {
        let dir = write_tmp("hash x\n");
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn synthetic_mlp_mirrors_aot_contract() {
        let m = Manifest::synthetic_mlp("tiny", &[6, 4, 3], 2);
        let spec = m.model("tiny").unwrap();
        assert_eq!(spec.batch, 2);
        assert_eq!(spec.classes, 3);
        assert_eq!(spec.input_dim, 6);
        assert_eq!(spec.params.len(), 4);
        assert_eq!(spec.quantized_numel(), 6 * 4 + 4 * 3);
        // every artifact kind + one assign artifact per bucket
        for art in ["tiny_fp_train", "tiny_ste_train", "tiny_lrp", "tiny_eval", "tiny_eval_actq", "tiny_eval_q"] {
            assert!(m.artifact(art).is_ok(), "{art} missing");
        }
        assert_eq!(
            m.artifacts.len(),
            6 + Manifest::ASSIGN_BUCKETS.len(),
            "artifact count"
        );
        // fp_train signature: 3 param groups + x y t lr in, +loss/correct out
        let fp = m.artifact("tiny_fp_train").unwrap();
        assert_eq!(fp.inputs.len(), 3 * 4 + 4);
        assert_eq!(fp.outputs.len(), 3 * 4 + 2);
        assert_eq!(fp.inputs[0].name, "p_w0");
        assert_eq!(fp.outputs.last().unwrap().name, "correct");
        // lrp outputs one relevance tensor per quantized layer
        let lrp = m.artifact("tiny_lrp").unwrap();
        assert_eq!(lrp.outputs.len(), 2);
        assert_eq!(lrp.outputs[0].shape, vec![6, 4]);
        // gather eval carries idx/cb/bias slots
        let evq = m.artifact("tiny_eval_q").unwrap();
        assert_eq!(evq.inputs[0].dtype, DType::I32);
        assert_eq!(m.bucket_for(6 * 4).unwrap(), 1024);
    }

    #[test]
    fn synthetic_cnn_mirrors_aot_contract() {
        let m = Manifest::synthetic_cnn("tcnn", (8, 8), 3, &[(4, 2), (8, 2)], &[16, 5], 2);
        let spec = m.model("tcnn").unwrap();
        assert_eq!(spec.classes, 5);
        assert_eq!(spec.input_dim, 8 * 8 * 3);
        // c0 cb0 c1 cb1 w0 b0 w1 b1
        assert_eq!(spec.params.len(), 8);
        assert_eq!(spec.params[0].shape, vec![3, 3, 3, 4]);
        // flat = 2·2·8 = 32 after two stride-2 SAME convs on 8×8
        let w0 = spec.params.iter().find(|p| p.name == "w0").unwrap();
        assert_eq!(w0.shape, vec![32, 16]);
        for art in [
            "tcnn_fp_train",
            "tcnn_ste_train",
            "tcnn_lrp",
            "tcnn_eval",
            "tcnn_eval_actq",
            "tcnn_eval_q",
        ] {
            let a = m.artifact(art).unwrap();
            assert_eq!(a.attrs["conv_strides"], "2,2", "{art}");
            assert_eq!(a.attrs["conv_pads"], "same,same", "{art}");
        }
        // one relevance output per quantized layer, conv shapes 4D
        let lrp = m.artifact("tcnn_lrp").unwrap();
        assert_eq!(lrp.outputs.len(), 4);
        assert_eq!(lrp.outputs[0].name, "r_c0");
        assert_eq!(lrp.outputs[0].shape, vec![3, 3, 3, 4]);
        // gather eval: 4D i32 idx slots + the conv bias slots
        let evq = m.artifact("tcnn_eval_q").unwrap();
        assert_eq!(evq.inputs[0].name, "idx_c0");
        assert_eq!(evq.inputs[0].dtype, DType::I32);
        assert!(evq.inputs.iter().any(|s| s.name == "p_cb0"));
        // x is 4D NHWC
        let ev = m.artifact("tcnn_eval").unwrap();
        let x = ev.inputs.iter().find(|s| s.name == "x").unwrap();
        assert_eq!(x.shape, vec![2, 8, 8, 3]);
        assert!(m.artifact("assign_1024").is_ok());
    }

    #[test]
    fn plain_ladder_emits_no_topology_attrs() {
        let m = Manifest::synthetic_cnn("tcnn", (8, 8), 3, &[(4, 2), (8, 2)], &[16, 5], 2);
        let a = m.artifact("tcnn_eval").unwrap();
        for key in ["conv_bn", "conv_pool", "conv_res"] {
            assert!(!a.attrs.contains_key(key), "plain ladder leaked {key}");
        }
    }

    #[test]
    fn vgg_bn_ladder_carries_bn_and_pool_attrs() {
        let m = Manifest::synthetic_vgg_bn("v", 2);
        let spec = m.model("v").unwrap();
        // 5 convs × (c, cb + 4 BN params) + 2 dense layers × (w, b)
        assert_eq!(spec.params.len(), 5 * 6 + 4);
        let bng0 = spec.params.iter().find(|p| p.name == "bng0").unwrap();
        assert_eq!(bng0.shape, vec![16]);
        assert!(!bng0.quantize, "BN params stay fp");
        assert_eq!(bng0.init, Init::Ones);
        let bnv4 = spec.params.iter().find(|p| p.name == "bnv4").unwrap();
        assert_eq!((bnv4.shape.clone(), bnv4.init), (vec![64], Init::Ones));
        // pooled ladder: 32→16→8→4, flat = 4·4·64 = 1024
        let w0 = spec.params.iter().find(|p| p.name == "w0").unwrap();
        assert_eq!(w0.shape, vec![1024, 128]);
        let a = m.artifact("v_fp_train").unwrap();
        assert_eq!(a.attrs["conv_strides"], "1,1,1,1,1");
        assert_eq!(a.attrs["conv_bn"], "1,1,1,1,1");
        assert_eq!(a.attrs["conv_pool"], "0,max2,0,max2,max2");
        assert!(!a.attrs.contains_key("conv_res"));
        // BN running stats come back as train outputs (EMA path)
        assert!(a.outputs.iter().any(|t| t.name == "p_bnm0"));
        // but are not quantized: no idx_/cb_/r_ slots for them
        let lrp = m.artifact("v_lrp").unwrap();
        assert!(lrp.outputs.iter().all(|t| !t.name.contains("bn")));
    }

    #[test]
    fn resnet_ladder_carries_residual_spans() {
        let m = Manifest::synthetic_resnet("r", 2);
        let spec = m.model("r").unwrap();
        assert_eq!(spec.classes, 20);
        // gap collapses to 1·1·64, single dense layer 64→20
        let w0 = spec.params.iter().find(|p| p.name == "w0").unwrap();
        assert_eq!(w0.shape, vec![64, 20]);
        let a = m.artifact("r_eval_q").unwrap();
        assert_eq!(a.attrs["conv_res"], "0,0,2,0,0,2,0,0,2");
        assert_eq!(a.attrs["conv_pool"], "0,0,max2,0,0,max2,0,0,gap");
        assert_eq!(a.attrs["conv_bn"], "1,1,1,1,1,1,1,1,1");
    }

    #[test]
    #[should_panic(expected = "residual skip shape mismatch")]
    fn residual_across_a_channel_change_is_rejected() {
        let l = |co: usize, res: usize| ConvLayer { co, stride: 1, bn: false, pool: "0", res };
        Manifest::synthetic_convnet("bad", (8, 8), 3, &[l(4, 0), l(8, 2)], &[5], 2);
    }

    #[test]
    fn merge_serves_both_models() {
        let m = Manifest::synthetic_mlp("m", &[6, 4, 3], 2)
            .merge(Manifest::synthetic_cnn("c", (8, 8), 3, &[(4, 2)], &[3], 2));
        assert!(m.model("m").is_ok() && m.model("c").is_ok());
        assert!(m.artifact("m_eval").is_ok() && m.artifact("c_eval").is_ok());
        assert!(m.artifact("assign_1024").is_ok());
        assert_eq!(m.kmax, Manifest::K_MAX);
    }

    #[test]
    fn attr_directive_round_trips() {
        let dir = write_tmp(
            "hash abc\n\
             artifact a file=a.hlo.txt\n\
             attr conv_strides 1,2\n\
             attr conv_pads same,valid\n\
             in x f32 2x4x4x3\n\
             out y f32 scalar\n\
             end\n",
        );
        let parsed = Manifest::load(&dir).unwrap();
        let a = parsed.artifact("a").unwrap();
        assert_eq!(a.attrs["conv_strides"], "1,2");
        assert_eq!(a.attrs["conv_pads"], "same,valid");
        assert_eq!(a.inputs[0].shape, vec![2, 4, 4, 3]);
        // a malformed attr line (value dropped) is a contextual parse
        // error carrying the line, not an index panic
        let dir = write_tmp("hash x\nartifact a file=a.hlo.txt\nattr conv_strides\nend\n");
        let err = Manifest::load(&dir).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("attr needs"), "{msg}");
        assert!(msg.contains("line 3"), "{msg}");
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mlp_gsc"));
            assert!(!m.buckets.is_empty());
            assert_eq!(m.kmax, 32);
        }
    }
}
