//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them —
//! concurrently — from the coordinator's hot path.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables live in a sharded reader-writer cache keyed by
//! artifact name, so concurrent `execute` calls from sweep workers take
//! uncontended read locks while a cold artifact compiles under a single
//! shard's write lock. The engine checks every call against the manifest
//! signature (shape + dtype), so binding bugs fail loudly at the boundary
//! instead of inside XLA. [`Engine`] is `Send + Sync` by construction
//! (asserted at compile time) — share one engine by reference across the
//! whole campaign worker pool.

pub mod manifest;

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::tensor::{Tensor, TensorI32, Value};
pub use manifest::{ArtifactSpec, DType, Init, Manifest, ModelSpec, ParamSpec, TensorSpec};

/// Shard count of the executable cache. Power of two, comfortably above
/// the artifact count of one model family so name collisions are rare.
const CACHE_SHARDS: usize = 16;

/// Smoke check that the PJRT CPU client can be constructed.
pub fn smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(format!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    ))
}

/// True when the vendored offline `xla` stand-in is active (no PJRT device
/// execution available). Tests and CLIs use this to skip execution paths
/// cleanly instead of failing on every artifact call.
///
/// NB: this is the one place referencing the stub-only `IS_STUB` const.
/// When swapping in the real PJRT bindings, add a one-line
/// `pub const IS_STUB: bool = false;` shim to them (or hardcode `false`
/// here) — see the dependency notes in `rust/Cargo.toml`.
pub fn backend_is_stub() -> bool {
    xla::IS_STUB
}

fn literal_from_value(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
    let lit = match v {
        Value::F32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
        Value::I32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
    };
    Ok(lit)
}

fn value_from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
    Ok(match spec.dtype {
        DType::F32 => {
            let data = lit.to_vec::<f32>()?;
            Value::F32(Tensor::new(spec.shape.clone(), data))
        }
        DType::I32 => {
            let data = lit.to_vec::<i32>()?;
            Value::I32(TensorI32::new(spec.shape.clone(), data))
        }
    })
}

/// Sharded executable cache: readers (the execute hot path) only contend
/// within one shard, and only while a cold artifact on that shard compiles.
struct ShardedCache {
    shards: Vec<RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>>,
}

impl ShardedCache {
    fn new() -> Self {
        ShardedCache {
            shards: (0..CACHE_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, name: &str) -> &RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        name.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }
}

/// The PJRT execution engine: one CPU client + a sharded compiled-executable
/// cache. Safe to share by reference across threads; see the module docs.
pub struct Engine {
    /// artifact/model signatures parsed from `manifest.txt`
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: ShardedCache,
    /// wall-clock spent compiling (for §Perf accounting)
    compile_s: Mutex<f64>,
}

// Compile-time proof that the engine can be shared across sweep workers;
// a non-Sync field added to Engine fails to build right here.
#[allow(dead_code)]
fn _assert_engine_send_sync() {
    fn assert<T: Send + Sync>() {}
    assert::<Engine>();
}

impl Engine {
    /// Load the manifest from `dir` and construct the CPU client.
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            manifest,
            client,
            cache: ShardedCache::new(),
            compile_s: Mutex::new(0.0),
        })
    }

    /// Total wall-clock seconds spent compiling artifacts so far.
    pub fn compile_seconds(&self) -> f64 {
        *self.compile_s.lock().unwrap()
    }

    /// Number of distinct artifacts compiled into the cache so far.
    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }

    /// Get (compile-on-demand) the executable for an artifact.
    ///
    /// The compile runs under the owning shard's write lock, so a cold
    /// artifact is compiled exactly once even when many workers race for
    /// it; cached artifacts on other shards stay readable throughout.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let shard = self.cache.shard(name);
        if let Some(exe) = shard.read().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let mut cache = shard.write().unwrap();
        // a racing worker may have compiled while we waited for the lock
        if let Some(exe) = cache.get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        *self.compile_s.lock().unwrap() += t0.elapsed().as_secs_f64();
        cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (amortizes compile time up front).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Validate `vals` against the artifact input signature.
    fn check_inputs(&self, spec: &ArtifactSpec, vals: &[Value]) -> Result<()> {
        if vals.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                vals.len()
            );
        }
        for (v, s) in vals.iter().zip(spec.inputs.iter()) {
            if v.shape() != s.shape.as_slice() {
                bail!(
                    "artifact {} input {}: shape {:?} != spec {:?}",
                    spec.name,
                    s.name,
                    v.shape(),
                    s.shape
                );
            }
            let dt_ok = matches!(
                (v, s.dtype),
                (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32)
            );
            if !dt_ok {
                bail!("artifact {} input {}: dtype mismatch", spec.name, s.name);
            }
        }
        Ok(())
    }

    /// Execute one artifact: inputs in manifest order, outputs in manifest
    /// order. (Artifacts are lowered with return_tuple=True, so the single
    /// device output is a tuple literal that we decompose.)
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.check_inputs(&spec, inputs)?;
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(literal_from_value)
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(spec.outputs.iter())
            .map(|(l, s)| value_from_literal(l, s))
            .collect()
    }

    /// Map outputs by name for convenient lookup.
    pub fn call_named(&self, name: &str, inputs: &[Value]) -> Result<HashMap<String, Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        let outs = self.call(name, inputs)?;
        Ok(spec
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }

    /// Execute one artifact over many independent input sets, fanning the
    /// calls across `jobs` worker threads (the batched-evaluation entry
    /// point). The executable is compiled once up front so workers hit the
    /// cache's read path only; outputs come back in input order.
    pub fn call_batch(
        &self,
        name: &str,
        inputs: &[Vec<Value>],
        jobs: usize,
    ) -> Result<Vec<Vec<Value>>> {
        self.executable(name)?;
        crate::util::par_map(inputs, jobs, |inp| self.call(name, inp))
            .into_iter()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_client() {
        let s = smoke().unwrap();
        assert!(s.contains("cpu"));
    }

    /// Manifest + dummy HLO-text artifact in a unique temp dir.
    fn stub_artifacts(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ecqx-runtime-test-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "hash test\n\
             kmax 32\n\
             buckets 1024\n\
             model m batch=2 classes=2 input=4\n\
             param w f32 4x2 init=he_in quant=1\n\
             artifact a file=a.hlo.txt\n\
             in x f32 2x4\n\
             out y f32 2x2\n\
             end\n",
        )
        .unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule a\nENTRY a {}\n").unwrap();
        dir
    }

    #[test]
    fn engine_compiles_once_under_concurrency() {
        if !backend_is_stub() {
            // garbage HLO text would not compile on a real PJRT backend
            return;
        }
        let dir = stub_artifacts("conc");
        let eng = Engine::new(&dir).unwrap();
        let eng_ref = &eng;
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(move || eng_ref.warmup(&["a"]).unwrap());
            }
        });
        assert_eq!(eng.cached_executables(), 1);
        assert!(eng.compile_seconds() >= 0.0);
    }

    #[test]
    fn call_batch_compiles_once_and_reports_stub() {
        if !backend_is_stub() {
            return;
        }
        let dir = stub_artifacts("batch");
        let eng = Engine::new(&dir).unwrap();
        let inp = vec![Value::F32(Tensor::zeros(&[2, 4]))];
        let r = eng.call_batch("a", &[inp.clone(), inp], 2);
        assert_eq!(eng.cached_executables(), 1, "compiled once up front");
        assert!(format!("{:?}", r.unwrap_err()).contains("offline xla stub"));
    }

    #[test]
    fn engine_checks_inputs_and_fails_loudly_offline() {
        if !backend_is_stub() {
            return;
        }
        let dir = stub_artifacts("check");
        let eng = Engine::new(&dir).unwrap();
        // wrong shape is rejected before any execution attempt
        let bad = eng.call("a", &[Value::F32(Tensor::zeros(&[3, 4]))]);
        assert!(format!("{:?}", bad.unwrap_err()).contains("shape"));
        // correct shape reaches the stub backend, which reports loudly
        let good = eng.call("a", &[Value::F32(Tensor::zeros(&[2, 4]))]);
        assert!(format!("{:?}", good.unwrap_err()).contains("offline xla stub"));
    }
}
