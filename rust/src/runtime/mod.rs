//! Execution runtime: one [`Engine`] facade over pluggable backends.
//!
//! Two [`Backend`] implementations execute the manifest's artifact
//! surface:
//!
//! * [`pjrt::PjrtBackend`] — loads AOT-compiled HLO-text artifacts from
//!   `artifacts/` and executes them through the `xla` crate (PJRT C API),
//!   with a sharded reader-writer executable cache so concurrent sweep
//!   workers take uncontended read locks while cold artifacts compile
//!   under a single shard's write lock.
//! * [`host::HostBackend`] — a pure-rust reference backend executing the
//!   dense-model kernel set (`qdense`, `qdense_gather`, `lrp_dense_rw`,
//!   the ECQ^x assignment, …) and the conv-ladder kernel set (`conv2d`
//!   and its backward/LRP/gather forms, lowered over im2col —
//!   `runtime::host_cnn`) directly on [`Value`]s, mirroring
//!   `python/compile/kernels/ref.py` and `model.py`; it needs neither an
//!   `artifacts/` directory nor real PJRT bindings, which is what turns
//!   the end-to-end suite into an always-on tier-1 gate.
//!
//! The engine owns the manifest and checks every call against the
//! artifact signature (shape + dtype), so binding bugs fail loudly at the
//! boundary instead of inside a backend. [`Engine`] is `Send + Sync` by
//! construction (asserted at compile time) — share one engine by
//! reference across the whole campaign worker pool.

pub mod host;
pub mod host_cnn;
pub mod manifest;
pub mod pjrt;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::linalg::with_thread_workspace;
use crate::tensor::Value;
pub use crate::linalg::Workspace;
pub use host::HostBackend;
pub use manifest::{ArtifactSpec, ConvLayer, DType, Init, Manifest, ModelSpec, ParamSpec, TensorSpec};
pub use pjrt::{smoke, PjrtBackend};

/// True when the vendored offline `xla` stand-in is active (no PJRT device
/// execution available). The CLI and `exp::engine` use this to fall back
/// to the host backend instead of failing on every artifact call.
///
/// NB: this is the one place referencing the stub-only `IS_STUB` const.
/// When swapping in the real PJRT bindings, add a one-line
/// `pub const IS_STUB: bool = false;` shim to them (or hardcode `false`
/// here) — see the dependency notes in `rust/Cargo.toml`.
pub fn backend_is_stub() -> bool {
    xla::IS_STUB
}

/// Execution bookkeeping a backend reports (all zero for the host
/// backend, which has nothing to compile).
#[derive(Clone, Copy, Debug, Default)]
pub struct BackendStats {
    /// wall-clock seconds spent compiling artifacts so far
    pub compile_s: f64,
    /// number of distinct artifacts compiled into the cache so far
    pub cached_executables: usize,
}

/// An artifact executor. Implementations must be `Send + Sync`: the
/// campaign worker pool calls [`Backend::execute`] concurrently through a
/// shared [`Engine`].
pub trait Backend: Send + Sync {
    /// Short backend identifier (`"pjrt"` / `"host"`).
    fn name(&self) -> &'static str;

    /// Make an artifact ready to execute (compile for PJRT; validate the
    /// signature is host-executable for the host backend). Amortizes the
    /// cold-start cost up front; [`Backend::execute`] must also succeed
    /// without a prior `prepare`.
    fn prepare(&self, spec: &ArtifactSpec) -> Result<()>;

    /// Execute one artifact: inputs in manifest order (already validated
    /// against the signature by the engine), outputs in manifest order.
    ///
    /// `scratch` is the caller's reusable [`Workspace`] (one per worker
    /// thread — the engine hands each thread its own, so steady-state
    /// execution packs GEMM panels without heap allocation). Backends
    /// with no host-side math (PJRT) simply ignore it; results must never
    /// depend on its prior contents.
    fn execute(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Value],
        scratch: &mut Workspace,
    ) -> Result<Vec<Value>>;

    /// Compile-time bookkeeping (for §Perf accounting).
    fn stats(&self) -> BackendStats {
        BackendStats::default()
    }
}

/// The execution engine: manifest signatures + a pluggable [`Backend`].
/// Safe to share by reference across threads; see the module docs.
pub struct Engine {
    /// artifact/model signatures (parsed from `manifest.txt` or
    /// synthesized for the host backend)
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
}

// Compile-time proof that the engine can be shared across sweep workers;
// a non-Sync backend handed to Engine fails to build right here.
#[allow(dead_code)]
fn _assert_engine_send_sync() {
    fn assert<T: Send + Sync>() {}
    assert::<Engine>();
}

impl Engine {
    /// PJRT engine: load the manifest from `dir` and construct the CPU
    /// client (the artifact-backed production path).
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Ok(Engine { manifest, backend: Box::new(PjrtBackend::new()?) })
    }

    /// Host engine over the default synthesized manifest (the paper's
    /// MLP_GSC ladder, the CIFAR-shaped `cnn_cifar` conv workload and the
    /// shared assign buckets) — no `artifacts/`, no PJRT.
    pub fn host() -> Engine {
        Engine::host_with(host::default_manifest())
    }

    /// Host engine over a caller-provided manifest (tests use this with
    /// small [`Manifest::synthetic_mlp`] / [`Manifest::synthetic_cnn`]
    /// models).
    pub fn host_with(manifest: Manifest) -> Engine {
        Engine { manifest, backend: Box::new(HostBackend::new()) }
    }

    /// Engine over an explicit backend (escape hatch for new backends).
    pub fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Engine {
        Engine { manifest, backend }
    }

    /// Short identifier of the active backend (`"pjrt"` / `"host"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Total wall-clock seconds spent compiling artifacts so far.
    pub fn compile_seconds(&self) -> f64 {
        self.backend.stats().compile_s
    }

    /// Number of distinct artifacts compiled into the cache so far.
    pub fn cached_executables(&self) -> usize {
        self.backend.stats().cached_executables
    }

    /// Pre-prepare a set of artifacts (amortizes compile time up front).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.backend.prepare(self.manifest.artifact(n)?)?;
        }
        Ok(())
    }

    /// Validate `vals` against the artifact input signature.
    fn check_inputs(&self, spec: &ArtifactSpec, vals: &[Value]) -> Result<()> {
        if vals.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                vals.len()
            );
        }
        for (v, s) in vals.iter().zip(spec.inputs.iter()) {
            if v.shape() != s.shape.as_slice() {
                bail!(
                    "artifact {} input {}: shape {:?} != spec {:?}",
                    spec.name,
                    s.name,
                    v.shape(),
                    s.shape
                );
            }
            let dt_ok = matches!(
                (v, s.dtype),
                (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32)
            );
            if !dt_ok {
                bail!("artifact {} input {}: dtype mismatch", spec.name, s.name);
            }
        }
        Ok(())
    }

    /// Execute one artifact: inputs in manifest order, outputs in manifest
    /// order. Uses this thread's shared [`Workspace`] — every worker
    /// thread (e.g. of [`Engine::call_batch`]) reuses its own packing
    /// scratch across calls with no API change at the call site.
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        with_thread_workspace(|ws| self.call_with(name, inputs, ws))
    }

    /// [`Engine::call`] with an explicit caller-held [`Workspace`] —
    /// long-running loops (the QAT trainer, validation passes) hold one
    /// and skip even the thread-local lookup. Results are identical to
    /// [`Engine::call`]: workspace state never influences outputs.
    pub fn call_with(
        &self,
        name: &str,
        inputs: &[Value],
        scratch: &mut Workspace,
    ) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?;
        self.check_inputs(spec, inputs)?;
        let outs = self.backend.execute(spec, inputs, scratch)?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                name,
                spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Map outputs by name for convenient lookup.
    pub fn call_named(&self, name: &str, inputs: &[Value]) -> Result<HashMap<String, Value>> {
        with_thread_workspace(|ws| self.call_named_with(name, inputs, ws))
    }

    /// [`Engine::call_named`] with an explicit caller-held [`Workspace`].
    pub fn call_named_with(
        &self,
        name: &str,
        inputs: &[Value],
        scratch: &mut Workspace,
    ) -> Result<HashMap<String, Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        let outs = self.call_with(name, inputs, scratch)?;
        Ok(spec
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }

    /// Execute one artifact over many independent input sets, fanning the
    /// calls across `jobs` [`crate::util::pool`] worker threads (the
    /// batched-evaluation entry point). The artifact is prepared once up
    /// front — PJRT workers then hit the cache's read path only, host
    /// workers run the validated pure kernels — and outputs come back in
    /// input order on either backend. Each worker thread executes through
    /// its own thread-local [`Workspace`], so fanning out does not share
    /// (or allocate per-call) GEMM packing scratch, and results stay
    /// independent of the jobs count.
    pub fn call_batch(
        &self,
        name: &str,
        inputs: &[Vec<Value>],
        jobs: usize,
    ) -> Result<Vec<Vec<Value>>> {
        self.backend.prepare(self.manifest.artifact(name)?)?;
        crate::util::par_map(inputs, jobs, |inp| self.call(name, inp))
            .into_iter()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    /// Manifest + dummy HLO-text artifact in a unique temp dir.
    fn stub_artifacts(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ecqx-runtime-test-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "hash test\n\
             kmax 32\n\
             buckets 1024\n\
             model m batch=2 classes=2 input=4\n\
             param w f32 4x2 init=he_in quant=1\n\
             artifact a file=a.hlo.txt\n\
             in x f32 2x4\n\
             out y f32 2x2\n\
             end\n",
        )
        .unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule a\nENTRY a {}\n").unwrap();
        dir
    }

    #[test]
    fn engine_compiles_once_under_concurrency() {
        if !backend_is_stub() {
            // garbage HLO text would not compile on a real PJRT backend
            return;
        }
        let dir = stub_artifacts("conc");
        let eng = Engine::new(&dir).unwrap();
        assert_eq!(eng.backend_name(), "pjrt");
        let eng_ref = &eng;
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(move || eng_ref.warmup(&["a"]).unwrap());
            }
        });
        assert_eq!(eng.cached_executables(), 1);
        assert!(eng.compile_seconds() >= 0.0);
    }

    #[test]
    fn call_batch_compiles_once_and_reports_stub() {
        if !backend_is_stub() {
            return;
        }
        let dir = stub_artifacts("batch");
        let eng = Engine::new(&dir).unwrap();
        let inp = vec![Value::F32(Tensor::zeros(&[2, 4]))];
        let r = eng.call_batch("a", &[inp.clone(), inp], 2);
        assert_eq!(eng.cached_executables(), 1, "compiled once up front");
        assert!(format!("{:?}", r.unwrap_err()).contains("offline xla stub"));
    }

    #[test]
    fn engine_checks_inputs_and_fails_loudly_offline() {
        if !backend_is_stub() {
            return;
        }
        let dir = stub_artifacts("check");
        let eng = Engine::new(&dir).unwrap();
        // wrong shape is rejected before any execution attempt
        let bad = eng.call("a", &[Value::F32(Tensor::zeros(&[3, 4]))]);
        assert!(format!("{:?}", bad.unwrap_err()).contains("shape"));
        // correct shape reaches the stub backend, which reports loudly
        let good = eng.call("a", &[Value::F32(Tensor::zeros(&[2, 4]))]);
        assert!(format!("{:?}", good.unwrap_err()).contains("offline xla stub"));
    }

    #[test]
    fn host_engine_runs_without_artifacts() {
        let eng = Engine::host_with(Manifest::synthetic_mlp("t", &[6, 5, 3], 2));
        assert_eq!(eng.backend_name(), "host");
        assert_eq!(eng.cached_executables(), 0, "nothing to compile");
        eng.warmup(&["t_eval", "t_lrp", "assign_1024"]).unwrap();
        let state = crate::nn::ModelState::init(eng.manifest.model("t").unwrap(), 3);
        let mut inputs: Vec<Value> = state
            .spec
            .params
            .iter()
            .map(|p| Value::F32(state.params[&p.name].clone()))
            .collect();
        inputs.push(Value::F32(Tensor::ones(&[2, 6])));
        inputs.push(Value::I32(crate::tensor::TensorI32::new(vec![2], vec![0, 2])));
        let outs = eng.call_named("t_eval", &inputs).unwrap();
        assert!(outs["loss"].as_f32().as_scalar() > 0.0);
        let c = outs["correct"].as_f32().as_scalar();
        assert!((0.0..=2.0).contains(&c));
    }

    #[test]
    fn host_engine_rejects_unknown_and_bad_shapes() {
        let eng = Engine::host_with(Manifest::synthetic_mlp("t", &[6, 3], 2));
        assert!(eng.call("nope", &[]).is_err());
        // wrong input count fails at the signature check
        let r = eng.call("t_eval", &[]);
        assert!(format!("{:?}", r.unwrap_err()).contains("expected"));
    }

    #[test]
    fn host_call_batch_is_order_preserving() {
        let eng = Engine::host_with(Manifest::synthetic_mlp("t", &[4, 3], 2));
        let state = crate::nn::ModelState::init(eng.manifest.model("t").unwrap(), 9);
        let mk = |scale: f32| -> Vec<Value> {
            let mut v: Vec<Value> = state
                .spec
                .params
                .iter()
                .map(|p| Value::F32(state.params[&p.name].clone()))
                .collect();
            v.push(Value::F32(Tensor::full(&[2, 4], scale)));
            v.push(Value::I32(crate::tensor::TensorI32::new(vec![2], vec![0, 1])));
            v
        };
        let sets: Vec<Vec<Value>> = (0..6).map(|i| mk(i as f32 * 0.3)).collect();
        let serial = eng.call_batch("t_eval", &sets, 1).unwrap();
        let par = eng.call_batch("t_eval", &sets, 4).unwrap();
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a[0].as_f32().as_scalar(), b[0].as_f32().as_scalar());
            assert_eq!(a[1].as_f32().as_scalar(), b[1].as_f32().as_scalar());
        }
    }
}
