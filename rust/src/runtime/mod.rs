//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the coordinator's hot path.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per artifact name; the engine checks
//! every call against the manifest signature (shape + dtype), so binding
//! bugs fail loudly at the boundary instead of inside XLA.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::tensor::{Tensor, TensorI32, Value};
pub use manifest::{ArtifactSpec, DType, Init, Manifest, ModelSpec, ParamSpec, TensorSpec};

/// Smoke check that the PJRT CPU client can be constructed.
pub fn smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(format!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    ))
}

fn literal_from_value(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
    let lit = match v {
        Value::F32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
        Value::I32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
    };
    Ok(lit)
}

fn value_from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
    Ok(match spec.dtype {
        DType::F32 => {
            let data = lit.to_vec::<f32>()?;
            Value::F32(Tensor::new(spec.shape.clone(), data))
        }
        DType::I32 => {
            let data = lit.to_vec::<i32>()?;
            Value::I32(TensorI32::new(spec.shape.clone(), data))
        }
    })
}

/// The PJRT execution engine: one CPU client + a compiled-executable cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// wall-clock spent compiling (for §Perf accounting)
    compile_s: Mutex<f64>,
}

impl Engine {
    /// Load the manifest from `dir` and construct the CPU client.
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            compile_s: Mutex::new(0.0),
        })
    }

    pub fn compile_seconds(&self) -> f64 {
        *self.compile_s.lock().unwrap()
    }

    /// Get (compile-on-demand) the executable for an artifact.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        *self.compile_s.lock().unwrap() += t0.elapsed().as_secs_f64();
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (amortizes compile time up front).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Validate `vals` against the artifact input signature.
    fn check_inputs(&self, spec: &ArtifactSpec, vals: &[Value]) -> Result<()> {
        if vals.len() != spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                vals.len()
            );
        }
        for (v, s) in vals.iter().zip(spec.inputs.iter()) {
            if v.shape() != s.shape.as_slice() {
                bail!(
                    "artifact {} input {}: shape {:?} != spec {:?}",
                    spec.name,
                    s.name,
                    v.shape(),
                    s.shape
                );
            }
            let dt_ok = matches!(
                (v, s.dtype),
                (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32)
            );
            if !dt_ok {
                bail!("artifact {} input {}: dtype mismatch", spec.name, s.name);
            }
        }
        Ok(())
    }

    /// Execute one artifact: inputs in manifest order, outputs in manifest
    /// order. (Artifacts are lowered with return_tuple=True, so the single
    /// device output is a tuple literal that we decompose.)
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.check_inputs(&spec, inputs)?;
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(literal_from_value)
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                name,
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(spec.outputs.iter())
            .map(|(l, s)| value_from_literal(l, s))
            .collect()
    }

    /// Map outputs by name for convenient lookup.
    pub fn call_named(&self, name: &str, inputs: &[Value]) -> Result<HashMap<String, Value>> {
        let spec = self.manifest.artifact(name)?.clone();
        let outs = self.call(name, inputs)?;
        Ok(spec
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_client() {
        let s = smoke().unwrap();
        assert!(s.contains("cpu"));
    }
}
